//! Bench: multi-tenant fleet placement — all registered apps co-scheduled
//! onto a sweep of board-pool sizes.
//!
//! Reports, per pool size: how many tenants placed / queued / rejected /
//! stayed on the CPU, per-board utilization, the fleet's aggregate
//! speedup vs all-CPU, the reconfiguration hours the packing charged,
//! and the real wall-clock of the whole flow (search + pack) cold vs
//! warm (the placement artifact and every stage under it are cached).
//!
//! ```sh
//! cargo bench --bench fleet_throughput                  # full paper scale
//! cargo bench --bench fleet_throughput -- --test-scale \
//!     --report reports/fleet_throughput.json            # CI smoke + JSON
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use flopt::apps;
use flopt::config::SearchConfig;
use flopt::cpu::XEON_3104;
use flopt::fleet::{self, FleetStatus};
use flopt::funcblock::BlockMode;
use flopt::service::BatchService;
use flopt::util::bench::{fmt_s, parse_bench_args};
use flopt::util::json::{self, Json};

fn main() {
    let opts = parse_bench_args();
    let cfg = SearchConfig { block_mode: BlockMode::On, ..SearchConfig::default() };
    let apps_list: Vec<&'static apps::App> = apps::all();
    let board_sweep = [1usize, 2, 4, 8];

    println!("=== fleet placement: {} apps x boards sweep ===", apps_list.len());
    println!(
        "{:<7} {:>7} {:>7} {:>9} {:>5} {:>10} {:>11} {:>10} {:>10}",
        "boards", "placed", "queued", "rejected", "cpu", "aggregate", "reconfig-h", "cold", "warm"
    );

    let mut rows = Vec::new();
    // flat, deterministic numbers for `flopt bench-compare`
    let mut metrics = BTreeMap::new();
    for &boards in &board_sweep {
        // one service per pool size: the first run is cold, the second
        // warm through the fleet-report cache
        let svc = BatchService::new(4, 1, &XEON_3104);
        let t0 = Instant::now();
        let cold = fleet::fleet_search(&svc, &apps_list, boards, &cfg, opts.test_scale)
            .expect("cold fleet");
        let cold_wall_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let warm = fleet::fleet_search(&svc, &apps_list, boards, &cfg, opts.test_scale)
            .expect("warm fleet");
        let warm_wall_s = t1.elapsed().as_secs_f64();
        assert_eq!(warm.render(), cold.render(), "warm fleet must be bit-identical");

        let count = |status: fn(&FleetStatus) -> bool| -> usize {
            cold.apps.iter().filter(|a| status(&a.status)).count()
        };
        let placed = count(|s| matches!(s, FleetStatus::Placed { .. }));
        let queued = count(|s| matches!(s, FleetStatus::Queued));
        let rejected = count(|s| matches!(s, FleetStatus::Rejected));
        let cpu = count(|s| matches!(s, FleetStatus::Cpu));
        println!(
            "{:<7} {:>7} {:>7} {:>9} {:>5} {:>9.2}x {:>11.2} {:>10} {:>10}",
            boards,
            placed,
            queued,
            rejected,
            cpu,
            cold.aggregate_speedup,
            cold.reconfig_hours,
            fmt_s(cold_wall_s),
            fmt_s(warm_wall_s)
        );

        let mut row = BTreeMap::new();
        row.insert("boards".to_string(), Json::Num(boards as f64));
        row.insert("placed".to_string(), Json::Num(placed as f64));
        row.insert("queued".to_string(), Json::Num(queued as f64));
        row.insert("rejected".to_string(), Json::Num(rejected as f64));
        row.insert("cpu".to_string(), Json::Num(cpu as f64));
        row.insert(
            "aggregate_speedup".to_string(),
            Json::Num(cold.aggregate_speedup),
        );
        row.insert("reconfig_hours".to_string(), Json::Num(cold.reconfig_hours));
        row.insert("sim_hours".to_string(), Json::Num(cold.sim_hours));
        row.insert("cold_wall_s".to_string(), Json::Num(cold_wall_s));
        row.insert("warm_wall_s".to_string(), Json::Num(warm_wall_s));
        let boards_json: Vec<Json> = cold
            .board_util
            .iter()
            .map(|b| {
                let mut bj = BTreeMap::new();
                bj.insert("board".to_string(), Json::Num(b.board as f64));
                bj.insert("utilization".to_string(), Json::Num(b.utilization));
                bj.insert(
                    "tenants".to_string(),
                    Json::Arr(b.tenants.iter().map(|t| Json::Str(t.clone())).collect()),
                );
                Json::Obj(bj)
            })
            .collect();
        row.insert("board_util".to_string(), Json::Arr(boards_json));
        rows.push(Json::Obj(row));
        metrics.insert(
            format!("aggregate_speedup_b{boards}"),
            Json::Num(cold.aggregate_speedup),
        );
        metrics.insert(format!("placed_b{boards}"), Json::Num(placed as f64));
        metrics.insert(
            format!("reconfig_hours_b{boards}"),
            Json::Num(cold.reconfig_hours),
        );
    }

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("fleet_throughput".to_string()));
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("apps".to_string(), Json::Num(apps_list.len() as f64));
        doc.insert("rows".to_string(), Json::Arr(rows));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("report written to {path}");
    }
}
