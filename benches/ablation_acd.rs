//! Ablation A/B: sweep the paper's narrowing parameters —
//! `a` (intensity top-k), `c` (resource-efficiency top-k), `d` (pattern
//! budget) — and report solution quality vs. simulated compile-hours.
//! This is the paper's core trade-off: measured patterns are 3-hour
//! compiles, so every extra candidate costs real wall-clock.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

fn main() {
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let analysis = analyze_app(app, false).expect("analysis");
        println!("=== {} ===", app.name);

        println!("--- sweep a (intensity top-k), c=3, d=4 ---");
        println!("{:>3} {:>10} {:>10} {:>14}", "a", "speedup", "patterns", "compile-h");
        for a in 1..=8 {
            let cfg = SearchConfig { a_intensity: a, ..Default::default() };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
            let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
            println!(
                "{:>3} {:>9.2}x {:>10} {:>14.1}",
                a,
                t.speedup(),
                t.patterns_measured(),
                t.compile_hours
            );
        }

        println!("--- sweep c (efficiency top-k), a=5, d=4 ---");
        println!("{:>3} {:>10} {:>10} {:>14}", "c", "speedup", "patterns", "compile-h");
        for c in 1..=5 {
            let cfg = SearchConfig { c_efficiency: c, ..Default::default() };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
            let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
            println!(
                "{:>3} {:>9.2}x {:>10} {:>14.1}",
                c,
                t.speedup(),
                t.patterns_measured(),
                t.compile_hours
            );
        }

        println!("--- sweep d (pattern budget), a=5, c=3 ---");
        println!("{:>3} {:>10} {:>10} {:>14}", "d", "speedup", "patterns", "compile-h");
        for d in 1..=8 {
            let cfg = SearchConfig { d_patterns: d, ..Default::default() };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
            let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
            println!(
                "{:>3} {:>9.2}x {:>10} {:>14.1}",
                d,
                t.speedup(),
                t.patterns_measured(),
                t.compile_hours
            );
        }
        println!();
    }
}
