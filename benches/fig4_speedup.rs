//! Bench: regenerate **Fig 4** — performance improvement of the proposed
//! FPGA auto-offload over all-CPU, for both paper applications at full
//! paper scale.  Also times the L3 search itself (wall clock).

use flopt::apps;
use flopt::config::{fig3_table, SearchConfig};
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;
use flopt::util::bench::{fmt_s, time_it};

fn main() {
    println!("=== Fig 3: evaluation environment (models calibrated to) ===");
    println!("{}", fig3_table());

    println!("=== Fig 4: performance improvement of the proposed method ===");
    println!(
        "{:<46} {:>8} {:>10}",
        "Application", "paper", "this repo"
    );
    let mut rows = Vec::new();
    for (app, paper, label) in [
        (&apps::TDFIR, 4.0, "Time domain finite impulse response filter"),
        (&apps::MRIQ, 7.1, "MRI-Q"),
    ] {
        let run = || {
            let env = VerifyEnv::new(&ARRIA10_GX, &XEON_3104, SearchConfig::default());
            offload_search(app, &env, false).expect("search")
        };
        let trace = run();
        println!("{:<46} {:>7.1}x {:>9.2}x", label, paper, trace.speedup());
        rows.push((app, label, run));
    }

    println!("\n=== search wall-clock (L3 hot path, full scale) ===");
    for (_, label, run) in rows {
        let t = time_it(3, run);
        println!("{:<46} median {}", label, fmt_s(t.median_s));
    }
}
