//! Bench: regenerate **Fig 4** — performance improvement of the proposed
//! FPGA auto-offload over all-CPU, for both paper applications at full
//! paper scale.  Also times the L3 search itself (wall clock).
//!
//! ```sh
//! cargo bench --bench fig4_speedup                      # full paper scale
//! cargo bench --bench fig4_speedup -- --test-scale \
//!     --report reports/fig4_speedup.json                # CI smoke + JSON
//! ```

use std::collections::BTreeMap;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::{fig3_table, SearchConfig};
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::util::bench::{fmt_s, parse_bench_args, time_it};
use flopt::util::json::{self, Json};

fn main() {
    let opts = parse_bench_args();
    println!("=== Fig 3: evaluation environment (models calibrated to) ===");
    println!("{}", fig3_table());

    println!("=== Fig 4: performance improvement of the proposed method ===");
    println!("{:<46} {:>8} {:>10}", "Application", "paper", "this repo");
    let mut report_rows = Vec::new();
    let mut timing_rows = Vec::new();
    // flat, deterministic (model-derived) numbers for `flopt bench-compare`
    let mut metrics = BTreeMap::new();
    let mut patterns_total = 0usize;
    for (app, paper, label) in [
        (&apps::TDFIR, 4.0, "Time domain finite impulse response filter"),
        (&apps::MRIQ, 7.1, "MRI-Q"),
    ] {
        let test_scale = opts.test_scale;
        let run = move || {
            let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
            offload_search(app, &env, test_scale).expect("search")
        };
        let trace = run();
        println!("{:<46} {:>7.1}x {:>9.2}x", label, paper, trace.speedup());
        let mut row = BTreeMap::new();
        row.insert("app".to_string(), Json::Str(app.name.to_string()));
        row.insert("label".to_string(), Json::Str(label.to_string()));
        row.insert(
            "destination".to_string(),
            Json::Str(trace.destination.to_string()),
        );
        row.insert("paper_speedup".to_string(), Json::Num(paper));
        row.insert("speedup".to_string(), Json::Num(trace.speedup()));
        row.insert(
            "patterns_measured".to_string(),
            Json::Num(trace.patterns_measured() as f64),
        );
        row.insert("sim_hours".to_string(), Json::Num(trace.sim_hours));
        row.insert("compile_hours".to_string(), Json::Num(trace.compile_hours));
        report_rows.push(Json::Obj(row));
        timing_rows.push((label, run));
        metrics.insert(
            format!("speedup_{}", app.name),
            Json::Num(trace.speedup()),
        );
        metrics.insert(
            format!("compile_hours_{}", app.name),
            Json::Num(trace.compile_hours),
        );
        patterns_total += trace.patterns_measured();
    }
    metrics.insert(
        "patterns_measured_total".to_string(),
        Json::Num(patterns_total as f64),
    );

    println!("\n=== search wall-clock (L3 hot path) ===");
    for (label, run) in timing_rows {
        let t = time_it(3, run);
        println!("{:<46} median {}", label, fmt_s(t.median_s));
    }

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("fig4_speedup".to_string()));
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("rows".to_string(), Json::Arr(report_rows));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("\nreport written to {path}");
    }
}
