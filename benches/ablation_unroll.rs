//! Ablation C: the unroll factor `b`.  The paper pins b=1 ("to isolate
//! the plain OpenCL offload effect; unrolling and multi-instancing
//! usually help the more resources they use").  This sweep quantifies
//! that: datapath resources scale with b, fmax derates with pressure,
//! and past the device cap the compile fails early.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;
use flopt::hls;

fn main() {
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let analysis = analyze_app(app, false).expect("analysis");
        // the app's hot loop (outermost loop of the bound function)
        let hot = {
            let f = app.binding.as_ref().unwrap().function;
            analysis
                .loops
                .iter()
                .find(|l| l.info.function == f && l.info.depth == 0)
                .expect("hot loop")
        };

        println!("=== {} — hot loop {} vs unroll b ===", app.name, hot.info.id);
        println!(
            "{:>4} {:>10} {:>8} {:>10} {:>12} {:>10}",
            "b", "util", "DSPs", "fmax MHz", "fits", "speedup"
        );
        for b in [1usize, 2, 4, 8, 16, 32] {
            let rep = hls::precompile(&analysis.program, hot, b, &ARRIA10_GX);
            let fits = ARRIA10_GX.fits(&rep.resources);
            let cfg = SearchConfig { b_unroll: b, ..Default::default() };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
            let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
            println!(
                "{:>4} {:>10.3} {:>8.0} {:>10.0} {:>12} {:>9.2}x",
                b,
                rep.utilization,
                rep.resources.dsps,
                rep.fmax_hz / 1e6,
                fits,
                t.speedup()
            );
        }
        println!();
    }
}
