//! L3 hot-path microbenchmarks (the §Perf working set): parse, loop
//! analysis, dynamic profiling, intensity ranking, HLS pre-compile,
//! whole search, and PJRT artifact execution latency.
//!
//! Run before/after optimization work; EXPERIMENTS.md §Perf records the
//! iteration log.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;
use flopt::runtime::{default_artifact_dir, Runtime};
use flopt::util::bench::{fmt_s, time_it};
use flopt::{cparse, hls, intensity, interp, ir};

fn main() {
    let app = &apps::TDFIR;

    let t = time_it(20, || cparse::parse(app.source).unwrap());
    println!("parse tdfir (36 loops):            {:>12}", fmt_s(t.median_s));

    let program = cparse::parse(app.source).unwrap();
    let t = time_it(20, || ir::analyze(&program));
    println!("loop+dep analysis:                 {:>12}", fmt_s(t.median_s));

    let t = time_it(5, || {
        let mut it = app.interp(&program, true);
        it.run_main().unwrap();
        it.into_profile()
    });
    println!("profile (test scale):              {:>12}", fmt_s(t.median_s));

    let t = time_it(3, || {
        let mut it = app.interp(&program, false);
        it.run_main().unwrap();
        it.into_profile()
    });
    println!("profile (full scale, 4096x128):    {:>12}", fmt_s(t.median_s));

    let loops = ir::analyze(&program);
    let profile = {
        let mut it = app.interp(&program, false);
        it.run_main().unwrap();
        it.into_profile()
    };
    let ints = intensity::analyze(&loops, &profile);
    let t = time_it(100, || intensity::top_a(&ints, &loops, 5));
    println!("intensity ranking:                 {:>12}", fmt_s(t.median_s));

    let hot = loops.iter().find(|l| l.info.id.0 == 8).unwrap();
    let t = time_it(50, || hls::precompile(&program, hot, 1, &ARRIA10_GX));
    println!("HLS pre-compile (hot loop):        {:>12}", fmt_s(t.median_s));

    let analysis = analyze_app(app, false).unwrap();
    let cfg = SearchConfig::default();
    let t = time_it(10, || {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        search_with_analysis(app, &analysis, &env, &cfg).unwrap()
    });
    println!("search (post-analysis, full):      {:>12}", fmt_s(t.median_s));

    let t = time_it(3, || {
        let mut it = interp::Interp::new(&program);
        it.run_main().unwrap()
    });
    println!("interpreter end-to-end run:        {:>12}", fmt_s(t.median_s));

    // PJRT path (needs `make artifacts`)
    match Runtime::load(default_artifact_dir()) {
        Ok(rt) => {
            let spec = rt.spec("tdfir_fpga").unwrap().clone();
            let inputs: Vec<Vec<f32>> = spec
                .input_shapes
                .iter()
                .map(|s| vec![0.5f32; s.iter().product()])
                .collect();
            // first call compiles the HLO
            let t = time_it(1, || rt.execute_f32("tdfir_fpga", &inputs).unwrap());
            println!("PJRT first-call (incl. compile):   {:>12}", fmt_s(t.median_s));
            let t = time_it(20, || rt.execute_f32("tdfir_fpga", &inputs).unwrap());
            println!("PJRT steady-state execute:         {:>12}", fmt_s(t.median_s));
        }
        Err(_) => println!("PJRT benches skipped (run `make artifacts`)"),
    }
}
