//! L3 hot-path microbenchmarks (the §Perf working set): parse, loop
//! analysis, dynamic profiling, intensity ranking, HLS pre-compile,
//! whole search, and PJRT artifact execution latency.
//!
//! Run before/after optimization work; EXPERIMENTS.md §Perf records the
//! iteration log.  The report's `metrics` mix deterministic pipeline
//! counters (loop counts, interpreter steps, patterns measured — gated
//! by `flopt bench-compare` against `BENCH_hot_paths.json`) with
//! wall-clock medians (left unblessed in the committed baseline so CI
//! machine jitter never fails the gate).
//!
//! ```sh
//! cargo bench --bench hot_paths                         # full paper scale
//! cargo bench --bench hot_paths -- --test-scale \
//!     --report reports/hot_paths.json                   # CI smoke + JSON
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;
use flopt::metrics::SimClock;
use flopt::runtime::{default_artifact_dir, Runtime};
use flopt::util::bench::{fmt_s, parse_bench_args, time_it, Timing};
use flopt::util::json::{self, Json};
use flopt::{cparse, hls, intensity, ir};

fn main() {
    let opts = parse_bench_args();
    let app = &apps::TDFIR;
    let mut rows = Vec::new();
    // flat, deterministic (simulated-model) numbers for bench-compare,
    // plus wall-clock medians (unblessed in the committed baseline)
    let mut metrics = BTreeMap::new();

    let section = |name: &str, t: &Timing, rows: &mut Vec<Json>| {
        println!("{:<35}{:>12}", format!("{name}:"), fmt_s(t.median_s));
        let mut row = BTreeMap::new();
        row.insert("section".to_string(), Json::Str(name.to_string()));
        row.insert("median_s".to_string(), Json::Num(t.median_s));
        row.insert("min_s".to_string(), Json::Num(t.min_s));
        row.insert("max_s".to_string(), Json::Num(t.max_s));
        row.insert("iters".to_string(), Json::Num(t.iters as f64));
        rows.push(Json::Obj(row));
        t.median_s
    };

    let t = time_it(20, || cparse::parse(app.source).unwrap());
    let w = section("parse tdfir (36 loops)", &t, &mut rows);
    metrics.insert("wall_parse_s".to_string(), Json::Num(w));

    let program = cparse::parse(app.source).unwrap();
    metrics.insert(
        "parse_loops_tdfir".to_string(),
        Json::Num(program.loop_count() as f64),
    );

    let t = time_it(20, || ir::analyze(&program));
    let w = section("loop+dep analysis", &t, &mut rows);
    metrics.insert("wall_analyze_s".to_string(), Json::Num(w));
    let loops = ir::analyze(&program);
    metrics.insert("analyzed_loops".to_string(), Json::Num(loops.len() as f64));

    // dependence engine vs the legacy gates: per-loop verdicts on the
    // same extraction, timed head-to-head.  The ratio (machine speed
    // cancels out) is pinned at <= 1.10 in BENCH_hot_paths.json — the
    // subscript tests may not make the Analyze stage more than 10%
    // slower than the ad-hoc walks they replaced.
    let infos = flopt::ir::loops::extract(&program);
    let engine_t = time_it(20, || {
        infos
            .iter()
            .filter(|i| {
                let refs = flopt::ir::varref::collect(i);
                flopt::analyze::analyze_loop(i, &refs).offloadable()
            })
            .count()
    });
    section("dep analysis (engine)", &engine_t, &mut rows);
    let legacy_t = time_it(20, || {
        infos
            .iter()
            .filter(|i| {
                let refs = flopt::ir::varref::collect(i);
                flopt::ir::deps::analyze_legacy(i, &refs).offloadable
            })
            .count()
    });
    section("dep analysis (legacy gates)", &legacy_t, &mut rows);
    let analyze_overhead = if legacy_t.median_s > 0.0 {
        engine_t.median_s / legacy_t.median_s
    } else {
        1.0
    };
    println!("{:<35}{:>11.3}x", "analyze overhead (engine/legacy):", analyze_overhead);
    metrics.insert("analyze_overhead".to_string(), Json::Num(analyze_overhead));

    let t = time_it(20, || {
        flopt::analyze::explain_program(app.name, &program).artifact()
    });
    let w = section("explain artifact (tdfir)", &t, &mut rows);
    metrics.insert("wall_explain_s".to_string(), Json::Num(w));

    // dependence counters over all nine apps: verdict mix, optimistic
    // notes, and which subscript tests fire how often.  Every counter is
    // emitted even when zero so the bench-compare baseline can pin the
    // full set without missing-metric failures.
    {
        use flopt::analyze::{DepTest, LoopVerdict};
        const ALL_TESTS: &[DepTest] = &[
            DepTest::Ziv,
            DepTest::SivStrong,
            DepTest::SivSymbolic,
            DepTest::BanerjeeSymbolic,
            DepTest::Gcd,
            DepTest::Banerjee,
            DepTest::MivBanerjee,
            DepTest::MivSymbolic,
        ];
        let (mut par, mut red, mut seqn, mut unk, mut notes) = (0u64, 0u64, 0u64, 0u64, 0u64);
        let mut fired: BTreeMap<DepTest, u64> = ALL_TESTS.iter().map(|t| (*t, 0)).collect();
        for a in apps::all() {
            let rep = flopt::analyze::explain_program(a.name, &a.parse());
            for l in &rep.loops {
                match &l.deps.verdict {
                    LoopVerdict::Parallel => par += 1,
                    LoopVerdict::Reduction(_) => red += 1,
                    LoopVerdict::Sequential(_) => seqn += 1,
                    LoopVerdict::Unknown(_) => unk += 1,
                }
                notes += l.deps.notes.len() as u64;
                for (t, c) in &l.deps.tests {
                    *fired.entry(*t).or_insert(0) += *c as u64;
                }
            }
        }
        metrics.insert("deps_verdict_parallel".to_string(), Json::Num(par as f64));
        metrics.insert("deps_verdict_reduction".to_string(), Json::Num(red as f64));
        metrics.insert("deps_verdict_sequential".to_string(), Json::Num(seqn as f64));
        metrics.insert("deps_verdict_unknown".to_string(), Json::Num(unk as f64));
        metrics.insert("deps_notes".to_string(), Json::Num(notes as f64));
        for (t, c) in &fired {
            metrics.insert(
                format!("deps_test_{}", t.as_str().replace('-', "_")),
                Json::Num(*c as f64),
            );
        }
    }

    let t = time_it(5, || {
        let mut it = app.interp(&program, true);
        it.run_main().unwrap();
        it.into_profile()
    });
    let w = section("profile (test scale)", &t, &mut rows);
    metrics.insert("wall_profile_test_s".to_string(), Json::Num(w));
    {
        let mut it = app.interp(&program, true);
        it.run_main().unwrap();
        let p = it.into_profile();
        metrics.insert("profile_steps_test".to_string(), Json::Num(p.steps as f64));
    }

    // the full-scale (4096x128) profile and search sections dominate the
    // wall clock; CI smoke (`--test-scale`) profiles and searches at the
    // apps' reduced workloads instead
    if !opts.test_scale {
        let t = time_it(3, || {
            let mut it = app.interp(&program, false);
            it.run_main().unwrap();
            it.into_profile()
        });
        let w = section("profile (full scale, 4096x128)", &t, &mut rows);
        metrics.insert("wall_profile_full_s".to_string(), Json::Num(w));
    }

    let profile = {
        let mut it = app.interp(&program, opts.test_scale);
        it.run_main().unwrap();
        it.into_profile()
    };
    let ints = intensity::analyze(&loops, &profile);
    let t = time_it(100, || intensity::top_a(&ints, &loops, 5));
    let w = section("intensity ranking", &t, &mut rows);
    metrics.insert("wall_intensity_s".to_string(), Json::Num(w));
    let top = intensity::top_a(&ints, &loops, 5);
    metrics.insert("top_a_candidates".to_string(), Json::Num(top.len() as f64));

    let hot = loops.iter().find(|l| l.info.id.0 == 8).unwrap();
    let t = time_it(50, || hls::precompile(&program, hot, 1, &ARRIA10_GX));
    let w = section("HLS pre-compile (hot loop)", &t, &mut rows);
    metrics.insert("wall_hls_precompile_s".to_string(), Json::Num(w));

    let analysis = analyze_app(app, opts.test_scale).unwrap();
    let cfg = SearchConfig::default();
    let t = time_it(if opts.test_scale { 3 } else { 10 }, || {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        search_with_analysis(app, &analysis, &env, &cfg).unwrap()
    });
    let w = section("search (post-analysis)", &t, &mut rows);
    metrics.insert("wall_search_s".to_string(), Json::Num(w));
    {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let trace = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
        metrics.insert("search_speedup".to_string(), Json::Num(trace.speedup()));
        metrics.insert(
            "search_patterns_measured".to_string(),
            Json::Num(trace.patterns_measured() as f64),
        );
        metrics.insert(
            "search_compile_hours".to_string(),
            Json::Num(trace.compile_hours),
        );
    }

    // tracing tax: the identical search on a traced vs an untraced
    // clock.  The ratio (not the raw medians — jitter hits both sides
    // alike) is pinned at <= 1.05 in BENCH_hot_paths.json, gating the
    // observability layer's overhead on the search hot path at 5%.
    let obs_iters = if opts.test_scale { 5 } else { 10 };
    let traced = time_it(obs_iters, || {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        search_with_analysis(app, &analysis, &env, &cfg).unwrap()
    });
    section("search (traced clock)", &traced, &mut rows);
    let untraced = time_it(obs_iters, || {
        let clock = Arc::new(SimClock::new_untraced(cfg.compile_parallelism));
        let env = VerifyEnv::with_clock(&FPGA, &XEON_3104, cfg.clone(), clock);
        search_with_analysis(app, &analysis, &env, &cfg).unwrap()
    });
    section("search (untraced clock)", &untraced, &mut rows);
    let overhead = if untraced.median_s > 0.0 {
        traced.median_s / untraced.median_s
    } else {
        1.0
    };
    println!("{:<35}{:>11.3}x", "obs overhead (traced/untraced):", overhead);
    metrics.insert("obs_overhead".to_string(), Json::Num(overhead));

    let t = time_it(3, || {
        let mut it = app.interp(&program, opts.test_scale);
        it.run_main().unwrap()
    });
    let w = section("interpreter end-to-end run", &t, &mut rows);
    metrics.insert("wall_interp_run_s".to_string(), Json::Num(w));

    // PJRT path (needs `make artifacts`)
    match Runtime::load(default_artifact_dir()) {
        Ok(rt) => {
            let spec = rt.spec("tdfir_fpga").unwrap().clone();
            let inputs: Vec<Vec<f32>> = spec
                .input_shapes
                .iter()
                .map(|s| vec![0.5f32; s.iter().product()])
                .collect();
            // first call compiles the HLO
            let t = time_it(1, || rt.execute_f32("tdfir_fpga", &inputs).unwrap());
            section("PJRT first-call (incl. compile)", &t, &mut rows);
            let t = time_it(20, || rt.execute_f32("tdfir_fpga", &inputs).unwrap());
            section("PJRT steady-state execute", &t, &mut rows);
        }
        Err(_) => println!("PJRT benches skipped (run `make artifacts`)"),
    }

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("hot_paths".to_string()));
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("app".to_string(), Json::Str(app.name.to_string()));
        doc.insert("rows".to_string(), Json::Arr(rows));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("report written to {path}");
    }
}
