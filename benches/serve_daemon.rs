//! Bench: the long-lived offload daemon — steady-state throughput and
//! latency of `flopt serve` under tenant churn, incremental re-packing
//! with live migration, DRR fairness, and an admission quota.
//!
//! The report's `metrics` are all simulated-model numbers (throughput,
//! latency percentiles, migration cost), so `flopt bench-compare` can
//! gate them; the pool-size sweep doubles as a determinism check (the
//! rendered report must be byte-identical for 1 and 8 workers).
//!
//! ```sh
//! cargo bench --bench serve_daemon                      # full paper scale
//! cargo bench --bench serve_daemon -- --test-scale \
//!     --report reports/serve_daemon.json                # CI smoke + JSON
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use flopt::cache::CacheStore;
use flopt::serve::{run_serve, ServeConfig};
use flopt::util::bench::{fmt_s, parse_bench_args};
use flopt::util::json::{self, Json};

fn main() {
    let opts = parse_bench_args();
    let cfg = ServeConfig {
        requests: 1200,
        quota: 25,
        test_scale: opts.test_scale,
        ..ServeConfig::default()
    };

    let t0 = Instant::now();
    let report = run_serve(&cfg, CacheStore::fresh()).expect("serve");
    let wall_s = t0.elapsed().as_secs_f64();
    print!("{}", report.render());
    println!("\nwall-clock: {} for {} arrivals", fmt_s(wall_s), cfg.requests);

    // determinism sweep: the report must not depend on the worker pool
    let narrow = run_serve(
        &ServeConfig { pool: 1, ..cfg.clone() },
        CacheStore::fresh(),
    )
    .expect("serve pool=1");
    assert_eq!(
        narrow.render(),
        report.render(),
        "serve report must be byte-identical across pool sizes"
    );
    println!("pool sweep 1 vs 4: byte-identical report");

    if let Some(path) = &opts.report {
        let mut metrics = BTreeMap::new();
        metrics.insert(
            "throughput_per_h".to_string(),
            Json::Num(report.throughput_per_h),
        );
        metrics.insert("p50_s".to_string(), Json::Num(report.p50_s));
        metrics.insert("p99_s".to_string(), Json::Num(report.p99_s));
        metrics.insert("completed".to_string(), Json::Num(report.completed as f64));
        metrics.insert(
            "rejected_quota".to_string(),
            Json::Num(report.rejected_quota as f64),
        );
        metrics.insert("joins".to_string(), Json::Num(report.joins as f64));
        metrics.insert(
            "warm_joins".to_string(),
            Json::Num(report.warm_joins as f64),
        );
        metrics.insert(
            "migrations".to_string(),
            Json::Num(report.migrations as f64),
        );
        metrics.insert(
            "migration_hours".to_string(),
            Json::Num(report.migration_hours),
        );
        metrics.insert(
            "search_hours".to_string(),
            Json::Num(report.search_hours),
        );
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("serve_daemon".to_string()));
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("requests".to_string(), Json::Num(cfg.requests as f64));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("report written to {path}");
    }
}
