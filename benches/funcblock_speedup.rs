//! Bench: function-block offloading vs loop-statement offloading — the
//! follow-up papers' headline claim (arXiv:2004.09883, 2005.04174):
//! recognizing whole blocks and substituting registry IP/library
//! kernels beats generating kernels from loop bodies, and never loses
//! because the combined search keeps whichever side wins.
//!
//! ```sh
//! cargo bench --bench funcblock_speedup                    # full paper scale
//! cargo bench --bench funcblock_speedup -- --test-scale \
//!     --report reports/funcblock_speedup.json              # CI smoke + JSON
//! ```

use std::collections::BTreeMap;

use flopt::apps;
use flopt::backend::{OffloadBackend, FPGA, GPU};
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{offload_search, SearchTrace};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::funcblock::BlockMode;
use flopt::util::bench::parse_bench_args;
use flopt::util::json::{self, Json};

fn run(
    app: &'static apps::App,
    backend: &'static dyn OffloadBackend,
    mode: BlockMode,
    test_scale: bool,
) -> SearchTrace {
    let cfg = SearchConfig { block_mode: mode, ..SearchConfig::default() };
    let env = VerifyEnv::new(backend, &XEON_3104, cfg);
    offload_search(app, &env, test_scale).expect("search")
}

fn main() {
    let opts = parse_bench_args();
    println!("=== function-block vs loop-statement offloading ===");
    println!(
        "{:<12} {:<6} {:>10} {:>10} {:>10} {:>8}  {}",
        "app", "dest", "loop-only", "blocks", "combined", "blk-cnt", "winner"
    );

    let mut rows = Vec::new();
    let mut loop_speedup_sum = 0.0;
    let mut combined_speedup_sum = 0.0;
    let mut blocks_total = 0usize;
    let mut loop_compile_total = 0.0;
    let mut blocks_compile_total = 0.0;
    let mut n_rows = 0usize;
    for app in apps::all() {
        for backend in [&FPGA as &'static dyn OffloadBackend, &GPU] {
            let loop_only = run(app, backend, BlockMode::Off, opts.test_scale);
            let blocks_only = run(app, backend, BlockMode::Only, opts.test_scale);
            let combined = run(app, backend, BlockMode::On, opts.test_scale);
            assert!(
                combined.speedup() >= loop_only.speedup(),
                "{}: combined must never lose",
                app.name
            );
            let winner = if combined.solution_is_block() {
                combined
                    .best_block
                    .as_ref()
                    .map(|b| b.label())
                    .unwrap_or_else(|| "block".to_string())
            } else {
                combined
                    .best
                    .as_ref()
                    .map(|b| format!("pattern {}", b.pattern.label()))
                    .unwrap_or_else(|| "cpu-only".to_string())
            };
            println!(
                "{:<12} {:<6} {:>9.2}x {:>9.2}x {:>9.2}x {:>8}  {}",
                app.name,
                backend.name(),
                loop_only.speedup(),
                blocks_only.speedup(),
                combined.speedup(),
                combined.blocks.len(),
                winner
            );

            let mut row = BTreeMap::new();
            row.insert("app".to_string(), Json::Str(app.name.to_string()));
            row.insert(
                "destination".to_string(),
                Json::Str(backend.name().to_string()),
            );
            row.insert("loop_speedup".to_string(), Json::Num(loop_only.speedup()));
            row.insert("block_speedup".to_string(), Json::Num(blocks_only.speedup()));
            row.insert(
                "combined_speedup".to_string(),
                Json::Num(combined.speedup()),
            );
            row.insert(
                "blocks_measured".to_string(),
                Json::Num(combined.blocks.len() as f64),
            );
            row.insert(
                "loop_compile_hours".to_string(),
                Json::Num(loop_only.compile_hours),
            );
            row.insert(
                "blocks_compile_hours".to_string(),
                Json::Num(blocks_only.compile_hours),
            );
            row.insert("winner".to_string(), Json::Str(winner));
            rows.push(Json::Obj(row));
            loop_speedup_sum += loop_only.speedup();
            combined_speedup_sum += combined.speedup();
            blocks_total += combined.blocks.len();
            loop_compile_total += loop_only.compile_hours;
            blocks_compile_total += blocks_only.compile_hours;
            n_rows += 1;
        }
    }

    println!(
        "\n(\"blocks\" = --blocks only: prebuilt IP, near-zero compile-lane hours;\n\
         \"combined\" = --blocks on: block placements co-searched with loop patterns)"
    );

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert(
            "bench".to_string(),
            Json::Str("funcblock_speedup".to_string()),
        );
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("rows".to_string(), Json::Arr(rows));
        // flat, deterministic aggregates for `flopt bench-compare`
        let denom = n_rows.max(1) as f64;
        let mut metrics = BTreeMap::new();
        metrics.insert(
            "loop_speedup_mean".to_string(),
            Json::Num(loop_speedup_sum / denom),
        );
        metrics.insert(
            "combined_speedup_mean".to_string(),
            Json::Num(combined_speedup_sum / denom),
        );
        metrics.insert(
            "blocks_measured_total".to_string(),
            Json::Num(blocks_total as f64),
        );
        metrics.insert(
            "loop_compile_hours_total".to_string(),
            Json::Num(loop_compile_total),
        );
        metrics.insert(
            "blocks_compile_hours_total".to_string(),
            Json::Num(blocks_compile_total),
        );
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("\nreport written to {path}");
    }
}
