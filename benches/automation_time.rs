//! Bench: regenerate the §5.2 automation-time observation — one full
//! FPGA compile ≈ 3 h, four patterns ≈ half a day — plus a compile-farm
//! lane sweep (an extension ablation: the paper compiles on one machine).

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::util::bench::fmt_sim_hours;

fn main() {
    println!("=== §5.2 automation time (simulated, paper: ~3 h/compile, ~half a day total) ===\n");
    println!(
        "{:<8} {:>10} {:>16} {:>16} {:>18}",
        "app", "patterns", "makespan", "compile-lane-h", "per-compile avg"
    );
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(app, &env, false).expect("search");
        let n = t.patterns_measured();
        println!(
            "{:<8} {:>10} {:>16} {:>16} {:>18}",
            app.name,
            n,
            fmt_sim_hours(t.sim_hours),
            fmt_sim_hours(t.compile_hours),
            fmt_sim_hours(t.compile_hours / n as f64)
        );
        let per = t.compile_hours / (n as f64);
        assert!(per > 2.0 && per < 4.0, "per-compile must be ~3 h, got {per}");
    }

    println!("\n=== extension: compile-farm lanes (paper uses 1) ===");
    println!("{:<8} {:>6} {:>16}", "app", "lanes", "makespan");
    for app in [&apps::TDFIR, &apps::MRIQ] {
        for lanes in [1usize, 2, 4] {
            let cfg = SearchConfig { compile_parallelism: lanes, ..Default::default() };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
            let t = offload_search(app, &env, false).expect("search");
            println!("{:<8} {:>6} {:>16}", app.name, lanes, fmt_sim_hours(t.sim_hours));
        }
    }
}
