//! Bench: regenerate the §5.1.2 evaluation-conditions narrowing trace —
//! loop statements found (tdfir 36, MRI-Q 16) → top-5 by arithmetic
//! intensity → top-3 by resource efficiency → ≤4 measured patterns.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

fn main() {
    println!("=== §5.1.2 narrowing conditions (a=5, b=1, c=3, d=4) ===\n");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "app", "loops", "paper-loops", "top-a", "top-c", "patterns"
    );
    for (app, paper_loops) in [(&apps::TDFIR, 36), (&apps::MRIQ, 16)] {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(app, &env, false).expect("search");
        println!(
            "{:<8} {:>12} {:>12} {:>12} {:>10} {:>10}",
            app.name,
            t.loop_count,
            paper_loops,
            t.top_a.len(),
            t.top_c.len(),
            t.patterns_measured()
        );
        assert_eq!(t.loop_count, paper_loops, "paper loop count must match");
        assert!(t.top_a.len() <= 5 && t.top_c.len() <= 3 && t.patterns_measured() <= 4);
    }

    println!("\n=== per-candidate detail (the intermediate data the paper logs) ===");
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(app, &env, false).expect("search");
        println!("\n{}:", app.name);
        println!(
            "  {:<6} {:>12} {:>10} {:>12}",
            "loop", "intensity", "resource", "efficiency"
        );
        for c in &t.candidates {
            println!(
                "  {:<6} {:>12.2} {:>10.3} {:>12.2}",
                c.id.to_string(),
                c.intensity,
                c.utilization,
                c.efficiency
            );
        }
    }
}
