//! Ablation D: the proposed narrowing vs the GPU-style GA ([Yamato
//! 2018]), exhaustive subsets, and naive offload-everything — the
//! quantitative version of the paper's §3.2 argument that measurement-
//! heavy search is infeasible when every evaluation is a ~3 h compile.
//!
//! ```sh
//! cargo bench --bench search_methods                    # full paper scale
//! cargo bench --bench search_methods -- --test-scale \
//!     --report reports/search_methods.json              # CI smoke + JSON
//! ```

use std::collections::BTreeMap;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::baselines;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::util::bench::parse_bench_args;
use flopt::util::json::{self, Json};

fn report_row(app: &str, method: &str, speedup: f64, evals: usize, compile_h: f64) -> Json {
    let mut row = BTreeMap::new();
    row.insert("app".to_string(), Json::Str(app.to_string()));
    row.insert("method".to_string(), Json::Str(method.to_string()));
    row.insert("speedup".to_string(), Json::Num(speedup));
    row.insert("evaluations".to_string(), Json::Num(evals as f64));
    row.insert("compile_hours".to_string(), Json::Num(compile_h));
    row.insert("compile_days".to_string(), Json::Num(compile_h / 24.0));
    Json::Obj(row)
}

fn main() {
    let opts = parse_bench_args();
    let mut report_rows = Vec::new();
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let analysis = analyze_app(app, opts.test_scale).expect("analysis");
        println!("=== {} ===", app.name);
        println!(
            "{:<12} {:>9} {:>8} {:>14} {:>16}",
            "method", "speedup", "evals", "compile-hours", "compile-days"
        );

        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
        println!(
            "{:<12} {:>8.2}x {:>8} {:>14.1} {:>16.2}",
            "proposed",
            t.speedup(),
            t.patterns_measured(),
            t.compile_hours,
            t.compile_hours / 24.0
        );
        report_rows.push(report_row(
            app.name,
            "proposed",
            t.speedup(),
            t.patterns_measured(),
            t.compile_hours,
        ));

        let ga_env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let ga = baselines::ga::search(&analysis, &ga_env, &baselines::ga::GaConfig::default());
        let ex_env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let ex = baselines::exhaustive::search(&analysis, &ex_env);
        let nv_env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let nv = baselines::naive::search(&analysis, &nv_env);
        for out in [ga, ex, nv] {
            println!(
                "{:<12} {:>8.2}x {:>8} {:>14.1} {:>16.2}",
                out.method,
                out.speedup(),
                out.evaluations,
                out.compile_hours,
                out.compile_hours / 24.0
            );
            report_rows.push(report_row(
                app.name,
                out.method,
                out.speedup(),
                out.evaluations,
                out.compile_hours,
            ));
        }
        println!();
    }
    println!(
        "note: 'compile-days' is what the verification machine would spend \
         compiling — the paper's point: GA/exhaustive burn days-to-weeks \
         where the proposed narrowing needs ~half a day."
    );

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("search_methods".to_string()));
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("rows".to_string(), Json::Arr(report_rows));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("report written to {path}");
    }
}
