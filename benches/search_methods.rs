//! Ablation D: the proposed narrowing vs the GPU-style GA ([Yamato
//! 2018]), exhaustive subsets, and naive offload-everything — the
//! quantitative version of the paper's §3.2 argument that measurement-
//! heavy search is infeasible when every evaluation is a ~3 h compile.

use flopt::apps;
use flopt::baselines;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;

fn main() {
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let analysis = analyze_app(app, false).expect("analysis");
        println!("=== {} ===", app.name);
        println!(
            "{:<12} {:>9} {:>8} {:>14} {:>16}",
            "method", "speedup", "evals", "compile-hours", "compile-days"
        );

        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&ARRIA10_GX, &XEON_3104, cfg.clone());
        let t = search_with_analysis(app, &analysis, &env, &cfg).expect("search");
        println!(
            "{:<12} {:>8.2}x {:>8} {:>14.1} {:>16.2}",
            "proposed",
            t.speedup(),
            t.patterns_measured(),
            t.compile_hours,
            t.compile_hours / 24.0
        );

        let ga_env = VerifyEnv::new(&ARRIA10_GX, &XEON_3104, cfg.clone());
        let ga = baselines::ga::search(&analysis, &ga_env, &baselines::ga::GaConfig::default());
        let ex_env = VerifyEnv::new(&ARRIA10_GX, &XEON_3104, cfg.clone());
        let ex = baselines::exhaustive::search(&analysis, &ex_env);
        let nv_env = VerifyEnv::new(&ARRIA10_GX, &XEON_3104, cfg.clone());
        let nv = baselines::naive::search(&analysis, &nv_env);
        for out in [ga, ex, nv] {
            println!(
                "{:<12} {:>8.2}x {:>8} {:>14.1} {:>16.2}",
                out.method,
                out.speedup(),
                out.evaluations,
                out.compile_hours,
                out.compile_hours / 24.0
            );
        }
        println!();
    }
    println!(
        "note: 'compile-days' is what the verification machine would spend \
         compiling — the paper's point: GA/exhaustive burn days-to-weeks \
         where the proposed narrowing needs ~half a day."
    );
}
