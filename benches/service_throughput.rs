//! Bench: batched offload service throughput — cold batch (every search
//! runs) vs warm batch (every request served from the content-addressed
//! cache), over all registered apps × {fpga, gpu}.
//!
//! Reports both dimensions that matter: real wall-clock of the service
//! itself (the L3 hot path) and the *simulated* compile-lane hours the
//! cache avoided — the paper's ≈3 h/compile is the cost being dodged.
//!
//! ```sh
//! cargo bench --bench service_throughput                # full paper scale
//! cargo bench --bench service_throughput -- --test-scale \
//!     --report reports/service_throughput.json          # CI smoke + JSON
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use flopt::apps;
use flopt::backend::Target;
use flopt::cpu::XEON_3104;
use flopt::service::{BatchRequest, BatchService};
use flopt::util::bench::{fmt_s, fmt_sim_hours, parse_bench_args};
use flopt::util::json::{self, Json};

fn main() {
    let opts = parse_bench_args();
    let mut requests = Vec::new();
    for app in apps::all() {
        for target in [Target::Fpga, Target::Gpu] {
            requests.push(BatchRequest::new(app, target, opts.test_scale));
        }
    }

    let svc = BatchService::new(/*workers=*/ 4, /*lanes=*/ 1, &XEON_3104);

    let t0 = Instant::now();
    let cold = svc.run(&requests).expect("cold batch");
    let cold_wall_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let warm = svc.run(&requests).expect("warm batch");
    let warm_wall_s = t1.elapsed().as_secs_f64();

    println!("=== batch offload service: cold vs warm ===");
    println!("{}", cold.render());
    println!(
        "{:<6} {:>9} {:>12} {:>14} {:>10} {:>8}",
        "batch", "requests", "unique-cold", "compile-lane", "makespan", "wall"
    );
    for (label, report, wall) in [("cold", &cold, cold_wall_s), ("warm", &warm, warm_wall_s)] {
        println!(
            "{:<6} {:>9} {:>12} {:>14} {:>10} {:>8}",
            label,
            report.items.len(),
            report.unique_cold,
            fmt_sim_hours(report.compile_hours),
            fmt_sim_hours(report.sim_hours),
            fmt_s(wall)
        );
    }
    println!(
        "warm batch avoided {} of simulated compile-lane time \
         and ran {:.1}x faster in real time",
        fmt_sim_hours(warm.saved_compile_hours),
        cold_wall_s / warm_wall_s.max(1e-9)
    );

    if let Some(path) = &opts.report {
        let mut doc = BTreeMap::new();
        doc.insert(
            "bench".to_string(),
            Json::Str("service_throughput".to_string()),
        );
        doc.insert(
            "scale".to_string(),
            Json::Str(if opts.test_scale { "test" } else { "full" }.to_string()),
        );
        doc.insert("requests".to_string(), Json::Num(requests.len() as f64));
        let mut rows = Vec::new();
        for (label, report, wall) in
            [("cold", &cold, cold_wall_s), ("warm", &warm, warm_wall_s)]
        {
            let mut row = BTreeMap::new();
            row.insert("batch".to_string(), Json::Str(label.to_string()));
            row.insert("unique_cold".to_string(), Json::Num(report.unique_cold as f64));
            row.insert("warm_hits".to_string(), Json::Num(report.warm_hits as f64));
            row.insert("deduped".to_string(), Json::Num(report.deduped as f64));
            row.insert(
                "compile_hours".to_string(),
                Json::Num(report.compile_hours),
            );
            row.insert("sim_hours".to_string(), Json::Num(report.sim_hours));
            row.insert(
                "saved_compile_hours".to_string(),
                Json::Num(report.saved_compile_hours),
            );
            row.insert("wall_s".to_string(), Json::Num(wall));
            rows.push(Json::Obj(row));
        }
        doc.insert("rows".to_string(), Json::Arr(rows));
        // flat, deterministic (simulated-model) numbers for
        // `flopt bench-compare` — wall-clock stays out of the gate
        let mut metrics = BTreeMap::new();
        metrics.insert("cold_unique".to_string(), Json::Num(cold.unique_cold as f64));
        metrics.insert(
            "cold_compile_hours".to_string(),
            Json::Num(cold.compile_hours),
        );
        metrics.insert("cold_sim_hours".to_string(), Json::Num(cold.sim_hours));
        metrics.insert("warm_hits".to_string(), Json::Num(warm.warm_hits as f64));
        metrics.insert(
            "warm_compile_hours".to_string(),
            Json::Num(warm.compile_hours),
        );
        metrics.insert(
            "warm_saved_compile_hours".to_string(),
            Json::Num(warm.saved_compile_hours),
        );
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        std::fs::write(path, json::to_string(&Json::Obj(doc))).expect("write report");
        println!("report written to {path}");
    }
}
