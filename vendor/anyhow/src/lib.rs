//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline, so this in-tree crate provides
//! the (small) API subset `flopt` uses with the same observable behavior
//! as anyhow 1.x:
//!
//! * [`Error`] — a dynamic error carrying a context chain;
//! * [`Result`] — `Result<T, Error>` alias with a defaulted error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — formatted-construction macros;
//! * [`Context`] — `context`/`with_context` adapters on `Result`;
//! * a blanket `From<E: std::error::Error>` conversion for `?`.
//!
//! Display semantics match anyhow: `{}` prints the outermost message,
//! `{:#}` prints the whole chain separated by `": "`, and `{:?}` prints
//! the message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, Error>` with the error type defaulted, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Adapters attaching context to fallible results, like `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error with `context` as the new outermost message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let alt = format!("{e:#}");
        assert!(alt.contains("reading manifest") && alt.contains("file missing"), "{alt}");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Err::<(), _>(io_err()).context("opening").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("Caused by"), "{d}");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
