//! Paper evaluation app #2: MRI-Q (Parboil) at full paper scale —
//! regenerates the MRI-Q row of Fig 4.
//!
//! ```sh
//! cargo run --release --example mriq_offload
//! ```

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

fn main() -> flopt::Result<()> {
    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
    let trace = offload_search(&apps::MRIQ, &env, /*test_scale=*/ false)?;
    println!("{}", trace.render());
    println!(
        "Fig 4 row — MRI-Q: paper 7.1x, this run {:.1}x on {}",
        trace.speedup(),
        trace.destination
    );
    Ok(())
}
