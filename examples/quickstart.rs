//! Quickstart: point the coordinator at an application and get an offload
//! decision.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

fn main() -> flopt::Result<()> {
    // 1. pick an app from the registry (or bring your own — see
    //    examples/custom_app.rs)
    let app = &apps::HISTOGRAM;
    println!("app: {} — {}\n", app.name, app.description);

    // 2. a verification environment: an offload backend (here the FPGA
    //    board model; `flopt::backend::GPU` is the other option), the
    //    CPU baseline model, and the paper's search parameters (a=5,
    //    b=1, c=3, d=4)
    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());

    // 3. run the paper's Steps 1-3: analyze, narrow, generate OpenCL,
    //    compile + measure patterns, select the fastest
    let trace = offload_search(app, &env, /*test_scale=*/ true)?;
    println!("{}", trace.render());

    // 4. the solution pattern's generated OpenCL kernel
    if let Some(best) = &trace.best {
        let code = trace
            .opencl
            .iter()
            .find(|c| c.pattern == best.pattern)
            .expect("solution has OpenCL");
        println!("--- solution kernel ---\n{}", code.cl_source());
    }
    Ok(())
}
