//! Bring-your-own application: run the offload search on MiniC source
//! you provide (here: a 1-D heat diffusion kernel written inline).
//!
//! ```sh
//! cargo run --release --example custom_app
//! ```

use flopt::apps::App;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

const SOURCE: &str = r#"
int N = 4096;
int STEPS = 50;
float u[4096]; float v[4096];
float stats_out[2];
int seed = 5;

float lcg(float lo, float hi2) {
    seed = (seed * 1103515245 + 12345) % 2147483647;
    if (seed < 0) { seed = -seed; }
    return lo + (hi2 - lo) * (seed % 100000) / 100000.0;
}

void init(float a[], int n) {
    for (int k = 0; k < n; k++) { a[k] = lcg(0.0, 1.0); }
}

// the hot diffusion nest: outer time loop is sequential, the inner
// space loop is the offload candidate
void diffuse(float a[], float b[], int n, int steps) {
    for (int t = 0; t < steps; t++) {
        for (int k = 1; k < n - 1; k++) {
            b[k] = a[k] + 0.25 * (a[k - 1] - 2.0 * a[k] + a[k + 1]);
        }
        for (int k = 1; k < n - 1; k++) { a[k] = b[k]; }
    }
}

float total(float a[], int n) {
    float s;
    s = 0.0;
    for (int k = 0; k < n; k++) { s += a[k]; }
    return s;
}

void main() {
    init(u, N);
    diffuse(u, v, N, STEPS);
    stats_out[0] = total(u, N);
}
"#;

fn main() -> flopt::Result<()> {
    // Registering a custom app: the registry types use &'static because
    // the built-in corpus is embedded; for runtime-loaded source, leak
    // the strings (one-off, lives for the process).
    let app = Box::leak(Box::new(App {
        name: "heat1d",
        description: "1-D heat diffusion (user-provided)",
        source: Box::leak(SOURCE.to_string().into_boxed_str()),
        paper_loop_count: None,
        binding: None,
        test_scale: &[("N", 512), ("STEPS", 10)],
        stats_array: "stats_out",
    }));

    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
    let trace = offload_search(app, &env, /*test_scale=*/ false)?;
    println!("{}", trace.render());

    // What the analysis concluded about each loop:
    println!("loop dependence verdicts:");
    let program = app.parse();
    for la in flopt::ir::analyze(&program) {
        println!(
            "  {} in {}: {}",
            la.info.id,
            la.info.function,
            if la.deps.offloadable {
                "offloadable".to_string()
            } else {
                format!("no ({})", la.deps.reject_reason.as_deref().unwrap_or("?"))
            }
        );
    }
    Ok(())
}
