//! END-TO-END DRIVER — proves all three layers compose on the real
//! paper workloads:
//!
//! 1. L3 parses + profiles each paper app (MiniC interpreter at full
//!    paper scale: tdfir N=4096/T=128, MRI-Q X=2048/K=512);
//! 2. the offload search narrows 36/16 loops → top-5 intensity → top-3
//!    resource efficiency → ≤4 compiled+measured patterns and picks the
//!    solution (Fig 4);
//! 3. the solution's hot-loop numerics execute through the **PJRT
//!    runtime** against the L1 Pallas artifacts (`make artifacts`), and
//!    must match the interpreter's all-CPU reference.
//!
//! The run recorded in EXPERIMENTS.md comes from this binary:
//!
//! ```sh
//! make artifacts && cargo run --release --example full_pipeline
//! ```

use std::time::Instant;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::runtime::{default_artifact_dir, Runtime};

fn main() -> flopt::Result<()> {
    println!("flopt end-to-end driver — paper workloads at full scale\n");
    println!("{}", flopt::config::fig3_table());

    let runtime = Runtime::load(default_artifact_dir())?;
    println!("artifacts loaded: {:?}\n", runtime.artifact_names());

    let mut rows = Vec::new();
    for (app, paper) in [(&apps::TDFIR, 4.0), (&apps::MRIQ, 7.1)] {
        let t0 = Instant::now();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let trace = offload_search(app, &env, /*test_scale=*/ false)?;
        let search_wall = t0.elapsed().as_secs_f64();
        println!("{}", trace.render());

        // numerics through the PJRT artifacts (the "FPGA run")
        let t1 = Instant::now();
        let check = env.check_numerics(app, &runtime)?;
        let verify_wall = t1.elapsed().as_secs_f64();
        println!(
            "numerics: artifact {} over {} elements -> max|fpga-interp| = {:.3e}, \
             max|pallas-jnp| = {:.3e} => {}\n",
            check.artifact,
            check.elements,
            check.max_abs_err,
            check.max_abs_err_vs_cpu_artifact,
            if check.passed { "PASS" } else { "FAIL" }
        );
        assert!(check.passed, "numerics must pass for {}", app.name);

        rows.push((
            app.name,
            paper,
            trace.speedup(),
            trace.destination,
            trace.patterns_measured(),
            trace.sim_hours,
            search_wall,
            verify_wall,
        ));
    }

    println!("==================== Fig 4 (reproduced) ====================");
    println!(
        "{:<42} {:>8} {:>10} {:>6} {:>9} {:>8}",
        "Application", "paper", "this repo", "dest", "patterns", "sim-h"
    );
    for (name, paper, got, dest, pats, sim_h, _, _) in &rows {
        println!(
            "{:<42} {:>7.1}x {:>9.2}x {:>6} {:>9} {:>8.1}",
            match *name {
                "tdfir" => "Time domain finite impulse response filter",
                other => other,
            },
            paper,
            got,
            dest,
            pats,
            sim_h
        );
    }
    println!();
    for (name, _, _, _, _, _, search_wall, verify_wall) in &rows {
        println!(
            "real wall-clock — {name}: search {:.2}s, PJRT verify {:.2}s",
            search_wall, verify_wall
        );
    }
    Ok(())
}
