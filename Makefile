# Convenience targets. The Rust crate builds fully offline; `artifacts`
# needs the Python environment (jax) and is only required for the
# PJRT-backed paths (`flopt verify`, tests behind the `xla` feature).

.PHONY: build test artifacts bench clean

build:
	cargo build --release

test:
	cargo test -q

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

bench:
	cargo bench --bench fig4_speedup
	cargo bench --bench narrowing
	cargo bench --bench automation_time

clean:
	cargo clean
	rm -rf artifacts
