"""AOT entry point: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/load_hlo/gen_hlo.py.

Run once at build time (``make artifacts``); emits one ``<name>.hlo.txt``
per model variant plus ``manifest.json`` describing the I/O signatures the
Rust runtime binds against.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# AOT shapes: HPEC tdfir set-1 scale (N samples, T taps) and a Parboil
# MRI-Q "small"-shaped problem (X voxels, K k-space samples).  The Rust
# runtime feeds exactly these shapes; tests in python/tests sweep other
# shapes through the kernels directly.
TDFIR_N = 4096
TDFIR_T = 128
MRIQ_X = 2048
MRIQ_K = 512


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, "float32")


def specs():
    """name -> (fn, example_args) for every artifact."""
    tdfir_args = (_f32(TDFIR_N), _f32(TDFIR_N), _f32(TDFIR_T), _f32(TDFIR_T))
    mriq_args = (
        _f32(MRIQ_X), _f32(MRIQ_X), _f32(MRIQ_X),
        _f32(MRIQ_K), _f32(MRIQ_K), _f32(MRIQ_K),
        _f32(MRIQ_K), _f32(MRIQ_K),
    )
    return {
        "tdfir_fpga": (model.tdfir_fpga, tdfir_args),
        "tdfir_cpu": (model.tdfir_cpu, tdfir_args),
        "mriq_fpga": (model.mriq_fpga, mriq_args),
        "mriq_cpu": (model.mriq_cpu, mriq_args),
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, example_args) in specs().items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        n_out = len(fn(*[jax.numpy.zeros(a.shape, a.dtype) for a in example_args]))
        manifest[name] = {
            "file": fname,
            "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in example_args],
            "num_outputs": n_out,
        }
        print(f"wrote {fname}: {len(text)} chars, "
              f"{len(example_args)} inputs, {n_out} outputs")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
