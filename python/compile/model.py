"""L2: whole-application JAX compute graphs for the two paper workloads.

Each workload exists in two variants that ``aot.py`` lowers to separate HLO
artifacts:

* ``*_fpga`` — calls the L1 Pallas kernels (the "FPGA bitstream" equivalent
  in the reproduction: the Rust verification environment executes this
  artifact for offloaded-loop numerics).
* ``*_cpu`` — pure-jnp reference graph (ref.py oracles) used by the Rust
  integration tests to cross-check the FPGA variant end to end.

Python never runs on the request path: these functions are traced once by
``aot.py`` and shipped as HLO text.
"""

import jax.numpy as jnp

from compile.kernels import mriq as mriq_kernels
from compile.kernels import ref
from compile.kernels import tdfir as tdfir_kernel


def tdfir_fpga(xr, xi, hr, hi):
    """TDFIR with the FIR hot loop on the Pallas kernel."""
    yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
    return (yr, yi)


def tdfir_cpu(xr, xi, hr, hi):
    """TDFIR all-CPU reference graph."""
    yr, yi = ref.tdfir_ref(xr, xi, hr, hi)
    return (yr, yi)


def mriq_fpga(x, y, z, kx, ky, kz, phi_r, phi_i):
    """MRI-Q with both hot loops (PhiMag, ComputeQ) on Pallas kernels."""
    qr, qi = mriq_kernels.mriq(x, y, z, kx, ky, kz, phi_r, phi_i)
    return (qr, qi)


def mriq_cpu(x, y, z, kx, ky, kz, phi_r, phi_i):
    """MRI-Q all-CPU reference graph."""
    qr, qi = ref.mriq_ref(x, y, z, kx, ky, kz, phi_r, phi_i)
    return (qr, qi)


def tdfir_energy(yr, yi):
    """Output energy — the sample-app "verification" reduction the paper's
    benchmark prints; kept in the graph library so the Rust side can fold
    outputs without reimplementing the reduction."""
    return (jnp.sum(yr * yr + yi * yi),)
