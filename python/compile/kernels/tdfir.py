"""L1 Pallas kernel: time-domain FIR filter (HPEC tdfir), complex f32.

FPGA→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's OpenCL
kernel keeps the tap array and a shift-register window of the input in FPGA
*local memory* and streams one output sample per clock through a MAC
pipeline.  Here the same locality insight becomes VMEM blocking: each grid
step owns one output block of ``BLOCK`` samples; the padded input stays
resident (it is small) and the tap loop is a ``fori_loop`` whose body does a
*vector* multiply-accumulate over the whole block — the block dimension is
what the FPGA unrolled in time, re-expressed as a VPU-wide vector op.

``interpret=True`` is mandatory: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output samples computed per grid step.  256 f32 lanes keeps the working
# set (window + accumulators) well under the 4 MiB VMEM budget noted in
# DESIGN.md §Perf while still amortizing the tap-loop overhead.
BLOCK = 256


def _tdfir_kernel(taps, block, xr_ref, xi_ref, hr_ref, hi_ref, yr_ref, yi_ref):
    """One output block of the complex FIR.

    ``xr_ref/xi_ref`` hold the zero-padded input (length N + taps - 1); the
    window for output index ``n = i*block + j`` and tap ``k`` is
    ``xp[i*block + j + (taps-1) - k]``.
    """
    i = pl.program_id(0)
    zero = jnp.zeros((block,), dtype=yr_ref.dtype)

    def tap_body(k, acc):
        acc_r, acc_i = acc
        start = i * block + (taps - 1) - k
        wr = xr_ref[pl.dslice(start, block)]
        wi = xi_ref[pl.dslice(start, block)]
        hr = hr_ref[pl.dslice(k, 1)][0]
        hi = hi_ref[pl.dslice(k, 1)][0]
        # Complex MAC: (wr + i*wi) * (hr + i*hi)
        return (acc_r + wr * hr - wi * hi, acc_i + wr * hi + wi * hr)

    acc_r, acc_i = jax.lax.fori_loop(0, taps, tap_body, (zero, zero))
    yr_ref[...] = acc_r
    yi_ref[...] = acc_i


def tdfir(xr, xi, hr, hi, *, block=BLOCK):
    """Complex causal FIR via the Pallas kernel.

    Args:
      xr, xi: (N,) float32 input samples (N need not be a block multiple).
      hr, hi: (T,) float32 filter taps.
    Returns:
      (yr, yi): (N,) float32, matching ``ref.tdfir_ref``.
    """
    n = xr.shape[0]
    taps = hr.shape[0]
    block = min(block, n)
    n_pad = -n % block  # round N up to a block multiple
    grid = (n + n_pad) // block
    # Zero-pad: (taps-1) history samples in front, block alignment at back.
    xr_p = jnp.pad(xr, (taps - 1, n_pad))
    xi_p = jnp.pad(xi, (taps - 1, n_pad))

    out_shape = jax.ShapeDtypeStruct((n + n_pad,), xr.dtype)
    kernel = functools.partial(_tdfir_kernel, taps, block)
    yr, yi = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(xr_p.shape, lambda i: (0,)),  # padded input resident
            pl.BlockSpec(xi_p.shape, lambda i: (0,)),
            pl.BlockSpec(hr.shape, lambda i: (0,)),  # taps resident (small)
            pl.BlockSpec(hi.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(xr_p, xi_p, hr, hi)
    return yr[:n], yi[:n]
