"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package must match these to float32 tolerance on every shape/dtype the
hypothesis sweep generates (python/tests/).

The two computations are the hot loops the paper offloads to FPGA:

* ``tdfir`` — HPEC-challenge time-domain finite impulse response filter:
  complex causal FIR, ``y[n] = sum_k h[k] * x[n-k]`` (zero-padded history).
* ``mriq`` — Parboil MRI-Q: ComputePhiMag (``|phi|^2`` per k-space sample)
  followed by ComputeQ (per-voxel sin/cos accumulation over k-space).
"""

import jax.numpy as jnp

TWO_PI = 6.283185307179586


def tdfir_ref(xr, xi, hr, hi):
    """Complex causal FIR via explicit convolution.

    Args:
      xr, xi: (N,) float32 — real/imag input samples.
      hr, hi: (T,) float32 — real/imag filter taps.
    Returns:
      (yr, yi): (N,) float32 — y[n] = sum_{k<T} h[k] * x[n-k], x[<0] = 0.
    """
    n = xr.shape[0]
    # jnp.convolve(full) gives length N+T-1; the causal output is the first N.
    yr = (jnp.convolve(xr, hr) - jnp.convolve(xi, hi))[:n]
    yi = (jnp.convolve(xr, hi) + jnp.convolve(xi, hr))[:n]
    return yr.astype(xr.dtype), yi.astype(xr.dtype)


def phimag_ref(phi_r, phi_i):
    """ComputePhiMag: squared magnitude of the k-space coil sensitivity."""
    return phi_r * phi_r + phi_i * phi_i


def mriq_ref(x, y, z, kx, ky, kz, phi_r, phi_i):
    """MRI-Q ComputePhiMag + ComputeQ.

    Args:
      x, y, z: (X,) float32 — voxel coordinates.
      kx, ky, kz: (K,) float32 — k-space trajectory.
      phi_r, phi_i: (K,) float32 — coil sensitivity at each k-space sample.
    Returns:
      (q_r, q_i): (X,) float32 —
        q[v] = sum_k phiMag[k] * exp(i * 2*pi * (kx[k]x[v]+ky[k]y[v]+kz[k]z[v]))
    """
    phi_mag = phimag_ref(phi_r, phi_i)
    exp_arg = TWO_PI * (
        x[:, None] * kx[None, :]
        + y[:, None] * ky[None, :]
        + z[:, None] * kz[None, :]
    )
    q_r = jnp.sum(phi_mag[None, :] * jnp.cos(exp_arg), axis=1)
    q_i = jnp.sum(phi_mag[None, :] * jnp.sin(exp_arg), axis=1)
    return q_r.astype(x.dtype), q_i.astype(x.dtype)
