"""L1 Pallas kernels: MRI-Q (Parboil) — ComputePhiMag and ComputeQ.

FPGA→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's OpenCL
ComputeQ kernel caches the k-space trajectory (kx/ky/kz/phiMag — a few KB)
in FPGA local memory and pipelines the per-voxel sin/cos accumulation.
Here the k-space arrays are kept VMEM-resident across the whole grid
(BlockSpec index_map pins them to block 0) while voxels are tiled in
``BLOCK``-sized chunks; the accumulation becomes a (BLOCK, K) outer-product
of trig evaluations reduced over K — the FPGA's K-deep pipeline re-expressed
as a vectorized reduction.

``interpret=True`` is mandatory: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TWO_PI = 6.283185307179586

# Voxels per grid step.  With K=512 k-space samples the (BLOCK, K) trig
# intermediate is 128*512*4 B = 256 KiB per array — comfortably inside the
# 4 MiB VMEM budget even with cos+sin live simultaneously.
BLOCK = 128


def _phimag_kernel(phi_r_ref, phi_i_ref, mag_ref):
    """ComputePhiMag: elementwise |phi|^2 over one block."""
    pr = phi_r_ref[...]
    pi = phi_i_ref[...]
    mag_ref[...] = pr * pr + pi * pi


def phimag(phi_r, phi_i, *, block=BLOCK):
    """Squared magnitude of the coil sensitivity, blockwise."""
    k = phi_r.shape[0]
    block = min(block, k)
    pad = -k % block
    pr = jnp.pad(phi_r, (0, pad))
    pi = jnp.pad(phi_i, (0, pad))
    out = pl.pallas_call(
        _phimag_kernel,
        grid=((k + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k + pad,), phi_r.dtype),
        interpret=True,
    )(pr, pi)
    return out[:k]


def _computeq_kernel(x_ref, y_ref, z_ref, kx_ref, ky_ref, kz_ref, mag_ref,
                     qr_ref, qi_ref):
    """ComputeQ over one voxel block with the k-space table resident."""
    xv = x_ref[...]
    yv = y_ref[...]
    zv = z_ref[...]
    exp_arg = TWO_PI * (
        xv[:, None] * kx_ref[...][None, :]
        + yv[:, None] * ky_ref[...][None, :]
        + zv[:, None] * kz_ref[...][None, :]
    )
    mag = mag_ref[...][None, :]
    qr_ref[...] = jnp.sum(mag * jnp.cos(exp_arg), axis=1)
    qi_ref[...] = jnp.sum(mag * jnp.sin(exp_arg), axis=1)


def computeq(x, y, z, kx, ky, kz, phi_mag, *, block=BLOCK):
    """Per-voxel Q accumulation over all k-space samples.

    Args:
      x, y, z: (X,) float32 voxel coordinates.
      kx, ky, kz: (K,) float32 k-space trajectory.
      phi_mag: (K,) float32 from :func:`phimag`.
    Returns:
      (q_r, q_i): (X,) float32, matching ``ref.mriq_ref``.
    """
    nx = x.shape[0]
    block = min(block, nx)
    pad = -nx % block
    xp = jnp.pad(x, (0, pad))
    yp = jnp.pad(y, (0, pad))
    zp = jnp.pad(z, (0, pad))
    out_shape = jax.ShapeDtypeStruct((nx + pad,), x.dtype)
    k_spec = pl.BlockSpec(kx.shape, lambda i: (0,))  # k-space table resident
    v_spec = pl.BlockSpec((block,), lambda i: (i,))
    qr, qi = pl.pallas_call(
        _computeq_kernel,
        grid=((nx + pad) // block,),
        in_specs=[v_spec, v_spec, v_spec, k_spec, k_spec, k_spec, k_spec],
        out_specs=[v_spec, v_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(xp, yp, zp, kx, ky, kz, phi_mag)
    return qr[:nx], qi[:nx]


def mriq(x, y, z, kx, ky, kz, phi_r, phi_i, *, block=BLOCK):
    """Full MRI-Q: ComputePhiMag then ComputeQ (both Pallas kernels)."""
    mag = phimag(phi_r, phi_i)
    return computeq(x, y, z, kx, ky, kz, mag, block=block)
