"""Pallas tdfir kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import tdfir as tk


def _rand(rng, n):
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=n).astype(np.float32))


def _check(n, t, block=tk.BLOCK, seed=0):
    rng = np.random.default_rng(seed)
    xr, xi = _rand(rng, n), _rand(rng, n)
    hr, hi = _rand(rng, t), _rand(rng, t)
    yr, yi = tk.tdfir(xr, xi, hr, hi, block=block)
    er, ei = ref.tdfir_ref(xr, xi, hr, hi)
    np.testing.assert_allclose(yr, er, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(yi, ei, rtol=2e-4, atol=2e-4)


def test_aot_shape():
    """The exact shape aot.py lowers."""
    _check(4096, 128)


def test_single_tap():
    """T=1 degenerates to complex scalar multiply."""
    _check(64, 1)


def test_input_shorter_than_taps():
    _check(8, 32)


def test_non_block_multiple():
    """N not a multiple of BLOCK exercises the pad/slice path."""
    _check(1000, 16)


def test_block_larger_than_input():
    _check(100, 4, block=256)


def test_identity_filter():
    """h = [1+0j] passes the input through unchanged."""
    rng = np.random.default_rng(1)
    xr, xi = _rand(rng, 300), _rand(rng, 300)
    one = jnp.ones((1,), jnp.float32)
    zero = jnp.zeros((1,), jnp.float32)
    yr, yi = tk.tdfir(xr, xi, one, zero)
    np.testing.assert_allclose(yr, xr, rtol=1e-6)
    np.testing.assert_allclose(yi, xi, rtol=1e-6)


def test_delay_filter():
    """h = delta delayed by d shifts the input by d samples."""
    rng = np.random.default_rng(2)
    d, n = 5, 128
    xr, xi = _rand(rng, n), _rand(rng, n)
    hr = jnp.zeros((d + 1,), jnp.float32).at[d].set(1.0)
    hi = jnp.zeros((d + 1,), jnp.float32)
    yr, yi = tk.tdfir(xr, xi, hr, hi)
    np.testing.assert_allclose(yr[d:], xr[:-d], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(yr[:d], np.zeros(d), atol=1e-6)
    np.testing.assert_allclose(yi[d:], xi[:-d], rtol=1e-6, atol=1e-6)


def test_linearity():
    """FIR is linear: F(a*x1 + x2) == a*F(x1) + F(x2)."""
    rng = np.random.default_rng(3)
    n, t, a = 200, 12, 2.5
    x1r, x1i = _rand(rng, n), _rand(rng, n)
    x2r, x2i = _rand(rng, n), _rand(rng, n)
    hr, hi = _rand(rng, t), _rand(rng, t)
    y1 = tk.tdfir(x1r, x1i, hr, hi)
    y2 = tk.tdfir(x2r, x2i, hr, hi)
    y3 = tk.tdfir(a * x1r + x2r, a * x1i + x2i, hr, hi)
    np.testing.assert_allclose(y3[0], a * y1[0] + y2[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y3[1], a * y1[1] + y2[1], rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=700),
    t=st.integers(min_value=1, max_value=96),
    block=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(n, t, block, seed):
    """Shape sweep: kernel matches the oracle for arbitrary (N, T, block)."""
    _check(n, t, block=block, seed=seed)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_float64(seed):
    """dtype sweep: the kernel is dtype-generic under x64."""
    rng = np.random.default_rng(seed)
    with jax.enable_x64(True):
        xr = jnp.asarray(rng.uniform(-1, 1, 130), jnp.float64)
        xi = jnp.asarray(rng.uniform(-1, 1, 130), jnp.float64)
        hr = jnp.asarray(rng.uniform(-1, 1, 9), jnp.float64)
        hi = jnp.asarray(rng.uniform(-1, 1, 9), jnp.float64)
        yr, yi = tk.tdfir(xr, xi, hr, hi, block=64)
        er, ei = ref.tdfir_ref(xr, xi, hr, hi)
        np.testing.assert_allclose(yr, er, rtol=1e-10)
        np.testing.assert_allclose(yi, ei, rtol=1e-10)
