"""Pallas MRI-Q kernels vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import mriq as mk
from compile.kernels import ref


def _rand(rng, n, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, size=n).astype(np.float32))


def _problem(nx, k, seed):
    rng = np.random.default_rng(seed)
    return (
        _rand(rng, nx), _rand(rng, nx), _rand(rng, nx),
        _rand(rng, k), _rand(rng, k), _rand(rng, k),
        _rand(rng, k), _rand(rng, k),
    )


def _check(nx, k, block=mk.BLOCK, seed=0, tol=2e-2):
    args = _problem(nx, k, seed)
    qr, qi = mk.mriq(*args, block=block)
    er, ei = ref.mriq_ref(*args)
    # Accumulation over K trig terms: absolute tolerance scales with K.
    atol = tol * np.sqrt(k)
    np.testing.assert_allclose(qr, er, rtol=1e-3, atol=atol)
    np.testing.assert_allclose(qi, ei, rtol=1e-3, atol=atol)


def test_aot_shape():
    """The exact shape aot.py lowers."""
    _check(2048, 512)


def test_phimag_matches_ref():
    rng = np.random.default_rng(0)
    pr, pi = _rand(rng, 500), _rand(rng, 500)
    got = mk.phimag(pr, pi)
    np.testing.assert_allclose(got, ref.phimag_ref(pr, pi), rtol=1e-6)


def test_phimag_nonnegative():
    rng = np.random.default_rng(1)
    pr, pi = _rand(rng, 333), _rand(rng, 333)
    assert np.all(np.asarray(mk.phimag(pr, pi)) >= 0.0)


def test_single_voxel():
    _check(1, 16)


def test_single_ksample():
    _check(64, 1)


def test_non_block_multiple():
    _check(200, 33, block=64)


def test_zero_phi_gives_zero_q():
    """phi == 0 => phiMag == 0 => Q == 0 regardless of trajectory."""
    rng = np.random.default_rng(2)
    x, y, z = _rand(rng, 50), _rand(rng, 50), _rand(rng, 50)
    kx, ky, kz = _rand(rng, 20), _rand(rng, 20), _rand(rng, 20)
    zero = jnp.zeros((20,), jnp.float32)
    qr, qi = mk.mriq(x, y, z, kx, ky, kz, zero, zero)
    np.testing.assert_allclose(qr, np.zeros(50), atol=1e-7)
    np.testing.assert_allclose(qi, np.zeros(50), atol=1e-7)


def test_origin_voxel_sums_phimag():
    """At (0,0,0): expArg == 0, so Qr == sum(phiMag), Qi == 0."""
    rng = np.random.default_rng(3)
    k = 40
    kx, ky, kz = _rand(rng, k), _rand(rng, k), _rand(rng, k)
    pr, pi = _rand(rng, k), _rand(rng, k)
    zero = jnp.zeros((1,), jnp.float32)
    qr, qi = mk.mriq(zero, zero, zero, kx, ky, kz, pr, pi)
    np.testing.assert_allclose(qr[0], float(jnp.sum(pr * pr + pi * pi)),
                               rtol=1e-5)
    np.testing.assert_allclose(qi[0], 0.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=128),
    block=st.sampled_from([16, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(nx, k, block, seed):
    """Shape sweep: kernels match the oracle for arbitrary (X, K, block)."""
    _check(nx, k, block=block, seed=seed)
