"""Model-level and AOT-lowering tests: every artifact lowers to HLO text the
xla 0.5.1 parser accepts (structurally: non-empty ENTRY, f32 I/O), fpga and
cpu variants agree numerically, and the manifest matches the specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def _args_for(spec):
    rng = np.random.default_rng(7)
    return [jnp.asarray(rng.uniform(-1, 1, a.shape).astype(a.dtype))
            for a in spec]


@pytest.mark.parametrize("name", list(aot.specs()))
def test_lowering_produces_hlo_text(name):
    fn, example_args = aot.specs()[name]
    lowered = jax.jit(fn).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, "HLO text must contain an ENTRY computation"
    assert "f32" in text
    # 0.5.1-safe interchange: text, never serialized proto bytes.
    assert isinstance(text, str) and len(text) > 100


def test_tdfir_variants_agree():
    fn_f, spec = aot.specs()["tdfir_fpga"]
    fn_c, _ = aot.specs()["tdfir_cpu"]
    args = _args_for(spec)
    yf, yc = fn_f(*args), fn_c(*args)
    for a, b in zip(yf, yc):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_mriq_variants_agree():
    fn_f, spec = aot.specs()["mriq_fpga"]
    fn_c, _ = aot.specs()["mriq_cpu"]
    args = _args_for(spec)
    yf, yc = fn_f(*args), fn_c(*args)
    for a, b in zip(yf, yc):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=0.5)


def test_specs_cover_both_apps_and_variants():
    names = set(aot.specs())
    assert names == {"tdfir_fpga", "tdfir_cpu", "mriq_fpga", "mriq_cpu"}


def test_tdfir_energy_scalar():
    yr = jnp.ones((8,), jnp.float32)
    yi = 2.0 * jnp.ones((8,), jnp.float32)
    (e,) = model.tdfir_energy(yr, yi)
    assert e.shape == ()
    np.testing.assert_allclose(e, 8 * (1 + 4), rtol=1e-6)
