//! Property-based tests (in-tree generator — proptest is unavailable in
//! the offline build; `flopt::util::rng` drives the cases).
//!
//! Invariants covered:
//! * pretty-print ∘ parse is the identity on random MiniC programs;
//! * the interpreter is deterministic;
//! * random offloadable loops: FPGA-offload candidates never carry
//!   unrecognized loop deps (consistency of deps vs varref);
//! * `top_a` monotonicity and subset ordering;
//! * round-2 patterns never exceed the cap, never duplicate round 1;
//! * JSON round-trips random documents.

use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::{self, pretty};
use flopt::cpu::XEON_3104;
use flopt::fpga::ARRIA10_GX;
use flopt::intensity;
use flopt::util::json::{self, Json};
use flopt::util::rng::Rng;

// ---- random program generation ------------------------------------------

/// Generate a random (but always-valid, always-terminating) MiniC program.
fn random_program(rng: &mut Rng) -> String {
    let n_arrays = rng.range_i64(1, 3);
    let mut src = String::from("float stats_out[4];\n");
    for a in 0..n_arrays {
        src.push_str(&format!("float arr{a}[64];\n"));
    }
    src.push_str("void main() {\n");
    let n_loops = rng.range_i64(1, 4);
    for l in 0..n_loops {
        let a = rng.range_i64(0, n_arrays - 1);
        let lo = rng.range_i64(0, 8);
        let hi = rng.range_i64(lo + 1, 63);
        match rng.below(4) {
            0 => src.push_str(&format!(
                "    for (int i{l} = {lo}; i{l} < {hi}; i{l}++) {{ arr{a}[i{l}] = i{l} * {:.1} + {:.1}; }}\n",
                rng.range_f64(0.5, 2.0),
                rng.range_f64(-1.0, 1.0)
            )),
            1 => src.push_str(&format!(
                "    for (int i{l} = {lo}; i{l} < {hi}; i{l}++) {{ arr{a}[i{l}] = sqrt(fabs(arr{a}[i{l}])) + {:.1}; }}\n",
                rng.range_f64(0.0, 1.0)
            )),
            2 => src.push_str(&format!(
                "    for (int i{l} = {lo}; i{l} < {hi}; i{l}++) {{\n        for (int j{l} = 0; j{l} < 4; j{l}++) {{ arr{a}[i{l}] += {:.1}; }}\n    }}\n",
                rng.range_f64(0.1, 0.9)
            )),
            _ => src.push_str(&format!(
                "    if (arr{a}[0] > 0.0) {{ for (int i{l} = {lo}; i{l} < {hi}; i{l}++) {{ arr{a}[i{l}] *= 0.5; }} }}\n"
            )),
        }
    }
    src.push_str(&format!("    stats_out[0] = arr0[{}];\n", rng.range_i64(0, 63)));
    src.push_str("}\n");
    src
}

#[test]
fn prop_pretty_parse_roundtrip() {
    let mut rng = Rng::new(101);
    for case in 0..60 {
        let src = random_program(&mut rng);
        let p1 = cparse::parse(&src).unwrap_or_else(|e| panic!("case {case}: {e}\n{src}"));
        let printed = pretty::program(&p1);
        let p2 = cparse::parse(&printed)
            .unwrap_or_else(|e| panic!("case {case} reparse: {e}\n{printed}"));
        assert_eq!(p1.loop_count(), p2.loop_count(), "case {case}");
        // printing is a fixpoint
        assert_eq!(pretty::program(&p2), printed, "case {case}");
    }
}

#[test]
fn prop_interpreter_deterministic() {
    let mut rng = Rng::new(202);
    for _ in 0..25 {
        let src = random_program(&mut rng);
        let p = cparse::parse(&src).unwrap();
        let run = || {
            let mut it = flopt::interp::Interp::new(&p);
            it.run_main().unwrap();
            it.read_array("stats_out").unwrap()
        };
        assert_eq!(run(), run());
    }
}

#[test]
fn prop_profile_counters_consistent() {
    let mut rng = Rng::new(303);
    for _ in 0..25 {
        let src = random_program(&mut rng);
        let p = cparse::parse(&src).unwrap();
        let prof = flopt::interp::profile_program(&p).unwrap();
        for (id, lp) in &prof.loops {
            assert!(lp.iterations >= lp.entries || lp.iterations == 0, "{id}");
            // footprint never exceeds traffic
            assert!(
                lp.footprint_bytes() <= lp.traffic_bytes().max(lp.footprint_bytes()),
                "{id}"
            );
            for fp in lp.footprints.values() {
                assert!(fp.min_idx <= fp.max_idx);
                assert!(fp.accesses > 0);
            }
        }
    }
}

#[test]
fn prop_top_a_monotone() {
    let mut rng = Rng::new(404);
    for _ in 0..20 {
        let src = random_program(&mut rng);
        let p = cparse::parse(&src).unwrap();
        let loops = flopt::ir::analyze(&p);
        let prof = flopt::interp::profile_program(&p).unwrap();
        let ints = intensity::analyze(&loops, &prof);
        let mut prev_len = 0;
        for a in 1..=6 {
            let top = intensity::top_a(&ints, &loops, a);
            assert!(top.len() >= prev_len, "top_a must grow with a");
            assert!(top.len() <= a);
            // ranking is by (intensity, flops) non-increasing
            for w in top.windows(2) {
                assert!(
                    w[0].intensity > w[1].intensity
                        || (w[0].intensity == w[1].intensity && w[0].flops >= w[1].flops)
                );
            }
            prev_len = top.len();
        }
    }
}

#[test]
fn prop_search_invariants_across_apps() {
    // full searches over the whole registry at test scale: structural
    // invariants hold regardless of app
    for app in flopt::apps::all() {
        let analysis = analyze_app(app, true).unwrap();
        for (a, c, d) in [(5, 3, 4), (2, 2, 2), (8, 5, 8), (1, 1, 1)] {
            let cfg = SearchConfig {
                a_intensity: a,
                c_efficiency: c,
                d_patterns: d,
                ..Default::default()
            };
            let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
            let t = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
            assert!(t.top_a.len() <= a);
            assert!(t.top_c.len() <= c);
            assert!(t.patterns_measured() <= d, "{}: d violated", app.name);
            assert!(t.top_c.iter().all(|x| t.top_a.contains(x)));
            // every measured pattern draws from top_c
            for round in &t.rounds {
                for m in round {
                    assert!(m.pattern.loops.iter().all(|l| t.top_c.contains(l)));
                    assert!(m.utilization >= ARRIA10_GX.bsp_frac - 1e-9);
                }
            }
            // round 2 never repeats a round-1 pattern
            if t.rounds.len() == 2 {
                for m2 in &t.rounds[1] {
                    assert!(t.rounds[0].iter().all(|m1| m1.pattern != m2.pattern));
                    assert!(m2.utilization <= cfg.resource_cap + 1e-9);
                }
            }
            // the solution is one of the measured patterns
            if let Some(best) = &t.best {
                assert!(t
                    .rounds
                    .iter()
                    .flatten()
                    .any(|m| m.pattern == best.pattern));
            }
        }
    }
}

// ---- JSON fuzz -----------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    if depth == 0 {
        return match rng.below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range_i64(-1000, 1000) as f64) / 4.0),
            _ => Json::Str(format!("s{}\n\"x\\", rng.below(100))),
        };
    }
    match rng.below(2) {
        0 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::new(505);
    for _ in 0..200 {
        let doc = random_json(&mut rng, 3);
        let text = json::to_string(&doc);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("{e}: {text}"));
        assert_eq!(back, doc, "{text}");
    }
}

#[test]
fn prop_json_rejects_random_garbage_without_panic() {
    let mut rng = Rng::new(606);
    for _ in 0..500 {
        let len = rng.below(24) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(94) + 32) as u8).collect();
        let s = String::from_utf8(bytes).unwrap();
        let _ = json::parse(&s); // must not panic
    }
}
