//! Golden-file suite (rsjsonnet-style): CLI output is locked against
//! files in `rust/tests/golden/`.
//!
//! Three goldens are **committed** and produced independently of the
//! Rust code they check (see `rust/tests/golden/gen_port.py`): the
//! `flopt gen` corpus for seed 42, the `flopt apps` table, and the
//! `flopt env` report.  A drift in the RNG, the generator's draw order,
//! or the emitted text fails against bytes Rust never wrote — the suite
//! cannot silently bless itself.
//!
//! The remaining goldens (`analyze`, `blocks`) hold model-driven
//! numbers that are impractical to hand-compute; they are blessed on
//! first run (or with `FLOPT_BLESS=1`) and lock the output from then
//! on.  See `rust/tests/golden/README.md` for the blessing workflow.

use std::path::PathBuf;
use std::process::Command;

use flopt::apps::gen;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// Compare `actual` against the golden file `name`.  Missing files are
/// written and accepted (first-run bless); `FLOPT_BLESS=1` forces a
/// rewrite.  Committed goldens always exist, so for them this is a
/// strict byte comparison.
fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var("FLOPT_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::write(&path, actual).unwrap_or_else(|e| panic!("blessing {name}: {e}"));
        if !bless {
            eprintln!("golden: blessed missing {name}");
        }
        return;
    }
    let expected =
        std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {name}: {e}"));
    assert_eq!(
        expected, actual,
        "golden mismatch for {name}; rerun with FLOPT_BLESS=1 to re-bless \
         (never re-bless gen_s42_n3.txt / apps.txt from Rust — regenerate \
         them with rust/tests/golden/gen_port.py instead)"
    );
}

/// Run the `flopt` binary and return its stdout.
fn flopt(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_flopt"))
        .args(args)
        .output()
        .unwrap_or_else(|e| panic!("spawning flopt {args:?}: {e}"));
    assert!(
        out.status.success(),
        "flopt {args:?} exited with {:?}\nstderr:\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("flopt output is UTF-8")
}

// ------------------------------------------------------- committed goldens

#[test]
fn gen_cli_matches_the_python_port_golden() {
    let stdout = flopt(&["gen", "--seed", "42", "--count", "3"]);
    assert!(
        golden_dir().join("gen_s42_n3.txt").exists(),
        "committed golden gen_s42_n3.txt is missing — regenerate with \
         rust/tests/golden/gen_port.py, do not bless from Rust"
    );
    check_golden("gen_s42_n3.txt", &stdout);
}

#[test]
fn gen_cli_output_equals_the_in_process_generator() {
    // the CLI is a plain print of gen_source with one blank separator
    // line; a drift here would make the golden pin the wrong layer
    let stdout = flopt(&["gen", "--seed", "42", "--count", "3"]);
    let expected: String = (0..3)
        .map(|i| gen::gen_source(42, i))
        .collect::<Vec<_>>()
        .join("\n");
    assert_eq!(expected, stdout);
}

#[test]
fn apps_cli_matches_the_committed_golden() {
    let stdout = flopt(&["apps"]);
    assert!(
        golden_dir().join("apps.txt").exists(),
        "committed golden apps.txt is missing — regenerate with \
         rust/tests/golden/gen_port.py, do not bless from Rust"
    );
    check_golden("apps.txt", &stdout);
}

#[test]
fn env_cli_matches_the_committed_golden() {
    // fully static output (Fig 3 testbed + device model lines), so it is
    // reproduced by the Python port rather than blessed from Rust
    let stdout = flopt(&["env"]);
    assert!(
        golden_dir().join("env.txt").exists(),
        "committed golden env.txt is missing — regenerate with \
         rust/tests/golden/gen_port.py, do not bless from Rust"
    );
    check_golden("env.txt", &stdout);
}

// ----------------------------------------------------- blessed-once goldens

#[test]
fn analyze_matmul_output_is_locked() {
    // test scale (the default), so trip counts and intensities are the
    // small deterministic profile
    check_golden("analyze_matmul.txt", &flopt(&["analyze", "matmul"]));
}

#[test]
fn blocks_tdfir_output_is_locked() {
    check_golden("blocks_tdfir.txt", &flopt(&["blocks", "tdfir"]));
}

/// Every registered app's `flopt explain` diagnostics (text and JSON)
/// are locked: the dependence engine's verdicts, the per-pair test that
/// decided each dependence, the optimistic notes, and the span anchors
/// may only change deliberately, with a re-bless.
#[test]
fn explain_output_is_locked_for_every_app() {
    for app in flopt::apps::all() {
        check_golden(
            &format!("explain_{}.txt", app.name),
            &flopt(&["explain", app.name]),
        );
        check_golden(
            &format!("explain_{}.json", app.name),
            &flopt(&["explain", app.name, "--json"]),
        );
    }
}

#[test]
fn explain_is_byte_identical_warm_and_cold() {
    let dir = std::env::temp_dir()
        .join(format!("flopt-golden-explain-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir = dir.to_str().expect("utf-8 temp path");
    // cold: computes and writes the cache; warm: served from disk
    let cold = flopt(&["explain", "tdfir", "--cache-dir", dir]);
    let warm = flopt(&["explain", "tdfir", "--cache-dir", dir]);
    assert_eq!(cold, warm, "warm explain must be byte-identical to cold");
    let cold_json = flopt(&["explain", "tdfir", "--json", "--cache-dir", dir]);
    let warm_json = flopt(&["explain", "tdfir", "--json", "--cache-dir", dir]);
    assert_eq!(cold_json, warm_json);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn explain_is_invariant_across_pool_widths() {
    let base = flopt(&["explain", "mriq"]);
    for pool in ["1", "2", "8"] {
        assert_eq!(
            base,
            flopt(&["explain", "mriq", "--pool", pool]),
            "--pool {pool} must not perturb explain output"
        );
    }
}

#[test]
fn blocks_fft_output_is_locked() {
    // locks the PR 6 detector arm: the butterfly nest must keep being
    // offered as the fft_butterfly registry block by both backends
    check_golden("blocks_fft.txt", &flopt(&["blocks", "fft"]));
}
