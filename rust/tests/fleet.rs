//! Fleet placement suite (ISSUE 5): no placement exceeds a board's
//! resource caps; the fleet aggregate never loses to all-CPU; output is
//! byte-identical across pool sizes 1/2/8 and warm cache re-runs; and a
//! NaN-poisoned measurement is rejected without panicking the run.

use flopt::apps;
use flopt::backend::{Destination, FPGA};
use flopt::cache::{self, codec, CacheStore};
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::analyze_app;
use flopt::coordinator::stages::{
    stage_block_narrow, stage_efficiency_narrow, stage_intensity_narrow, stage_measure_blocks,
    stage_measure_rounds, stage_precompile, stage_select, BlockMeasureArtifact, EfficiencyCut,
    IntensityCut, MeasureArtifact, PrecompileArtifact,
};
use flopt::coordinator::verify_env::{PatternMeasurement, VerifyEnv};
use flopt::cparse::ast::LoopId;
use flopt::cpu::XEON_3104;
use flopt::fleet::{self, first_fit_decreasing, tenant_from_trace, FleetStatus};
use flopt::fpga::ARRIA10_GX;
use flopt::funcblock::BlockMode;
use flopt::opencl::OffloadPattern;
use flopt::service::BatchService;

fn blocks_on() -> SearchConfig {
    SearchConfig { block_mode: BlockMode::On, ..SearchConfig::default() }
}

fn run_fleet(pool: usize, boards: usize, cfg: &SearchConfig) -> flopt::fleet::FleetReport {
    let svc = BatchService::new(pool, 1, &XEON_3104);
    let apps_list: Vec<&'static apps::App> = apps::all();
    fleet::fleet_search(&svc, &apps_list, boards, cfg, true).unwrap()
}

#[test]
fn no_placement_ever_exceeds_a_boards_resource_caps() {
    for boards in [1usize, 2, 8] {
        let cfg = blocks_on();
        let r = run_fleet(2, boards, &cfg);
        assert_eq!(r.board_util.len(), boards);
        for b in &r.board_util {
            assert!(
                b.utilization <= cfg.resource_cap + 1e-12,
                "board {} util {} exceeds the cap",
                b.board,
                b.utilization
            );
            // per-type caps: the dynamic region never outgrows the
            // non-BSP share of the device
            let avail = 1.0 - ARRIA10_GX.bsp_frac;
            assert!(b.resources.alms <= ARRIA10_GX.total.alms * avail);
            assert!(b.resources.ffs <= ARRIA10_GX.total.ffs * avail);
            assert!(b.resources.luts <= ARRIA10_GX.total.luts * avail);
            assert!(b.resources.dsps <= ARRIA10_GX.total.dsps * avail);
            assert!(b.resources.m20ks <= ARRIA10_GX.total.m20ks * avail);
        }
        // every placed app's row points at a real board
        for a in &r.apps {
            if let FleetStatus::Placed { board } = &a.status {
                assert!(*board < boards);
                assert!(a.speedup > 1.0, "{}: only improving placements admit", a.app_name);
            }
        }
    }
}

#[test]
fn fleet_aggregate_never_loses_to_all_cpu() {
    for cfg in [SearchConfig::default(), blocks_on()] {
        let r = run_fleet(2, 2, &cfg);
        assert!(
            r.aggregate_speedup >= 1.0,
            "aggregate {} must never lose to all-CPU",
            r.aggregate_speedup
        );
        assert!(r.cpu_total_s > 0.0);
        assert!(r.fleet_total_s <= r.cpu_total_s + 1e-12);
        for a in &r.apps {
            assert!(a.speedup >= 1.0, "{}: per-app never below CPU", a.app_name);
        }
        // at least one app should actually win a board at test scale
        assert!(
            r.apps.iter().any(|a| matches!(a.status, FleetStatus::Placed { .. })),
            "someone must place: {}",
            r.render()
        );
    }
}

#[test]
fn fleet_output_is_byte_identical_for_pool_sizes_1_2_8() {
    for boards in [1usize, 2, 8] {
        let renders: Vec<String> = [1usize, 2, 8]
            .iter()
            .map(|&pool| run_fleet(pool, boards, &blocks_on()).render())
            .collect();
        assert_eq!(renders[0], renders[1], "boards={boards}: pool 1 vs 2");
        assert_eq!(renders[0], renders[2], "boards={boards}: pool 1 vs 8");
    }
}

#[test]
fn warm_fleet_reruns_are_byte_identical_and_free() {
    let dir = std::env::temp_dir().join(format!(
        "flopt-fleet-warm-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = blocks_on();
    let apps_list: Vec<&'static apps::App> = apps::all();

    let cold_svc =
        BatchService::new(2, 1, &XEON_3104).with_cache(CacheStore::with_dir(&dir));
    let cold = fleet::fleet_search(&cold_svc, &apps_list, 2, &cfg, true).unwrap();

    // same service, warm in-memory hit
    let warm_mem = fleet::fleet_search(&cold_svc, &apps_list, 2, &cfg, true).unwrap();
    assert_eq!(warm_mem.render(), cold.render());
    assert_eq!(warm_mem, cold);

    // fresh service + fresh store over the same disk dir: warm from disk,
    // burning nothing on the new shared clock
    let warm_svc =
        BatchService::new(2, 1, &XEON_3104).with_cache(CacheStore::with_dir(&dir));
    let warm_disk = fleet::fleet_search(&warm_svc, &apps_list, 2, &cfg, true).unwrap();
    assert_eq!(warm_disk.render(), cold.render(), "disk-warm run must be bit-identical");
    assert_eq!(warm_disk, cold);
    assert_eq!(
        warm_svc.clock().total_hours(),
        0.0,
        "a fleet-report cache hit must not touch the clock"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Build a minimal compiled pattern measurement for selector tests.
fn pm(loops: &[u32], speedup: f64) -> PatternMeasurement {
    PatternMeasurement {
        pattern: OffloadPattern::of(loops.iter().map(|l| LoopId(*l)).collect()),
        utilization: 0.4,
        compiled: true,
        compile_sim_s: 3.0 * 3600.0,
        time_s: if speedup.is_nan() { f64::NAN } else { 1.0 / speedup },
        speedup,
        kernels: Vec::new(),
    }
}

fn empty_stage_inputs() -> (IntensityCut, PrecompileArtifact, EfficiencyCut) {
    (
        IntensityCut { top_a: Vec::new() },
        PrecompileArtifact { candidates: Vec::new() },
        EfficiencyCut { top_c: Vec::new() },
    )
}

#[test]
fn select_rejects_nan_and_is_byte_identical_across_repeats() {
    let analysis = analyze_app(&apps::MATMUL, true).unwrap();
    let (cut, pre, eff) = empty_stage_inputs();
    let meas = MeasureArtifact {
        cpu_time_s: 1.0,
        opencl: Vec::new(),
        // the poisoned measurement has the "highest" speedup slot (NaN)
        rounds: vec![vec![pm(&[1], f64::NAN), pm(&[2], 2.0), pm(&[3], 1.5)]],
    };
    let traces: Vec<String> = (0..3)
        .map(|_| {
            let t = stage_select(
                &analysis,
                Destination::Fpga,
                &cut,
                &pre,
                &eff,
                &meas,
                &BlockMeasureArtifact::empty(),
            );
            codec::trace_to_string(&t)
        })
        .collect();
    assert_eq!(traces[0], traces[1]);
    assert_eq!(traces[0], traces[2]);
    let t = stage_select(
        &analysis,
        Destination::Fpga,
        &cut,
        &pre,
        &eff,
        &meas,
        &BlockMeasureArtifact::empty(),
    );
    let best = t.best.expect("a finite pattern must win");
    assert_eq!(best.pattern, OffloadPattern::single(LoopId(2)), "NaN never wins");
    assert!(best.speedup.is_finite());
}

#[test]
fn equal_speedup_ties_break_on_pattern_id_not_iteration_order() {
    let analysis = analyze_app(&apps::MATMUL, true).unwrap();
    let (cut, pre, eff) = empty_stage_inputs();
    let fwd = MeasureArtifact {
        cpu_time_s: 1.0,
        opencl: Vec::new(),
        rounds: vec![vec![pm(&[5], 2.0), pm(&[3], 2.0)]],
    };
    let rev = MeasureArtifact {
        cpu_time_s: 1.0,
        opencl: Vec::new(),
        rounds: vec![vec![pm(&[3], 2.0), pm(&[5], 2.0)]],
    };
    for meas in [&fwd, &rev] {
        let t = stage_select(
            &analysis,
            Destination::Fpga,
            &cut,
            &pre,
            &eff,
            meas,
            &BlockMeasureArtifact::empty(),
        );
        assert_eq!(
            t.best.unwrap().pattern,
            OffloadPattern::single(LoopId(3)),
            "the tie must go to the smaller pattern id in either order"
        );
    }
}

#[test]
fn nan_poisoned_block_measurement_is_rejected_through_block_stages() {
    let cfg = blocks_on();
    let analysis = analyze_app(&apps::TDFIR, true).unwrap();
    let cut = stage_intensity_narrow(&analysis, &FPGA, cfg.a_intensity);
    let pre = stage_precompile(&analysis, &cut, &FPGA, cfg.b_unroll);
    let eff = stage_efficiency_narrow(&pre, cfg.c_efficiency);
    let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
    let meas = stage_measure_rounds(&analysis, &pre, &eff, &env, &cfg);
    let offers = stage_block_narrow(&analysis, &FPGA, &XEON_3104, BlockMode::On);
    assert!(!offers.offers.is_empty(), "tdfir has registry blocks");
    let mut blocks = stage_measure_blocks(&analysis, &pre, &meas, &offers, &env, &cfg);
    assert!(!blocks.placements.is_empty());

    // poison every block placement: the selector must fall back to the
    // loop-pattern side without panicking, deterministically
    for p in &mut blocks.placements {
        p.speedup = f64::NAN;
        p.time_s = f64::NAN;
    }
    let s1 = {
        let t = stage_select(&analysis, Destination::Fpga, &cut, &pre, &eff, &meas, &blocks);
        assert!(t.best_block.is_none() || t.best_block.as_ref().unwrap().speedup.is_finite());
        assert!(
            !t.solution_is_block(),
            "a poisoned block side can never be the solution"
        );
        assert!(t.best.is_some(), "the loop side still wins");
        codec::trace_to_string(&t)
    };
    let s2 = {
        let t = stage_select(&analysis, Destination::Fpga, &cut, &pre, &eff, &meas, &blocks);
        codec::trace_to_string(&t)
    };
    assert_eq!(s1, s2, "poisoned selection must stay byte-identical");
}

#[test]
fn nan_poisoned_trace_degrades_to_cpu_and_the_fleet_run_completes() {
    // obtain a genuine trace, then poison its winner end to end
    let svc = BatchService::new(2, 1, &XEON_3104);
    let apps_list: Vec<&'static apps::App> = vec![&apps::TDFIR, &apps::MATMUL];
    fleet::fleet_search(&svc, &apps_list, 2, &SearchConfig::default(), true).unwrap();
    let tkey = cache::trace_key(&apps::TDFIR, true, &FPGA, &SearchConfig::default());
    let mut poisoned = svc.cache().get_trace(tkey).expect("trace cached");
    if let Some(best) = &mut poisoned.best {
        best.speedup = f64::NAN;
        best.time_s = f64::NAN;
    }
    poisoned.best_block = None;

    let healthy_key = cache::trace_key(&apps::MATMUL, true, &FPGA, &SearchConfig::default());
    let healthy = svc.cache().get_trace(healthy_key).expect("trace cached");

    let demands = vec![
        tenant_from_trace(&poisoned, FPGA.device, 0),
        tenant_from_trace(&healthy, FPGA.device, 1),
    ];
    assert!(demands[0].options.is_empty(), "poisoned winner must be rejected");
    let outcome = first_fit_decreasing(&demands, 2, 0.85, &ARRIA10_GX);
    let report = fleet::report::build(&demands, &outcome, 2, &ARRIA10_GX, 1.0, 1.0);
    assert_eq!(report.apps[0].status, FleetStatus::Cpu, "poisoned tenant stays on CPU");
    assert_eq!(report.apps[0].speedup, 1.0);
    assert!(
        matches!(report.apps[1].status, FleetStatus::Placed { .. }),
        "the healthy tenant still places: {}",
        report.render()
    );
    assert!(report.aggregate_speedup >= 1.0);
}
