//! Function-block offloading subsystem: pinned acceptance invariants.
//!
//! * Combined loop+block search (`--blocks on`) is **never worse** than
//!   loop-only search, for every registered app on both backends.
//! * The structural detector finds the FIR block in tdfir, the
//!   accumulation block in matmul, and the PR 6 families (fft's
//!   butterfly, spmv's gather, nbody's pair nest); it rejects the
//!   boundary-guarded stencils (laplace2d, stencil3d) — per backend,
//!   no IP offer is quoted for those.
//! * A warm cached re-run of a `--blocks on` search is bit-identical
//!   and burns zero new compile-lane hours.

use flopt::apps::{self, App};
use flopt::backend::{OffloadBackend, FPGA, GPU};
use flopt::cache::codec;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, offload_search, SearchTrace};
use flopt::coordinator::stages::stage_block_narrow;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::ast::LoopId;
use flopt::cpu::XEON_3104;
use flopt::funcblock::{self, BlockMode};
use flopt::ir;

fn cfg_with(mode: BlockMode) -> SearchConfig {
    SearchConfig { block_mode: mode, ..SearchConfig::default() }
}

fn search(app: &App, backend: &'static dyn OffloadBackend, mode: BlockMode) -> SearchTrace {
    let env = VerifyEnv::new(backend, &XEON_3104, cfg_with(mode));
    offload_search(app, &env, true).expect("search")
}

#[test]
fn combined_search_never_loses_to_loop_only() {
    for app in apps::all() {
        for backend in [&FPGA as &'static dyn OffloadBackend, &GPU] {
            let loop_only = search(app, backend, BlockMode::Off);
            let combined = search(app, backend, BlockMode::On);
            assert!(
                combined.speedup() >= loop_only.speedup(),
                "{} on {}: combined {} < loop-only {}",
                app.name,
                backend.name(),
                combined.speedup(),
                loop_only.speedup()
            );
            // the loop-statement side of the combined search is the
            // loop-only search, bit for bit
            assert_eq!(combined.top_a, loop_only.top_a, "{}", app.name);
            assert_eq!(combined.top_c, loop_only.top_c, "{}", app.name);
            assert_eq!(combined.rounds.len(), loop_only.rounds.len());
            assert_eq!(
                combined.best.as_ref().map(|b| b.speedup),
                loop_only.best.as_ref().map(|b| b.speedup),
                "{}",
                app.name
            );
        }
    }
}

#[test]
fn detector_finds_fir_in_tdfir() {
    let loops = ir::analyze(&apps::TDFIR.parse());
    let blocks = funcblock::detect(&loops);
    let fir = blocks
        .iter()
        .find(|b| b.root == LoopId(8))
        .expect("the hot FIR nest must be detected");
    assert_eq!(fir.name, "fir_filter");
    assert_eq!(fir.loops, vec![LoopId(8), LoopId(9)]);
}

#[test]
fn detector_finds_accumulation_block_in_matmul() {
    let loops = ir::analyze(&apps::MATMUL.parse());
    let blocks = funcblock::detect(&loops);
    let mm = blocks
        .iter()
        .find(|b| b.name == "dense_matmul")
        .expect("the i/j/k accumulation nest must be detected");
    assert_eq!(mm.root, LoopId(1));
    assert_eq!(mm.loops, vec![LoopId(1), LoopId(2), LoopId(3)]);
}

#[test]
fn detector_classifies_the_new_corpus_families() {
    // fft: the butterfly 2-nest (strided cross-read pairs, no scalar
    // accumulator) is the fft_butterfly registry block
    let loops = ir::analyze(&apps::FFT.parse());
    let b = funcblock::detect(&loops)
        .into_iter()
        .find(|b| b.root == LoopId(2))
        .expect("fft butterfly nest must be detected");
    assert_eq!(b.name, "fft_butterfly");
    assert_eq!(b.loops, vec![LoopId(2), LoopId(3)]);

    // spmv: the row×nnz gather-accumulate nest is the spmv_csr block
    let loops = ir::analyze(&apps::SPMV.parse());
    let b = funcblock::detect(&loops)
        .into_iter()
        .find(|b| b.root == LoopId(4))
        .expect("spmv gather nest must be detected");
    assert_eq!(b.name, "spmv_csr");
    assert_eq!(b.loops, vec![LoopId(4), LoopId(5)]);

    // nbody: the guarded all-pairs nest is the nbody_pair block
    let loops = ir::analyze(&apps::NBODY.parse());
    let b = funcblock::detect(&loops)
        .into_iter()
        .find(|b| b.root == LoopId(1))
        .expect("nbody pair nest must be detected");
    assert_eq!(b.name, "nbody_pair");
    assert_eq!(b.loops, vec![LoopId(1), LoopId(2)]);
}

#[test]
fn stencil3d_is_pinned_negative_space() {
    // the 4-deep guarded Jacobi sweep matches nothing in the registry —
    // same pinned negative as laplace2d, one dimension deeper
    let loops = ir::analyze(&apps::STENCIL3D.parse());
    assert!(
        funcblock::detect(&loops).is_empty(),
        "stencil3d must not match any registry block"
    );
    let analysis = analyze_app(&apps::STENCIL3D, true).unwrap();
    for backend in [&FPGA as &'static dyn OffloadBackend, &GPU] {
        let offers = stage_block_narrow(&analysis, backend, &XEON_3104, BlockMode::On);
        assert!(
            offers.offers.is_empty(),
            "{} must quote no IP for stencil3d",
            backend.name()
        );
        let t = search(&apps::STENCIL3D, backend, BlockMode::On);
        assert!(t.blocks.is_empty(), "{}: no false-positive placements", backend.name());
        assert!(t.best_block.is_none());
    }
}

#[test]
fn fft_fpga_butterfly_block_is_measured_and_beats_cpu() {
    let t = search(&apps::FFT, &FPGA, BlockMode::On);
    let b = t
        .blocks
        .iter()
        .find(|m| m.block == "fft_butterfly" && m.block_loops.contains(&LoopId(2)))
        .expect("the butterfly placement must be measured");
    assert!(b.compiled);
    assert!(b.speedup > 1.0, "the butterfly IP must beat all-CPU: {}", b.speedup);
}

#[test]
fn nbody_is_the_family_where_the_gpu_library_core_is_faster() {
    // the registry models the tiled SIMT n-body kernel as the one IP
    // that out-runs its FPGA counterpart (the mixed placement layer
    // gets a real GPU-vs-FPGA decision); both still place and beat CPU
    let entry = funcblock::entry_for("nbody_pair").expect("registered");
    let f = entry.for_destination(flopt::backend::Destination::Fpga).unwrap();
    let g = entry.for_destination(flopt::backend::Destination::Gpu).unwrap();
    assert!(
        g.speedup_vs_cpu > f.speedup_vs_cpu,
        "GPU core ({}) must out-run the FPGA core ({}) for nbody_pair",
        g.speedup_vs_cpu,
        f.speedup_vs_cpu
    );
    for backend in [&FPGA as &'static dyn OffloadBackend, &GPU] {
        let t = search(&apps::NBODY, backend, BlockMode::Only);
        let best = t.best_block.as_ref().expect("pair core must place");
        assert!(
            best.speedup > 1.0,
            "{}: pair core must beat all-CPU: {}",
            backend.name(),
            best.speedup
        );
    }
}

#[test]
fn laplace2d_rejected_per_backend() {
    // detector level: the boundary-guarded stencil matches no registry
    // block at all
    let loops = ir::analyze(&apps::LAPLACE2D.parse());
    assert!(
        funcblock::detect(&loops).is_empty(),
        "laplace2d must not match any registry block"
    );
    // backend level: neither backend quotes an offer, and a blocks-on
    // search measures no block placement
    let analysis = analyze_app(&apps::LAPLACE2D, true).unwrap();
    for backend in [&FPGA as &'static dyn OffloadBackend, &GPU] {
        let offers = stage_block_narrow(&analysis, backend, &XEON_3104, BlockMode::On);
        assert!(
            offers.offers.is_empty(),
            "{} must quote no IP for laplace2d",
            backend.name()
        );
        let t = search(&apps::LAPLACE2D, backend, BlockMode::On);
        assert!(t.blocks.is_empty(), "{}: no false-positive placements", backend.name());
        assert!(t.best_block.is_none());
    }
}

#[test]
fn tdfir_fpga_block_replacement_is_measured_and_wins_or_ties() {
    let t = search(&apps::TDFIR, &FPGA, BlockMode::On);
    assert_eq!(t.block_mode, BlockMode::On);
    assert!(!t.blocks.is_empty(), "tdfir must measure block placements");
    let fir = t
        .blocks
        .iter()
        .find(|m| m.block == "fir_filter" && m.block_loops.contains(&LoopId(8)))
        .expect("the FIR placement must be measured");
    assert!(fir.compiled);
    assert!(fir.compile_sim_s < 3600.0, "prebuilt IP links in minutes");
    assert!(fir.speedup > 1.0, "the FIR IP must beat all-CPU: {}", fir.speedup);
    // combined never loses; here the hand-tuned core should strictly win
    let loop_only = search(&apps::TDFIR, &FPGA, BlockMode::Off);
    assert!(
        t.speedup() > loop_only.speedup(),
        "FIR IP ({}) must beat the generated loop kernel ({})",
        t.speedup(),
        loop_only.speedup()
    );
    assert!(t.solution_is_block());
    let rendered = t.render();
    assert!(rendered.contains("block placements"), "{rendered}");
    assert!(rendered.contains("solution: block fir_filter"), "{rendered}");
}

#[test]
fn histogram_scatter_is_unlocked_by_blocks() {
    // the histogram fill is NOT loop-offloadable (data-dependent writes)
    // but the registry's banked-bin core handles the whole block — the
    // scenario the loop-only pipeline cannot express
    let t = search(&apps::HISTOGRAM, &FPGA, BlockMode::On);
    let hist = t
        .blocks
        .iter()
        .find(|m| m.block == "histogram_bin")
        .expect("the scatter block must be measured");
    assert!(hist.compiled);
    assert!(hist.block_loops.contains(&LoopId(3)));
}

#[test]
fn warm_blocks_on_rerun_is_bit_identical_with_zero_new_compile_hours() {
    let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg_with(BlockMode::On));
    let t1 = offload_search(&apps::TDFIR, &env, true).unwrap();
    let total = env.clock.total_seconds();
    let lanes = env.clock.compile_lane_seconds();
    assert!(total > 0.0 && lanes > 0.0, "cold run must charge");

    let t2 = offload_search(&apps::TDFIR, &env, true).unwrap();
    assert_eq!(
        env.clock.total_seconds(),
        total,
        "warm re-run must burn zero simulated time"
    );
    assert_eq!(
        env.clock.compile_lane_seconds(),
        lanes,
        "warm re-run must burn zero compile-lane hours"
    );
    assert_eq!(
        codec::trace_to_string(&t1),
        codec::trace_to_string(&t2),
        "warm trace must be bit-identical"
    );
}

#[test]
fn blocks_only_mode_skips_loop_candidates() {
    let t = search(&apps::TDFIR, &FPGA, BlockMode::Only);
    assert_eq!(t.block_mode, BlockMode::Only);
    assert!(t.candidates.is_empty(), "no loop pre-compiles under --blocks only");
    assert_eq!(t.rounds.iter().map(|r| r.len()).sum::<usize>(), 0);
    assert!(t.best.is_none());
    let best = t.best_block.as_ref().expect("a block must be placed");
    assert!(best.speedup > 1.0);
    assert!(
        t.compile_hours < 1.0,
        "prebuilt IP search must be nearly compile-free: {} h",
        t.compile_hours
    );
    // the loop-only flow pays hours-scale compiles for the same app
    let loop_only = search(&apps::TDFIR, &FPGA, BlockMode::Off);
    assert!(loop_only.compile_hours > 5.0);
}

#[test]
fn gpu_ga_flow_carries_blocks_through_destination_search() {
    use flopt::coordinator::mixed::ga_destination_search;
    let analysis = analyze_app(&apps::MATMUL, true).unwrap();
    let cfg = cfg_with(BlockMode::Only);
    let env = VerifyEnv::new(&GPU, &XEON_3104, cfg.clone());
    let ds = ga_destination_search(&analysis, &env, &cfg);
    assert_eq!(ds.method, "ip-registry", "--blocks only never runs the GA");
    assert!(ds.patterns_measured >= 1, "block placements count as measurements");
    let best = ds.best.as_ref().expect("cuBLAS block must place");
    assert!(best.pattern.loops.contains(&LoopId(1)), "{:?}", best.pattern);
    assert!(best.kernels.is_empty(), "an IP placement has no per-kernel breakdown");
}

#[test]
fn batch_service_dedupes_and_warms_blocks_on_requests() {
    use flopt::backend::Target;
    use flopt::service::{BatchRequest, BatchService};
    let cfg = cfg_with(BlockMode::On);
    let req = |target| BatchRequest {
        app: &apps::MATMUL,
        target,
        cfg: cfg.clone(),
        test_scale: true,
    };
    let svc = BatchService::new(2, 1, &XEON_3104);
    let first = svc
        .run(&[req(Target::Fpga), req(Target::Gpu), req(Target::Fpga)])
        .unwrap();
    assert_eq!(first.unique_cold, 2);
    assert_eq!(first.deduped, 1);
    let second = svc
        .run(&[req(Target::Fpga), req(Target::Gpu)])
        .unwrap();
    assert_eq!(second.warm_hits, 2, "blocks-on requests must warm-hit");
    assert_eq!(second.compile_hours, 0.0);
    for (a, b) in first.items.iter().zip(&second.items) {
        assert_eq!(a.outcome.speedup, b.outcome.speedup);
    }
}
