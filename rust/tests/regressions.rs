//! Minimized reproducers for bugs the generative fuzzer surfaced while
//! building the property suite (`rust/tests/generative.rs`).  Each
//! fixture under `rust/tests/fixtures/` is one shrunk program; the
//! tests pin both the analysis verdict that was wrong and that the
//! end-to-end search still completes on the program.
//!
//! The `fixtures/deps/` set pins the dependence engine
//! (`rust/src/analyze/`) instead: one minimized program per verdict
//! class — flow/anti/output carried dependences, GCD-provable
//! independence, SIV distance vectors, aliased vs distinct arrays, and
//! an oracle-confirmed reduction — each checked against both the static
//! verdict (which test fired, which fact was recorded) and the dynamic
//! oracle's observed conflicts.

use flopt::apps::gen::leak_app;
use flopt::backend;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::parse;
use flopt::cpu::XEON_3104;
use flopt::funcblock;
use flopt::ir;

const SCATTER: &str = include_str!("fixtures/scatter_through_index_array.mc");
const PREFIX_SUM: &str = include_str!("fixtures/prefix_sum_store.mc");
const COUNTER_STEP: &str = include_str!("fixtures/counter_step_not_accumulator.mc");

const DEP_FLOW: &str = include_str!("fixtures/deps/flow_carried.mc");
const DEP_ANTI: &str = include_str!("fixtures/deps/anti_carried.mc");
const DEP_OUTPUT: &str = include_str!("fixtures/deps/output_carried.mc");
const DEP_GCD: &str = include_str!("fixtures/deps/gcd_independent.mc");
const DEP_SIV: &str = include_str!("fixtures/deps/siv_distance.mc");
const DEP_ALIAS: &str = include_str!("fixtures/deps/alias_distinct.mc");
const DEP_REDUCTION: &str = include_str!("fixtures/deps/oracle_reduction.mc");

fn reject_reason(src: &str, loop_index: usize) -> String {
    let program = parse(src).expect("fixture parses");
    let loops = ir::analyze(&program);
    let l = &loops[loop_index];
    assert!(
        !l.deps.offloadable,
        "{} must not be offloadable",
        l.info.id
    );
    l.deps.reject_reason.expect("rejects carry a reason").to_string()
}

#[test]
fn scatter_through_index_array_is_rejected_as_data_dependent() {
    // the write index `vals[j]` mentions the counter, which used to be
    // enough to pass rule 3 — the subscript values are data, though
    let reason = reject_reason(SCATTER, 1);
    assert!(
        reason.contains("data-dependent"),
        "wrong reject reason: {reason}"
    );
}

#[test]
fn scatter_fixture_still_reads_as_a_histogram_block() {
    // rejecting the loop for LOOP offloading must not hide it from the
    // BLOCK detector — the registry histogram core handles the scatter
    let program = parse(SCATTER).expect("fixture parses");
    let loops = ir::analyze(&program);
    let blocks = funcblock::detect(&loops);
    assert!(
        blocks
            .iter()
            .any(|b| b.name == funcblock::detect::HISTOGRAM_BIN),
        "expected a histogram block, got {:?}",
        blocks.iter().map(|b| b.name).collect::<Vec<_>>()
    );
}

#[test]
fn prefix_sum_store_is_rejected_as_consumed_reduction() {
    // `t = t + a[j]` matches the reduction form but `pre[j] = t` makes
    // the loop order-dependent — the recognizer used to accept it
    let reason = reject_reason(PREFIX_SUM, 1);
    assert!(reason.contains("consumed"), "wrong reject reason: {reason}");
}

#[test]
fn counter_step_is_not_an_accumulator() {
    // `Stmt::walk` visits nested `for` headers, so the inner `k++` step
    // used to register as a scalar accumulator; `accumulations == 0`
    // was unsatisfiable and this butterfly misfiled as fir_filter
    let program = parse(COUNTER_STEP).expect("fixture parses");
    let loops = ir::analyze(&program);
    let blocks = funcblock::detect(&loops);
    let names: Vec<&str> = blocks.iter().map(|b| b.name).collect();
    assert_eq!(names, vec![funcblock::detect::FFT_BUTTERFLY]);
    assert_eq!(blocks[0].signature.accumulations, 0, "{:?}", blocks[0].signature);
}

// ------------------------------------------------- dependence-engine pins

use flopt::analyze::{DepClass, DepTest, LoopDeps, LoopVerdict, NoteKind, RejectReason};
use flopt::cparse::ast::LoopId;
use flopt::interp::LoopConflicts;

/// Engine verdicts for every loop of a fixture, in extraction order.
fn engine_deps(src: &str) -> Vec<LoopDeps> {
    let program = parse(src).expect("fixture parses");
    flopt::analyze::explain_program("fixture", &program)
        .loops
        .into_iter()
        .map(|l| l.deps)
        .collect()
}

/// Run a fixture under the instrumented interpreter and return every
/// loop with an observed carried conflict.
fn oracle_report(src: &str) -> Vec<(LoopId, LoopConflicts)> {
    let program = parse(src).expect("fixture parses");
    let mut it = flopt::interp::Interp::new(&program);
    it.enable_oracle(&program);
    it.run_main().expect("fixture runs");
    it.oracle_report()
}

#[test]
fn flow_carried_fixture_is_sequential_by_strong_siv() {
    let deps = engine_deps(DEP_FLOW);
    assert_eq!(
        deps[0].verdict,
        LoopVerdict::Sequential(RejectReason::ReadWriteMismatch)
    );
    assert_eq!(deps[0].deps.len(), 1);
    assert_eq!(deps[0].deps[0].class, DepClass::FlowAnti);
    assert_eq!(deps[0].deps[0].test, DepTest::SivStrong);
}

#[test]
fn anti_carried_fixture_serializes_only_the_update_loop() {
    let deps = engine_deps(DEP_ANTI);
    assert_eq!(deps[0].verdict, LoopVerdict::Parallel, "init sweep");
    assert_eq!(
        deps[1].verdict,
        LoopVerdict::Sequential(RejectReason::ReadWriteMismatch)
    );
    assert_eq!(deps[1].deps[0].class, DepClass::FlowAnti);
    assert_eq!(deps[1].deps[0].test, DepTest::SivStrong);
}

#[test]
fn output_overlap_fixture_is_rejected_as_write_write() {
    let deps = engine_deps(DEP_OUTPUT);
    assert_eq!(deps[0].verdict, LoopVerdict::Sequential(RejectReason::WwOverlap));
    assert_eq!(deps[0].deps[0].class, DepClass::Output);
    assert_eq!(deps[0].deps[0].test, DepTest::SivStrong);
}

#[test]
fn gcd_fixture_is_proved_parallel() {
    let deps = engine_deps(DEP_GCD);
    assert_eq!(deps[1].verdict, LoopVerdict::Parallel);
    assert_eq!(deps[1].tests.get(&DepTest::Gcd), Some(&1), "{:?}", deps[1].tests);
    assert!(deps[1]
        .notes
        .iter()
        .any(|n| n.kind == NoteKind::ReadProvedIndependent));
}

#[test]
fn siv_distance_fixture_splits_on_the_distance() {
    let deps = engine_deps(DEP_SIV);
    // distance 2 within the trip width: carried
    assert_eq!(
        deps[0].verdict,
        LoopVerdict::Sequential(RejectReason::ReadWriteMismatch)
    );
    assert_eq!(deps[0].deps[0].test, DepTest::SivStrong);
    // distance 100 beyond width 49: provably disjoint
    assert_eq!(deps[1].verdict, LoopVerdict::Parallel);
    assert_eq!(deps[1].tests.get(&DepTest::SivStrong), Some(&1));
}

#[test]
fn alias_fixture_distinct_arrays_do_not_alias() {
    let deps = engine_deps(DEP_ALIAS);
    // same subscript pattern, distinct arrays: no pair to test at all
    assert_eq!(deps[1].verdict, LoopVerdict::Parallel);
    assert!(deps[1].tests.is_empty(), "{:?}", deps[1].tests);
    // ...and the aliased version of the same pattern is carried
    assert_eq!(
        deps[2].verdict,
        LoopVerdict::Sequential(RejectReason::ReadWriteMismatch)
    );
}

#[test]
fn reduction_fixture_is_oracle_confirmed() {
    let deps = engine_deps(DEP_REDUCTION);
    assert!(
        matches!(&deps[1].verdict, LoopVerdict::Reduction(vars) if vars.len() == 1),
        "{:?}",
        deps[1].verdict
    );
    assert_eq!(deps[1].reductions[0].var, "s");
    // the oracle sees conflicts on the accumulator and on nothing else
    let report = oracle_report(DEP_REDUCTION);
    assert_eq!(report.len(), 1, "{report:?}");
    assert_eq!(report[0].0, LoopId(1));
    assert!(report[0].1.arrays.is_empty(), "{report:?}");
    assert_eq!(report[0].1.scalars.len(), 1);
}

#[test]
fn oracle_agrees_with_every_carried_fixture_verdict() {
    // each statically-sequential loop must show a real observed conflict
    // on `a` (the oracle is ground truth, not a formality), and each
    // statically-parallel loop must stay clean
    for (name, src, carried) in [
        ("flow", DEP_FLOW, LoopId(0)),
        ("anti", DEP_ANTI, LoopId(1)),
        ("output", DEP_OUTPUT, LoopId(0)),
        ("siv", DEP_SIV, LoopId(0)),
        ("alias", DEP_ALIAS, LoopId(2)),
    ] {
        let report = oracle_report(src);
        assert_eq!(report.len(), 1, "{name}: {report:?}");
        assert_eq!(report[0].0, carried, "{name}: {report:?}");
        assert!(!report[0].1.arrays.is_empty(), "{name}: {report:?}");
    }
    for (name, src) in [("gcd", DEP_GCD)] {
        assert!(oracle_report(src).is_empty(), "{name} must be clean");
    }
}

#[test]
fn pr6_soundness_fixtures_are_rejected_by_the_engine_itself() {
    // the three PR-6 bugs must now be caught by the dependence engine's
    // own verdicts (typed RejectReason), not by legacy special-cases
    let scatter = engine_deps(SCATTER);
    assert_eq!(
        scatter[1].verdict,
        LoopVerdict::Sequential(RejectReason::DataDependentWriteIndex)
    );
    let prefix = engine_deps(PREFIX_SUM);
    assert_eq!(
        prefix[1].verdict,
        LoopVerdict::Sequential(RejectReason::ReductionConsumed)
    );
    // counter-as-accumulator: no loop of the butterfly nest may report
    // a spurious reduction on an induction variable
    let counter = engine_deps(COUNTER_STEP);
    assert!(
        counter.iter().all(|d| d.reductions.is_empty()),
        "{:?}",
        counter.iter().map(|d| &d.reductions).collect::<Vec<_>>()
    );
}

#[test]
fn fixtures_run_under_the_interpreter() {
    for (name, src) in [
        ("scatter", SCATTER),
        ("prefix_sum", PREFIX_SUM),
        ("counter_step", COUNTER_STEP),
    ] {
        let app = leak_app(format!("fixture-{name}"), src.to_string());
        let program = app.parse();
        let mut it = app.interp(&program, true);
        it.run_main().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn deep_nesting_runs_on_a_tiny_thread_stack() {
    // 500 nested blocks each bumping a counter, plus a 500-deep
    // parenthesized sum.  The old recursive evaluator burned a host
    // stack frame per nesting level and overflowed far shallower than
    // this; the iterative machine keeps its continuation/operand stacks
    // on the heap, so execution must complete on a 64 KiB thread stack.
    // Parsing and lowering still recurse over the AST, so they get a
    // deliberately roomy stack — only `run_main` moves to the tiny one.
    const DEPTH: usize = 500;
    let src = flopt::apps::gen::deep_source(DEPTH);
    std::thread::Builder::new()
        .name("deep-parse".into())
        .stack_size(32 * 1024 * 1024)
        .spawn(move || {
            let program = parse(&src).expect("deep fixture parses");
            let mut it = flopt::interp::Interp::new(&program);
            let out = std::thread::scope(|s| {
                std::thread::Builder::new()
                    .name("tiny-stack-eval".into())
                    .stack_size(64 * 1024)
                    .spawn_scoped(s, move || {
                        it.run_main().expect("deep program runs");
                        it.read_array("out").expect("out array")
                    })
                    .expect("spawn tiny-stack thread")
                    .join()
                    .expect("evaluation must not overflow 64 KiB")
            });
            assert_eq!(out, vec![DEPTH as f64, (DEPTH + 1) as f64]);
        })
        .expect("spawn parse thread")
        .join()
        .expect("deep-nest fixture");
}

#[test]
fn search_completes_end_to_end_on_both_fixtures() {
    // neither fixture may panic the pipeline; whatever wins (a block
    // offer or staying on the CPU) must never lose to all-CPU
    for (name, src) in [
        ("scatter", SCATTER),
        ("prefix_sum", PREFIX_SUM),
        ("counter_step", COUNTER_STEP),
    ] {
        let app = leak_app(format!("fixture-e2e-{name}"), src.to_string());
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, cfg.clone());
        let trace =
            offload_search(app, &env, true).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            trace.speedup() >= 1.0 - 1e-9,
            "{name}: search result {}x loses to all-CPU",
            trace.speedup()
        );
    }
}
