//! Minimized reproducers for bugs the generative fuzzer surfaced while
//! building the property suite (`rust/tests/generative.rs`).  Each
//! fixture under `rust/tests/fixtures/` is one shrunk program; the
//! tests pin both the analysis verdict that was wrong and that the
//! end-to-end search still completes on the program.

use flopt::apps::gen::leak_app;
use flopt::backend;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::parse;
use flopt::cpu::XEON_3104;
use flopt::funcblock;
use flopt::ir;

const SCATTER: &str = include_str!("fixtures/scatter_through_index_array.mc");
const PREFIX_SUM: &str = include_str!("fixtures/prefix_sum_store.mc");
const COUNTER_STEP: &str = include_str!("fixtures/counter_step_not_accumulator.mc");

fn reject_reason(src: &str, loop_index: usize) -> String {
    let program = parse(src).expect("fixture parses");
    let loops = ir::analyze(&program);
    let l = &loops[loop_index];
    assert!(
        !l.deps.offloadable,
        "{} must not be offloadable",
        l.info.id
    );
    l.deps.reject_reason.clone().expect("rejects carry a reason")
}

#[test]
fn scatter_through_index_array_is_rejected_as_data_dependent() {
    // the write index `vals[j]` mentions the counter, which used to be
    // enough to pass rule 3 — the subscript values are data, though
    let reason = reject_reason(SCATTER, 1);
    assert!(
        reason.contains("data-dependent"),
        "wrong reject reason: {reason}"
    );
}

#[test]
fn scatter_fixture_still_reads_as_a_histogram_block() {
    // rejecting the loop for LOOP offloading must not hide it from the
    // BLOCK detector — the registry histogram core handles the scatter
    let program = parse(SCATTER).expect("fixture parses");
    let loops = ir::analyze(&program);
    let blocks = funcblock::detect(&loops);
    assert!(
        blocks
            .iter()
            .any(|b| b.name == funcblock::detect::HISTOGRAM_BIN),
        "expected a histogram block, got {:?}",
        blocks.iter().map(|b| b.name).collect::<Vec<_>>()
    );
}

#[test]
fn prefix_sum_store_is_rejected_as_consumed_reduction() {
    // `t = t + a[j]` matches the reduction form but `pre[j] = t` makes
    // the loop order-dependent — the recognizer used to accept it
    let reason = reject_reason(PREFIX_SUM, 1);
    assert!(reason.contains("consumed"), "wrong reject reason: {reason}");
}

#[test]
fn counter_step_is_not_an_accumulator() {
    // `Stmt::walk` visits nested `for` headers, so the inner `k++` step
    // used to register as a scalar accumulator; `accumulations == 0`
    // was unsatisfiable and this butterfly misfiled as fir_filter
    let program = parse(COUNTER_STEP).expect("fixture parses");
    let loops = ir::analyze(&program);
    let blocks = funcblock::detect(&loops);
    let names: Vec<&str> = blocks.iter().map(|b| b.name).collect();
    assert_eq!(names, vec![funcblock::detect::FFT_BUTTERFLY]);
    assert_eq!(blocks[0].signature.accumulations, 0, "{:?}", blocks[0].signature);
}

#[test]
fn fixtures_run_under_the_interpreter() {
    for (name, src) in [
        ("scatter", SCATTER),
        ("prefix_sum", PREFIX_SUM),
        ("counter_step", COUNTER_STEP),
    ] {
        let app = leak_app(format!("fixture-{name}"), src.to_string());
        let program = app.parse();
        let mut it = app.interp(&program, true);
        it.run_main().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn deep_nesting_runs_on_a_tiny_thread_stack() {
    // 500 nested blocks each bumping a counter, plus a 500-deep
    // parenthesized sum.  The old recursive evaluator burned a host
    // stack frame per nesting level and overflowed far shallower than
    // this; the iterative machine keeps its continuation/operand stacks
    // on the heap, so execution must complete on a 64 KiB thread stack.
    // Parsing and lowering still recurse over the AST, so they get a
    // deliberately roomy stack — only `run_main` moves to the tiny one.
    const DEPTH: usize = 500;
    let src = flopt::apps::gen::deep_source(DEPTH);
    std::thread::Builder::new()
        .name("deep-parse".into())
        .stack_size(32 * 1024 * 1024)
        .spawn(move || {
            let program = parse(&src).expect("deep fixture parses");
            let mut it = flopt::interp::Interp::new(&program);
            let out = std::thread::scope(|s| {
                std::thread::Builder::new()
                    .name("tiny-stack-eval".into())
                    .stack_size(64 * 1024)
                    .spawn_scoped(s, move || {
                        it.run_main().expect("deep program runs");
                        it.read_array("out").expect("out array")
                    })
                    .expect("spawn tiny-stack thread")
                    .join()
                    .expect("evaluation must not overflow 64 KiB")
            });
            assert_eq!(out, vec![DEPTH as f64, (DEPTH + 1) as f64]);
        })
        .expect("spawn parse thread")
        .join()
        .expect("deep-nest fixture");
}

#[test]
fn search_completes_end_to_end_on_both_fixtures() {
    // neither fixture may panic the pipeline; whatever wins (a block
    // offer or staying on the CPU) must never lose to all-CPU
    for (name, src) in [
        ("scatter", SCATTER),
        ("prefix_sum", PREFIX_SUM),
        ("counter_step", COUNTER_STEP),
    ] {
        let app = leak_app(format!("fixture-e2e-{name}"), src.to_string());
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, cfg.clone());
        let trace =
            offload_search(app, &env, true).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            trace.speedup() >= 1.0 - 1e-9,
            "{name}: search result {}x loses to all-CPU",
            trace.speedup()
        );
    }
}
