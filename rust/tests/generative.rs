//! Generative property suite (PR 6): hundreds of seeded MiniC programs
//! from [`flopt::apps::gen`] are pushed through parse → analyze → search
//! on both backends, asserting the seven invariants the rest of the
//! test suite pins only on the hand-written corpus:
//!
//! 1. pretty-print → reparse is the identity (modulo positions);
//! 2. combined block+loop search never loses to loop-only (per backend);
//! 3. mixed placement never loses to staying all-CPU;
//! 4. a warm-cache re-run is byte-identical and burns zero simulated time;
//! 5. fleet placement's aggregate speedup never drops below 1.0;
//! 6. two cold runs export byte-identical span logs (trace determinism);
//! 7. the static dependence engine is sound against the dynamic oracle:
//!    a loop it calls `parallel` never shows an observed loop-carried
//!    conflict, and a `reduction` loop conflicts only on its
//!    reduction scalars.
//!
//! The seed/count are pinned in CI (`FLOPT_GEN_SEED` / `FLOPT_GEN_COUNT`,
//! defaults 1106/200) so failures reproduce exactly; every failing
//! program is dumped to `target/generative/` (uploaded as a CI artifact)
//! and shrinks naturally — programs are small and independent, so the
//! dumped `.mc` file IS the minimized reproducer to commit under
//! `rust/tests/fixtures/`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use flopt::apps::{self, gen};
use flopt::backend::{self, OffloadBackend, Target};
use flopt::cache::{codec, CacheStore};
use flopt::config::SearchConfig;
use flopt::coordinator::mixed::mixed_search_on;
use flopt::coordinator::pipeline::{analyze_app, offload_search, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::ast::strip_positions;
use flopt::cparse::{parse, pretty};
use flopt::cpu::XEON_3104;
use flopt::fleet;
use flopt::funcblock::BlockMode;
use flopt::service::BatchService;

fn ci_seed() -> u64 {
    std::env::var("FLOPT_GEN_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1106)
}

fn ci_count() -> u64 {
    std::env::var("FLOPT_GEN_COUNT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

/// Persist a failing program for the CI artifact upload; returns the path.
fn dump_failing(tag: &str, seed: u64, index: u64, src: &str) -> String {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/generative");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{tag}-s{seed}-i{index}.mc"));
    let _ = std::fs::write(&path, src);
    path.display().to_string()
}

/// Run one invariant over the whole pool, catching panics (a detector or
/// selector crash is a failure to report, not a suite abort), dumping
/// every failing program, and reporting all failures at once.
fn run_invariant(tag: &str, f: impl Fn(u64, &str) -> Result<(), String>) {
    let (seed, count) = (ci_seed(), ci_count());
    let mut failures = Vec::new();
    for index in 0..count {
        let src = gen::gen_source(seed, index);
        let outcome = catch_unwind(AssertUnwindSafe(|| f(index, &src)));
        let err = match outcome {
            Ok(Ok(())) => continue,
            Ok(Err(msg)) => msg,
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "non-string panic payload".into());
                format!("panicked: {msg}")
            }
        };
        let path = dump_failing(tag, seed, index, &src);
        failures.push(format!("gen({seed}, {index}): {err}\n  dumped to {path}"));
    }
    assert!(
        failures.is_empty(),
        "{tag}: {}/{count} generated programs failed:\n{}",
        failures.len(),
        failures.join("\n")
    );
}

/// The small search configuration the fuzz searches run under (full
/// defaults would make 200 programs × 2 backends needlessly slow).
fn small_cfg(mode: BlockMode) -> SearchConfig {
    SearchConfig {
        a_intensity: 3,
        c_efficiency: 2,
        d_patterns: 3,
        block_mode: mode,
        ..SearchConfig::default()
    }
}

const BACKENDS: [&'static dyn OffloadBackend; 2] = [&backend::FPGA, &backend::GPU];

// ---------------------------------------------------------------- 1
#[test]
fn generated_programs_roundtrip_through_the_pretty_printer() {
    run_invariant("roundtrip", |_index, src| {
        let p1 = parse(src).map_err(|e| format!("parse failed: {e}"))?;
        let printed = pretty::program(&p1);
        let p2 = parse(&printed).map_err(|e| format!("reparse failed: {e}\n{printed}"))?;
        if strip_positions(&p1) != strip_positions(&p2) {
            return Err("pretty-print did not reparse to the identical AST".into());
        }
        if pretty::program(&p2) != printed {
            return Err("printing is not a fixpoint".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 2
#[test]
fn combined_search_never_loses_to_loop_only_on_generated_programs() {
    let seed = ci_seed();
    run_invariant("combined-vs-loop", |index, src| {
        let app = gen::leak_app(format!("gcmb-{seed}-{index}"), src.to_string());
        let analysis = analyze_app(app, true).map_err(|e| format!("analyze: {e}"))?;
        for be in BACKENDS {
            let mut speedups = [0.0f64; 2];
            for (slot, mode) in [(0, BlockMode::Off), (1, BlockMode::On)] {
                let cfg = small_cfg(mode);
                let env = VerifyEnv::new(be, &XEON_3104, cfg.clone());
                let t = search_with_analysis(app, &analysis, &env, &cfg)
                    .map_err(|e| format!("{} search ({mode:?}): {e}", be.name()))?;
                speedups[slot] = t.speedup();
            }
            let [loop_only, combined] = speedups;
            if combined < loop_only - 1e-9 {
                return Err(format!(
                    "{}: combined {combined:.4}x < loop-only {loop_only:.4}x",
                    be.name()
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 3
#[test]
fn mixed_placement_never_loses_to_all_cpu_on_generated_programs() {
    let (seed, count) = (ci_seed(), ci_count());
    let apps_list: Vec<&'static apps::App> = (0..count)
        .map(|i| gen::leak_app(format!("gmix-{seed}-{i}"), gen::gen_source(seed, i)))
        .collect();
    let cfg = small_cfg(BlockMode::On);
    let mut checked = 0;
    // fresh service per chunk: bounds shared-clock state while still
    // exercising the batch path many apps at a time
    for (chunk_no, chunk) in apps_list.chunks(20).enumerate() {
        let chunk: Vec<&'static apps::App> = chunk.to_vec();
        let service = BatchService::new(4, cfg.compile_parallelism, &XEON_3104);
        let traces = mixed_search_on(&service, &chunk, &Target::Mixed.backends(), &cfg, true)
            .expect("mixed search over generated programs");
        assert_eq!(traces.len(), chunk.len(), "one trace per generated app");
        for (slot, t) in traces.iter().enumerate() {
            let index = (chunk_no * 20 + slot) as u64;
            assert!(
                t.speedup >= 1.0 - 1e-9,
                "{}: mixed winner {:?} at {:.4}x loses to all-CPU\n  dumped to {}",
                t.app_name,
                t.winner,
                t.speedup,
                dump_failing("mixed", seed, index, chunk[slot].source)
            );
            checked += 1;
        }
    }
    assert_eq!(checked, count as usize);
}

// ---------------------------------------------------------------- 4
#[test]
fn warm_cache_rerun_is_byte_identical_on_generated_programs() {
    run_invariant("warm-cache", |index, src| {
        let app = gen::leak_app(format!("gwarm-{}-{index}", ci_seed()), src.to_string());
        let store = CacheStore::fresh();
        let run = |store: &Arc<CacheStore>| {
            let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, small_cfg(BlockMode::On))
                .with_cache(Arc::clone(store));
            let t = offload_search(app, &env, true)
                .map_err(|e| format!("offload search: {e}"))?;
            Ok::<_, String>((t, env.clock.total_seconds()))
        };
        let (cold, cold_total) = run(&store)?;
        let (warm, warm_total) = run(&store)?;
        if warm_total != 0.0 {
            return Err(format!(
                "warm re-run burned {warm_total:.3} simulated seconds (cold: {cold_total:.3})"
            ));
        }
        if codec::trace_to_string(&cold) != codec::trace_to_string(&warm) {
            return Err("warm trace is not byte-identical to the cold trace".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 5
#[test]
fn fleet_aggregate_speedup_never_below_one_on_generated_programs() {
    let (seed, count) = (ci_seed(), ci_count());
    let apps_list: Vec<&'static apps::App> = (0..count)
        .map(|i| gen::leak_app(format!("gflt-{seed}-{i}"), gen::gen_source(seed, i)))
        .collect();
    let cfg = small_cfg(BlockMode::On);
    for chunk in apps_list.chunks(10) {
        let chunk: Vec<&'static apps::App> = chunk.to_vec();
        let service = BatchService::new(4, cfg.compile_parallelism, &XEON_3104);
        let report = fleet::fleet_search(&service, &chunk, 2, &cfg, true)
            .expect("fleet search over generated programs");
        assert_eq!(report.apps.len(), chunk.len(), "one placement row per tenant");
        assert!(
            report.aggregate_speedup >= 1.0 - 1e-9,
            "fleet aggregate {:.4}x below 1.0 for chunk starting at {}",
            report.aggregate_speedup,
            chunk[0].name
        );
    }
}

// ---------------------------------------------------------------- 6
#[test]
fn trace_export_is_deterministic_across_cold_runs_on_generated_programs() {
    run_invariant("trace-determinism", |index, src| {
        let app = gen::leak_app(format!("gobs-{}-{index}", ci_seed()), src.to_string());
        let run = || {
            let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, small_cfg(BlockMode::On))
                .with_cache(CacheStore::fresh());
            offload_search(app, &env, true).map_err(|e| format!("offload search: {e}"))?;
            Ok::<_, String>(flopt::obs::export::render_jsonl(env.clock.obs()))
        };
        let a = run()?;
        if a.is_empty() {
            return Err("cold run exported an empty span log".into());
        }
        if a != run()? {
            return Err("two cold runs exported different span logs".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- 7
#[test]
fn static_parallel_verdicts_hold_under_the_dynamic_oracle() {
    use flopt::analyze::{explain_program, LoopVerdict};
    run_invariant("oracle-soundness", |index, src| {
        let program = parse(src).map_err(|e| format!("parse failed: {e}"))?;
        let report = explain_program(&format!("gorc-{index}"), &program);
        let mut it = flopt::interp::Interp::new(&program);
        it.enable_oracle(&program);
        if it.run_main().is_err() {
            // a program that faults at runtime yields no observation
            return Ok(());
        }
        for l in &report.loops {
            let Some(c) = it.oracle_conflicts(l.id) else { continue };
            match &l.deps.verdict {
                LoopVerdict::Parallel => {
                    if !c.arrays.is_empty() || !c.scalars.is_empty() {
                        return Err(format!(
                            "{} claimed parallel but the oracle saw conflicts \
                             (arrays {:?}, scalars {:?})",
                            l.id, c.arrays, c.scalars
                        ));
                    }
                }
                LoopVerdict::Reduction(reds) => {
                    let rvars: Vec<_> = reds.iter().map(|r| r.var).collect();
                    let extra: Vec<_> =
                        c.scalars.iter().filter(|s| !rvars.contains(s)).collect();
                    if !c.arrays.is_empty() || !extra.is_empty() {
                        return Err(format!(
                            "{} claimed reduction on {rvars:?} but the oracle saw \
                             conflicts (arrays {:?}, extra scalars {extra:?})",
                            l.id, c.arrays
                        ));
                    }
                }
                LoopVerdict::Sequential(_) | LoopVerdict::Unknown(_) => {}
            }
        }
        Ok(())
    });
}

// ----------------------------------------------------------------
// opt-in deep-program sweep: `FLOPT_GEN_DEEP=<max depth>` enables it
// (off by default — CI's pinned pool stays exactly as it was).  Sweeps
// nesting depths up to the knob, running each program on a 64 KiB
// evaluation stack: the iterative interpreter machine must be
// indifferent to program depth, whatever the host stack.
#[test]
fn deep_programs_run_on_a_tiny_stack_when_opted_in() {
    let Some(max) = std::env::var("FLOPT_GEN_DEEP")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    else {
        return;
    };
    for depth in [max / 4, max / 2, max] {
        let depth = depth.max(1);
        let src = gen::deep_source(depth);
        std::thread::Builder::new()
            .stack_size(64 * 1024 * 1024)
            .spawn(move || {
                let program = parse(&src).expect("deep program parses");
                let mut it = flopt::interp::Interp::new(&program);
                let out = std::thread::scope(|s| {
                    std::thread::Builder::new()
                        .stack_size(64 * 1024)
                        .spawn_scoped(s, move || {
                            it.run_main().expect("deep program runs");
                            it.read_array("out").expect("out array")
                        })
                        .expect("spawn")
                        .join()
                        .expect("evaluation must not overflow 64 KiB")
                });
                assert_eq!(out, vec![depth as f64, (depth + 1) as f64], "depth {depth}");
            })
            .expect("spawn")
            .join()
            .unwrap_or_else(|_| panic!("deep sweep failed at depth {depth}"));
    }
}

// ----------------------------------------------------------------
// generator self-checks at the CI seed (byte determinism across pool
// sizes is unit-tested in `apps::gen`; this pins it at the CI scale)
#[test]
fn ci_pool_is_deterministic_and_order_independent() {
    let (seed, count) = (ci_seed(), ci_count().min(50));
    let forward: Vec<String> = (0..count).map(|i| gen::gen_source(seed, i)).collect();
    let reverse: Vec<String> = (0..count).rev().map(|i| gen::gen_source(seed, i)).collect();
    for i in 0..count as usize {
        assert_eq!(forward[i], reverse[count as usize - 1 - i], "program {i}");
        assert_eq!(forward[i], gen::gen_source(seed, i as u64), "program {i} re-gen");
    }
}
