//! Batch-service suite (PR 3): `flopt batch` over every registered app
//! × {fpga, gpu} must produce byte-identical output for pool sizes 1,
//! 2, and 8;
//! in-batch duplicates dedupe; a repeat batch is fully warm; and the
//! mixed-destination veneer over the service preserves its contract.

use flopt::apps;
use flopt::backend::{Destination, Target};
use flopt::config::SearchConfig;
use flopt::cpu::XEON_3104;
use flopt::service::{BatchRequest, BatchService, CacheDisposition};

fn all_apps_both_targets() -> Vec<BatchRequest> {
    let mut reqs = Vec::new();
    for app in apps::all() {
        for target in [Target::Fpga, Target::Gpu] {
            reqs.push(BatchRequest::new(app, target, /*test_scale=*/ true));
        }
    }
    reqs
}

#[test]
fn batch_output_is_identical_for_pool_sizes_1_2_and_8() {
    let requests = all_apps_both_targets();
    let mut renders = Vec::new();
    let mut reports = Vec::new();
    for workers in [1usize, 2, 8] {
        let svc = BatchService::new(workers, 1, &XEON_3104);
        let report = svc.run(&requests).unwrap();
        renders.push((workers, report.render()));
        reports.push((workers, report));
    }
    let (_, reference) = &renders[0];
    for (workers, render) in &renders[1..] {
        assert_eq!(
            render, reference,
            "pool size {workers} produced different batch output"
        );
    }
    // structural spot-checks beyond the rendered text
    let (_, ref r1) = reports[0];
    for (workers, report) in &reports[1..] {
        assert_eq!(report.items.len(), r1.items.len());
        for (a, b) in r1.items.iter().zip(&report.items) {
            assert_eq!(a.outcome.speedup, b.outcome.speedup, "workers={workers}");
            assert_eq!(a.outcome.compile_hours, b.outcome.compile_hours);
            assert_eq!(a.sim_hours_after, b.sim_hours_after, "workers={workers}");
            assert_eq!(a.disposition, b.disposition);
        }
        assert_eq!(report.sim_hours, r1.sim_hours, "workers={workers}");
        assert_eq!(report.compile_hours, r1.compile_hours, "workers={workers}");
    }
}

#[test]
fn batch_covers_every_request_in_submission_order() {
    let requests = all_apps_both_targets();
    let svc = BatchService::new(4, 1, &XEON_3104);
    let report = svc.run(&requests).unwrap();
    assert_eq!(report.items.len(), 2 * apps::all().len());
    for (req, item) in requests.iter().zip(&report.items) {
        assert_eq!(item.outcome.app_name, req.app.name);
        assert_eq!(Some(item.outcome.destination), req.target.destination());
        assert_eq!(item.disposition, CacheDisposition::Cold);
        assert!(item.outcome.cpu_time_s > 0.0);
    }
    // FPGA rows ran the narrowed flow, GPU rows the GA
    for item in &report.items {
        match item.outcome.destination {
            Destination::Fpga => assert_eq!(item.outcome.method, "narrowed-2round"),
            Destination::Gpu => {
                assert_eq!(item.outcome.method, "ga");
                assert!(item.outcome.patterns_measured > 0);
            }
            Destination::Cpu => panic!("no CPU rows in a batch"),
        }
    }
    // the shared clock accumulates monotonically in submission order
    for w in report.items.windows(2) {
        assert!(w[1].sim_hours_after >= w[0].sim_hours_after);
    }
    assert!(report.compile_hours > 0.0);
    assert_eq!(report.unique_cold, 2 * apps::all().len());
    assert_eq!(report.warm_hits, 0);
    assert_eq!(report.deduped, 0);
}

#[test]
fn interleaved_duplicates_dedupe_against_the_first_occurrence() {
    let a = BatchRequest::new(&apps::TDFIR, Target::Fpga, true);
    let b = BatchRequest::new(&apps::MRIQ, Target::Gpu, true);
    let svc = BatchService::new(3, 1, &XEON_3104);
    let report = svc
        .run(&[a.clone(), b.clone(), a.clone(), b.clone(), a])
        .unwrap();
    assert_eq!(report.unique_cold, 2);
    assert_eq!(report.deduped, 3);
    let dispositions: Vec<CacheDisposition> =
        report.items.iter().map(|it| it.disposition).collect();
    assert_eq!(
        dispositions,
        vec![
            CacheDisposition::Cold,
            CacheDisposition::Cold,
            CacheDisposition::Deduped,
            CacheDisposition::Deduped,
            CacheDisposition::Deduped,
        ]
    );
    // deduped rows carry the identical outcome
    assert_eq!(report.items[0].outcome.speedup, report.items[2].outcome.speedup);
    assert_eq!(report.items[0].outcome.speedup, report.items[4].outcome.speedup);
    assert!(report.saved_compile_hours > 0.0);
}

#[test]
fn repeat_batch_on_one_service_is_fully_warm() {
    let requests = all_apps_both_targets();
    let svc = BatchService::new(4, 1, &XEON_3104);
    let cold = svc.run(&requests).unwrap();
    let clock_after_cold = svc.clock().total_hours();
    let warm = svc.run(&requests).unwrap();
    assert_eq!(warm.warm_hits, 2 * apps::all().len());
    assert_eq!(warm.unique_cold, 0);
    assert_eq!(warm.compile_hours, 0.0);
    assert_eq!(warm.sim_hours, 0.0);
    assert_eq!(
        svc.clock().total_hours(),
        clock_after_cold,
        "a warm batch must not advance the shared clock"
    );
    for (c, w) in cold.items.iter().zip(&warm.items) {
        assert_eq!(c.outcome.speedup, w.outcome.speedup);
        assert_eq!(c.outcome.compile_hours, w.outcome.compile_hours);
        assert_eq!(w.disposition, CacheDisposition::Warm);
    }
    assert!(
        (warm.saved_compile_hours - cold.compile_hours).abs() < 1e-9,
        "warm batch saves what the cold batch burned: saved {} vs burned {}",
        warm.saved_compile_hours,
        cold.compile_hours
    );
}

#[test]
fn mixed_over_the_service_matches_direct_batch_rows() {
    use flopt::coordinator::mixed::mixed_search_all;
    let apps_list: Vec<&'static apps::App> = apps::all();
    let traces = mixed_search_all(
        &apps_list,
        &Target::Mixed.backends(),
        &XEON_3104,
        &SearchConfig::default(),
        true,
    )
    .unwrap();
    assert_eq!(traces.len(), apps::all().len());
    for t in &traces {
        assert_eq!(t.searches.len(), 2);
        assert_eq!(t.searches[0].destination, Destination::Fpga);
        assert_eq!(t.searches[1].destination, Destination::Gpu);
        assert!(t.speedup >= 1.0, "{}: mixed never loses to CPU", t.app_name);
        assert!(t.cpu_time_s > 0.0);
    }
    // per-app snapshots accumulate on the one shared clock
    for w in traces.windows(2) {
        assert!(w[1].sim_hours > w[0].sim_hours);
    }
}
