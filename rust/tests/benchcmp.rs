//! Bench-regression gate suite (PR 7): `flopt bench-compare` must pass
//! a matching report, fail (exit 1) on an injected regression or a
//! pinned-but-missing metric, exit 2 on usage/IO errors, and write
//! usable diff and blessed-baseline artifacts — the exact contract the
//! CI `bench-smoke` job gates on.

use std::path::PathBuf;
use std::process::Command;

fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("flopt-benchcmp-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bench_compare(args: &[&str]) -> (Option<i32>, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_flopt"))
        .arg("bench-compare")
        .args(args)
        .output()
        .expect("run flopt bench-compare");
    (
        out.status.code(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

const BASELINE: &str = r#"{
  "bench": "demo", "schema": 1,
  "metrics": {
    "speedup": {"value": 4.0, "tol_rel": 0.05, "direction": "higher_better"},
    "hours":   {"value": 10.0, "tol_rel": 0.05, "direction": "lower_better"},
    "count":   {"value": 7, "tol_rel": 0, "direction": "exact"}
  }
}"#;

fn write(dir: &std::path::Path, name: &str, text: &str) -> String {
    let p = dir.join(name);
    std::fs::write(&p, text).unwrap();
    p.to_string_lossy().into_owned()
}

#[test]
fn matching_report_passes_with_exit_0() {
    let dir = temp_dir("pass");
    let b = write(&dir, "base.json", BASELINE);
    let r = write(
        &dir,
        "report.json",
        r#"{"bench":"demo","metrics":{"speedup":4.1,"hours":9.8,"count":7}}"#,
    );
    let (code, stdout, stderr) = bench_compare(&["--baseline", &b, "--report", &r]);
    assert_eq!(code, Some(0), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("=> ok"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_regression_fails_with_exit_1_and_writes_the_diff() {
    let dir = temp_dir("regress");
    let b = write(&dir, "base.json", BASELINE);
    // speedup collapses 4.0 -> 2.0: far outside the 5% tolerance
    let r = write(
        &dir,
        "report.json",
        r#"{"bench":"demo","metrics":{"speedup":2.0,"hours":10.0,"count":7}}"#,
    );
    let diff = dir.join("diffs").join("demo.json");
    let (code, stdout, _) = bench_compare(&[
        "--baseline",
        &b,
        "--report",
        &r,
        "--diff",
        diff.to_str().unwrap(),
    ]);
    assert_eq!(code, Some(1), "a regression must gate with exit 1\n{stdout}");
    assert!(stdout.contains("REGRESSED"), "{stdout}");
    assert!(stdout.contains("FAIL"), "{stdout}");
    let diff_text = std::fs::read_to_string(&diff).expect("diff artifact written");
    assert!(diff_text.contains("\"failed\": true"), "{diff_text}");
    assert!(diff_text.contains("REGRESSED"), "{diff_text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pinned_but_missing_metric_fails() {
    let dir = temp_dir("missing");
    let b = write(&dir, "base.json", BASELINE);
    let r = write(&dir, "report.json", r#"{"bench":"demo","metrics":{"speedup":4.0}}"#);
    let (code, stdout, _) = bench_compare(&["--baseline", &b, "--report", &r]);
    assert_eq!(code, Some(1), "{stdout}");
    assert!(stdout.contains("MISSING"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unblessed_baseline_passes_and_bless_writes_a_committable_one() {
    let dir = temp_dir("bless");
    let b = write(
        &dir,
        "base.json",
        r#"{"bench":"demo","schema":1,"metrics":{
            "speedup":{"value":null,"tol_rel":0.05,"direction":"higher_better"}}}"#,
    );
    let r = write(&dir, "report.json", r#"{"bench":"demo","metrics":{"speedup":4.25}}"#);
    let blessed = dir.join("blessed.json");
    let (code, stdout, _) = bench_compare(&[
        "--baseline",
        &b,
        "--report",
        &r,
        "--bless",
        blessed.to_str().unwrap(),
    ]);
    assert_eq!(code, Some(0), "unblessed pins must warn, not fail\n{stdout}");
    assert!(stdout.contains("unblessed"), "{stdout}");

    // the blessed copy now pins the observed value and gates for real
    let (code, stdout, _) =
        bench_compare(&["--baseline", blessed.to_str().unwrap(), "--report", &r]);
    assert_eq!(code, Some(0), "{stdout}");
    let r2 = write(&dir, "report2.json", r#"{"bench":"demo","metrics":{"speedup":3.0}}"#);
    let (code, stdout, _) =
        bench_compare(&["--baseline", blessed.to_str().unwrap(), "--report", &r2]);
    assert_eq!(code, Some(1), "the blessed pin must catch the regression\n{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn usage_and_io_errors_exit_2() {
    let dir = temp_dir("usage");
    let b = write(&dir, "base.json", BASELINE);
    let (code, _, stderr) = bench_compare(&["--baseline", &b]);
    assert_eq!(code, Some(2), "{stderr}");
    let (code, _, stderr) =
        bench_compare(&["--baseline", &b, "--report", "/nonexistent/report.json"]);
    assert_eq!(code, Some(2), "{stderr}");
    assert!(stderr.contains("cannot read"), "{stderr}");
    let bad = write(&dir, "bad.json", "not json at all");
    let (code, _, stderr) = bench_compare(&["--baseline", &bad, "--report", &b]);
    assert_eq!(code, Some(2), "{stderr}");
    let mismatched = write(&dir, "other.json", r#"{"bench":"other","metrics":{}}"#);
    let (code, _, stderr) = bench_compare(&["--baseline", &b, "--report", &mismatched]);
    assert_eq!(code, Some(2), "bench-name mismatch is a usage error: {stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn committed_repo_baselines_parse_and_pin_every_bench() {
    // the six BENCH_*.json files at the repo root must stay parseable
    // and self-consistent (the `bench` field matches the filename)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    for name in [
        "fig4_speedup",
        "service_throughput",
        "funcblock_speedup",
        "fleet_throughput",
        "serve_daemon",
        "hot_paths",
    ] {
        let path = root.join(format!("BENCH_{name}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let doc = flopt::util::json::parse(&text).expect("baseline JSON");
        let base = flopt::benchcmp::parse_baseline(&doc).expect("baseline schema");
        assert_eq!(base.bench, name, "{}", path.display());
        assert!(!base.metrics.is_empty(), "{name}: a baseline must pin metrics");
    }
}
