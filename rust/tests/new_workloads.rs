//! Coverage for the extra registered workloads (matmul, laplace2d,
//! histogram): loop counts, dependence verdicts on the interesting loop
//! shapes (nested accumulation, boundary-guarded nests, data-dependent
//! writes), and the top-a intensity rankings the narrowing relies on.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::ast::LoopId;
use flopt::cpu::XEON_3104;
use flopt::intensity;

#[test]
fn loop_counts_are_stable() {
    assert_eq!(apps::MATMUL.parse().loop_count(), 5);
    assert_eq!(apps::LAPLACE2D.parse().loop_count(), 9);
    assert_eq!(apps::HISTOGRAM.parse().loop_count(), 6);
}

#[test]
fn matmul_nest_structure_and_reduction() {
    let p = apps::MATMUL.parse();
    let loops = flopt::ir::analyze(&p);
    let outer = loops
        .iter()
        .find(|l| l.info.function == "mm" && l.info.depth == 0)
        .expect("mm outer loop");
    assert_eq!(outer.info.id, LoopId(1));
    assert!(outer.deps.offloadable, "{:?}", outer.deps.reject_reason);
    // the innermost k-loop carries the `acc` accumulation
    let inner = loops
        .iter()
        .find(|l| l.info.function == "mm" && l.info.depth == 2)
        .expect("mm innermost loop");
    assert_eq!(inner.info.id, LoopId(3));
    assert!(inner.deps.offloadable);
    assert_eq!(inner.deps.reductions[0].var, "acc");
}

#[test]
fn matmul_top_a_ranks_the_nest_first() {
    let analysis = analyze_app(&apps::MATMUL, true).unwrap();
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    assert_eq!(top[0].id, LoopId(1), "top-a: {:?}",
        top.iter().map(|l| l.id).collect::<Vec<_>>());
}

#[test]
fn laplace_guarded_nest_is_the_candidate() {
    let analysis = analyze_app(&apps::LAPLACE2D, true).unwrap();
    // the boundary-guarded row nest (first depth-1 loop of jacobi)
    let grid = analysis
        .loops
        .iter()
        .find(|l| l.info.function == "jacobi" && l.info.depth == 1)
        .expect("grid nest");
    assert!(grid.deps.offloadable, "{:?}", grid.deps.reject_reason);
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    let ids: Vec<LoopId> = top.iter().map(|l| l.id).collect();
    assert!(ids.contains(&grid.info.id), "top-a {ids:?}");
}

#[test]
fn histogram_transform_ranks_first_fill_is_rejected() {
    let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    assert_eq!(top[0].id, LoopId(2), "transform sweep must rank first");
    let fill = analysis
        .loops
        .iter()
        .find(|l| l.info.function == "build_hist")
        .expect("fill loop");
    assert!(!fill.deps.offloadable, "data-dependent writes must reject");
    assert!(!top.iter().any(|l| l.id == fill.info.id));
}

#[test]
fn new_workloads_complete_the_full_search() {
    for app in [&apps::MATMUL, &apps::LAPLACE2D, &apps::HISTOGRAM] {
        let analysis = analyze_app(app, true).unwrap();
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let t = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
        let best = t.best.as_ref()
            .unwrap_or_else(|| panic!("{}: a pattern must win", app.name));
        assert!(best.speedup > 1.0, "{}: speedup {}", app.name, best.speedup);
        assert!(t.patterns_measured() <= cfg.d_patterns);
        let rendered = t.render();
        assert!(rendered.contains("solution: pattern"), "{rendered}");
    }
}
