//! Coverage for the extra registered workloads (matmul, laplace2d,
//! histogram, and the PR 6 corpus: fft, spmv, stencil3d, nbody): loop
//! counts, dependence verdicts on the interesting loop shapes (nested
//! accumulation, boundary-guarded nests, data-dependent writes, strided
//! cross-reads, indirect gathers, pair interactions), and the top-a
//! intensity rankings the narrowing relies on.

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::ast::LoopId;
use flopt::cpu::XEON_3104;
use flopt::intensity;

#[test]
fn loop_counts_are_stable() {
    assert_eq!(apps::MATMUL.parse().loop_count(), 5);
    assert_eq!(apps::LAPLACE2D.parse().loop_count(), 9);
    assert_eq!(apps::HISTOGRAM.parse().loop_count(), 6);
    assert_eq!(apps::FFT.parse().loop_count(), 8);
    assert_eq!(apps::SPMV.parse().loop_count(), 7);
    assert_eq!(apps::STENCIL3D.parse().loop_count(), 9);
    assert_eq!(apps::NBODY.parse().loop_count(), 6);
}

#[test]
fn matmul_nest_structure_and_reduction() {
    let p = apps::MATMUL.parse();
    let loops = flopt::ir::analyze(&p);
    let outer = loops
        .iter()
        .find(|l| l.info.function == "mm" && l.info.depth == 0)
        .expect("mm outer loop");
    assert_eq!(outer.info.id, LoopId(1));
    assert!(outer.deps.offloadable, "{:?}", outer.deps.reject_reason);
    // the innermost k-loop carries the `acc` accumulation
    let inner = loops
        .iter()
        .find(|l| l.info.function == "mm" && l.info.depth == 2)
        .expect("mm innermost loop");
    assert_eq!(inner.info.id, LoopId(3));
    assert!(inner.deps.offloadable);
    assert_eq!(inner.deps.reductions[0].var, "acc");
}

#[test]
fn matmul_top_a_ranks_the_nest_first() {
    let analysis = analyze_app(&apps::MATMUL, true).unwrap();
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    assert_eq!(top[0].id, LoopId(1), "top-a: {:?}",
        top.iter().map(|l| l.id).collect::<Vec<_>>());
}

#[test]
fn laplace_guarded_nest_is_the_candidate() {
    let analysis = analyze_app(&apps::LAPLACE2D, true).unwrap();
    // the boundary-guarded row nest (first depth-1 loop of jacobi)
    let grid = analysis
        .loops
        .iter()
        .find(|l| l.info.function == "jacobi" && l.info.depth == 1)
        .expect("grid nest");
    assert!(grid.deps.offloadable, "{:?}", grid.deps.reject_reason);
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    let ids: Vec<LoopId> = top.iter().map(|l| l.id).collect();
    assert!(ids.contains(&grid.info.id), "top-a {ids:?}");
}

#[test]
fn histogram_transform_ranks_first_fill_is_rejected() {
    let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    assert_eq!(top[0].id, LoopId(2), "transform sweep must rank first");
    let fill = analysis
        .loops
        .iter()
        .find(|l| l.info.function == "build_hist")
        .expect("fill loop");
    assert!(!fill.deps.offloadable, "data-dependent writes must reject");
    assert!(!top.iter().any(|l| l.id == fill.info.id));
}

#[test]
fn fft_butterfly_is_parallel_but_the_stage_sweep_stays_on_cpu() {
    let p = apps::FFT.parse();
    let loops = flopt::ir::analyze(&p);
    // the group loop of the butterfly nest ping-pongs into br/bi, so
    // despite the strided cross-reads it is fully parallel
    let group = loops
        .iter()
        .find(|l| l.info.function == "butterfly" && l.info.depth == 0)
        .expect("butterfly group loop");
    assert_eq!(group.info.id, LoopId(2));
    assert!(group.deps.offloadable, "{:?}", group.deps.reject_reason);
    // the stage sweep in main calls butterfly/copy_back — never a candidate
    let stage = loops
        .iter()
        .find(|l| l.info.function == "main")
        .expect("stage sweep");
    assert_eq!(stage.info.id, LoopId(7));
    assert!(!stage.deps.offloadable);
}

#[test]
fn spmv_gather_is_parallel_but_the_prefix_sum_is_consumed() {
    let p = apps::SPMV.parse();
    let loops = flopt::ir::analyze(&p);
    // the row loop gathers x[c] through loaded column indices — reads
    // may collide, writes (ys[i]) never do, so it stays offloadable
    let row = loops
        .iter()
        .find(|l| l.info.function == "spmv" && l.info.depth == 0)
        .expect("spmv row loop");
    assert_eq!(row.info.id, LoopId(4));
    assert!(row.deps.offloadable, "{:?}", row.deps.reject_reason);
    // the CSR row-extent build stores its running total every iteration
    let build = loops
        .iter()
        .find(|l| l.info.function == "build_rows")
        .expect("prefix-sum build loop");
    let reason = build.deps.reject_reason.map(|r| r.as_str()).unwrap_or_default();
    assert!(!build.deps.offloadable);
    assert!(reason.contains("consumed"), "wrong reject reason: {reason}");
}

#[test]
fn stencil3d_plane_nest_is_the_candidate() {
    let analysis = analyze_app(&apps::STENCIL3D, true).unwrap();
    // the i-plane nest inside the time sweep only reads `a`, writes `b`
    let plane = analysis
        .loops
        .iter()
        .find(|l| l.info.function == "jacobi3d" && l.info.depth == 1)
        .expect("plane nest");
    assert_eq!(plane.info.id, LoopId(3));
    assert!(plane.deps.offloadable, "{:?}", plane.deps.reject_reason);
    let top = intensity::top_a(&analysis.intensities, &analysis.loops, 5);
    let ids: Vec<LoopId> = top.iter().map(|l| l.id).collect();
    assert!(ids.contains(&plane.info.id), "top-a {ids:?}");
}

#[test]
fn nbody_pair_nest_is_parallel_with_private_accumulators() {
    let p = apps::NBODY.parse();
    let loops = flopt::ir::analyze(&p);
    // ax/ay/az are declared inside the body loop, so the inner-pair
    // accumulation never becomes a loop-carried dependence of the nest
    let body = loops
        .iter()
        .find(|l| l.info.function == "forces" && l.info.depth == 0)
        .expect("body loop");
    assert_eq!(body.info.id, LoopId(1));
    assert!(body.deps.offloadable, "{:?}", body.deps.reject_reason);
    let stepping = loops
        .iter()
        .find(|l| l.info.function == "main")
        .expect("time stepping");
    assert!(!stepping.deps.offloadable, "calls forces/integrate");
}

#[test]
fn corpus_workloads_complete_the_search_without_losing_to_cpu() {
    // the new families must flow through the whole loop pipeline; what
    // wins varies by shape, but the search may never end below all-CPU
    for app in [&apps::FFT, &apps::SPMV, &apps::STENCIL3D, &apps::NBODY] {
        let analysis = analyze_app(app, true).unwrap();
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let t = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
        assert!(
            t.speedup() >= 1.0,
            "{}: search result {}x loses to all-CPU",
            app.name,
            t.speedup()
        );
        assert!(t.patterns_measured() <= cfg.d_patterns);
    }
}

#[test]
fn new_workloads_complete_the_full_search() {
    for app in [&apps::MATMUL, &apps::LAPLACE2D, &apps::HISTOGRAM] {
        let analysis = analyze_app(app, true).unwrap();
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let t = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
        let best = t.best.as_ref()
            .unwrap_or_else(|| panic!("{}: a pattern must win", app.name));
        assert!(best.speedup > 1.0, "{}: speedup {}", app.name, best.speedup);
        assert!(t.patterns_measured() <= cfg.d_patterns);
        let rendered = t.render();
        assert!(rendered.contains("solution: pattern"), "{rendered}");
    }
}
