//! Integration: the paper's headline results reproduce in shape.
//!
//! Fig 4 bands: we do not chase the authors' absolute testbed numbers —
//! the assertion is the *shape*: both apps gain, MRI-Q gains more than
//! tdfir, and both land in the right factor band.

use std::sync::OnceLock;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{offload_search, SearchTrace};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

/// Full-scale searches are deterministic — run each app once per test
/// binary (the interpreter profile run is the expensive part).
fn search(app: &'static flopt::apps::App) -> &'static SearchTrace {
    static TDFIR: OnceLock<SearchTrace> = OnceLock::new();
    static MRIQ: OnceLock<SearchTrace> = OnceLock::new();
    let cell = match app.name {
        "tdfir" => &TDFIR,
        "mriq" => &MRIQ,
        other => panic!("unexpected app {other}"),
    };
    cell.get_or_init(|| {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(app, &env, /*test_scale=*/ false).expect("search")
    })
}

#[test]
fn fig4_tdfir_band() {
    let t = search(&apps::TDFIR);
    let s = t.speedup();
    assert!((3.0..=5.0).contains(&s), "tdfir speedup {s} (paper: 4.0)");
}

#[test]
fn fig4_mriq_band() {
    let t = search(&apps::MRIQ);
    let s = t.speedup();
    assert!((5.5..=9.0).contains(&s), "mriq speedup {s} (paper: 7.1)");
}

#[test]
fn fig4_ordering_mriq_beats_tdfir() {
    // the paper's shape: the trig-heavy MRI-Q gains more than tdfir
    assert!(search(&apps::MRIQ).speedup() > search(&apps::TDFIR).speedup());
}

#[test]
fn evaluation_conditions_hold() {
    for (app, loops) in [(&apps::TDFIR, 36), (&apps::MRIQ, 16)] {
        let t = search(app);
        assert_eq!(t.loop_count, loops);
        assert!(t.top_a.len() <= 5, "a=5");
        assert!(t.top_c.len() <= 3, "c=3");
        assert!(t.patterns_measured() <= 4, "d=4");
        // top-c must be a subset of top-a
        assert!(t.top_c.iter().all(|c| t.top_a.contains(c)));
    }
}

#[test]
fn automation_time_about_half_a_day() {
    // paper §5.2: ~3 h per compile, 4 patterns ≈ half a day
    let t = search(&apps::TDFIR);
    let per_compile = t.compile_hours / t.patterns_measured() as f64;
    assert!((2.0..=4.0).contains(&per_compile), "per-compile {per_compile} h");
    assert!((6.0..=16.0).contains(&t.sim_hours), "total {} h", t.sim_hours);
}

#[test]
fn solution_contains_the_hot_loop() {
    for (app, hot_func) in [(&apps::TDFIR, "fir_filter"), (&apps::MRIQ, "compute_q")] {
        let t = search(app);
        let best = t.best.clone().expect("a pattern wins");
        let program = app.parse();
        let loops = flopt::ir::analyze(&program);
        let hot = loops
            .iter()
            .find(|l| l.info.function == hot_func && l.info.depth == 0)
            .unwrap();
        assert!(
            best.pattern.loops.contains(&hot.info.id),
            "{}: solution {:?} must include {}",
            app.name,
            best.pattern,
            hot.info.id
        );
    }
}

#[test]
fn solution_beats_every_other_measured_pattern() {
    let t = search(&apps::TDFIR);
    let best = t.best.as_ref().unwrap();
    for round in &t.rounds {
        for m in round {
            assert!(best.speedup >= m.speedup);
        }
    }
}

#[test]
fn round2_combines_round1_improvers() {
    // tdfir has two improving singles => a round-2 combination exists
    let t = search(&apps::TDFIR);
    assert_eq!(t.rounds.len(), 2, "tdfir search must reach round 2");
    let improving: Vec<_> = t.rounds[0]
        .iter()
        .filter(|m| m.speedup > 1.0)
        .map(|m| m.pattern.loops[0])
        .collect();
    assert!(improving.len() >= 2);
    for combo in &t.rounds[1] {
        assert!(combo.pattern.loops.len() >= 2);
        for l in &combo.pattern.loops {
            assert!(improving.contains(l), "round-2 loops come from round-1 improvers");
        }
    }
}
