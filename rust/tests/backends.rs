//! Backend-seam suite (PR 2): the trait extraction must not change FPGA
//! behavior, the GPU backend must make the paper's §3.2 contrast an
//! executable property, and the mixed-destination mode must pick the
//! right placement.
//!
//! * FPGA-backend search results are **bit-identical** to composing the
//!   pre-seam models (`hls::precompile` → `pnr::full_compile` →
//!   `timing::kernel_time_s`) by hand, for all five registered apps;
//! * GPU GA search stays within its compile-minutes budget while the
//!   same GA on the FPGA burns hours per evaluation;
//! * mixed mode picks FPGA for tdfir (3–5× band) and MRI-Q (5.5–9×
//!   band) and never loses to the all-CPU baseline on any app.

use std::collections::HashMap;

use flopt::apps;
use flopt::backend::{Destination, FPGA, GPU, Target};
use flopt::baselines::ga::{self, GaConfig};
use flopt::config::SearchConfig;
use flopt::coordinator::mixed::mixed_search;
use flopt::coordinator::pipeline::{analyze_app, search_with_analysis};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cparse::ast::LoopId;
use flopt::cpu::XEON_3104;
use flopt::fpga::{ARRIA10_GX, pnr, timing};
use flopt::hls::{self, HlsReport};

/// Run the FPGA search through the backend trait and re-derive every
/// measured number by composing the pre-seam models directly.  Exact
/// (`==`) f64 equality: the adapter must delegate, not approximate.
fn assert_fpga_search_matches_reference(app: &'static apps::App, test_scale: bool) {
    let cfg = SearchConfig::default();
    let analysis = analyze_app(app, test_scale).unwrap();
    let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
    let t = search_with_analysis(app, &analysis, &env, &cfg).unwrap();
    assert_eq!(t.destination, Destination::Fpga, "{}", app.name);

    // direct pre-seam reports for every surviving candidate
    let mut direct: HashMap<LoopId, HlsReport> = HashMap::new();
    for id in &t.top_a {
        let la = analysis.loops.iter().find(|l| l.info.id == *id).unwrap();
        direct.insert(
            *id,
            hls::precompile(&analysis.program, la, cfg.b_unroll, &ARRIA10_GX),
        );
    }
    for c in &t.candidates {
        let d = &direct[&c.id];
        assert_eq!(c.utilization, d.utilization, "{}: {}", app.name, c.id);
        assert_eq!(c.efficiency, c.intensity / d.utilization, "{}: {}", app.name, c.id);
    }

    let cpu_total = XEON_3104.program_time_s(&analysis.profile);
    assert_eq!(t.cpu_time_s, cpu_total, "{}", app.name);
    for round in &t.rounds {
        for m in round {
            let label = m.pattern.label();
            let refs: Vec<&HlsReport> = m.pattern.loops.iter().map(|l| &direct[l]).collect();
            assert_eq!(
                m.utilization,
                hls::combined_utilization(&refs, &ARRIA10_GX),
                "{}: {label}",
                app.name
            );
            let outcome = pnr::full_compile(&refs, &ARRIA10_GX, &label);
            assert_eq!(m.compiled, outcome.is_ok(), "{}: {label}", app.name);
            assert_eq!(m.compile_sim_s, outcome.sim_seconds(), "{}: {label}", app.name);
            if m.compiled {
                let kernels: Vec<timing::KernelExec> = m
                    .pattern
                    .loops
                    .iter()
                    .map(|l| {
                        timing::kernel_time_s(
                            &analysis.loops,
                            &analysis.profile,
                            &direct[l],
                            &ARRIA10_GX,
                        )
                    })
                    .collect();
                let mut offloaded_cpu = 0.0;
                for l in &m.pattern.loops {
                    if let Some(lp) = analysis.profile.loop_profile(*l) {
                        offloaded_cpu += XEON_3104.loop_time_s(lp);
                    }
                }
                let expect_time = (cpu_total - offloaded_cpu).max(0.0)
                    + timing::pattern_fpga_time_s(&kernels);
                assert_eq!(m.time_s, expect_time, "{}: {label}", app.name);
                assert_eq!(m.speedup, cpu_total / expect_time, "{}: {label}", app.name);
            }
        }
    }
}

#[test]
fn fpga_backend_is_bit_identical_for_all_apps_at_test_scale() {
    for app in apps::all() {
        assert_fpga_search_matches_reference(app, true);
    }
}

#[test]
fn fpga_backend_is_bit_identical_for_tdfir_at_full_scale() {
    // the Fig-4 path: no behavior drift from the trait extraction
    assert_fpga_search_matches_reference(&apps::TDFIR, false);
}

#[test]
fn gpu_ga_stays_in_its_compile_minutes_budget() {
    let analysis = analyze_app(&apps::MRIQ, true).unwrap();

    let gpu_env = VerifyEnv::new(&GPU, &XEON_3104, SearchConfig::default());
    let gpu_out = ga::search(&analysis, &gpu_env, &GaConfig::default());
    assert!(gpu_out.evaluations > 4, "GA must measure more than d=4 patterns");
    assert!(
        gpu_out.compile_hours < 6.0,
        "GPU GA compile budget blown: {} h",
        gpu_out.compile_hours
    );
    let per_eval_h = gpu_out.compile_hours / gpu_out.evaluations as f64;
    assert!(per_eval_h < 0.5, "GPU per-eval must be minutes: {per_eval_h} h");

    // the same GA on the FPGA pays ~3 h per evaluation — the §3.2
    // argument, now executable across the seam
    let fpga_env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
    let fpga_out = ga::search(&analysis, &fpga_env, &GaConfig::default());
    assert!(
        fpga_out.compile_hours > 3.0 * gpu_out.compile_hours,
        "FPGA GA {} h vs GPU GA {} h",
        fpga_out.compile_hours,
        gpu_out.compile_hours
    );
}

#[test]
fn mixed_full_scale_selects_fpga_for_the_paper_apps() {
    for (app, lo, hi) in [(&apps::TDFIR, 3.0, 5.0), (&apps::MRIQ, 5.5, 9.0)] {
        let t = mixed_search(
            app,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            /*test_scale=*/ false,
        )
        .unwrap();
        let summary: Vec<(Destination, f64)> = t
            .searches
            .iter()
            .map(|s| (s.destination, s.speedup))
            .collect();
        assert_eq!(t.winner, Destination::Fpga, "{}: {summary:?}", app.name);
        assert!(
            (lo..=hi).contains(&t.speedup),
            "{}: winning speedup {} outside [{lo}, {hi}]",
            app.name,
            t.speedup
        );
        let fpga = &t.searches[0];
        let gpu = &t.searches[1];
        assert!(
            gpu.speedup < fpga.speedup,
            "{}: GPU {} must trail FPGA {}",
            app.name,
            gpu.speedup,
            fpga.speedup
        );
        // automation-time contrast on the one shared clock
        assert!(fpga.compile_hours / fpga.patterns_measured as f64 > 2.0);
        assert!(gpu.patterns_measured > 0);
        assert!(gpu.compile_hours / gpu.patterns_measured as f64 < 0.5);
        assert!(t.sim_hours > 0.0);
    }
}

#[test]
fn mixed_never_loses_to_all_cpu_on_any_app() {
    for app in apps::all() {
        let t = mixed_search(
            app,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            /*test_scale=*/ true,
        )
        .unwrap();
        assert_eq!(t.searches.len(), 2, "{}", app.name);
        assert_eq!(t.searches[0].destination, Destination::Fpga);
        assert_eq!(t.searches[1].destination, Destination::Gpu);
        assert!(
            t.speedup >= 1.0,
            "{}: mixed placement lost to all-CPU ({})",
            app.name,
            t.speedup
        );
        // winner selection must be *consistent* with the per-backend
        // results, not just clamped: the winner is the best improving
        // destination, or CPU exactly when nothing improved.
        let improving: Vec<_> = t
            .searches
            .iter()
            .filter(|s| s.best.is_some() && s.speedup > 1.0)
            .collect();
        // the coordinator's own rule: highest speedup, NaN rejected,
        // ties to the earlier (FPGA-first) search
        let winner = flopt::util::order::select_best(
            improving.iter().enumerate(),
            |(_, s)| s.speedup,
            |(i, _)| *i,
        )
        .map(|(_, s)| s);
        match winner {
            Some(best) => {
                assert_eq!(t.winner, best.destination, "{}", app.name);
                assert_eq!(t.speedup, best.speedup, "{}", app.name);
            }
            None => {
                assert_eq!(t.winner, Destination::Cpu, "{}", app.name);
                assert_eq!(t.speedup, 1.0, "{}", app.name);
            }
        }
    }
}
