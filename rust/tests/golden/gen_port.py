#!/usr/bin/env python3
"""Independent Python port of `flopt gen` used to produce the committed
goldens `gen_s42_n3.txt`, the static `apps.txt` table, and the static
`env.txt` environment report.

This is deliberately a from-scratch reimplementation of
`rust/src/util/rng.rs` (SplitMix64-seeded xoshiro256** with Lemire
integer reduction) and `rust/src/apps/gen.rs`: the golden test then
checks the Rust generator against bytes that were NOT produced by the
Rust generator, so a silent behaviour drift in either the RNG or the
emitter fails the suite instead of blessing itself.  `env.txt` mirrors
the fully static format strings of `flopt env` (config::fig3_table plus
the backend description lines) for the same reason.

Usage:
    python3 gen_port.py   # rewrites gen_s42_n3.txt, apps.txt, env.txt
"""

import os

MASK = (1 << 64) - 1
MIX = 0x9E3779B97F4A7C15
ARRAY_LEN = 96


def _splitmix64(state):
    state = (state + MIX) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** with SplitMix64 seeding — mirrors util/rng.rs."""

    def __init__(self, seed):
        sm = seed & MASK
        self.s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            self.s.append(v)

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def below(self, n):
        assert n > 0
        x = self.next_u64()
        m = x * n
        low = m & MASK
        if low < n:
            threshold = ((1 << 64) - n) % n  # n.wrapping_neg() % n
            while low < threshold:
                x = self.next_u64()
                m = x * n
                low = m & MASK
        return m >> 64

    def range_i64(self, lo, hi):
        assert hi >= lo
        return lo + self.below(hi - lo + 1)


def program_seed(seed, index):
    return seed ^ ((index * MIX) & MASK)


def emit_construct(lines, rng, kind, c, n):
    if kind == 0:
        a = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        d1 = rng.range_i64(1, 9)
        d2 = rng.range_i64(1, 9)
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(
            f"        arr{a}[i{c}] = sin(i{c} * 0.0{d1}) + cos(i{c} * 0.0{d2}) * 0.5;"
        )
        lines.append("    }")
    elif kind == 1:
        a = rng.below(n)
        b = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        d1 = rng.range_i64(1, 9)
        d2 = rng.range_i64(1, 9)
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        arr{a}[i{c}] = arr{b}[i{c}] * 1.{d1} + 0.{d2};")
        lines.append("    }")
    elif kind == 2:
        a = rng.below(n)
        b = (a + 1) % n
        hi = rng.range_i64(16, ARRAY_LEN)
        g = rng.range_i64(1, 4)
        d = rng.range_i64(1, 9)
        lines.append(f"    for (int i{c} = 1; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        if (i{c} > {g}) {{")
        lines.append(
            f"            arr{a}[i{c}] = arr{b}[i{c} - 1] * 0.{d} + arr{b}[i{c}] * 0.5;"
        )
        lines.append("        }")
        lines.append("    }")
    elif kind == 3:
        a = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        slot = rng.range_i64(4, 7)
        lines.append(f"    float s{c};")
        lines.append(f"    s{c} = 0.0;")
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        s{c} += arr{a}[i{c}] * arr{a}[i{c}];")
        lines.append("    }")
        lines.append(f"    stats_out[{slot}] = s{c};")
    elif kind == 4:
        a = rng.below(n)
        b = (a + 1) % n
        taps = rng.range_i64(4, 12)
        hi = rng.range_i64(16, ARRAY_LEN)
        if rng.below(2) == 1:
            e = rng.below(n)
            tap = f"arr{e}[k{c}]"
        else:
            d = rng.range_i64(1, 9)
            tap = f"0.{d}"
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        float acc{c};")
        lines.append(f"        acc{c} = 0.0;")
        lines.append(f"        for (int k{c} = 0; k{c} < {taps}; k{c}++) {{")
        lines.append(f"            if (i{c} - k{c} >= 0) {{")
        lines.append(f"                acc{c} += arr{a}[i{c} - k{c}] * {tap};")
        lines.append("            }")
        lines.append("        }")
        lines.append(f"        arr{b}[i{c}] = acc{c};")
        lines.append("    }")
    elif kind == 5:
        src = rng.below(n)
        h = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        int b{c};")
        lines.append(f"        b{c} = floor((arr{src}[i{c}] + 4.0) * 2.0);")
        lines.append(f"        if (b{c} < 0) {{")
        lines.append(f"            b{c} = 0;")
        lines.append("        }")
        lines.append(f"        if (b{c} > 15) {{")
        lines.append(f"            b{c} = 15;")
        lines.append("        }")
        lines.append(f"        arr{h}[b{c}] += 1.0;")
        lines.append("    }")
    elif kind == 6:
        a = rng.below(n)
        b = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        d = rng.range_i64(1, 9)
        lines.append(f"    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{")
        lines.append(f"        arr{a}[i{c}] = sqrt(fabs(arr{b}[i{c}])) + 0.{d};")
        lines.append("    }")
    elif kind == 7:
        a = rng.below(n)
        b = rng.below(n)
        dst = rng.below(n)
        lines.append(f"    for (int i{c} = 0; i{c} < 8; i{c}++) {{")
        lines.append(f"        for (int j{c} = 0; j{c} < 8; j{c}++) {{")
        lines.append(f"            float m{c};")
        lines.append(f"            m{c} = 0.0;")
        lines.append(f"            for (int k{c} = 0; k{c} < 8; k{c}++) {{")
        lines.append(
            f"                m{c} += arr{a}[i{c} * 8 + k{c}] * arr{b}[k{c} * 8 + j{c}];"
        )
        lines.append("            }")
        lines.append(f"            arr{dst}[i{c} * 8 + j{c}] = m{c};")
        lines.append("        }")
        lines.append("    }")
    else:
        a = rng.below(n)
        hi = rng.range_i64(16, ARRAY_LEN)
        d = rng.range_i64(1, 9)
        lines.append(f"    int w{c};")
        lines.append(f"    w{c} = 0;")
        lines.append(f"    while (w{c} < {hi}) {{")
        lines.append(f"        arr{a}[w{c}] += 0.{d};")
        lines.append(f"        w{c} = w{c} + 1;")
        lines.append("    }")


def gen_source(seed, index):
    rng = Rng(program_seed(seed, index))
    n_arrays = rng.range_i64(2, 4)

    lines = [f"// gen seed={seed} index={index}", "float stats_out[8];"]
    for a in range(n_arrays):
        lines.append(f"float arr{a}[{ARRAY_LEN}];")
    lines.append("")
    lines.append("void main() {")

    constructs = rng.range_i64(2, 5)
    for c in range(constructs):
        kind = 0 if c == 0 else rng.below(9)
        emit_construct(lines, rng, kind, c, n_arrays)

    for slot in range(4):
        a = rng.below(n_arrays)
        idx = rng.range_i64(0, ARRAY_LEN - 1)
        lines.append(f"    stats_out[{slot}] = arr{a}[{idx}];")
    lines.append("}")
    return "\n".join(lines) + "\n"


# (name, loop_count, description, paper_loop_count) rows of `flopt apps`,
# in apps::all() order; loop counts are the pinned values from
# rust/tests/new_workloads.rs / the .mc header comments.
APPS = [
    ("tdfir", 36, "Time-domain finite impulse response filter (HPEC Challenge)", 36),
    ("mriq", 16, "MRI-Q non-Cartesian reconstruction (Parboil)", 16),
    ("matmul", 5, "Dense single-precision matrix multiply", None),
    ("laplace2d", 9, "2-D Laplace stencil (Jacobi sweeps)", None),
    ("histogram", 6, "Histogram + pointwise transform pipeline", None),
    ("fft", 8, "Radix-2 FFT butterfly sweep (strided cross-read pairs)", None),
    ("spmv", 7, "Sparse CSR matrix-vector product (indirect gather)", None),
    ("stencil3d", 9, "3-D 7-point heat stencil (Jacobi sweeps)", None),
    ("nbody", 6, "All-pairs n-body gravitational interaction", None),
]


def apps_table():
    out = []
    for name, loops, desc, paper in APPS:
        suffix = f"  [paper: {paper}]" if paper is not None else ""
        out.append(f"{name:<12} {loops:>3} loops  {desc}{suffix}")
    return "\n".join(out) + "\n"


# (name, hardware, cpu, ram, fpga, os, accel_stack) rows of the paper's
# Fig 3 testbed, mirroring config::FIG3_TESTBED.
FIG3_TESTBED = [
    (
        "Verification machine",
        "Dell PowerEdge R740",
        "Intel Xeon Bronze 3104 (6C/1.7GHz)",
        "32GB RDIMM DDR4-2666 x2",
        "Intel PAC with Intel Arria10 GX FPGA",
        "CentOS 7.4",
        "Intel Acceleration Stack 1.2",
    ),
    (
        "Running environment",
        "Dell PowerEdge R740",
        "Intel Xeon Bronze 3104 (6C/1.7GHz)",
        "32GB RDIMM DDR4-2666 x2",
        "Intel PAC with Intel Arria10 GX FPGA",
        "CentOS 7.4",
        "Intel Acceleration Stack 1.2",
    ),
    (
        "Client",
        "HP ProBook 470 G3",
        "Intel Core i5-6200U @2.3GHz",
        "8GB",
        "-",
        "Windows 7 Professional",
        "-",
    ),
]

# `{:<5} model: {}` lines in Target::Mixed.backends() order, then the
# CPU model; the descriptions come from the static device constants
# (fpga::device::ARRIA10_GX, backend::gpu's Tesla P100, cpu::XEON_3104).
ENV_MODELS = [
    (
        "FPGA",
        "Intel PAC with Intel Arria10 GX FPGA"
        " | base fmax 280 MHz | PCIe 6.0 GB/s | full compile ~3 h",
    ),
    (
        "GPU",
        "NVIDIA Tesla P100 (PCIe, 16 GB)"
        " | 56 SMs | PCIe 12.0 GB/s | full build ~2.5 min",
    ),
    ("CPU", "Intel Xeon Bronze 3104 @ 1.70GHz"),
]


def env_table():
    out = [
        "Name                   | Hardware               | CPU            "
        "                    | RAM      | FPGA                            "
        "       | OS         | Accel stack",
        "-" * 150,
    ]
    for name, hw, cpu, ram, fpga, osname, accel in FIG3_TESTBED:
        out.append(
            f"{name:<22} | {hw:<22} | {cpu:<34} | {ram:<8} | {fpga:<38}"
            f" | {osname:<10} | {accel}"
        )
    out.append("")  # println!("{}", fig3_table()) adds a blank line
    for kind, desc in ENV_MODELS:
        out.append(f"{kind:<5} model: {desc}")
    return "\n".join(out) + "\n"


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    # `flopt gen --seed 42 --count 3`: programs separated by one blank line
    gen = "\n".join(gen_source(42, i) for i in range(3))
    with open(os.path.join(here, "gen_s42_n3.txt"), "w") as f:
        f.write(gen)
    with open(os.path.join(here, "apps.txt"), "w") as f:
        f.write(apps_table())
    with open(os.path.join(here, "env.txt"), "w") as f:
        f.write(env_table())
    print("wrote gen_s42_n3.txt, apps.txt, and env.txt")


if __name__ == "__main__":
    main()
