//! Source lint: the analysis front end (`ir/`), the dependence engine
//! (`analyze/`), the interpreter (`interp/`), the simulated clock
//! (`metrics/`), and the observability layer (`obs/`) are
//! `Symbol`-keyed by design — identifier/metric maps
//! on their hot paths hash a `u32`, never string bytes.  This test
//! greps the sources so a `HashMap<String, _>` (or `&str`-keyed) map
//! can't creep back in unnoticed; a genuinely cold, deliberate
//! exception can opt out with a `lint-allow: string-key` comment on the
//! same line.

use std::fs;
use std::path::{Path, PathBuf};

/// Directories whose identifier maps must be `Symbol`-keyed.
const SCANNED_DIRS: &[&str] = &[
    "rust/src/ir",
    "rust/src/analyze",
    "rust/src/interp",
    "rust/src/metrics",
    "rust/src/obs",
];

/// Map/set types keyed by owned or borrowed strings (matched with all
/// whitespace stripped, so spacing variants can't dodge the lint).
const BANNED: &[&str] = &[
    "HashMap<String",
    "BTreeMap<String",
    "HashSet<String",
    "BTreeSet<String",
    "HashMap<&",
    "BTreeMap<&",
    "HashSet<&",
    "BTreeSet<&",
];

const ALLOW_MARKER: &str = "lint-allow: string-key";

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries =
        fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()));
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn ir_and_interp_hot_paths_stay_symbol_keyed() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = Vec::new();
    for dir in SCANNED_DIRS {
        rs_files(&root.join(dir), &mut files);
    }
    assert!(
        files.len() >= 5,
        "lint scanned only {} files — directory layout changed?",
        files.len()
    );

    let mut violations = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        for (lineno, line) in src.lines().enumerate() {
            if line.contains(ALLOW_MARKER) {
                continue;
            }
            let flat: String = line.chars().filter(|c| !c.is_whitespace()).collect();
            if BANNED.iter().any(|b| flat.contains(b)) {
                violations.push(format!(
                    "{}:{}: {}",
                    path.display(),
                    lineno + 1,
                    line.trim()
                ));
            }
        }
    }
    assert!(
        violations.is_empty(),
        "string-keyed map on a Symbol-keyed hot path — key by \
         `crate::util::intern::Symbol` instead (or justify with a \
         `{ALLOW_MARKER}` comment):\n{}",
        violations.join("\n")
    );
}
