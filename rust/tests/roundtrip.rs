//! cparse round-trip coverage: pretty-print → reparse → identical AST
//! for every registered application (the two paper apps and the extra
//! workloads).
//!
//! Source positions necessarily differ after printing, so ASTs are
//! compared with positions normalized to `Pos::default()`.

use flopt::apps;
use flopt::cparse::ast::{strip_positions, Program};
use flopt::cparse::{parse, pretty};

#[test]
fn every_registered_app_round_trips_to_an_identical_ast() {
    for app in apps::all() {
        let p1 = app.parse();
        let printed = pretty::program(&p1);
        let p2 = parse(&printed)
            .unwrap_or_else(|e| panic!("{}: reparse failed: {e}\n{printed}", app.name));
        assert_eq!(
            strip_positions(&p1),
            strip_positions(&p2),
            "{}: pretty-print must reparse to the identical AST",
            app.name
        );
    }
}

#[test]
fn printing_is_a_fixpoint_for_every_app() {
    for app in apps::all() {
        let p1 = app.parse();
        let printed = pretty::program(&p1);
        let p2 = parse(&printed).expect("reparse");
        assert_eq!(pretty::program(&p2), printed, "{}", app.name);
    }
}

#[test]
fn loop_ids_survive_the_round_trip() {
    for app in apps::all() {
        let p1 = app.parse();
        let p2 = parse(&pretty::program(&p1)).expect("reparse");
        let ids = |p: &Program| {
            flopt::ir::loops::extract(p)
                .into_iter()
                .map(|l| (l.id, l.function.clone(), l.depth))
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&p1), ids(&p2), "{}", app.name);
    }
}

#[test]
fn interned_symbols_render_their_original_spelling() {
    // the AST stores identifiers as interned `Symbol`s; printing must
    // resolve every one back to the source spelling, byte for byte —
    // the interner may never canonicalize, truncate, or rename
    let src = "float weights_Out1[8];\n\
               float _tmp[8];\n\n\
               void main() {\n\
               \x20   int loopVar_2;\n\
               \x20   for (loopVar_2 = 0; loopVar_2 < 8; loopVar_2++) {\n\
               \x20       weights_Out1[loopVar_2] = _tmp[loopVar_2] * 2.0;\n\
               \x20   }\n\
               }\n";
    let p = parse(src).expect("parse");
    let printed = pretty::program(&p);
    for name in ["weights_Out1", "_tmp", "loopVar_2", "main"] {
        assert!(
            printed.contains(name),
            "printed source lost the spelling of `{name}`:\n{printed}"
        );
        let sym = flopt::util::intern::Symbol::intern(name);
        assert_eq!(sym.as_str(), name, "Symbol round-trip for `{name}`");
        assert_eq!(sym.to_string(), name, "Display for `{name}`");
    }
    // and the printed spelling reparses to the same interned symbols
    let p2 = parse(&printed).expect("reparse");
    assert_eq!(
        strip_positions(&p),
        strip_positions(&p2),
        "spelling-preserving print must reparse identically"
    );
}

#[test]
fn round_tripped_programs_behave_identically() {
    // the reparse of the printed source must produce the same dynamic
    // profile (trip counts) as the original at test scale
    for app in [&apps::MATMUL, &apps::HISTOGRAM] {
        let p1 = app.parse();
        let p2 = parse(&pretty::program(&p1)).expect("reparse");
        let run = |p: &Program| {
            let mut it = app.interp(p, true);
            it.run_main().expect("run");
            it.read_array(app.stats_array).expect("stats")
        };
        assert_eq!(run(&p1), run(&p2), "{}", app.name);
    }
}
