//! cparse round-trip coverage: pretty-print → reparse → identical AST
//! for every registered application (the two paper apps and the extra
//! workloads).
//!
//! Source positions necessarily differ after printing, so ASTs are
//! compared with positions normalized to `Pos::default()`.

use flopt::apps;
use flopt::cparse::ast::{Decl, ForHeader, Function, Program, Stmt};
use flopt::cparse::error::Pos;
use flopt::cparse::{parse, pretty};

fn norm_decl(d: &Decl) -> Decl {
    Decl { pos: Pos::default(), ..d.clone() }
}

fn norm_stmts(body: &[Stmt]) -> Vec<Stmt> {
    body.iter().map(norm_stmt).collect()
}

fn norm_stmt(s: &Stmt) -> Stmt {
    match s {
        Stmt::Decl(d) => Stmt::Decl(norm_decl(d)),
        Stmt::Assign { target, op, value, .. } => Stmt::Assign {
            target: target.clone(),
            op: *op,
            value: value.clone(),
            pos: Pos::default(),
        },
        Stmt::If { cond, then_branch, else_branch, .. } => Stmt::If {
            cond: cond.clone(),
            then_branch: norm_stmts(then_branch),
            else_branch: norm_stmts(else_branch),
            pos: Pos::default(),
        },
        Stmt::For { id, header, body, .. } => Stmt::For {
            id: *id,
            header: ForHeader {
                init: header.init.as_deref().map(|s| Box::new(norm_stmt(s))),
                cond: header.cond.clone(),
                step: header.step.as_deref().map(|s| Box::new(norm_stmt(s))),
            },
            body: norm_stmts(body),
            pos: Pos::default(),
        },
        Stmt::While { id, cond, body, .. } => Stmt::While {
            id: *id,
            cond: cond.clone(),
            body: norm_stmts(body),
            pos: Pos::default(),
        },
        Stmt::Return(e, _) => Stmt::Return(e.clone(), Pos::default()),
        Stmt::Expr(e, _) => Stmt::Expr(e.clone(), Pos::default()),
        Stmt::Block(body) => Stmt::Block(norm_stmts(body)),
    }
}

fn normalize(p: &Program) -> Program {
    Program {
        globals: p.globals.iter().map(norm_decl).collect(),
        functions: p
            .functions
            .iter()
            .map(|f| Function {
                ret: f.ret.clone(),
                name: f.name.clone(),
                params: f.params.clone(),
                body: norm_stmts(&f.body),
                pos: Pos::default(),
            })
            .collect(),
    }
}

#[test]
fn every_registered_app_round_trips_to_an_identical_ast() {
    for app in apps::all() {
        let p1 = app.parse();
        let printed = pretty::program(&p1);
        let p2 = parse(&printed)
            .unwrap_or_else(|e| panic!("{}: reparse failed: {e}\n{printed}", app.name));
        assert_eq!(
            normalize(&p1),
            normalize(&p2),
            "{}: pretty-print must reparse to the identical AST",
            app.name
        );
    }
}

#[test]
fn printing_is_a_fixpoint_for_every_app() {
    for app in apps::all() {
        let p1 = app.parse();
        let printed = pretty::program(&p1);
        let p2 = parse(&printed).expect("reparse");
        assert_eq!(pretty::program(&p2), printed, "{}", app.name);
    }
}

#[test]
fn loop_ids_survive_the_round_trip() {
    for app in apps::all() {
        let p1 = app.parse();
        let p2 = parse(&pretty::program(&p1)).expect("reparse");
        let ids = |p: &Program| {
            flopt::ir::loops::extract(p)
                .into_iter()
                .map(|l| (l.id, l.function.clone(), l.depth))
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(&p1), ids(&p2), "{}", app.name);
    }
}

#[test]
fn round_tripped_programs_behave_identically() {
    // the reparse of the printed source must produce the same dynamic
    // profile (trip counts) as the original at test scale
    for app in [&apps::MATMUL, &apps::HISTOGRAM] {
        let p1 = app.parse();
        let p2 = parse(&pretty::program(&p1)).expect("reparse");
        let run = |p: &Program| {
            let mut it = app.interp(p, true);
            it.run_main().expect("run");
            it.read_array(app.stats_array).expect("stats")
        };
        assert_eq!(run(&p1), run(&p2), "{}", app.name);
    }
}
