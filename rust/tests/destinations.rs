//! `Destination` round-trips: CLI parsing, trace labels, report JSON —
//! plus the CLI's unknown-`--target` error path.

use flopt::apps;
use flopt::backend::{Destination, Target, FPGA, GPU};
use flopt::cache::codec;
use flopt::config::SearchConfig;
use flopt::coordinator::mixed::DestinationSearch;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::util::json;

const ALL: [Destination; 3] = [Destination::Cpu, Destination::Fpga, Destination::Gpu];

#[test]
fn every_variant_roundtrips_through_cli_parsing() {
    for d in ALL {
        assert_eq!(Destination::parse(d.as_str()), Some(d), "canonical label");
        assert_eq!(
            Destination::parse(&d.as_str().to_ascii_lowercase()),
            Some(d),
            "parsing is case-insensitive"
        );
        assert_eq!(format!("{d}"), d.as_str(), "Display matches the label");
    }
    assert_eq!(Destination::parse("tpu"), None);
    assert_eq!(Destination::parse(""), None);
}

#[test]
fn target_parsing_covers_destinations_and_rejects_unknowns() {
    assert_eq!(Target::parse("fpga"), Some(Target::Fpga));
    assert_eq!(Target::parse("GPU"), Some(Target::Gpu));
    assert_eq!(Target::parse("mixed"), Some(Target::Mixed));
    assert_eq!(Target::parse("cpu"), None, "the baseline is not a search target");
    assert_eq!(Target::parse("npu"), None);
    assert_eq!(Target::Fpga.destination(), Some(Destination::Fpga));
    assert_eq!(Target::Gpu.destination(), Some(Destination::Gpu));
    assert_eq!(Target::Mixed.destination(), None);
}

#[test]
fn every_variant_roundtrips_through_report_json() {
    for d in ALL {
        let ds = DestinationSearch {
            app_name: "probe".to_string(),
            destination: d,
            method: "ga",
            speedup: 1.5,
            best: None,
            patterns_measured: 3,
            compile_hours: 0.25,
            cpu_time_s: 0.01,
        };
        let encoded = json::to_string(&codec::destination_to_json(&ds));
        let back = codec::destination_from_json(&json::parse(&encoded).unwrap())
            .expect("decode");
        assert_eq!(back.destination, d, "JSON round-trip must preserve the variant");
        assert!(
            ds.render().contains(d.as_str()),
            "report render must label the destination: {}",
            ds.render()
        );
    }
}

#[test]
fn trace_labels_carry_the_destination() {
    for (backend, label) in [
        (&FPGA as &'static dyn flopt::backend::OffloadBackend, "FPGA"),
        (&GPU, "GPU"),
    ] {
        let env = VerifyEnv::new(backend, &XEON_3104, SearchConfig::default());
        let t = offload_search(&apps::MATMUL, &env, true).unwrap();
        assert_eq!(t.destination.as_str(), label);
        let rendered = t.render();
        assert!(
            rendered.contains(&format!("matmul → {label}")),
            "trace header must label {label}: {rendered}"
        );
        assert!(
            rendered.contains(&format!("on {label}")) || rendered.contains(&format!("no {label}")),
            "solution line must label {label}: {rendered}"
        );
    }
}

#[test]
fn unknown_cli_target_errors_helpfully() {
    let exe = env!("CARGO_BIN_EXE_flopt");
    let out = std::process::Command::new(exe)
        .args(["offload", "matmul", "--target", "tpu"])
        .output()
        .expect("run flopt");
    assert_eq!(out.status.code(), Some(2), "bad --target must exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("unknown --target `tpu`"),
        "error must name the bad value: {stderr}"
    );
    assert!(
        stderr.contains("fpga") && stderr.contains("gpu") && stderr.contains("mixed"),
        "error must list the accepted targets: {stderr}"
    );
}

#[test]
fn missing_flag_values_name_the_flag_and_exit_2() {
    let exe = env!("CARGO_BIN_EXE_flopt");
    for flag in ["--target", "--blocks", "--cache-dir", "--a", "--d", "--boards", "--pool"] {
        let out = std::process::Command::new(exe)
            .args(["offload", "matmul", flag])
            .output()
            .expect("run flopt");
        assert_eq!(out.status.code(), Some(2), "{flag}: a missing value must exit 2");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            stderr.contains(&format!("missing value for {flag}")),
            "{flag}: error must name the missing flag: {stderr}"
        );
    }
}

#[test]
fn non_numeric_flag_values_name_flag_and_value_and_exit_2() {
    let exe = env!("CARGO_BIN_EXE_flopt");
    let out = std::process::Command::new(exe)
        .args(["offload", "matmul", "--a", "lots"])
        .output()
        .expect("run flopt");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("invalid value for --a: `lots`"),
        "error must name the flag and the bad value: {stderr}"
    );
}

#[test]
fn unknown_cli_blocks_mode_errors_helpfully() {
    let exe = env!("CARGO_BIN_EXE_flopt");
    let out = std::process::Command::new(exe)
        .args(["offload", "matmul", "--blocks", "sometimes"])
        .output()
        .expect("run flopt");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("unknown --blocks `sometimes`"),
        "error must name the bad value: {stderr}"
    );
}
