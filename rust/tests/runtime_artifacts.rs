//! Integration over the PJRT runtime: load the AOT artifacts, execute
//! them, and prove the three layers agree — MiniC interpreter (L3 CPU
//! reference) vs Pallas kernel (L1, "FPGA" variant) vs pure-jnp graph
//! (L2 oracle), all through real XLA execution.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).

use flopt::apps;
use flopt::backend::FPGA;
use flopt::config::SearchConfig;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::runtime::{default_artifact_dir, Runtime};

fn runtime() -> Runtime {
    Runtime::load(default_artifact_dir())
        .expect("artifacts missing — run `make artifacts` before `cargo test`")
}

#[test]
fn manifest_lists_all_four_artifacts() {
    let rt = runtime();
    assert_eq!(
        rt.artifact_names(),
        vec!["mriq_cpu", "mriq_fpga", "tdfir_cpu", "tdfir_fpga"]
    );
}

#[test]
fn artifact_specs_match_paper_shapes() {
    let rt = runtime();
    let t = rt.spec("tdfir_fpga").unwrap();
    assert_eq!(t.input_shapes, vec![vec![4096], vec![4096], vec![128], vec![128]]);
    assert_eq!(t.num_outputs, 2);
    let m = rt.spec("mriq_fpga").unwrap();
    assert_eq!(m.input_shapes.len(), 8);
    assert_eq!(m.num_outputs, 2);
}

#[test]
fn tdfir_identity_filter_through_pjrt() {
    // h = delta => y == x, an analytic check straight through XLA
    let rt = runtime();
    let n = 4096;
    let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut hr = vec![0.0f32; 128];
    hr[0] = 1.0;
    let inputs = vec![x.clone(), vec![0.0; n], hr, vec![0.0; 128]];
    let out = rt.execute_f32("tdfir_fpga", &inputs).unwrap();
    assert_eq!(out.len(), 2);
    for i in 0..n {
        assert!((out[0][i] - x[i]).abs() < 1e-5, "yr[{i}]");
        assert!(out[1][i].abs() < 1e-5, "yi[{i}]");
    }
}

#[test]
fn fpga_and_cpu_variants_agree_on_random_input() {
    let rt = runtime();
    let mut rng = flopt::util::rng::Rng::new(2024);
    for (fpga, cpu) in [("tdfir_fpga", "tdfir_cpu"), ("mriq_fpga", "mriq_cpu")] {
        let spec = rt.spec(fpga).unwrap().clone();
        let inputs: Vec<Vec<f32>> = spec
            .input_shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>())
                    .map(|_| rng.range_f64(-1.0, 1.0) as f32)
                    .collect()
            })
            .collect();
        let a = rt.execute_f32(fpga, &inputs).unwrap();
        let b = rt.execute_f32(cpu, &inputs).unwrap();
        for (va, vb) in a.iter().zip(&b) {
            for (x, y) in va.iter().zip(vb) {
                assert!((x - y).abs() < 0.05, "{fpga} vs {cpu}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn wrong_input_count_is_rejected() {
    let rt = runtime();
    assert!(rt.execute_f32("tdfir_fpga", &[vec![0.0; 4096]]).is_err());
}

#[test]
fn wrong_input_length_is_rejected() {
    let rt = runtime();
    let bad = vec![vec![0.0f32; 7]; 4];
    assert!(rt.execute_f32("tdfir_fpga", &bad).is_err());
}

#[test]
fn unknown_artifact_is_rejected() {
    let rt = runtime();
    assert!(rt.execute_f32("nope", &[]).is_err());
}

#[test]
fn numerics_check_passes_for_both_paper_apps() {
    // THE three-layer composition test: interpreter vs pallas vs jnp
    let rt = runtime();
    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
    for app in [&apps::TDFIR, &apps::MRIQ] {
        let check = env.check_numerics(app, &rt).expect("check runs");
        assert!(
            check.passed,
            "{}: max_err {} / vs cpu artifact {}",
            app.name, check.max_abs_err, check.max_abs_err_vs_cpu_artifact
        );
    }
}
