//! Serve suite (PR 7): the long-lived offload daemon must be a pure
//! function of its config — byte-identical across worker-pool sizes and
//! reproducible from the seed — while demonstrating tenant churn with
//! warm re-joins, quota fairness under a heavy hitter, and consistent
//! live-migration accounting.

use flopt::cache::CacheStore;
use flopt::serve::{run_serve, Arrival, ServeConfig};

/// A small-but-representative config: ~10 simulated hours of load over
/// the default 6 tenants with churn on.
fn base_cfg() -> ServeConfig {
    ServeConfig { requests: 500, ..ServeConfig::default() }
}

#[test]
fn report_is_byte_identical_across_pool_sizes() {
    let mut renders = Vec::new();
    for pool in [1usize, 2, 8] {
        let cfg = ServeConfig { pool, ..base_cfg() };
        let report = run_serve(&cfg, CacheStore::fresh()).unwrap();
        renders.push((pool, report.render()));
    }
    let (_, first) = &renders[0];
    for (pool, r) in &renders[1..] {
        assert_eq!(r, first, "pool {pool} changed the serve report");
    }
}

#[test]
fn report_is_byte_identical_across_pools_with_quota_and_eviction() {
    // the full composition: quotas, a cache TTL, and a memory budget
    // must all stay deterministic under any worker count
    let mut renders = Vec::new();
    for pool in [1usize, 8] {
        let cfg = ServeConfig {
            pool,
            quota: 15,
            cache_ttl_s: Some(6.0 * 3600.0),
            cache_budget_bytes: Some(64 * 1024),
            ..base_cfg()
        };
        let report = run_serve(&cfg, CacheStore::fresh()).unwrap();
        renders.push(report.render());
    }
    assert_eq!(renders[0], renders[1]);
}

#[test]
fn same_seed_reproduces_and_different_seed_diverges() {
    let a = run_serve(&base_cfg(), CacheStore::fresh()).unwrap();
    let b = run_serve(&base_cfg(), CacheStore::fresh()).unwrap();
    assert_eq!(a, b, "same seed must reproduce the full report struct");
    assert_eq!(a.render(), b.render());

    let c = run_serve(&ServeConfig { seed: 43, ..base_cfg() }, CacheStore::fresh()).unwrap();
    assert_ne!(
        a.render(),
        c.render(),
        "a different seed must produce a different arrival stream"
    );
}

#[test]
fn churn_joins_leave_and_rejoin_warm_on_a_pinned_trace() {
    // 60 arrivals every half hour → 30 simulated hours → epoch
    // boundaries at 4,8,...,28 h: joins fire at epochs 1,3,5,7 and
    // leaves at 3,6.  By epoch 5 the only inactive tenant is one that
    // already ran (epoch-3 leaver or an initial spare), so its re-join
    // is served entirely from warm cache artifacts — same at epoch 7.
    let arrivals: Vec<Arrival> = (0..60)
        .map(|i| Arrival { at_s: (i + 1) as f64 * 1800.0, tenant: Some(0), pick: 0.0 })
        .collect();
    let cfg = ServeConfig { arrivals: Some(arrivals), ..ServeConfig::default() };
    let report = run_serve(&cfg, CacheStore::fresh()).unwrap();

    assert_eq!(report.epochs, 7);
    assert_eq!(report.joins, 4, "joins at epochs 1, 3, 5, 7");
    assert_eq!(report.leaves, 2, "leaves at epochs 3 and 6");
    assert_eq!(report.warm_joins, 2, "epoch 5 and 7 re-joins are warm");
    assert_eq!(report.rejected_inactive, 0, "tenant 0 never leaves");
    assert_eq!(report.completed, 60);
    assert_eq!(report.repacks, report.epochs + 1, "one re-pack per epoch + initial");
}

#[test]
fn quota_caps_admissions_and_hits_the_heavy_tenant_hardest() {
    let cfg = ServeConfig {
        requests: 800,
        quota: 10,
        churn: false, // fixed 6-tenant population keeps the math clean
        ..ServeConfig::default()
    };
    let report = run_serve(&cfg, CacheStore::fresh()).unwrap();

    assert!(report.rejected_quota > 0, "800 arrivals must overflow 6x10/epoch");
    let windows = report.epochs + 1;
    for t in &report.tenants {
        assert!(
            t.admitted <= windows * cfg.quota,
            "{}: admitted {} exceeds {} windows x quota {}",
            t.name,
            t.admitted,
            windows,
            cfg.quota
        );
        assert_eq!(
            t.admitted, t.completed,
            "{}: every admitted request must complete",
            t.name
        );
    }
    let heavy = &report.tenants[0];
    let max_light = report.tenants[1..].iter().map(|t| t.rejected_quota).max().unwrap();
    assert!(
        heavy.rejected_quota > max_light,
        "the weighted-heavy tenant must absorb the most quota rejections \
         (heavy {} vs max light {})",
        heavy.rejected_quota,
        max_light
    );
    // accounting closes: every arrival is completed or rejected
    assert_eq!(
        report.completed as u64 + report.rejected_quota + report.rejected_inactive,
        report.requests as u64
    );
}

#[test]
fn migration_accounting_is_consistent() {
    let report = run_serve(&base_cfg(), CacheStore::fresh()).unwrap();
    assert!(
        report.migrations > 0 || report.migration_hours == 0.0,
        "swap hours without a counted migration (count {}, hours {})",
        report.migrations,
        report.migration_hours
    );
    assert!(report.migration_hours >= 0.0);
    assert!(report.full_repacks <= report.repacks);
    assert_eq!(report.repacks, report.epochs + 1);
    assert!(report.search_hours > 0.0, "provisioning must cost simulated time");
    assert!(report.compile_hours > 0.0);
    assert_eq!(
        report.completed as u64 + report.rejected_quota + report.rejected_inactive,
        report.requests as u64
    );
    assert!(report.throughput_per_h > 0.0);
    assert!(report.p50_s <= report.p99_s && report.p99_s <= report.max_s);
}
