//! Cache suite (PR 3): a warm re-run of any search must return a
//! bit-identical `SearchTrace` while burning **zero** additional
//! simulated compile-lane hours, through both the in-memory store and a
//! fresh process's on-disk store; corrupt or missing disk entries must
//! fall back to recompute — never to wrong results.

use std::path::PathBuf;
use std::sync::Arc;

use flopt::apps;
use flopt::backend::FPGA;
use flopt::cache::{codec, CacheStore, EvictionPolicy};
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::{offload_search, SearchTrace};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;

/// "Bit-identical" means the canonical serialization is byte-equal
/// (every f64 compared by exact bits via shortest-roundtrip encoding)
/// and the rendered report is byte-equal.
fn assert_bit_identical(app: &str, cold: &SearchTrace, warm: &SearchTrace) {
    assert_eq!(
        codec::trace_to_string(cold),
        codec::trace_to_string(warm),
        "{app}: warm trace must serialize byte-identically"
    );
    assert_eq!(cold.render(), warm.render(), "{app}: rendered reports must match");
    assert_eq!(cold.speedup(), warm.speedup(), "{app}");
    assert_eq!(cold.sim_hours, warm.sim_hours, "{app}");
    assert_eq!(cold.compile_hours, warm.compile_hours, "{app}");
}

fn run_with(store: &Arc<CacheStore>, app: &'static apps::App) -> (SearchTrace, f64, f64) {
    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default())
        .with_cache(Arc::clone(store));
    let t = offload_search(app, &env, true).unwrap();
    (t, env.clock.compile_lane_seconds(), env.clock.total_seconds())
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("flopt-cache-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_memory_rerun_is_bit_identical_and_free_for_all_apps() {
    for app in apps::all() {
        let store = CacheStore::fresh();
        let (cold, cold_lane_s, cold_total_s) = run_with(&store, app);
        assert!(cold_lane_s > 0.0, "{}: cold run must burn compile-lane time", app.name);
        assert!(cold_total_s > 0.0, "{}", app.name);

        let (warm, warm_lane_s, warm_total_s) = run_with(&store, app);
        assert_eq!(warm_lane_s, 0.0, "{}: warm run burned compile-lane hours", app.name);
        assert_eq!(warm_total_s, 0.0, "{}: warm run burned simulated time", app.name);
        assert_bit_identical(app.name, &cold, &warm);
    }
}

#[test]
fn warm_disk_rerun_is_bit_identical_and_free_for_all_apps() {
    let dir = temp_dir("disk");
    // cold run, writing through to disk
    let mut colds = Vec::new();
    {
        let store = CacheStore::with_dir(&dir);
        for app in apps::all() {
            colds.push((app.name, run_with(&store, app).0));
        }
    }
    // fresh store over the same directory — simulates a new process
    // whose in-memory tier is empty
    let store = CacheStore::with_dir(&dir);
    for (app, (name, cold)) in apps::all().into_iter().zip(&colds) {
        assert_eq!(app.name, *name);
        let (warm, lane_s, total_s) = run_with(&store, app);
        assert_eq!(lane_s, 0.0, "{name}: disk-warm run burned compile-lane hours");
        assert_eq!(total_s, 0.0, "{name}: disk-warm run burned simulated time");
        assert_bit_identical(name, cold, &warm);
    }
    assert!(store.stats().disk_hits >= apps::all().len() as u64);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_disk_entries_recompute_never_lie() {
    let dir = temp_dir("corrupt");
    let (cold, _, _) = run_with(&CacheStore::with_dir(&dir), &apps::TDFIR);

    // corrupt every cached payload in the directory
    let mut corrupted = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        std::fs::write(&path, "garbage{{{").unwrap();
        corrupted += 1;
    }
    assert!(corrupted > 0, "the cold run must have persisted artifacts");

    let store = CacheStore::with_dir(&dir);
    let (recomputed, lane_s, _) = run_with(&store, &apps::TDFIR);
    assert!(lane_s > 0.0, "corrupt cache must recompute, not serve garbage");
    assert!(store.stats().disk_rejects > 0, "corrupt payloads must be counted");
    assert_bit_identical("tdfir", &cold, &recomputed);

    // and the recompute must have healed the on-disk entries
    let healed = CacheStore::with_dir(&dir);
    let (warm, lane_s, _) = run_with(&healed, &apps::TDFIR);
    assert_eq!(lane_s, 0.0, "healed cache must serve warm again");
    assert_bit_identical("tdfir", &cold, &warm);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_disk_entries_recompute() {
    let dir = temp_dir("missing");
    let (cold, _, _) = run_with(&CacheStore::with_dir(&dir), &apps::MRIQ);
    // delete everything: equivalent to an empty cache dir
    let _ = std::fs::remove_dir_all(&dir);
    let store = CacheStore::with_dir(&dir);
    let (recomputed, lane_s, _) = run_with(&store, &apps::MRIQ);
    assert!(lane_s > 0.0, "missing entries must recompute");
    assert_bit_identical("mriq", &cold, &recomputed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disabled_cache_matches_default_pipeline_exactly() {
    let (plain, plain_lane, _) = {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(&apps::MATMUL, &env, true).unwrap();
        let lane = env.clock.compile_lane_seconds();
        (t, lane, 0)
    };
    let store = CacheStore::disabled();
    let (a, lane_a, _) = run_with(&store, &apps::MATMUL);
    let (b, lane_b, _) = run_with(&store, &apps::MATMUL);
    assert_eq!(lane_a, plain_lane, "disabled cache must not change accounting");
    assert_eq!(lane_b, plain_lane, "disabled cache re-burns every run");
    assert_bit_identical("matmul", &plain, &a);
    assert_bit_identical("matmul", &a, &b);
}

#[test]
fn stage_cache_shares_precompiles_across_d_configs() {
    // same a/b narrowing, different d: the pre-compile artifact is
    // shared, only measurement re-runs — fewer serial precompile
    // seconds on the second search
    let store = CacheStore::fresh();
    let cfg_d4 = SearchConfig::default();
    let cfg_d6 = SearchConfig { d_patterns: 6, ..SearchConfig::default() };

    let env1 = VerifyEnv::new(&FPGA, &XEON_3104, cfg_d4).with_cache(Arc::clone(&store));
    let t1 = offload_search(&apps::TDFIR, &env1, true).unwrap();
    assert!(t1.sim_hours > 0.0);

    let env2 = VerifyEnv::new(&FPGA, &XEON_3104, cfg_d6).with_cache(Arc::clone(&store));
    let t2 = offload_search(&apps::TDFIR, &env2, true).unwrap();
    // candidates (and their pre-compile reports) are byte-identical —
    // they came from the shared stage artifact
    assert_eq!(t1.candidates.len(), t2.candidates.len());
    for (c1, c2) in t1.candidates.iter().zip(&t2.candidates) {
        assert_eq!(c1.id, c2.id);
        assert_eq!(c1.utilization, c2.utilization);
        assert_eq!(c1.efficiency, c2.efficiency);
    }
    // the d=6 search re-measured but did not re-analyze or re-precompile:
    // its clock shows only compile + measurement time
    let events = env2.clock.events();
    assert!(
        events.iter().all(|e| !e.label.as_str().starts_with("precompile")
            && e.label != "code analysis"
            && e.label != "intensity analysis"),
        "warm stages must not re-charge: {:?}",
        events.iter().map(|e| e.label).collect::<Vec<_>>()
    );
    assert!(events.iter().any(|e| e.compile), "measurement must still compile");
}

#[test]
fn ttl_expiry_recomputes_byte_identical_and_counts_evictions() {
    // a reference trace from an unbounded store
    let (reference, _, _) = run_with(&CacheStore::fresh(), &apps::TDFIR);

    let store = CacheStore::fresh();
    store.set_policy(EvictionPolicy { budget_bytes: None, ttl_s: Some(3600.0) });
    let (cold, _, _) = run_with(&store, &apps::TDFIR);
    assert_bit_identical("tdfir", &reference, &cold);

    // within TTL: still warm and free
    store.set_now_sim_s(1800.0);
    let (warm, lane_s, _) = run_with(&store, &apps::TDFIR);
    assert_eq!(lane_s, 0.0, "entries within TTL must serve warm");
    assert_bit_identical("tdfir", &cold, &warm);

    // past TTL: every search artifact expires — the re-run recomputes,
    // burns compile-lane time again, and lands on identical bytes
    store.set_now_sim_s(2.0 * 24.0 * 3600.0);
    assert!(store.stats().ttl_evictions > 0, "the sweep must count expiries");
    let (recomputed, lane_s, _) = run_with(&store, &apps::TDFIR);
    assert!(lane_s > 0.0, "expired entries must recompute");
    assert_bit_identical("tdfir", &cold, &recomputed);
    assert!(store.stats().evictions() >= store.stats().ttl_evictions);
}

#[test]
fn ttl_expiry_falls_back_to_the_disk_tier_when_one_exists() {
    // with a disk mirror, TTL expiry only empties the memory tier: the
    // re-run re-admits from disk — still free, still byte-identical
    let dir = temp_dir("ttl-disk");
    let store = CacheStore::with_dir(&dir);
    store.set_policy(EvictionPolicy { budget_bytes: None, ttl_s: Some(3600.0) });
    let (cold, _, _) = run_with(&store, &apps::MRIQ);

    store.set_now_sim_s(7.0 * 24.0 * 3600.0);
    assert!(store.stats().ttl_evictions > 0);
    let disk_hits_before = store.stats().disk_hits;
    let (warm, lane_s, _) = run_with(&store, &apps::MRIQ);
    assert_eq!(lane_s, 0.0, "disk tier must absorb the expiry");
    assert!(store.stats().disk_hits > disk_hits_before, "must re-admit from disk");
    assert_bit_identical("mriq", &cold, &warm);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn budget_pressure_evicts_lru_but_never_changes_results() {
    // reference traces from an unbounded store
    let ref_tdfir = run_with(&CacheStore::fresh(), &apps::TDFIR).0;
    let ref_mriq = run_with(&CacheStore::fresh(), &apps::MRIQ).0;

    // a budget far too small to hold both apps' artifacts: the second
    // search must evict the first's, and every re-run must recompute to
    // byte-identical traces
    let store = CacheStore::fresh();
    store.set_policy(EvictionPolicy { budget_bytes: Some(2_000), ttl_s: None });
    let (a, _, _) = run_with(&store, &apps::TDFIR);
    let (b, _, _) = run_with(&store, &apps::MRIQ);
    assert!(
        store.stats().lru_evictions > 0,
        "a 2 kB budget must force LRU evictions (resident {} B)",
        store.resident_bytes()
    );
    assert!(
        store.resident_bytes() <= 2_000,
        "the memory tier must respect its budget"
    );
    assert_bit_identical("tdfir", &ref_tdfir, &a);
    assert_bit_identical("mriq", &ref_mriq, &b);

    let (a2, _, _) = run_with(&store, &apps::TDFIR);
    let (b2, _, _) = run_with(&store, &apps::MRIQ);
    assert_bit_identical("tdfir", &a, &a2);
    assert_bit_identical("mriq", &b, &b2);
    assert_eq!(
        store.stats().evictions(),
        store.stats().ttl_evictions + store.stats().lru_evictions
    );
}
