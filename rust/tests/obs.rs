//! Observability suite (PR 9): the span tracer and metrics registry are
//! stamped in **simulated** time, so every export is a pure function of
//! the inputs.  Pinned here:
//!
//! 1. a cold search emits exactly one span per pipeline stage;
//! 2. trace and metrics exports are byte-identical across pool sizes
//!    1, 2, and 8;
//! 3. a warm re-run adds only cache-hit marker spans and zero new
//!    compile-lane seconds;
//! 4. the Chrome `trace_event` export is well-formed JSON.

use std::sync::Arc;

use flopt::apps;
use flopt::backend::{Target, FPGA};
use flopt::cache::CacheStore;
use flopt::config::SearchConfig;
use flopt::coordinator::pipeline::offload_search;
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::funcblock::BlockMode;
use flopt::obs::export::{render_chrome, render_jsonl, render_prometheus};
use flopt::service::{BatchRequest, BatchService};
use flopt::util::json::{self, Json};

/// The six coordinator stages plus the two function-block stages — the
/// full staged pipeline a cold blocks-on search walks exactly once.
const STAGES: &[&str] = &[
    "stage.analyze",
    "stage.intensity_narrow",
    "stage.precompile",
    "stage.efficiency_narrow",
    "stage.measure_rounds",
    "stage.block_narrow",
    "stage.measure_blocks",
    "stage.select",
];

fn all_apps_both_targets() -> Vec<BatchRequest> {
    let mut reqs = Vec::new();
    for app in apps::all() {
        for target in [Target::Fpga, Target::Gpu] {
            reqs.push(BatchRequest::new(app, target, /*test_scale=*/ true));
        }
    }
    reqs
}

// ---------------------------------------------------------------- 1
#[test]
fn cold_search_emits_exactly_one_span_per_pipeline_stage() {
    let cfg = SearchConfig {
        block_mode: BlockMode::On,
        ..SearchConfig::default()
    };
    let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg).with_cache(CacheStore::fresh());
    offload_search(&apps::TDFIR, &env, true).unwrap();
    let spans = env.clock.obs().spans();
    for stage in STAGES {
        let n = spans.iter().filter(|s| s.name.as_str() == *stage).count();
        assert_eq!(n, 1, "{stage}: expected exactly one span, saw {n}");
    }
    // every stage span is categorized under the pipeline, and no stage
    // name outside the pinned list sneaks in
    for s in spans.iter().filter(|s| s.name.as_str().starts_with("stage.")) {
        assert_eq!(s.cat.as_str(), "pipeline", "{}", s.name.as_str());
        assert!(
            STAGES.contains(&s.name.as_str()),
            "unknown stage span {}",
            s.name.as_str()
        );
    }
    // a cold run hits nothing and misses every cacheable stage once
    let obs = env.clock.obs();
    assert_eq!(obs.counter("cache.miss.trace"), 1);
    assert_eq!(obs.counter("cache.miss.analysis"), 1);
    assert_eq!(obs.counter("cache.miss.precompile"), 1);
    assert_eq!(obs.counter("cache.miss.measure"), 1);
    assert_eq!(obs.counter("cache.miss.blocks"), 1);
    assert!(spans.iter().all(|s| s.cat.as_str() != "cache"));
}

// ---------------------------------------------------------------- 2
#[test]
fn trace_and_metrics_exports_are_byte_identical_across_pool_sizes() {
    let requests = all_apps_both_targets();
    let mut exports = Vec::new();
    for workers in [1usize, 2, 8] {
        let svc = BatchService::new(workers, 2, &XEON_3104);
        let report = svc.run(&requests).unwrap();
        let rec = svc.clock().obs();
        exports.push((
            workers,
            render_jsonl(rec),
            render_chrome(rec),
            render_prometheus(rec, Some(&report.cache)),
        ));
    }
    let (_, ref_jsonl, ref_chrome, ref_prom) = &exports[0];
    assert!(!ref_jsonl.is_empty(), "the span log must not be empty");
    for (workers, jsonl, chrome, prom) in &exports[1..] {
        assert_eq!(jsonl, ref_jsonl, "pool {workers}: span log diverged");
        assert_eq!(chrome, ref_chrome, "pool {workers}: Chrome trace diverged");
        assert_eq!(prom, ref_prom, "pool {workers}: metrics snapshot diverged");
    }
    // deliberately no per-pool gauge exists: the snapshot must not
    // encode the worker count anywhere
    assert!(!ref_prom.contains("workers"), "snapshot leaks the pool size");
}

// ---------------------------------------------------------------- 3
#[test]
fn warm_rerun_adds_only_cache_hit_marks_and_no_lane_time() {
    let requests = all_apps_both_targets();
    let svc = BatchService::new(4, 2, &XEON_3104);
    svc.run(&requests).unwrap();
    let rec = svc.clock().obs();
    let cold_spans = rec.spans().len();
    let cold_lane_s = svc.clock().compile_lane_seconds();
    assert!(cold_lane_s > 0.0, "cold batch must burn compile-lane time");
    assert_eq!(rec.counter("cache.hit.trace"), 0, "cold batch cannot hit");

    svc.run(&requests).unwrap();
    assert_eq!(
        svc.clock().compile_lane_seconds(),
        cold_lane_s,
        "warm batch burned new compile-lane seconds"
    );
    let spans = rec.spans();
    assert!(spans.len() > cold_spans, "warm hits must leave marker spans");
    for s in &spans[cold_spans..] {
        assert_eq!(
            s.cat.as_str(),
            "cache",
            "non-cache span {} appeared on a fully warm re-run",
            s.name.as_str()
        );
        assert_eq!(s.dur_s, 0.0, "cache-hit marks are instant");
    }
    let hits = rec.counter("cache.hit.destination") + rec.counter("cache.hit.trace");
    assert_eq!(hits, requests.len() as u64, "every warm request must count a hit");
}

// ---------------------------------------------------------------- 4
#[test]
fn chrome_trace_export_is_wellformed_json() {
    let store = CacheStore::fresh();
    let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default())
        .with_cache(Arc::clone(&store));
    offload_search(&apps::MRIQ, &env, true).unwrap();
    let text = render_chrome(env.clock.obs());
    let doc = json::parse(&text).expect("chrome trace parses");
    let Json::Obj(o) = doc else {
        panic!("trace document must be an object")
    };
    let Some(Json::Arr(events)) = o.get("traceEvents") else {
        panic!("missing traceEvents array")
    };
    assert!(!events.is_empty());
    for e in events {
        let Json::Obj(e) = e else {
            panic!("every trace event must be an object")
        };
        assert_eq!(e.get("ph"), Some(&Json::Str("X".into())));
        for field in ["name", "cat", "ts", "dur", "pid", "tid"] {
            assert!(e.contains_key(field), "event missing {field}");
        }
    }
}
