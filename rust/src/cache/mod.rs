//! Content-addressed artifact cache for the staged offload pipeline.
//!
//! The paper's entire method exists because one full FPGA compile costs
//! ≈3 hours — every compile avoided is the product.  This subsystem
//! makes *repeat* searches free: each pipeline stage's artifact is keyed
//! by a stable hash of everything that determines it — application
//! source, [`SearchConfig`] narrowing parameters, backend identity, and
//! workload scale — and stored in memory (always) and optionally on disk
//! as JSON (`--cache-dir`, via [`crate::util::json`]).  A warm re-run of
//! a search burns **zero** additional simulated compile-lane hours and
//! returns a bit-identical [`SearchTrace`].
//!
//! Cache-key definition (see DESIGN.md §9 for the rationale):
//!
//! ```text
//! app_fp       = H(app.name, app.source, test_scale flag + overrides)
//! analysis_fp  = H(app.name, loop_count,
//!                  per-loop {id, trips, flops, footprint, traffic,
//!                            intensity bits, offloadable})
//! backend_fp   = H(backend.name, backend.description)   // device identity
//! analyze_key  = H("analyze",    app_fp)
//! precompile_key = H("precompile", app_fp, analysis_fp, backend_fp, a, b, loops?)
//! measure_key  = H("measure",    precompile inputs, c, d, resource_cap, loops?)
//! blocks_key   = H("blocks",     measure inputs, block_mode)
//! trace_key    = H("trace",      app_fp, backend_fp, full SearchConfig)
//! dest_key     = H("destination", app_fp, backend_fp, full SearchConfig)
//! explain_key  = H("explain",    app.name, app.source)   // scale-free
//! ```
//!
//! `loops?` is the loops-enabled flag: `--blocks only` empties the loop
//! stages, so its (empty) stage artifacts key separately, while `off`
//! and `on` share loop-stage artifacts (their loop stages are identical
//! by construction).  The full `SearchConfig` mixed into trace/dest
//! keys includes the block mode.
//!
//! Stage keys include only the inputs that stage actually depends on, so
//! e.g. two searches differing only in `d_patterns` share pre-compile
//! artifacts.  The workload scale enters twice: the literal test-scale
//! flag (trace/destination keys, where the analysis is not yet in hand)
//! and the analysis fingerprint (stage keys, which digest the observed
//! profile — so *any* workload change reshapes the key).
//!
//! Corrupt or missing on-disk entries are never trusted: a payload that
//! fails to parse or decode is discarded and the stage recomputes.

pub mod codec;
pub mod key;
pub mod store;

pub use key::{CacheKey, KeyHasher};
pub use store::{CacheStats, CacheStore, EvictionPolicy};

use crate::apps::App;
use crate::backend::OffloadBackend;
use crate::config::SearchConfig;
use crate::coordinator::pipeline::AppAnalysis;

/// Fingerprint of an application at a workload scale.
pub fn app_fingerprint(app: &App, test_scale: bool) -> u64 {
    let mut h = KeyHasher::new("app");
    h.write_str(app.name).write_str(app.source).write_bool(test_scale);
    if test_scale {
        h.write_usize(app.test_scale.len());
        for (name, v) in app.test_scale {
            h.write_str(name).write_u64(*v as u64);
        }
    }
    h.finish().0
}

/// Fingerprint of a completed Steps-1/2 analysis: digests the observed
/// profile, so any workload-scale or source change reshapes the key.
pub fn analysis_fingerprint(analysis: &AppAnalysis) -> u64 {
    let mut h = KeyHasher::new("analysis");
    h.write_str(&analysis.app_name);
    h.write_usize(analysis.program.loop_count());
    h.write_usize(analysis.intensities.len());
    for li in &analysis.intensities {
        h.write_u64(li.id.0 as u64)
            .write_u64(li.trips)
            .write_u64(li.flops)
            .write_u64(li.footprint_bytes)
            .write_u64(li.traffic_bytes)
            .write_f64(li.intensity)
            .write_bool(li.offloadable);
    }
    h.finish().0
}

/// Fingerprint of a backend (device identity: the description embeds the
/// board model and its headline parameters).
pub fn backend_fingerprint(backend: &dyn OffloadBackend) -> u64 {
    KeyHasher::new("backend")
        .write_str(backend.name())
        .write_str(&backend.description())
        .finish()
        .0
}

fn mix_full_config(h: &mut KeyHasher, cfg: &SearchConfig) {
    h.write_usize(cfg.a_intensity)
        .write_usize(cfg.b_unroll)
        .write_usize(cfg.c_efficiency)
        .write_usize(cfg.d_patterns)
        .write_f64(cfg.resource_cap)
        .write_usize(cfg.compile_parallelism)
        .write_usize(cfg.ga_population)
        .write_usize(cfg.ga_generations)
        .write_str(cfg.block_mode.as_str());
}

/// Do the loop-statement stages actually run under this config?
/// `--blocks only` empties them, so its stage artifacts must not share
/// keys with the loop-enabled modes (`off` and `on` *do* share: the loop
/// stages are identical there by construction).
fn loops_enabled(cfg: &SearchConfig) -> bool {
    cfg.block_mode != crate::funcblock::BlockMode::Only
}

/// Key of the Analyze-stage artifact (backend-independent).
pub fn analyze_key(app: &App, test_scale: bool) -> CacheKey {
    KeyHasher::new("analyze")
        .write_u64(app_fingerprint(app, test_scale))
        .finish()
}

/// Key of the Precompile-stage artifact (depends on the analysis, the
/// backend, and the `a`/`b` narrowing parameters only).
pub fn precompile_key(
    app: &App,
    analysis: &AppAnalysis,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
) -> CacheKey {
    KeyHasher::new("precompile")
        .write_str(app.name)
        .write_str(app.source)
        .write_u64(analysis_fingerprint(analysis))
        .write_u64(backend_fingerprint(backend))
        .write_usize(cfg.a_intensity)
        .write_usize(cfg.b_unroll)
        .write_bool(loops_enabled(cfg))
        .finish()
}

/// Key of the MeasureRounds-stage artifact (adds the `c`/`d` cuts and
/// the resource cap on top of the pre-compile inputs).
pub fn measure_key(
    app: &App,
    analysis: &AppAnalysis,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
) -> CacheKey {
    KeyHasher::new("measure")
        .write_str(app.name)
        .write_str(app.source)
        .write_u64(analysis_fingerprint(analysis))
        .write_u64(backend_fingerprint(backend))
        .write_usize(cfg.a_intensity)
        .write_usize(cfg.b_unroll)
        .write_usize(cfg.c_efficiency)
        .write_usize(cfg.d_patterns)
        .write_f64(cfg.resource_cap)
        .write_bool(loops_enabled(cfg))
        .finish()
}

/// Key of the MeasureBlocks-stage artifact
/// ([`crate::coordinator::stages::BlockMeasureArtifact`]): the measure
/// inputs (combined placements ride the best loop pattern) plus the
/// block mode itself (`on` and `only` measure different combinations).
pub fn blocks_key(
    app: &App,
    analysis: &AppAnalysis,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
) -> CacheKey {
    KeyHasher::new("blocks")
        .write_str(app.name)
        .write_str(app.source)
        .write_u64(analysis_fingerprint(analysis))
        .write_u64(backend_fingerprint(backend))
        .write_usize(cfg.a_intensity)
        .write_usize(cfg.b_unroll)
        .write_usize(cfg.c_efficiency)
        .write_usize(cfg.d_patterns)
        .write_f64(cfg.resource_cap)
        .write_str(cfg.block_mode.as_str())
        .finish()
}

/// Key of a complete [`crate::coordinator::pipeline::SearchTrace`].
pub fn trace_key(
    app: &App,
    test_scale: bool,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
) -> CacheKey {
    let mut h = KeyHasher::new("trace");
    h.write_u64(app_fingerprint(app, test_scale))
        .write_u64(backend_fingerprint(backend));
    mix_full_config(&mut h, cfg);
    h.finish()
}

/// Key of an `flopt explain` artifact.  Dependence diagnostics are pure
/// static analysis — they depend only on the source text, never on the
/// workload scale, the backend, or the search config, so the key digests
/// the app name and source alone.
pub fn explain_key(app: &App) -> CacheKey {
    KeyHasher::new("explain")
        .write_str(app.name)
        .write_str(app.source)
        .finish()
}

/// Key of a complete fleet placement report ([`crate::fleet`]): the
/// ordered tenant set, workload scale, board-backend identity, the full
/// search config, and the board count — any change to any tenant's
/// search inputs reshapes the key.
pub fn fleet_key(
    apps: &[&App],
    test_scale: bool,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
    boards: usize,
) -> CacheKey {
    let mut h = KeyHasher::new("fleet");
    h.write_usize(apps.len());
    for app in apps {
        h.write_u64(app_fingerprint(app, test_scale));
    }
    h.write_u64(backend_fingerprint(backend));
    mix_full_config(&mut h, cfg);
    h.write_usize(boards);
    h.finish()
}

/// Key of a complete [`crate::coordinator::mixed::DestinationSearch`]
/// (the batch service's request-level unit of work).
pub fn destination_key(
    app: &App,
    test_scale: bool,
    backend: &dyn OffloadBackend,
    cfg: &SearchConfig,
) -> CacheKey {
    let mut h = KeyHasher::new("destination");
    h.write_u64(app_fingerprint(app, test_scale))
        .write_u64(backend_fingerprint(backend));
    mix_full_config(&mut h, cfg);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::{FPGA, GPU};

    #[test]
    fn keys_separate_apps_backends_scales_and_configs() {
        let cfg = SearchConfig::default();
        let base = trace_key(&apps::TDFIR, true, &FPGA, &cfg);
        assert_eq!(base, trace_key(&apps::TDFIR, true, &FPGA, &cfg));
        assert_ne!(base, trace_key(&apps::MRIQ, true, &FPGA, &cfg));
        assert_ne!(base, trace_key(&apps::TDFIR, false, &FPGA, &cfg));
        assert_ne!(base, trace_key(&apps::TDFIR, true, &GPU, &cfg));
        let mut wider = cfg.clone();
        wider.d_patterns = 6;
        assert_ne!(base, trace_key(&apps::TDFIR, true, &FPGA, &wider));
    }

    #[test]
    fn stage_keys_ignore_unrelated_config_knobs() {
        let analysis =
            crate::coordinator::pipeline::analyze_app(&apps::MATMUL, true).unwrap();
        let cfg = SearchConfig::default();
        let mut lanes = cfg.clone();
        lanes.compile_parallelism = 4; // affects makespan, not artifacts
        assert_eq!(
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &cfg),
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &lanes)
        );
        assert_eq!(
            measure_key(&apps::MATMUL, &analysis, &FPGA, &cfg),
            measure_key(&apps::MATMUL, &analysis, &FPGA, &lanes)
        );
        let mut more_d = cfg.clone();
        more_d.d_patterns = 6; // reshapes measurement, not pre-compiles
        assert_eq!(
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &cfg),
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &more_d)
        );
        assert_ne!(
            measure_key(&apps::MATMUL, &analysis, &FPGA, &cfg),
            measure_key(&apps::MATMUL, &analysis, &FPGA, &more_d)
        );
    }

    #[test]
    fn block_mode_reshapes_exactly_the_right_keys() {
        use crate::funcblock::BlockMode;
        let analysis =
            crate::coordinator::pipeline::analyze_app(&apps::MATMUL, true).unwrap();
        let off = SearchConfig::default();
        let mut on = off.clone();
        on.block_mode = BlockMode::On;
        let mut only = off.clone();
        only.block_mode = BlockMode::Only;

        // off and on share loop-stage artifacts; only does not
        assert_eq!(
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &off),
            precompile_key(&apps::MATMUL, &analysis, &FPGA, &on)
        );
        assert_ne!(
            measure_key(&apps::MATMUL, &analysis, &FPGA, &on),
            measure_key(&apps::MATMUL, &analysis, &FPGA, &only)
        );
        // the block artifact and the trace separate all three modes
        assert_ne!(
            blocks_key(&apps::MATMUL, &analysis, &FPGA, &on),
            blocks_key(&apps::MATMUL, &analysis, &FPGA, &only)
        );
        assert_ne!(
            trace_key(&apps::MATMUL, true, &FPGA, &off),
            trace_key(&apps::MATMUL, true, &FPGA, &on)
        );
        assert_ne!(
            trace_key(&apps::MATMUL, true, &FPGA, &on),
            trace_key(&apps::MATMUL, true, &FPGA, &only)
        );
        // backend identity still separates block artifacts
        assert_ne!(
            blocks_key(&apps::MATMUL, &analysis, &FPGA, &on),
            blocks_key(&apps::MATMUL, &analysis, &GPU, &on)
        );
    }

    #[test]
    fn analysis_fingerprint_tracks_scale() {
        let small = crate::coordinator::pipeline::analyze_app(&apps::MATMUL, true).unwrap();
        let full = crate::coordinator::pipeline::analyze_app(&apps::MATMUL, false).unwrap();
        assert_ne!(analysis_fingerprint(&small), analysis_fingerprint(&full));
        assert_eq!(analysis_fingerprint(&small), analysis_fingerprint(&small));
    }
}
