//! Stable content hashing for cache keys.
//!
//! Keys must be identical across processes and Rust versions (the
//! on-disk store is addressed by them), so the hasher is a fixed-seed
//! FNV-1a 64 rather than `std::collections::hash_map::DefaultHasher`
//! (SipHash with a per-process random key).

use std::fmt;

/// A content-addressed cache key (64-bit FNV-1a digest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey(pub u64);

impl fmt::Display for CacheKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 hasher with typed, length-prefixed writes (so
/// `"ab" + "c"` and `"a" + "bc"` hash differently).
#[derive(Debug, Clone)]
pub struct KeyHasher(u64);

impl KeyHasher {
    /// A fresh hasher, domain-separated by `tag` (e.g. `"trace"`).
    pub fn new(tag: &str) -> Self {
        let mut h = KeyHasher(FNV_OFFSET);
        h.write_str(tag);
        h
    }

    /// Mix raw bytes.
    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for b in bytes {
            self.0 ^= *b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Mix a length-prefixed string.
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes())
    }

    /// Mix a `u64` (little-endian bytes).
    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write_bytes(&v.to_le_bytes())
    }

    /// Mix a `usize`.
    pub fn write_usize(&mut self, v: usize) -> &mut Self {
        self.write_u64(v as u64)
    }

    /// Mix an `f64` by its exact bit pattern.
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    /// Mix a boolean.
    pub fn write_bool(&mut self, v: bool) -> &mut Self {
        self.write_bytes(&[v as u8])
    }

    /// Finish into a key.
    pub fn finish(&self) -> CacheKey {
        CacheKey(self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_tag_separated() {
        let k1 = KeyHasher::new("trace").write_str("tdfir").finish();
        let k2 = KeyHasher::new("trace").write_str("tdfir").finish();
        let k3 = KeyHasher::new("measure").write_str("tdfir").finish();
        assert_eq!(k1, k2);
        assert_ne!(k1, k3);
    }

    #[test]
    fn length_prefix_prevents_concat_collisions() {
        let a = KeyHasher::new("t").write_str("ab").write_str("c").finish();
        let b = KeyHasher::new("t").write_str("a").write_str("bc").finish();
        assert_ne!(a, b);
    }

    #[test]
    fn stable_reference_digest() {
        // pin the digest so an accidental hasher change (which would
        // orphan every on-disk cache entry) fails loudly
        let k = KeyHasher::new("ref").write_str("flopt").write_u64(42).finish();
        assert_eq!(k, KeyHasher::new("ref").write_str("flopt").write_u64(42).finish());
        assert_eq!(format!("{k}").len(), 16);
    }

    #[test]
    fn typed_writes_mix() {
        let base = KeyHasher::new("t").write_f64(1.0).finish();
        assert_ne!(base, KeyHasher::new("t").write_f64(-1.0).finish());
        assert_ne!(
            KeyHasher::new("t").write_bool(true).finish(),
            KeyHasher::new("t").write_bool(false).finish()
        );
    }
}
