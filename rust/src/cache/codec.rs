//! JSON (de)serialization of cached pipeline artifacts.
//!
//! Built on the in-tree [`crate::util::json`] (no external crates).  The
//! encoding is **lossless for `f64`**: finite numbers go through Rust's
//! shortest-roundtrip `Display` in the writer and parse back to the same
//! bits; non-finite values (a failed compile records `time_s = inf`) are
//! encoded as the strings `"inf"` / `"-inf"` / `"nan"`.  Every decoder
//! returns `Option` — a corrupt or truncated payload yields `None` and
//! the caller recomputes; the cache never fabricates a result.

use crate::analyze::ExplainArtifact;
use crate::backend::gpu::GpuKernelReport;
use crate::backend::{BackendReport, Destination, ReportDetail};
use crate::coordinator::mixed::DestinationSearch;
use crate::coordinator::pipeline::{CandidateReport, SearchTrace};
use crate::coordinator::stages::{BlockMeasureArtifact, MeasureArtifact, PrecompileArtifact};
use crate::coordinator::verify_env::PatternMeasurement;
use crate::fleet::{AppPlacement, BoardReport, FleetReport, FleetStatus};
use crate::funcblock::{BlockMeasurement, BlockMode};
use crate::cparse::ast::{LoopId, Type};
use crate::fpga::device::Resources;
use crate::fpga::timing::KernelExec;
use crate::hls::{HlsReport, OpCounts};
use crate::intensity::LoopIntensity;
use crate::opencl::{KernelArg, KernelSource, OffloadPattern, OpenClCode};
use crate::util::json::{self, Json};

/// Format version stamped into every payload; bump on layout changes so
/// stale on-disk entries decode to `None` and recompute.  v2 added the
/// function-block fields (`block_mode`, `blocks`, `best_block`) and the
/// `blocks` artifact kind.
pub const VERSION: f64 = 2.0;

// ---------------------------------------------------------------- helpers

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".to_string())
    } else if v > 0.0 {
        Json::Str("inf".to_string())
    } else {
        Json::Str("-inf".to_string())
    }
}

fn f64_of(j: &Json) -> Option<f64> {
    match j {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            "nan" => Some(f64::NAN),
            _ => None,
        },
        _ => None,
    }
}

fn get_f64(j: &Json, k: &str) -> Option<f64> {
    f64_of(j.get(k)?)
}

fn get_u64(j: &Json, k: &str) -> Option<u64> {
    // reject fractional or negative payloads outright — a bit-flipped
    // disk entry must recompute, never round into a "valid" value
    match j.get(k)? {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
        _ => None,
    }
}

fn get_u32(j: &Json, k: &str) -> Option<u32> {
    get_u64(j, k).map(|v| v as u32)
}

fn get_usize(j: &Json, k: &str) -> Option<usize> {
    get_u64(j, k).map(|v| v as usize)
}

fn get_bool(j: &Json, k: &str) -> Option<bool> {
    match j.get(k)? {
        Json::Bool(b) => Some(*b),
        _ => None,
    }
}

fn get_str<'a>(j: &'a Json, k: &str) -> Option<&'a str> {
    j.get(k)?.as_str()
}

fn get_arr<'a>(j: &'a Json, k: &str) -> Option<&'a [Json]> {
    j.get(k)?.as_arr()
}

fn check_header(j: &Json, kind: &str) -> Option<()> {
    (get_str(j, "kind")? == kind && get_f64(j, "v")? == VERSION).then_some(())
}

/// Is this a well-formed payload written by a *different* codec version?
/// The store treats these as silent stale misses — a documented format
/// bump must not be reported (or counted) as disk corruption.
pub fn is_stale_version(j: &Json) -> bool {
    match j.get("v") {
        Some(Json::Num(v)) => *v != VERSION,
        _ => false,
    }
}

fn loop_ids_to_json(ids: &[LoopId]) -> Json {
    Json::Arr(ids.iter().map(|l| Json::Num(l.0 as f64)).collect())
}

fn loop_ids_from_json(j: &Json) -> Option<Vec<LoopId>> {
    j.as_arr()?
        .iter()
        .map(|v| {
            v.as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| LoopId(n as u32))
        })
        .collect()
}

// ------------------------------------------------------------- components

fn type_to_json(t: &Type) -> Json {
    match t {
        Type::Void => Json::Str("void".to_string()),
        Type::Int => Json::Str("int".to_string()),
        Type::Float => Json::Str("float".to_string()),
        Type::Double => Json::Str("double".to_string()),
        Type::Array(elem, len) => obj(vec![
            ("elem", type_to_json(elem)),
            ("len", len.map(|n| Json::Num(n as f64)).unwrap_or(Json::Null)),
        ]),
    }
}

fn type_from_json(j: &Json) -> Option<Type> {
    match j {
        Json::Str(s) => match s.as_str() {
            "void" => Some(Type::Void),
            "int" => Some(Type::Int),
            "float" => Some(Type::Float),
            "double" => Some(Type::Double),
            _ => None,
        },
        Json::Obj(_) => {
            let elem = type_from_json(j.get("elem")?)?;
            let len = match j.get("len")? {
                Json::Null => None,
                Json::Num(n) => Some(*n as usize),
                _ => return None,
            };
            Some(Type::Array(Box::new(elem), len))
        }
        _ => None,
    }
}

fn ops_to_json(o: &OpCounts) -> Json {
    obj(vec![
        ("fadd", Json::Num(o.fadd as f64)),
        ("fmul", Json::Num(o.fmul as f64)),
        ("fdiv", Json::Num(o.fdiv as f64)),
        ("trig", Json::Num(o.trig as f64)),
        ("sqrt", Json::Num(o.sqrt as f64)),
        ("exp", Json::Num(o.exp as f64)),
        ("fmisc", Json::Num(o.fmisc as f64)),
        ("int_ops", Json::Num(o.int_ops as f64)),
        ("cmps", Json::Num(o.cmps as f64)),
        ("arrays", Json::Num(o.arrays as f64)),
        ("plus_reductions", Json::Num(o.plus_reductions as f64)),
        ("star_reductions", Json::Num(o.star_reductions as f64)),
        ("nest_depth", Json::Num(o.nest_depth as f64)),
    ])
}

fn ops_from_json(j: &Json) -> Option<OpCounts> {
    Some(OpCounts {
        fadd: get_u32(j, "fadd")?,
        fmul: get_u32(j, "fmul")?,
        fdiv: get_u32(j, "fdiv")?,
        trig: get_u32(j, "trig")?,
        sqrt: get_u32(j, "sqrt")?,
        exp: get_u32(j, "exp")?,
        fmisc: get_u32(j, "fmisc")?,
        int_ops: get_u32(j, "int_ops")?,
        cmps: get_u32(j, "cmps")?,
        arrays: get_u32(j, "arrays")?,
        plus_reductions: get_u32(j, "plus_reductions")?,
        star_reductions: get_u32(j, "star_reductions")?,
        nest_depth: get_u32(j, "nest_depth")?,
    })
}

fn resources_to_json(r: &Resources) -> Json {
    obj(vec![
        ("alms", num(r.alms)),
        ("ffs", num(r.ffs)),
        ("luts", num(r.luts)),
        ("dsps", num(r.dsps)),
        ("m20ks", num(r.m20ks)),
    ])
}

fn resources_from_json(j: &Json) -> Option<Resources> {
    Some(Resources {
        alms: get_f64(j, "alms")?,
        ffs: get_f64(j, "ffs")?,
        luts: get_f64(j, "luts")?,
        dsps: get_f64(j, "dsps")?,
        m20ks: get_f64(j, "m20ks")?,
    })
}

fn hls_to_json(r: &HlsReport) -> Json {
    obj(vec![
        ("loop_id", Json::Num(r.loop_id.0 as f64)),
        ("unroll", Json::Num(r.unroll as f64)),
        ("resources", resources_to_json(&r.resources)),
        ("utilization", num(r.utilization)),
        ("ii", Json::Num(r.ii as f64)),
        ("depth", Json::Num(r.depth as f64)),
        ("fmax_hz", num(r.fmax_hz)),
        ("precompile_s", num(r.precompile_s)),
        ("ops", ops_to_json(&r.ops)),
    ])
}

fn hls_from_json(j: &Json) -> Option<HlsReport> {
    Some(HlsReport {
        loop_id: LoopId(get_u32(j, "loop_id")?),
        unroll: get_usize(j, "unroll")?,
        resources: resources_from_json(j.get("resources")?)?,
        utilization: get_f64(j, "utilization")?,
        ii: get_u32(j, "ii")?,
        depth: get_u32(j, "depth")?,
        fmax_hz: get_f64(j, "fmax_hz")?,
        precompile_s: get_f64(j, "precompile_s")?,
        ops: ops_from_json(j.get("ops")?)?,
    })
}

fn gpu_to_json(r: &GpuKernelReport) -> Json {
    obj(vec![
        ("loop_id", Json::Num(r.loop_id.0 as f64)),
        ("ops", ops_to_json(&r.ops)),
        ("occupancy", num(r.occupancy)),
        ("simt_speedup", num(r.simt_speedup)),
        ("compile_s", num(r.compile_s)),
    ])
}

fn gpu_from_json(j: &Json) -> Option<GpuKernelReport> {
    Some(GpuKernelReport {
        loop_id: LoopId(get_u32(j, "loop_id")?),
        ops: ops_from_json(j.get("ops")?)?,
        occupancy: get_f64(j, "occupancy")?,
        simt_speedup: get_f64(j, "simt_speedup")?,
        compile_s: get_f64(j, "compile_s")?,
    })
}

fn backend_report_to_json(r: &BackendReport) -> Json {
    let (device, detail) = match &r.detail {
        ReportDetail::Fpga(h) => ("fpga", hls_to_json(h)),
        ReportDetail::Gpu(g) => ("gpu", gpu_to_json(g)),
    };
    obj(vec![
        ("loop_id", Json::Num(r.loop_id.0 as f64)),
        ("utilization", num(r.utilization)),
        ("precompile_s", num(r.precompile_s)),
        ("device", Json::Str(device.to_string())),
        ("detail", detail),
    ])
}

fn backend_report_from_json(j: &Json) -> Option<BackendReport> {
    let detail = match get_str(j, "device")? {
        "fpga" => ReportDetail::Fpga(hls_from_json(j.get("detail")?)?),
        "gpu" => ReportDetail::Gpu(gpu_from_json(j.get("detail")?)?),
        _ => return None,
    };
    Some(BackendReport {
        loop_id: LoopId(get_u32(j, "loop_id")?),
        utilization: get_f64(j, "utilization")?,
        precompile_s: get_f64(j, "precompile_s")?,
        detail,
    })
}

fn candidate_to_json(c: &CandidateReport) -> Json {
    obj(vec![
        ("id", Json::Num(c.id.0 as f64)),
        ("intensity", num(c.intensity)),
        ("utilization", num(c.utilization)),
        ("efficiency", num(c.efficiency)),
        ("report", backend_report_to_json(&c.report)),
    ])
}

fn candidate_from_json(j: &Json) -> Option<CandidateReport> {
    Some(CandidateReport {
        id: LoopId(get_u32(j, "id")?),
        intensity: get_f64(j, "intensity")?,
        utilization: get_f64(j, "utilization")?,
        efficiency: get_f64(j, "efficiency")?,
        report: backend_report_from_json(j.get("report")?)?,
    })
}

fn intensity_to_json(l: &LoopIntensity) -> Json {
    obj(vec![
        ("id", Json::Num(l.id.0 as f64)),
        ("function", Json::Str(l.function.clone())),
        ("trips", Json::Num(l.trips as f64)),
        ("flops", Json::Num(l.flops as f64)),
        ("footprint_bytes", Json::Num(l.footprint_bytes as f64)),
        ("traffic_bytes", Json::Num(l.traffic_bytes as f64)),
        ("intensity", num(l.intensity)),
        ("offloadable", Json::Bool(l.offloadable)),
    ])
}

fn intensity_from_json(j: &Json) -> Option<LoopIntensity> {
    Some(LoopIntensity {
        id: LoopId(get_u32(j, "id")?),
        function: get_str(j, "function")?.to_string(),
        trips: get_u64(j, "trips")?,
        flops: get_u64(j, "flops")?,
        footprint_bytes: get_u64(j, "footprint_bytes")?,
        traffic_bytes: get_u64(j, "traffic_bytes")?,
        intensity: get_f64(j, "intensity")?,
        offloadable: get_bool(j, "offloadable")?,
    })
}

fn kernel_exec_to_json(k: &KernelExec) -> Json {
    obj(vec![
        ("loop_id", Json::Num(k.loop_id.0 as f64)),
        ("kernel_s", num(k.kernel_s)),
        ("transfer_in_s", num(k.transfer_in_s)),
        ("transfer_out_s", num(k.transfer_out_s)),
        ("inner_iters", Json::Num(k.inner_iters as f64)),
    ])
}

fn kernel_exec_from_json(j: &Json) -> Option<KernelExec> {
    Some(KernelExec {
        loop_id: LoopId(get_u32(j, "loop_id")?),
        kernel_s: get_f64(j, "kernel_s")?,
        transfer_in_s: get_f64(j, "transfer_in_s")?,
        transfer_out_s: get_f64(j, "transfer_out_s")?,
        inner_iters: get_u64(j, "inner_iters")?,
    })
}

fn measurement_to_json(m: &PatternMeasurement) -> Json {
    obj(vec![
        ("pattern", loop_ids_to_json(&m.pattern.loops)),
        ("utilization", num(m.utilization)),
        ("compiled", Json::Bool(m.compiled)),
        ("compile_sim_s", num(m.compile_sim_s)),
        ("time_s", num(m.time_s)),
        ("speedup", num(m.speedup)),
        (
            "kernels",
            Json::Arr(m.kernels.iter().map(kernel_exec_to_json).collect()),
        ),
    ])
}

fn measurement_from_json(j: &Json) -> Option<PatternMeasurement> {
    Some(PatternMeasurement {
        pattern: OffloadPattern::of(loop_ids_from_json(j.get("pattern")?)?),
        utilization: get_f64(j, "utilization")?,
        compiled: get_bool(j, "compiled")?,
        compile_sim_s: get_f64(j, "compile_sim_s")?,
        time_s: get_f64(j, "time_s")?,
        speedup: get_f64(j, "speedup")?,
        kernels: get_arr(j, "kernels")?
            .iter()
            .map(kernel_exec_from_json)
            .collect::<Option<Vec<_>>>()?,
    })
}

fn kernel_arg_to_json(a: &KernelArg) -> Json {
    obj(vec![
        ("name", Json::Str(a.name.clone())),
        ("decl", Json::Str(a.decl.clone())),
        ("is_array", Json::Bool(a.is_array)),
        ("elem", type_to_json(&a.elem)),
    ])
}

fn kernel_arg_from_json(j: &Json) -> Option<KernelArg> {
    Some(KernelArg {
        name: get_str(j, "name")?.to_string(),
        decl: get_str(j, "decl")?.to_string(),
        is_array: get_bool(j, "is_array")?,
        elem: type_from_json(j.get("elem")?)?,
    })
}

fn kernel_source_to_json(k: &KernelSource) -> Json {
    obj(vec![
        ("loop_id", Json::Num(k.loop_id.0 as f64)),
        ("name", Json::Str(k.name.clone())),
        ("code", Json::Str(k.code.clone())),
        ("args", Json::Arr(k.args.iter().map(kernel_arg_to_json).collect())),
        ("unroll", Json::Num(k.unroll as f64)),
        (
            "shift_register_reductions",
            Json::Arr(
                k.shift_register_reductions
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        ),
    ])
}

fn kernel_source_from_json(j: &Json) -> Option<KernelSource> {
    Some(KernelSource {
        loop_id: LoopId(get_u32(j, "loop_id")?),
        name: get_str(j, "name")?.to_string(),
        code: get_str(j, "code")?.to_string(),
        args: get_arr(j, "args")?
            .iter()
            .map(kernel_arg_from_json)
            .collect::<Option<Vec<_>>>()?,
        unroll: get_usize(j, "unroll")?,
        shift_register_reductions: get_arr(j, "shift_register_reductions")?
            .iter()
            .map(|s| s.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()?,
    })
}

fn opencl_to_json(c: &OpenClCode) -> Json {
    obj(vec![
        ("pattern", loop_ids_to_json(&c.pattern.loops)),
        (
            "kernels",
            Json::Arr(c.kernels.iter().map(kernel_source_to_json).collect()),
        ),
        ("host", Json::Str(c.host.clone())),
    ])
}

fn opencl_from_json(j: &Json) -> Option<OpenClCode> {
    Some(OpenClCode {
        pattern: OffloadPattern::of(loop_ids_from_json(j.get("pattern")?)?),
        kernels: get_arr(j, "kernels")?
            .iter()
            .map(kernel_source_from_json)
            .collect::<Option<Vec<_>>>()?,
        host: get_str(j, "host")?.to_string(),
    })
}

fn block_measurement_to_json(m: &BlockMeasurement) -> Json {
    obj(vec![
        ("block", Json::Str(m.block.clone())),
        ("block_loops", loop_ids_to_json(&m.block_loops)),
        ("extra_loops", loop_ids_to_json(&m.extra_loops)),
        ("utilization", num(m.utilization)),
        ("compiled", Json::Bool(m.compiled)),
        ("compile_sim_s", num(m.compile_sim_s)),
        ("time_s", num(m.time_s)),
        ("speedup", num(m.speedup)),
    ])
}

fn block_measurement_from_json(j: &Json) -> Option<BlockMeasurement> {
    Some(BlockMeasurement {
        block: get_str(j, "block")?.to_string(),
        block_loops: loop_ids_from_json(j.get("block_loops")?)?,
        extra_loops: loop_ids_from_json(j.get("extra_loops")?)?,
        utilization: get_f64(j, "utilization")?,
        compiled: get_bool(j, "compiled")?,
        compile_sim_s: get_f64(j, "compile_sim_s")?,
        time_s: get_f64(j, "time_s")?,
        speedup: get_f64(j, "speedup")?,
    })
}

fn rounds_to_json(rounds: &[Vec<PatternMeasurement>]) -> Json {
    Json::Arr(
        rounds
            .iter()
            .map(|r| Json::Arr(r.iter().map(measurement_to_json).collect()))
            .collect(),
    )
}

fn rounds_from_json(j: &Json) -> Option<Vec<Vec<PatternMeasurement>>> {
    j.as_arr()?
        .iter()
        .map(|r| {
            r.as_arr()?
                .iter()
                .map(measurement_from_json)
                .collect::<Option<Vec<_>>>()
        })
        .collect()
}

// ---------------------------------------------------------- top-level docs

/// Encode a full [`SearchTrace`].
pub fn trace_to_json(t: &SearchTrace) -> Json {
    obj(vec![
        ("kind", Json::Str("trace".to_string())),
        ("v", Json::Num(VERSION)),
        ("app_name", Json::Str(t.app_name.clone())),
        ("destination", Json::Str(t.destination.as_str().to_string())),
        ("loop_count", Json::Num(t.loop_count as f64)),
        (
            "intensities",
            Json::Arr(t.intensities.iter().map(intensity_to_json).collect()),
        ),
        ("top_a", loop_ids_to_json(&t.top_a)),
        (
            "candidates",
            Json::Arr(t.candidates.iter().map(candidate_to_json).collect()),
        ),
        ("top_c", loop_ids_to_json(&t.top_c)),
        ("opencl", Json::Arr(t.opencl.iter().map(opencl_to_json).collect())),
        ("rounds", rounds_to_json(&t.rounds)),
        ("cpu_time_s", num(t.cpu_time_s)),
        (
            "best",
            t.best
                .as_ref()
                .map(measurement_to_json)
                .unwrap_or(Json::Null),
        ),
        ("block_mode", Json::Str(t.block_mode.as_str().to_string())),
        (
            "blocks",
            Json::Arr(t.blocks.iter().map(block_measurement_to_json).collect()),
        ),
        (
            "best_block",
            t.best_block
                .as_ref()
                .map(block_measurement_to_json)
                .unwrap_or(Json::Null),
        ),
        ("sim_hours", num(t.sim_hours)),
        ("compile_hours", num(t.compile_hours)),
    ])
}

/// Decode a [`SearchTrace`]; `None` on any structural mismatch.
pub fn trace_from_json(j: &Json) -> Option<SearchTrace> {
    check_header(j, "trace")?;
    Some(SearchTrace {
        app_name: get_str(j, "app_name")?.to_string(),
        destination: Destination::parse(get_str(j, "destination")?)?,
        loop_count: get_usize(j, "loop_count")?,
        intensities: get_arr(j, "intensities")?
            .iter()
            .map(intensity_from_json)
            .collect::<Option<Vec<_>>>()?,
        top_a: loop_ids_from_json(j.get("top_a")?)?,
        candidates: get_arr(j, "candidates")?
            .iter()
            .map(candidate_from_json)
            .collect::<Option<Vec<_>>>()?,
        top_c: loop_ids_from_json(j.get("top_c")?)?,
        opencl: get_arr(j, "opencl")?
            .iter()
            .map(opencl_from_json)
            .collect::<Option<Vec<_>>>()?,
        rounds: rounds_from_json(j.get("rounds")?)?,
        cpu_time_s: get_f64(j, "cpu_time_s")?,
        best: match j.get("best")? {
            Json::Null => None,
            b => Some(measurement_from_json(b)?),
        },
        block_mode: BlockMode::parse(get_str(j, "block_mode")?)?,
        blocks: get_arr(j, "blocks")?
            .iter()
            .map(block_measurement_from_json)
            .collect::<Option<Vec<_>>>()?,
        best_block: match j.get("best_block")? {
            Json::Null => None,
            b => Some(block_measurement_from_json(b)?),
        },
        sim_hours: get_f64(j, "sim_hours")?,
        compile_hours: get_f64(j, "compile_hours")?,
    })
}

/// Encode a MeasureBlocks-stage artifact.
pub fn blocks_to_json(b: &BlockMeasureArtifact) -> Json {
    obj(vec![
        ("kind", Json::Str("blocks".to_string())),
        ("v", Json::Num(VERSION)),
        (
            "placements",
            Json::Arr(b.placements.iter().map(block_measurement_to_json).collect()),
        ),
    ])
}

/// Decode a MeasureBlocks-stage artifact.
pub fn blocks_from_json(j: &Json) -> Option<BlockMeasureArtifact> {
    check_header(j, "blocks")?;
    Some(BlockMeasureArtifact {
        placements: get_arr(j, "placements")?
            .iter()
            .map(block_measurement_from_json)
            .collect::<Option<Vec<_>>>()?,
    })
}

/// Encode a Precompile-stage artifact.
pub fn precompile_to_json(p: &PrecompileArtifact) -> Json {
    obj(vec![
        ("kind", Json::Str("precompile".to_string())),
        ("v", Json::Num(VERSION)),
        (
            "candidates",
            Json::Arr(p.candidates.iter().map(candidate_to_json).collect()),
        ),
    ])
}

/// Decode a Precompile-stage artifact.
pub fn precompile_from_json(j: &Json) -> Option<PrecompileArtifact> {
    check_header(j, "precompile")?;
    Some(PrecompileArtifact {
        candidates: get_arr(j, "candidates")?
            .iter()
            .map(candidate_from_json)
            .collect::<Option<Vec<_>>>()?,
    })
}

/// Encode a MeasureRounds-stage artifact.
pub fn measure_to_json(m: &MeasureArtifact) -> Json {
    obj(vec![
        ("kind", Json::Str("measure".to_string())),
        ("v", Json::Num(VERSION)),
        ("cpu_time_s", num(m.cpu_time_s)),
        ("opencl", Json::Arr(m.opencl.iter().map(opencl_to_json).collect())),
        ("rounds", rounds_to_json(&m.rounds)),
    ])
}

/// Decode a MeasureRounds-stage artifact.
pub fn measure_from_json(j: &Json) -> Option<MeasureArtifact> {
    check_header(j, "measure")?;
    Some(MeasureArtifact {
        cpu_time_s: get_f64(j, "cpu_time_s")?,
        opencl: get_arr(j, "opencl")?
            .iter()
            .map(opencl_from_json)
            .collect::<Option<Vec<_>>>()?,
        rounds: rounds_from_json(j.get("rounds")?)?,
    })
}

fn fleet_status_to_json(s: &FleetStatus) -> Json {
    let (label, board) = match s {
        FleetStatus::Placed { board } => ("placed", Some(*board)),
        FleetStatus::Queued => ("queued", None),
        FleetStatus::Rejected => ("rejected", None),
        FleetStatus::Cpu => ("cpu", None),
    };
    obj(vec![
        ("status", Json::Str(label.to_string())),
        (
            "board",
            board.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
        ),
    ])
}

fn fleet_status_from_json(j: &Json) -> Option<FleetStatus> {
    match get_str(j, "status")? {
        "placed" => Some(FleetStatus::Placed { board: get_usize(j, "board")? }),
        "queued" => Some(FleetStatus::Queued),
        "rejected" => Some(FleetStatus::Rejected),
        "cpu" => Some(FleetStatus::Cpu),
        _ => None,
    }
}

fn app_placement_to_json(a: &AppPlacement) -> Json {
    obj(vec![
        ("app_name", Json::Str(a.app_name.clone())),
        ("status", fleet_status_to_json(&a.status)),
        ("solution", Json::Str(a.solution.clone())),
        ("kind", Json::Str(a.kind.to_string())),
        ("utilization", num(a.utilization)),
        ("time_s", num(a.time_s)),
        ("speedup", num(a.speedup)),
        ("reconfig_s", num(a.reconfig_s)),
    ])
}

fn app_placement_from_json(j: &Json) -> Option<AppPlacement> {
    let kind = match get_str(j, "kind")? {
        "bitstream" => "bitstream",
        "ip-link" => "ip-link",
        "cpu" => "cpu",
        _ => return None,
    };
    Some(AppPlacement {
        app_name: get_str(j, "app_name")?.to_string(),
        status: fleet_status_from_json(j.get("status")?)?,
        solution: get_str(j, "solution")?.to_string(),
        kind,
        utilization: get_f64(j, "utilization")?,
        time_s: get_f64(j, "time_s")?,
        speedup: get_f64(j, "speedup")?,
        reconfig_s: get_f64(j, "reconfig_s")?,
    })
}

fn board_report_to_json(b: &BoardReport) -> Json {
    obj(vec![
        ("board", Json::Num(b.board as f64)),
        ("utilization", num(b.utilization)),
        ("resources", resources_to_json(&b.resources)),
        (
            "tenants",
            Json::Arr(b.tenants.iter().map(|t| Json::Str(t.clone())).collect()),
        ),
    ])
}

fn board_report_from_json(j: &Json) -> Option<BoardReport> {
    Some(BoardReport {
        board: get_usize(j, "board")?,
        utilization: get_f64(j, "utilization")?,
        resources: resources_from_json(j.get("resources")?)?,
        tenants: get_arr(j, "tenants")?
            .iter()
            .map(|t| t.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()?,
    })
}

/// Encode a fleet placement report.
pub fn fleet_to_json(f: &FleetReport) -> Json {
    obj(vec![
        ("kind", Json::Str("fleet".to_string())),
        ("v", Json::Num(VERSION)),
        ("boards", Json::Num(f.boards as f64)),
        (
            "apps",
            Json::Arr(f.apps.iter().map(app_placement_to_json).collect()),
        ),
        (
            "board_util",
            Json::Arr(f.board_util.iter().map(board_report_to_json).collect()),
        ),
        ("cpu_total_s", num(f.cpu_total_s)),
        ("fleet_total_s", num(f.fleet_total_s)),
        ("aggregate_speedup", num(f.aggregate_speedup)),
        ("reconfig_hours", num(f.reconfig_hours)),
        ("sim_hours", num(f.sim_hours)),
        ("compile_hours", num(f.compile_hours)),
    ])
}

/// Decode a fleet placement report; `None` on any structural mismatch.
pub fn fleet_from_json(j: &Json) -> Option<FleetReport> {
    check_header(j, "fleet")?;
    Some(FleetReport {
        boards: get_usize(j, "boards")?,
        apps: get_arr(j, "apps")?
            .iter()
            .map(app_placement_from_json)
            .collect::<Option<Vec<_>>>()?,
        board_util: get_arr(j, "board_util")?
            .iter()
            .map(board_report_from_json)
            .collect::<Option<Vec<_>>>()?,
        cpu_total_s: get_f64(j, "cpu_total_s")?,
        fleet_total_s: get_f64(j, "fleet_total_s")?,
        aggregate_speedup: get_f64(j, "aggregate_speedup")?,
        reconfig_hours: get_f64(j, "reconfig_hours")?,
        sim_hours: get_f64(j, "sim_hours")?,
        compile_hours: get_f64(j, "compile_hours")?,
    })
}

/// Encode a request-level [`DestinationSearch`] outcome.
pub fn destination_to_json(d: &DestinationSearch) -> Json {
    obj(vec![
        ("kind", Json::Str("destination".to_string())),
        ("v", Json::Num(VERSION)),
        ("app_name", Json::Str(d.app_name.clone())),
        ("destination", Json::Str(d.destination.as_str().to_string())),
        ("method", Json::Str(d.method.to_string())),
        ("speedup", num(d.speedup)),
        (
            "best",
            d.best
                .as_ref()
                .map(measurement_to_json)
                .unwrap_or(Json::Null),
        ),
        ("patterns_measured", Json::Num(d.patterns_measured as f64)),
        ("compile_hours", num(d.compile_hours)),
        ("cpu_time_s", num(d.cpu_time_s)),
    ])
}

/// Decode a [`DestinationSearch`]; unknown method labels decode to `None`.
pub fn destination_from_json(j: &Json) -> Option<DestinationSearch> {
    check_header(j, "destination")?;
    let method = match get_str(j, "method")? {
        "narrowed-2round" => "narrowed-2round",
        "ga" => "ga",
        "ip-registry" => "ip-registry",
        _ => return None,
    };
    Some(DestinationSearch {
        app_name: get_str(j, "app_name")?.to_string(),
        destination: Destination::parse(get_str(j, "destination")?)?,
        method,
        speedup: get_f64(j, "speedup")?,
        best: match j.get("best")? {
            Json::Null => None,
            b => Some(measurement_from_json(b)?),
        },
        patterns_measured: get_usize(j, "patterns_measured")?,
        compile_hours: get_f64(j, "compile_hours")?,
        cpu_time_s: get_f64(j, "cpu_time_s")?,
    })
}

/// Encode an `flopt explain` artifact (both renderings, pre-serialized).
pub fn explain_to_json(a: &ExplainArtifact) -> Json {
    obj(vec![
        ("kind", Json::Str("explain".to_string())),
        ("v", Json::Num(VERSION)),
        ("text", Json::Str(a.text.clone())),
        ("json", Json::Str(a.json.clone())),
    ])
}

/// Decode an `flopt explain` artifact.
pub fn explain_from_json(j: &Json) -> Option<ExplainArtifact> {
    check_header(j, "explain")?;
    Some(ExplainArtifact {
        text: get_str(j, "text")?.to_string(),
        json: get_str(j, "json")?.to_string(),
    })
}

/// Canonical string form of a trace — the definition of "bit-identical"
/// the cache tests compare by.
pub fn trace_to_string(t: &SearchTrace) -> String {
    json::to_string(&trace_to_json(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::offload_search;
    use crate::coordinator::verify_env::VerifyEnv;
    use crate::cpu::XEON_3104;

    #[test]
    fn trace_roundtrips_bit_identically() {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(&apps::TDFIR, &env, true).unwrap();
        let s1 = trace_to_string(&t);
        let parsed = json::parse(&s1).unwrap();
        let back = trace_from_json(&parsed).expect("decode");
        assert_eq!(trace_to_string(&back), s1, "encode∘decode must be identity");
        // exact f64 equality on the load-bearing numbers
        assert_eq!(back.speedup(), t.speedup());
        assert_eq!(back.cpu_time_s, t.cpu_time_s);
        assert_eq!(back.sim_hours, t.sim_hours);
        assert_eq!(back.compile_hours, t.compile_hours);
        assert_eq!(back.render(), t.render());
    }

    #[test]
    fn blocks_on_trace_roundtrips_bit_identically() {
        let cfg = SearchConfig {
            block_mode: crate::funcblock::BlockMode::On,
            ..SearchConfig::default()
        };
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
        let t = offload_search(&apps::TDFIR, &env, true).unwrap();
        assert!(!t.blocks.is_empty(), "tdfir must measure block placements");
        let s1 = trace_to_string(&t);
        let back = trace_from_json(&json::parse(&s1).unwrap()).expect("decode");
        assert_eq!(trace_to_string(&back), s1);
        assert_eq!(back.block_mode, t.block_mode);
        assert_eq!(back.blocks, t.blocks);
        assert_eq!(back.best_block, t.best_block);
        assert_eq!(back.speedup(), t.speedup());
    }

    #[test]
    fn blocks_artifact_roundtrips() {
        let cfg = SearchConfig {
            block_mode: crate::funcblock::BlockMode::On,
            ..SearchConfig::default()
        };
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
        let t = offload_search(&apps::MRIQ, &env, true).unwrap();
        let artifact = BlockMeasureArtifact { placements: t.blocks.clone() };
        let j = blocks_to_json(&artifact);
        let back = blocks_from_json(&j).expect("decode");
        assert_eq!(back.placements, artifact.placements);
        assert!(blocks_from_json(&trace_to_json(&t)).is_none(), "wrong kind rejects");
    }

    #[test]
    fn fleet_report_roundtrips_bit_identically() {
        use crate::service::BatchService;
        let svc = BatchService::new(2, 1, &XEON_3104);
        let apps_list: Vec<&'static crate::apps::App> =
            vec![&apps::TDFIR, &apps::MATMUL];
        let r = crate::fleet::fleet_search(
            &svc,
            &apps_list,
            2,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        let s1 = json::to_string(&fleet_to_json(&r));
        let back = fleet_from_json(&json::parse(&s1).unwrap()).expect("decode");
        assert_eq!(json::to_string(&fleet_to_json(&back)), s1);
        assert_eq!(back, r, "decode must be the identity on every field");
        assert_eq!(back.render(), r.render());
        assert!(fleet_from_json(&Json::Null).is_none());
    }

    #[test]
    fn explain_artifact_roundtrips() {
        let program = apps::TDFIR.parse();
        let a = crate::analyze::explain_program("tdfir", &program).artifact();
        let j = explain_to_json(&a);
        let back = explain_from_json(&j).expect("decode");
        assert_eq!(back, a);
        assert!(explain_from_json(&Json::Null).is_none());
        assert!(
            explain_from_json(&obj(vec![("kind", Json::Str("explain".into()))])).is_none()
        );
    }

    #[test]
    fn non_finite_times_survive() {
        let j = num(f64::INFINITY);
        assert_eq!(f64_of(&j), Some(f64::INFINITY));
        assert_eq!(f64_of(&num(f64::NEG_INFINITY)), Some(f64::NEG_INFINITY));
        assert!(f64_of(&num(f64::NAN)).unwrap().is_nan());
    }

    #[test]
    fn corrupt_payloads_decode_to_none() {
        assert!(trace_from_json(&Json::Null).is_none());
        assert!(trace_from_json(&obj(vec![("kind", Json::Str("trace".into()))])).is_none());
        // right kind, wrong version
        assert!(trace_from_json(&obj(vec![
            ("kind", Json::Str("trace".into())),
            ("v", Json::Num(999.0)),
        ]))
        .is_none());
        // wrong kind entirely
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let t = offload_search(&apps::MATMUL, &env, true).unwrap();
        assert!(precompile_from_json(&trace_to_json(&t)).is_none());
    }

    #[test]
    fn type_encoding_roundtrips() {
        for t in [
            Type::Void,
            Type::Int,
            Type::Float,
            Type::Double,
            Type::Array(Box::new(Type::Float), Some(128)),
            Type::Array(Box::new(Type::Int), None),
        ] {
            assert_eq!(type_from_json(&type_to_json(&t)), Some(t));
        }
    }
}
