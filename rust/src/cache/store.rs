//! The artifact store: always-on in-memory maps plus an optional
//! on-disk JSON mirror.
//!
//! * **Memory** — every `put` lands in a typed `HashMap` behind a
//!   mutex; `get` clones out (analyses are shared as `Arc`, they are the
//!   only artifact too big to clone casually).
//! * **Disk** — when built [`CacheStore::with_dir`], the serializable
//!   artifacts (pre-compiles, measurements, traces, destination
//!   outcomes) are mirrored as `<kind>-<key>.json`; a memory miss falls
//!   through to disk.  Disk entries are never trusted: payloads that
//!   fail to parse or decode are discarded (counted in
//!   [`CacheStats::disk_rejects`]) and the stage recomputes.  All disk
//!   I/O is best-effort — an unwritable directory degrades to
//!   memory-only operation, never to an error.
//! * **Disabled** — [`CacheStore::disabled`] stores nothing and returns
//!   nothing: every search runs exactly as the pre-cache pipeline did.
//!
//! # Eviction
//!
//! Long-lived services (`flopt serve`) cannot let the memory tier grow
//! without bound, so the store takes an [`EvictionPolicy`]:
//!
//! * **LRU under a byte budget** — every serializable artifact is
//!   weighed by the byte length of its canonical JSON encoding; when
//!   `budget_bytes` is exceeded the globally least-recently-*used* slot
//!   (a strictly increasing access sequence number, so victim choice is
//!   deterministic) is dropped until the store fits.
//! * **TTL on simulated time** — the store never consults a wall clock
//!   (that would break byte-identical replay); the service advances
//!   [`CacheStore::set_now_sim_s`] from its own `SimClock`, which sweeps
//!   entries older than `ttl_s`, and `get` lazily expires on touch.
//!
//! Both policies apply to the **memory tier only**: the disk mirror is
//! the persistent tier and keeps every artifact ever written, and the
//! analysis map is exempt (analyses are unserialized `Arc`s, cheap to
//! recompute, and never part of a result).  Eviction therefore can cost
//! time (a recompute) but can never change a result — recomputed
//! artifacts are byte-identical by the determinism the cache tests pin.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::analyze::ExplainArtifact;
use crate::coordinator::mixed::DestinationSearch;
use crate::coordinator::pipeline::{AppAnalysis, SearchTrace};
use crate::coordinator::stages::{BlockMeasureArtifact, MeasureArtifact, PrecompileArtifact};
use crate::fleet::FleetReport;
use crate::util::json::{self, Json};

use super::codec;
use super::key::CacheKey;

/// Hit/miss counters (diagnostics; not part of any cache key).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Artifacts served from memory.
    pub mem_hits: u64,
    /// Artifacts served from the on-disk store.
    pub disk_hits: u64,
    /// Lookups that found nothing and recomputed.
    pub misses: u64,
    /// On-disk payloads discarded as corrupt/undecodable.
    pub disk_rejects: u64,
    /// On-disk entries that *exist* but could not be read (I/O error —
    /// distinct from a clean not-found miss); each one recomputes.
    pub disk_read_errors: u64,
    /// Memory entries dropped because their age (in simulated seconds)
    /// exceeded [`EvictionPolicy::ttl_s`].
    pub ttl_evictions: u64,
    /// Memory entries dropped to get back under
    /// [`EvictionPolicy::budget_bytes`] (least-recently-used first).
    pub lru_evictions: u64,
}

impl CacheStats {
    /// Total recomputes forced by a bad disk entry (corrupt payloads
    /// plus unreadable files) — the corrupt-entry metric `flopt batch`
    /// and the tests watch.
    pub fn corrupt_recomputes(&self) -> u64 {
        self.disk_rejects + self.disk_read_errors
    }

    /// Total memory-tier evictions (TTL plus budget-pressure LRU).
    pub fn evictions(&self) -> u64 {
        self.ttl_evictions + self.lru_evictions
    }
}

/// Memory-tier eviction policy (see module docs): both knobs default to
/// `None` = unbounded, which is exactly the pre-eviction store.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvictionPolicy {
    /// Byte budget for the memory tier (canonical-JSON weight of every
    /// resident serializable artifact); exceeding it evicts LRU-first.
    pub budget_bytes: Option<u64>,
    /// Max age in **simulated** seconds since an artifact was last
    /// written; older entries expire lazily on `get` and eagerly on
    /// [`CacheStore::set_now_sim_s`].
    pub ttl_s: Option<f64>,
}

/// One resident artifact plus the bookkeeping eviction needs.
struct Slot<T> {
    value: T,
    /// Canonical-JSON byte weight (what `budget_bytes` counts).
    bytes: u64,
    /// Access sequence number — strictly increasing store-wide, so the
    /// LRU victim (minimum `seq`) is unique and deterministic.
    seq: u64,
    /// Simulated-time write stamp (TTL measures from last write; a read
    /// refreshes recency, not age).
    stamp_s: f64,
}

#[derive(Default)]
struct Mem {
    /// Analyses are exempt from eviction: unserialized `Arc`s with no
    /// canonical byte weight, cheap to recompute, never part of output.
    analyses: HashMap<CacheKey, Arc<AppAnalysis>>,
    precompiles: HashMap<CacheKey, Slot<PrecompileArtifact>>,
    measures: HashMap<CacheKey, Slot<MeasureArtifact>>,
    blocks: HashMap<CacheKey, Slot<BlockMeasureArtifact>>,
    traces: HashMap<CacheKey, Slot<SearchTrace>>,
    destinations: HashMap<CacheKey, Slot<DestinationSearch>>,
    fleets: HashMap<CacheKey, Slot<FleetReport>>,
    explains: HashMap<CacheKey, Slot<ExplainArtifact>>,
    /// Next access sequence number (shared by every evictable map).
    seq: u64,
    /// Current simulated time; only ever advances (monotonic max).
    now_s: f64,
    /// Total `bytes` of every resident evictable slot.
    resident: u64,
}

/// What touching a memory slot found.
enum Touched<T> {
    Hit(T),
    /// The slot existed but its TTL had lapsed; it has been removed.
    Expired,
    Miss,
}

fn mem_precompiles(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<PrecompileArtifact>> {
    &mut m.precompiles
}
fn mem_measures(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<MeasureArtifact>> {
    &mut m.measures
}
fn mem_blocks(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<BlockMeasureArtifact>> {
    &mut m.blocks
}
fn mem_traces(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<SearchTrace>> {
    &mut m.traces
}
fn mem_destinations(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<DestinationSearch>> {
    &mut m.destinations
}
fn mem_fleets(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<FleetReport>> {
    &mut m.fleets
}
fn mem_explains(m: &mut Mem) -> &mut HashMap<CacheKey, Slot<ExplainArtifact>> {
    &mut m.explains
}

/// Touch one slot: expire it if the TTL lapsed, otherwise refresh its
/// recency and clone the value out.
fn touch<T: Clone>(
    map: &mut HashMap<CacheKey, Slot<T>>,
    key: CacheKey,
    seq: u64,
    now_s: f64,
    ttl_s: Option<f64>,
) -> (Touched<T>, u64) {
    let expired = match map.get(&key) {
        None => return (Touched::Miss, 0),
        Some(slot) => matches!(ttl_s, Some(ttl) if now_s - slot.stamp_s > ttl),
    };
    if expired {
        let slot = map.remove(&key).expect("slot present");
        return (Touched::Expired, slot.bytes);
    }
    let slot = map.get_mut(&key).expect("slot present");
    slot.seq = seq;
    (Touched::Hit(slot.value.clone()), 0)
}

/// Insert (or replace) a slot; returns the byte weight it displaced.
fn insert_slot<T>(
    map: &mut HashMap<CacheKey, Slot<T>>,
    key: CacheKey,
    value: T,
    bytes: u64,
    seq: u64,
    stamp_s: f64,
) -> u64 {
    map.insert(key, Slot { value, bytes, seq, stamp_s })
        .map_or(0, |old| old.bytes)
}

/// Drop every slot older than `ttl` seconds; returns (count, bytes).
fn sweep<T>(map: &mut HashMap<CacheKey, Slot<T>>, now_s: f64, ttl: f64) -> (u64, u64) {
    let mut count = 0;
    let mut bytes = 0;
    map.retain(|_, slot| {
        let keep = now_s - slot.stamp_s <= ttl;
        if !keep {
            count += 1;
            bytes += slot.bytes;
        }
        keep
    });
    (count, bytes)
}

fn scan_oldest<T>(
    map: &HashMap<CacheKey, Slot<T>>,
    kind: u8,
    best: &mut Option<(u64, u8, CacheKey)>,
) {
    for (k, slot) in map {
        let older = match best {
            None => true,
            Some((seq, _, _)) => slot.seq < seq,
        };
        if older {
            *best = Some((slot.seq, kind, *k));
        }
    }
}

impl Mem {
    /// The store-wide least-recently-used slot, if any: access sequence
    /// numbers are unique, so the victim is deterministic.
    fn lru_victim(&self) -> Option<(u8, CacheKey)> {
        let mut best: Option<(u64, u8, CacheKey)> = None;
        scan_oldest(&self.precompiles, 0, &mut best);
        scan_oldest(&self.measures, 1, &mut best);
        scan_oldest(&self.blocks, 2, &mut best);
        scan_oldest(&self.traces, 3, &mut best);
        scan_oldest(&self.destinations, 4, &mut best);
        scan_oldest(&self.fleets, 5, &mut best);
        scan_oldest(&self.explains, 6, &mut best);
        best.map(|(_, kind, key)| (kind, key))
    }

    fn evict_at(&mut self, kind: u8, key: CacheKey) {
        let bytes = match kind {
            0 => self.precompiles.remove(&key).map(|s| s.bytes),
            1 => self.measures.remove(&key).map(|s| s.bytes),
            2 => self.blocks.remove(&key).map(|s| s.bytes),
            3 => self.traces.remove(&key).map(|s| s.bytes),
            4 => self.destinations.remove(&key).map(|s| s.bytes),
            5 => self.fleets.remove(&key).map(|s| s.bytes),
            _ => self.explains.remove(&key).map(|s| s.bytes),
        }
        .unwrap_or(0);
        self.resident = self.resident.saturating_sub(bytes);
    }

    /// Evict LRU-first until the resident set fits; returns the count.
    fn enforce_budget(&mut self, budget: u64) -> u64 {
        let mut evicted = 0;
        while self.resident > budget {
            let Some((kind, key)) = self.lru_victim() else { break };
            self.evict_at(kind, key);
            evicted += 1;
        }
        evicted
    }
}

/// The content-addressed artifact store (see module docs).
pub struct CacheStore {
    enabled: bool,
    dir: Option<PathBuf>,
    mem: Mutex<Mem>,
    policy: Mutex<EvictionPolicy>,
    stats: Mutex<CacheStats>,
}

impl CacheStore {
    fn build(enabled: bool, dir: Option<PathBuf>) -> Arc<CacheStore> {
        Arc::new(CacheStore {
            enabled,
            dir,
            mem: Mutex::new(Mem::default()),
            policy: Mutex::new(EvictionPolicy::default()),
            stats: Mutex::new(CacheStats::default()),
        })
    }

    /// An enabled, memory-only store.
    pub fn fresh() -> Arc<CacheStore> {
        Self::build(true, None)
    }

    /// A store that persists serializable artifacts under `dir`
    /// (created on first write; unwritable directories degrade to
    /// memory-only).
    pub fn with_dir(dir: impl Into<PathBuf>) -> Arc<CacheStore> {
        Self::build(true, Some(dir.into()))
    }

    /// A store that caches nothing (`--no-cache`): every get misses,
    /// every put is a no-op.
    pub fn disabled() -> Arc<CacheStore> {
        Self::build(false, None)
    }

    /// Is this store recording anything at all?
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Snapshot of the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().expect("poisoned")
    }

    /// Install a memory-tier eviction policy; a lowered byte budget
    /// takes effect immediately (LRU slots drop until the store fits).
    pub fn set_policy(&self, policy: EvictionPolicy) {
        *self.policy.lock().expect("poisoned") = policy;
        if let Some(budget) = policy.budget_bytes {
            let evicted = self.mem.lock().expect("poisoned").enforce_budget(budget);
            if evicted > 0 {
                self.stats.lock().expect("poisoned").lru_evictions += evicted;
            }
        }
    }

    /// The current eviction policy.
    pub fn policy(&self) -> EvictionPolicy {
        *self.policy.lock().expect("poisoned")
    }

    /// Advance the store's notion of simulated time (monotonic — stale
    /// updates from out-of-order callers are ignored) and eagerly sweep
    /// TTL-expired entries.  The store never reads a wall clock: callers
    /// running on a [`crate::metrics::SimClock`] feed it their own time
    /// so expiry is reproducible byte-for-byte.
    pub fn set_now_sim_s(&self, now_s: f64) {
        let ttl = self.policy.lock().expect("poisoned").ttl_s;
        let expired = {
            let mut m = self.mem.lock().expect("poisoned");
            if now_s > m.now_s {
                m.now_s = now_s;
            }
            let Some(ttl) = ttl else { return };
            let now = m.now_s;
            let mut count = 0;
            let mut bytes = 0;
            for (c, b) in [
                sweep(&mut m.precompiles, now, ttl),
                sweep(&mut m.measures, now, ttl),
                sweep(&mut m.blocks, now, ttl),
                sweep(&mut m.traces, now, ttl),
                sweep(&mut m.destinations, now, ttl),
                sweep(&mut m.fleets, now, ttl),
                sweep(&mut m.explains, now, ttl),
            ] {
                count += c;
                bytes += b;
            }
            m.resident = m.resident.saturating_sub(bytes);
            count
        };
        if expired > 0 {
            self.stats.lock().expect("poisoned").ttl_evictions += expired;
        }
    }

    /// Total canonical-JSON bytes of the resident evictable artifacts
    /// (what [`EvictionPolicy::budget_bytes`] bounds).
    pub fn resident_bytes(&self) -> u64 {
        self.mem.lock().expect("poisoned").resident
    }

    fn note_mem_hit(&self) {
        self.stats.lock().expect("poisoned").mem_hits += 1;
    }

    fn note_disk_hit(&self) {
        self.stats.lock().expect("poisoned").disk_hits += 1;
    }

    fn note_miss(&self) {
        self.stats.lock().expect("poisoned").misses += 1;
    }

    fn note_disk_reject(&self) {
        self.stats.lock().expect("poisoned").disk_rejects += 1;
    }

    fn note_disk_read_error(&self) {
        self.stats.lock().expect("poisoned").disk_read_errors += 1;
    }

    fn note_ttl_eviction(&self) {
        self.stats.lock().expect("poisoned").ttl_evictions += 1;
    }

    // ------------------------------------------------- memory tier core

    /// Touch the memory slot for `key` in the map `pick` selects,
    /// expiring it lazily if the TTL lapsed.
    fn mem_get<T: Clone>(
        &self,
        key: CacheKey,
        pick: fn(&mut Mem) -> &mut HashMap<CacheKey, Slot<T>>,
    ) -> Touched<T> {
        let ttl = self.policy.lock().expect("poisoned").ttl_s;
        let mut m = self.mem.lock().expect("poisoned");
        m.seq += 1;
        let seq = m.seq;
        let now = m.now_s;
        let (touched, freed) = touch(pick(&mut m), key, seq, now, ttl);
        m.resident = m.resident.saturating_sub(freed);
        touched
    }

    /// Admit an artifact to the memory tier and enforce the byte budget
    /// (the freshly admitted slot has the highest `seq`, so it is only
    /// evicted when it alone exceeds the whole budget).
    fn admit<T: Clone>(
        &self,
        key: CacheKey,
        value: T,
        bytes: u64,
        pick: fn(&mut Mem) -> &mut HashMap<CacheKey, Slot<T>>,
    ) {
        let policy = *self.policy.lock().expect("poisoned");
        let evicted = {
            let mut m = self.mem.lock().expect("poisoned");
            m.seq += 1;
            let seq = m.seq;
            let now = m.now_s;
            let displaced = insert_slot(pick(&mut m), key, value, bytes, seq, now);
            m.resident = m.resident.saturating_sub(displaced) + bytes;
            match policy.budget_bytes {
                Some(budget) => m.enforce_budget(budget),
                None => 0,
            }
        };
        if evicted > 0 {
            self.stats.lock().expect("poisoned").lru_evictions += evicted;
        }
    }

    // ------------------------------------------------------------- disk

    fn disk_path(&self, kind: &str, key: CacheKey) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("{kind}-{key}.json")))
    }

    /// Read + parse + decode one disk entry; any failure rejects it and
    /// the stage recomputes.  A missing file is a *clean miss* (silent);
    /// an entry that exists but cannot be read, or reads but fails to
    /// parse/decode, gets a one-line warning and its own counter — a
    /// corrupt store should be visible, never mistaken for cold.
    fn disk_get<T>(&self, kind: &str, key: CacheKey, decode: impl Fn(&Json) -> Option<T>) -> Option<T> {
        let path = self.disk_path(kind, key)?;
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                eprintln!(
                    "flopt: cache: failed to read {}: {e}; recomputing",
                    path.display()
                );
                self.note_disk_read_error();
                return None;
            }
        };
        let parsed = json::parse(&text).ok();
        if let Some(j) = parsed.as_ref() {
            if codec::is_stale_version(j) {
                // a documented format bump, not corruption: stale
                // entries silently recompute (and get overwritten)
                return None;
            }
        }
        match parsed.as_ref().and_then(&decode) {
            Some(v) => {
                self.note_disk_hit();
                Some(v)
            }
            None => {
                eprintln!(
                    "flopt: cache: corrupt {kind} entry {}; recomputing",
                    path.display()
                );
                self.note_disk_reject();
                None
            }
        }
    }

    /// Best-effort disk write (never fails the search).
    fn disk_put(&self, kind: &str, key: CacheKey, payload: &Json) {
        let Some(path) = self.disk_path(kind, key) else { return };
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let _ = std::fs::write(&path, json::to_string(payload));
    }

    // --------------------------------------------------------- analyses

    /// Fetch a memoized Steps-1/2 analysis (memory only — the AST and
    /// profile are cheap to recompute and expensive to serialize; the
    /// analysis map is exempt from eviction, see module docs).
    pub fn get_analysis(&self, key: CacheKey) -> Option<Arc<AppAnalysis>> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").analyses.get(&key).cloned();
        match hit {
            Some(a) => {
                self.note_mem_hit();
                Some(a)
            }
            None => {
                self.note_miss();
                None
            }
        }
    }

    /// Memoize a Steps-1/2 analysis.
    pub fn put_analysis(&self, key: CacheKey, analysis: Arc<AppAnalysis>) {
        if self.enabled {
            self.mem.lock().expect("poisoned").analyses.insert(key, analysis);
        }
    }

    // ------------------------------------------------------ precompiles

    /// Fetch a Precompile-stage artifact (memory, then disk).
    pub fn get_precompile(&self, key: CacheKey) -> Option<PrecompileArtifact> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_precompiles) {
            Touched::Hit(p) => {
                self.note_mem_hit();
                return Some(p);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(p) = self.disk_get("precompile", key, codec::precompile_from_json) {
            let bytes = json::to_string(&codec::precompile_to_json(&p)).len() as u64;
            self.admit(key, p.clone(), bytes, mem_precompiles);
            return Some(p);
        }
        self.note_miss();
        None
    }

    /// Store a Precompile-stage artifact.
    pub fn put_precompile(&self, key: CacheKey, p: &PrecompileArtifact) {
        if !self.enabled {
            return;
        }
        let payload = codec::precompile_to_json(p);
        self.admit(key, p.clone(), json::to_string(&payload).len() as u64, mem_precompiles);
        self.disk_put("precompile", key, &payload);
    }

    // --------------------------------------------------------- measures

    /// Fetch a MeasureRounds-stage artifact (memory, then disk).
    pub fn get_measure(&self, key: CacheKey) -> Option<MeasureArtifact> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_measures) {
            Touched::Hit(m) => {
                self.note_mem_hit();
                return Some(m);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(m) = self.disk_get("measure", key, codec::measure_from_json) {
            let bytes = json::to_string(&codec::measure_to_json(&m)).len() as u64;
            self.admit(key, m.clone(), bytes, mem_measures);
            return Some(m);
        }
        self.note_miss();
        None
    }

    /// Store a MeasureRounds-stage artifact.
    pub fn put_measure(&self, key: CacheKey, m: &MeasureArtifact) {
        if !self.enabled {
            return;
        }
        let payload = codec::measure_to_json(m);
        self.admit(key, m.clone(), json::to_string(&payload).len() as u64, mem_measures);
        self.disk_put("measure", key, &payload);
    }

    // ----------------------------------------------------------- blocks

    /// Fetch a MeasureBlocks-stage artifact (memory, then disk).
    pub fn get_blocks(&self, key: CacheKey) -> Option<BlockMeasureArtifact> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_blocks) {
            Touched::Hit(b) => {
                self.note_mem_hit();
                return Some(b);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(b) = self.disk_get("blocks", key, codec::blocks_from_json) {
            let bytes = json::to_string(&codec::blocks_to_json(&b)).len() as u64;
            self.admit(key, b.clone(), bytes, mem_blocks);
            return Some(b);
        }
        self.note_miss();
        None
    }

    /// Store a MeasureBlocks-stage artifact.
    pub fn put_blocks(&self, key: CacheKey, b: &BlockMeasureArtifact) {
        if !self.enabled {
            return;
        }
        let payload = codec::blocks_to_json(b);
        self.admit(key, b.clone(), json::to_string(&payload).len() as u64, mem_blocks);
        self.disk_put("blocks", key, &payload);
    }

    // ----------------------------------------------------------- traces

    /// Fetch a complete search trace (memory, then disk).
    pub fn get_trace(&self, key: CacheKey) -> Option<SearchTrace> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_traces) {
            Touched::Hit(t) => {
                self.note_mem_hit();
                return Some(t);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(t) = self.disk_get("trace", key, codec::trace_from_json) {
            let bytes = json::to_string(&codec::trace_to_json(&t)).len() as u64;
            self.admit(key, t.clone(), bytes, mem_traces);
            return Some(t);
        }
        self.note_miss();
        None
    }

    /// Store a complete search trace.
    pub fn put_trace(&self, key: CacheKey, t: &SearchTrace) {
        if !self.enabled {
            return;
        }
        let payload = codec::trace_to_json(t);
        self.admit(key, t.clone(), json::to_string(&payload).len() as u64, mem_traces);
        self.disk_put("trace", key, &payload);
    }

    // ----------------------------------------------------- destinations

    /// Fetch a request-level destination-search outcome (memory, disk).
    pub fn get_destination(&self, key: CacheKey) -> Option<DestinationSearch> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_destinations) {
            Touched::Hit(d) => {
                self.note_mem_hit();
                return Some(d);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(d) = self.disk_get("destination", key, codec::destination_from_json) {
            let bytes = json::to_string(&codec::destination_to_json(&d)).len() as u64;
            self.admit(key, d.clone(), bytes, mem_destinations);
            return Some(d);
        }
        self.note_miss();
        None
    }

    /// Store a request-level destination-search outcome.
    pub fn put_destination(&self, key: CacheKey, d: &DestinationSearch) {
        if !self.enabled {
            return;
        }
        let payload = codec::destination_to_json(d);
        self.admit(key, d.clone(), json::to_string(&payload).len() as u64, mem_destinations);
        self.disk_put("destination", key, &payload);
    }

    // ----------------------------------------------------------- fleets

    /// Fetch a fleet placement report (memory, then disk).
    pub fn get_fleet(&self, key: CacheKey) -> Option<FleetReport> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_fleets) {
            Touched::Hit(f) => {
                self.note_mem_hit();
                return Some(f);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(f) = self.disk_get("fleet", key, codec::fleet_from_json) {
            let bytes = json::to_string(&codec::fleet_to_json(&f)).len() as u64;
            self.admit(key, f.clone(), bytes, mem_fleets);
            return Some(f);
        }
        self.note_miss();
        None
    }

    /// Store a fleet placement report.
    pub fn put_fleet(&self, key: CacheKey, f: &FleetReport) {
        if !self.enabled {
            return;
        }
        let payload = codec::fleet_to_json(f);
        self.admit(key, f.clone(), json::to_string(&payload).len() as u64, mem_fleets);
        self.disk_put("fleet", key, &payload);
    }

    // --------------------------------------------------------- explains

    /// Fetch an `flopt explain` artifact (memory, then disk).
    pub fn get_explain(&self, key: CacheKey) -> Option<ExplainArtifact> {
        if !self.enabled {
            return None;
        }
        match self.mem_get(key, mem_explains) {
            Touched::Hit(a) => {
                self.note_mem_hit();
                return Some(a);
            }
            Touched::Expired => self.note_ttl_eviction(),
            Touched::Miss => {}
        }
        if let Some(a) = self.disk_get("explain", key, codec::explain_from_json) {
            let bytes = json::to_string(&codec::explain_to_json(&a)).len() as u64;
            self.admit(key, a.clone(), bytes, mem_explains);
            return Some(a);
        }
        self.note_miss();
        None
    }

    /// Store an `flopt explain` artifact.
    pub fn put_explain(&self, key: CacheKey, a: &ExplainArtifact) {
        if !self.enabled {
            return;
        }
        let payload = codec::explain_to_json(a);
        self.admit(key, a.clone(), json::to_string(&payload).len() as u64, mem_explains);
        self.disk_put("explain", key, &payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::offload_search;
    use crate::coordinator::verify_env::VerifyEnv;
    use crate::cpu::XEON_3104;

    fn sample_trace() -> SearchTrace {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(&apps::MATMUL, &env, true).unwrap()
    }

    fn trace_bytes(t: &SearchTrace) -> u64 {
        json::to_string(&codec::trace_to_json(t)).len() as u64
    }

    #[test]
    fn disabled_store_is_inert() {
        let store = CacheStore::disabled();
        let t = sample_trace();
        let key = CacheKey(7);
        store.put_trace(key, &t);
        assert!(store.get_trace(key).is_none());
        assert!(!store.is_enabled());
    }

    #[test]
    fn memory_roundtrip() {
        let store = CacheStore::fresh();
        let t = sample_trace();
        let key = CacheKey(1);
        assert!(store.get_trace(key).is_none());
        store.put_trace(key, &t);
        let back = store.get_trace(key).expect("hit");
        assert_eq!(codec::trace_to_string(&back), codec::trace_to_string(&t));
        let stats = store.stats();
        assert_eq!(stats.mem_hits, 1);
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn disk_roundtrip_and_corruption_fallback() {
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let t = sample_trace();
        let key = CacheKey(2);

        // write through store A, read back through a fresh store B
        let a = CacheStore::with_dir(&dir);
        a.put_trace(key, &t);
        let b = CacheStore::with_dir(&dir);
        let back = b.get_trace(key).expect("disk hit");
        assert_eq!(codec::trace_to_string(&back), codec::trace_to_string(&t));
        assert_eq!(b.stats().disk_hits, 1);

        // corrupt the payload: a fresh store must reject and miss
        let path = dir.join(format!("trace-{key}.json"));
        std::fs::write(&path, "{ not json !!").unwrap();
        let c = CacheStore::with_dir(&dir);
        assert!(c.get_trace(key).is_none());
        let stats = c.stats();
        assert_eq!(stats.disk_rejects, 1);
        assert_eq!(stats.misses, 1);

        // valid current-version JSON of the wrong shape must also reject
        std::fs::write(
            &path,
            format!("{{\"kind\":\"trace\",\"v\":{}}}", codec::VERSION),
        )
        .unwrap();
        let d = CacheStore::with_dir(&dir);
        assert!(d.get_trace(key).is_none());
        assert_eq!(d.stats().disk_rejects, 1);

        // a payload from an older codec version is a *stale* entry — a
        // silent recompute, never reported or counted as corruption
        std::fs::write(&path, "{\"kind\":\"trace\",\"v\":1}").unwrap();
        let e = CacheStore::with_dir(&dir);
        assert!(e.get_trace(key).is_none());
        let stats = e.stats();
        assert_eq!(stats.disk_rejects, 0, "stale version is not corruption");
        assert_eq!(stats.disk_read_errors, 0);
        assert_eq!(stats.misses, 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unreadable_entry_counts_as_read_error_not_clean_miss() {
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-readerr-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let key = CacheKey(9);
        // a *directory* where the entry file should be: exists, unreadable
        std::fs::create_dir_all(dir.join(format!("trace-{key}.json"))).unwrap();
        let store = CacheStore::with_dir(&dir);
        assert!(store.get_trace(key).is_none(), "unreadable entry recomputes");
        let stats = store.stats();
        assert_eq!(stats.disk_read_errors, 1, "read failure must be counted");
        assert_eq!(stats.disk_rejects, 0, "not a decode reject");
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.corrupt_recomputes(), 1);

        // a clean not-found miss stays silent: no read-error, no reject
        assert!(store.get_trace(CacheKey(10)).is_none());
        let stats = store.stats();
        assert_eq!(stats.disk_read_errors, 1);
        assert_eq!(stats.disk_rejects, 0);
        assert_eq!(stats.misses, 2);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blocks_artifact_roundtrips_through_disk() {
        use crate::funcblock::BlockMode;
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-blocks-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SearchConfig { block_mode: BlockMode::On, ..SearchConfig::default() };
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
        let t = offload_search(&apps::MATMUL, &env, true).unwrap();
        assert!(!t.blocks.is_empty(), "matmul must measure a block placement");

        let key = CacheKey(11);
        let artifact = crate::coordinator::stages::BlockMeasureArtifact {
            placements: t.blocks.clone(),
        };
        let a = CacheStore::with_dir(&dir);
        a.put_blocks(key, &artifact);
        let b = CacheStore::with_dir(&dir);
        let back = b.get_blocks(key).expect("disk hit");
        assert_eq!(back.placements, artifact.placements);
        assert_eq!(b.stats().disk_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unwritable_dir_degrades_to_memory_only() {
        // a path under a *file* can never be created
        let file = std::env::temp_dir().join(format!("flopt-store-file-{}", std::process::id()));
        std::fs::write(&file, "x").unwrap();
        let store = CacheStore::with_dir(file.join("sub"));
        let t = sample_trace();
        let key = CacheKey(3);
        store.put_trace(key, &t); // must not panic
        assert!(store.get_trace(key).is_some(), "memory tier still works");
        let _ = std::fs::remove_file(&file);
    }

    #[test]
    fn resident_bytes_tracks_canonical_json_weight() {
        let store = CacheStore::fresh();
        let t = sample_trace();
        assert_eq!(store.resident_bytes(), 0);
        store.put_trace(CacheKey(1), &t);
        assert_eq!(store.resident_bytes(), trace_bytes(&t));
        // replacing the same key must not double-count
        store.put_trace(CacheKey(1), &t);
        assert_eq!(store.resident_bytes(), trace_bytes(&t));
        store.put_trace(CacheKey(2), &t);
        assert_eq!(store.resident_bytes(), 2 * trace_bytes(&t));
    }

    #[test]
    fn budget_pressure_evicts_lru_first_and_counts() {
        let store = CacheStore::fresh();
        let t = sample_trace();
        let one = trace_bytes(&t);
        // room for exactly two traces
        store.set_policy(EvictionPolicy { budget_bytes: Some(2 * one), ttl_s: None });
        store.put_trace(CacheKey(1), &t);
        store.put_trace(CacheKey(2), &t);
        assert_eq!(store.stats().lru_evictions, 0);
        // touch key 1 so key 2 becomes the LRU victim
        assert!(store.get_trace(CacheKey(1)).is_some());
        store.put_trace(CacheKey(3), &t);
        assert_eq!(store.stats().lru_evictions, 1);
        assert!(store.get_trace(CacheKey(2)).is_none(), "LRU slot evicted");
        assert!(store.get_trace(CacheKey(1)).is_some(), "recently used survives");
        assert!(store.get_trace(CacheKey(3)).is_some(), "newest survives");
        assert!(store.resident_bytes() <= 2 * one);
        assert_eq!(store.stats().evictions(), 1);
    }

    #[test]
    fn lowering_the_budget_evicts_immediately() {
        let store = CacheStore::fresh();
        let t = sample_trace();
        store.put_trace(CacheKey(1), &t);
        store.put_trace(CacheKey(2), &t);
        store.set_policy(EvictionPolicy {
            budget_bytes: Some(trace_bytes(&t)),
            ttl_s: None,
        });
        assert_eq!(store.stats().lru_evictions, 1);
        assert!(store.get_trace(CacheKey(1)).is_none(), "oldest dropped");
        assert!(store.get_trace(CacheKey(2)).is_some());
    }

    #[test]
    fn ttl_expires_on_simulated_time_only() {
        let store = CacheStore::fresh();
        store.set_policy(EvictionPolicy { budget_bytes: None, ttl_s: Some(100.0) });
        let t = sample_trace();
        store.put_trace(CacheKey(1), &t); // written at sim t=0
        store.set_now_sim_s(50.0);
        assert!(store.get_trace(CacheKey(1)).is_some(), "fresh under TTL");
        assert_eq!(store.stats().ttl_evictions, 0);

        // the eager sweep on time advance expires it
        store.put_trace(CacheKey(2), &t); // written at sim t=50
        store.set_now_sim_s(200.0);
        assert_eq!(store.stats().ttl_evictions, 2, "both writes aged out");
        assert!(store.get_trace(CacheKey(1)).is_none());
        assert_eq!(store.resident_bytes(), 0);

        // time never runs backwards: a stale update is ignored
        store.set_now_sim_s(10.0);
        store.put_trace(CacheKey(3), &t);
        assert!(store.get_trace(CacheKey(3)).is_some());
    }

    #[test]
    fn ttl_expiry_recomputes_byte_identical() {
        // the satellite guarantee: eviction costs a recompute, never a
        // different answer
        let store = CacheStore::fresh();
        store.set_policy(EvictionPolicy { budget_bytes: None, ttl_s: Some(10.0) });
        let t = sample_trace();
        let key = CacheKey(4);
        store.put_trace(key, &t);
        store.set_now_sim_s(1000.0);
        assert!(store.get_trace(key).is_none(), "expired entry recomputes");
        let again = sample_trace();
        assert_eq!(
            codec::trace_to_string(&t),
            codec::trace_to_string(&again),
            "recomputed trace is byte-identical"
        );
        store.put_trace(key, &again);
        assert!(store.get_trace(key).is_some());
    }
}
