//! The artifact store: always-on in-memory maps plus an optional
//! on-disk JSON mirror.
//!
//! * **Memory** — every `put` lands in a typed `HashMap` behind a
//!   mutex; `get` clones out (analyses are shared as `Arc`, they are the
//!   only artifact too big to clone casually).
//! * **Disk** — when built [`CacheStore::with_dir`], the serializable
//!   artifacts (pre-compiles, measurements, traces, destination
//!   outcomes) are mirrored as `<kind>-<key>.json`; a memory miss falls
//!   through to disk.  Disk entries are never trusted: payloads that
//!   fail to parse or decode are discarded (counted in
//!   [`CacheStats::disk_rejects`]) and the stage recomputes.  All disk
//!   I/O is best-effort — an unwritable directory degrades to
//!   memory-only operation, never to an error.
//! * **Disabled** — [`CacheStore::disabled`] stores nothing and returns
//!   nothing: every search runs exactly as the pre-cache pipeline did.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::coordinator::mixed::DestinationSearch;
use crate::coordinator::pipeline::{AppAnalysis, SearchTrace};
use crate::coordinator::stages::{BlockMeasureArtifact, MeasureArtifact, PrecompileArtifact};
use crate::fleet::FleetReport;
use crate::util::json::{self, Json};

use super::codec;
use super::key::CacheKey;

/// Hit/miss counters (diagnostics; not part of any cache key).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Artifacts served from memory.
    pub mem_hits: u64,
    /// Artifacts served from the on-disk store.
    pub disk_hits: u64,
    /// Lookups that found nothing and recomputed.
    pub misses: u64,
    /// On-disk payloads discarded as corrupt/undecodable.
    pub disk_rejects: u64,
    /// On-disk entries that *exist* but could not be read (I/O error —
    /// distinct from a clean not-found miss); each one recomputes.
    pub disk_read_errors: u64,
}

impl CacheStats {
    /// Total recomputes forced by a bad disk entry (corrupt payloads
    /// plus unreadable files) — the corrupt-entry metric `flopt batch`
    /// and the tests watch.
    pub fn corrupt_recomputes(&self) -> u64 {
        self.disk_rejects + self.disk_read_errors
    }
}

#[derive(Default)]
struct Mem {
    analyses: HashMap<CacheKey, Arc<AppAnalysis>>,
    precompiles: HashMap<CacheKey, PrecompileArtifact>,
    measures: HashMap<CacheKey, MeasureArtifact>,
    blocks: HashMap<CacheKey, BlockMeasureArtifact>,
    traces: HashMap<CacheKey, SearchTrace>,
    destinations: HashMap<CacheKey, DestinationSearch>,
    fleets: HashMap<CacheKey, FleetReport>,
}

/// The content-addressed artifact store (see module docs).
pub struct CacheStore {
    enabled: bool,
    dir: Option<PathBuf>,
    mem: Mutex<Mem>,
    stats: Mutex<CacheStats>,
}

impl CacheStore {
    /// An enabled, memory-only store.
    pub fn fresh() -> Arc<CacheStore> {
        Arc::new(CacheStore {
            enabled: true,
            dir: None,
            mem: Mutex::new(Mem::default()),
            stats: Mutex::new(CacheStats::default()),
        })
    }

    /// A store that persists serializable artifacts under `dir`
    /// (created on first write; unwritable directories degrade to
    /// memory-only).
    pub fn with_dir(dir: impl Into<PathBuf>) -> Arc<CacheStore> {
        Arc::new(CacheStore {
            enabled: true,
            dir: Some(dir.into()),
            mem: Mutex::new(Mem::default()),
            stats: Mutex::new(CacheStats::default()),
        })
    }

    /// A store that caches nothing (`--no-cache`): every get misses,
    /// every put is a no-op.
    pub fn disabled() -> Arc<CacheStore> {
        Arc::new(CacheStore {
            enabled: false,
            dir: None,
            mem: Mutex::new(Mem::default()),
            stats: Mutex::new(CacheStats::default()),
        })
    }

    /// Is this store recording anything at all?
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Snapshot of the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().expect("poisoned")
    }

    fn note_mem_hit(&self) {
        self.stats.lock().expect("poisoned").mem_hits += 1;
    }

    fn note_disk_hit(&self) {
        self.stats.lock().expect("poisoned").disk_hits += 1;
    }

    fn note_miss(&self) {
        self.stats.lock().expect("poisoned").misses += 1;
    }

    fn note_disk_reject(&self) {
        self.stats.lock().expect("poisoned").disk_rejects += 1;
    }

    fn note_disk_read_error(&self) {
        self.stats.lock().expect("poisoned").disk_read_errors += 1;
    }

    // ------------------------------------------------------------- disk

    fn disk_path(&self, kind: &str, key: CacheKey) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("{kind}-{key}.json")))
    }

    /// Read + parse + decode one disk entry; any failure rejects it and
    /// the stage recomputes.  A missing file is a *clean miss* (silent);
    /// an entry that exists but cannot be read, or reads but fails to
    /// parse/decode, gets a one-line warning and its own counter — a
    /// corrupt store should be visible, never mistaken for cold.
    fn disk_get<T>(&self, kind: &str, key: CacheKey, decode: impl Fn(&Json) -> Option<T>) -> Option<T> {
        let path = self.disk_path(kind, key)?;
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                eprintln!(
                    "flopt: cache: failed to read {}: {e}; recomputing",
                    path.display()
                );
                self.note_disk_read_error();
                return None;
            }
        };
        let parsed = json::parse(&text).ok();
        if let Some(j) = parsed.as_ref() {
            if codec::is_stale_version(j) {
                // a documented format bump, not corruption: stale
                // entries silently recompute (and get overwritten)
                return None;
            }
        }
        match parsed.as_ref().and_then(&decode) {
            Some(v) => {
                self.note_disk_hit();
                Some(v)
            }
            None => {
                eprintln!(
                    "flopt: cache: corrupt {kind} entry {}; recomputing",
                    path.display()
                );
                self.note_disk_reject();
                None
            }
        }
    }

    /// Best-effort disk write (never fails the search).
    fn disk_put(&self, kind: &str, key: CacheKey, payload: &Json) {
        let Some(path) = self.disk_path(kind, key) else { return };
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let _ = std::fs::write(&path, json::to_string(payload));
    }

    // --------------------------------------------------------- analyses

    /// Fetch a memoized Steps-1/2 analysis (memory only — the AST and
    /// profile are cheap to recompute and expensive to serialize).
    pub fn get_analysis(&self, key: CacheKey) -> Option<Arc<AppAnalysis>> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").analyses.get(&key).cloned();
        match hit {
            Some(a) => {
                self.note_mem_hit();
                Some(a)
            }
            None => {
                self.note_miss();
                None
            }
        }
    }

    /// Memoize a Steps-1/2 analysis.
    pub fn put_analysis(&self, key: CacheKey, analysis: Arc<AppAnalysis>) {
        if self.enabled {
            self.mem.lock().expect("poisoned").analyses.insert(key, analysis);
        }
    }

    // ------------------------------------------------------ precompiles

    /// Fetch a Precompile-stage artifact (memory, then disk).
    pub fn get_precompile(&self, key: CacheKey) -> Option<PrecompileArtifact> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").precompiles.get(&key).cloned();
        if let Some(p) = hit {
            self.note_mem_hit();
            return Some(p);
        }
        if let Some(p) = self.disk_get("precompile", key, codec::precompile_from_json) {
            self.mem.lock().expect("poisoned").precompiles.insert(key, p.clone());
            return Some(p);
        }
        self.note_miss();
        None
    }

    /// Store a Precompile-stage artifact.
    pub fn put_precompile(&self, key: CacheKey, p: &PrecompileArtifact) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").precompiles.insert(key, p.clone());
        self.disk_put("precompile", key, &codec::precompile_to_json(p));
    }

    // --------------------------------------------------------- measures

    /// Fetch a MeasureRounds-stage artifact (memory, then disk).
    pub fn get_measure(&self, key: CacheKey) -> Option<MeasureArtifact> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").measures.get(&key).cloned();
        if let Some(m) = hit {
            self.note_mem_hit();
            return Some(m);
        }
        if let Some(m) = self.disk_get("measure", key, codec::measure_from_json) {
            self.mem.lock().expect("poisoned").measures.insert(key, m.clone());
            return Some(m);
        }
        self.note_miss();
        None
    }

    /// Store a MeasureRounds-stage artifact.
    pub fn put_measure(&self, key: CacheKey, m: &MeasureArtifact) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").measures.insert(key, m.clone());
        self.disk_put("measure", key, &codec::measure_to_json(m));
    }

    // ----------------------------------------------------------- blocks

    /// Fetch a MeasureBlocks-stage artifact (memory, then disk).
    pub fn get_blocks(&self, key: CacheKey) -> Option<BlockMeasureArtifact> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").blocks.get(&key).cloned();
        if let Some(b) = hit {
            self.note_mem_hit();
            return Some(b);
        }
        if let Some(b) = self.disk_get("blocks", key, codec::blocks_from_json) {
            self.mem.lock().expect("poisoned").blocks.insert(key, b.clone());
            return Some(b);
        }
        self.note_miss();
        None
    }

    /// Store a MeasureBlocks-stage artifact.
    pub fn put_blocks(&self, key: CacheKey, b: &BlockMeasureArtifact) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").blocks.insert(key, b.clone());
        self.disk_put("blocks", key, &codec::blocks_to_json(b));
    }

    // ----------------------------------------------------------- traces

    /// Fetch a complete search trace (memory, then disk).
    pub fn get_trace(&self, key: CacheKey) -> Option<SearchTrace> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").traces.get(&key).cloned();
        if let Some(t) = hit {
            self.note_mem_hit();
            return Some(t);
        }
        if let Some(t) = self.disk_get("trace", key, codec::trace_from_json) {
            self.mem.lock().expect("poisoned").traces.insert(key, t.clone());
            return Some(t);
        }
        self.note_miss();
        None
    }

    /// Store a complete search trace.
    pub fn put_trace(&self, key: CacheKey, t: &SearchTrace) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").traces.insert(key, t.clone());
        self.disk_put("trace", key, &codec::trace_to_json(t));
    }

    // ----------------------------------------------------- destinations

    /// Fetch a request-level destination-search outcome (memory, disk).
    pub fn get_destination(&self, key: CacheKey) -> Option<DestinationSearch> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").destinations.get(&key).cloned();
        if let Some(d) = hit {
            self.note_mem_hit();
            return Some(d);
        }
        if let Some(d) = self.disk_get("destination", key, codec::destination_from_json) {
            self.mem.lock().expect("poisoned").destinations.insert(key, d.clone());
            return Some(d);
        }
        self.note_miss();
        None
    }

    /// Store a request-level destination-search outcome.
    pub fn put_destination(&self, key: CacheKey, d: &DestinationSearch) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").destinations.insert(key, d.clone());
        self.disk_put("destination", key, &codec::destination_to_json(d));
    }

    // ----------------------------------------------------------- fleets

    /// Fetch a fleet placement report (memory, then disk).
    pub fn get_fleet(&self, key: CacheKey) -> Option<FleetReport> {
        if !self.enabled {
            return None;
        }
        let hit = self.mem.lock().expect("poisoned").fleets.get(&key).cloned();
        if let Some(f) = hit {
            self.note_mem_hit();
            return Some(f);
        }
        if let Some(f) = self.disk_get("fleet", key, codec::fleet_from_json) {
            self.mem.lock().expect("poisoned").fleets.insert(key, f.clone());
            return Some(f);
        }
        self.note_miss();
        None
    }

    /// Store a fleet placement report.
    pub fn put_fleet(&self, key: CacheKey, f: &FleetReport) {
        if !self.enabled {
            return;
        }
        self.mem.lock().expect("poisoned").fleets.insert(key, f.clone());
        self.disk_put("fleet", key, &codec::fleet_to_json(f));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::offload_search;
    use crate::coordinator::verify_env::VerifyEnv;
    use crate::cpu::XEON_3104;

    fn sample_trace() -> SearchTrace {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(&apps::MATMUL, &env, true).unwrap()
    }

    #[test]
    fn disabled_store_is_inert() {
        let store = CacheStore::disabled();
        let t = sample_trace();
        let key = CacheKey(7);
        store.put_trace(key, &t);
        assert!(store.get_trace(key).is_none());
        assert!(!store.is_enabled());
    }

    #[test]
    fn memory_roundtrip() {
        let store = CacheStore::fresh();
        let t = sample_trace();
        let key = CacheKey(1);
        assert!(store.get_trace(key).is_none());
        store.put_trace(key, &t);
        let back = store.get_trace(key).expect("hit");
        assert_eq!(codec::trace_to_string(&back), codec::trace_to_string(&t));
        let stats = store.stats();
        assert_eq!(stats.mem_hits, 1);
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn disk_roundtrip_and_corruption_fallback() {
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let t = sample_trace();
        let key = CacheKey(2);

        // write through store A, read back through a fresh store B
        let a = CacheStore::with_dir(&dir);
        a.put_trace(key, &t);
        let b = CacheStore::with_dir(&dir);
        let back = b.get_trace(key).expect("disk hit");
        assert_eq!(codec::trace_to_string(&back), codec::trace_to_string(&t));
        assert_eq!(b.stats().disk_hits, 1);

        // corrupt the payload: a fresh store must reject and miss
        let path = dir.join(format!("trace-{key}.json"));
        std::fs::write(&path, "{ not json !!").unwrap();
        let c = CacheStore::with_dir(&dir);
        assert!(c.get_trace(key).is_none());
        let stats = c.stats();
        assert_eq!(stats.disk_rejects, 1);
        assert_eq!(stats.misses, 1);

        // valid current-version JSON of the wrong shape must also reject
        std::fs::write(
            &path,
            format!("{{\"kind\":\"trace\",\"v\":{}}}", codec::VERSION),
        )
        .unwrap();
        let d = CacheStore::with_dir(&dir);
        assert!(d.get_trace(key).is_none());
        assert_eq!(d.stats().disk_rejects, 1);

        // a payload from an older codec version is a *stale* entry — a
        // silent recompute, never reported or counted as corruption
        std::fs::write(&path, "{\"kind\":\"trace\",\"v\":1}").unwrap();
        let e = CacheStore::with_dir(&dir);
        assert!(e.get_trace(key).is_none());
        let stats = e.stats();
        assert_eq!(stats.disk_rejects, 0, "stale version is not corruption");
        assert_eq!(stats.disk_read_errors, 0);
        assert_eq!(stats.misses, 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unreadable_entry_counts_as_read_error_not_clean_miss() {
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-readerr-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let key = CacheKey(9);
        // a *directory* where the entry file should be: exists, unreadable
        std::fs::create_dir_all(dir.join(format!("trace-{key}.json"))).unwrap();
        let store = CacheStore::with_dir(&dir);
        assert!(store.get_trace(key).is_none(), "unreadable entry recomputes");
        let stats = store.stats();
        assert_eq!(stats.disk_read_errors, 1, "read failure must be counted");
        assert_eq!(stats.disk_rejects, 0, "not a decode reject");
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.corrupt_recomputes(), 1);

        // a clean not-found miss stays silent: no read-error, no reject
        assert!(store.get_trace(CacheKey(10)).is_none());
        let stats = store.stats();
        assert_eq!(stats.disk_read_errors, 1);
        assert_eq!(stats.disk_rejects, 0);
        assert_eq!(stats.misses, 2);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blocks_artifact_roundtrips_through_disk() {
        use crate::funcblock::BlockMode;
        let dir = std::env::temp_dir().join(format!(
            "flopt-store-blocks-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SearchConfig { block_mode: BlockMode::On, ..SearchConfig::default() };
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
        let t = offload_search(&apps::MATMUL, &env, true).unwrap();
        assert!(!t.blocks.is_empty(), "matmul must measure a block placement");

        let key = CacheKey(11);
        let artifact = crate::coordinator::stages::BlockMeasureArtifact {
            placements: t.blocks.clone(),
        };
        let a = CacheStore::with_dir(&dir);
        a.put_blocks(key, &artifact);
        let b = CacheStore::with_dir(&dir);
        let back = b.get_blocks(key).expect("disk hit");
        assert_eq!(back.placements, artifact.placements);
        assert_eq!(b.stats().disk_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unwritable_dir_degrades_to_memory_only() {
        // a path under a *file* can never be created
        let file = std::env::temp_dir().join(format!("flopt-store-file-{}", std::process::id()));
        std::fs::write(&file, "x").unwrap();
        let store = CacheStore::with_dir(file.join("sub"));
        let t = sample_trace();
        let key = CacheKey(3);
        store.put_trace(key, &t); // must not panic
        assert!(store.get_trace(key).is_some(), "memory tier still works");
        let _ = std::fs::remove_file(&file);
    }
}
