//! HLS **pre-compile** simulator — the stand-in for `aoc -c` (Intel FPGA
//! SDK for OpenCL) producing the resource report the paper's Step 3 uses.
//!
//! The paper's observation: translating OpenCL to the HDL level takes
//! *minutes* and already yields Flip-Flop / Look-Up-Table usage, so
//! resource efficiency can be computed without the hours-long full
//! compile.  This module performs that translation analytically:
//!
//! 1. walk the kernel loop body and count datapath operators per
//!    (innermost) iteration, with float/int typing resolved from the
//!    program's symbol table;
//! 2. apply the Arria10 per-operator cost table (DESIGN.md §6) — trig
//!    cores, dividers, LSUs per distinct global array, shift registers
//!    for rewritten reductions, loop-control and kernel-interface
//!    overhead;
//! 3. schedule: pipeline II (1 for parallel loops and `+`-reductions via
//!    the shift-register idiom; the fp-mul latency for `*`-reductions)
//!    and pipeline depth from operator latencies;
//! 4. report resources, utilization, achievable fmax, and the simulated
//!    pre-compile minutes.

pub mod opcount;

use crate::cparse::Program;
use crate::fpga::device::{Device, Resources};
use crate::ir::LoopAnalysis;

pub use opcount::OpCounts;

/// Per-operator resource cost table (Arria10, hardened fp32 DSP blocks).
mod cost {
    use crate::fpga::device::Resources;

    pub const FADD: Resources = Resources { alms: 120.0, ffs: 300.0, luts: 150.0, dsps: 1.0, m20ks: 0.0 };
    pub const FMUL: Resources = Resources { alms: 80.0, ffs: 200.0, luts: 100.0, dsps: 1.0, m20ks: 0.0 };
    pub const FDIV: Resources = Resources { alms: 800.0, ffs: 1500.0, luts: 900.0, dsps: 4.0, m20ks: 0.0 };
    pub const TRIG: Resources = Resources { alms: 2600.0, ffs: 5000.0, luts: 2800.0, dsps: 8.0, m20ks: 2.0 };
    pub const SQRT: Resources = Resources { alms: 450.0, ffs: 800.0, luts: 500.0, dsps: 2.0, m20ks: 0.0 };
    pub const EXP: Resources = Resources { alms: 1400.0, ffs: 2500.0, luts: 1500.0, dsps: 6.0, m20ks: 0.0 };
    pub const FMISC: Resources = Resources { alms: 60.0, ffs: 100.0, luts: 60.0, dsps: 0.0, m20ks: 0.0 };
    pub const INT_OP: Resources = Resources { alms: 32.0, ffs: 64.0, luts: 32.0, dsps: 0.0, m20ks: 0.0 };
    pub const CMP: Resources = Resources { alms: 16.0, ffs: 16.0, luts: 16.0, dsps: 0.0, m20ks: 0.0 };
    pub const LSU: Resources = Resources { alms: 900.0, ffs: 1800.0, luts: 1000.0, dsps: 0.0, m20ks: 4.0 };
    pub const SHIFT_REG: Resources = Resources { alms: 200.0, ffs: 600.0, luts: 250.0, dsps: 0.0, m20ks: 0.0 };
    pub const LOOP_CTRL: Resources = Resources { alms: 250.0, ffs: 500.0, luts: 300.0, dsps: 0.0, m20ks: 0.0 };
    pub const KERNEL_BASE: Resources = Resources { alms: 2500.0, ffs: 5000.0, luts: 3000.0, dsps: 0.0, m20ks: 8.0 };
}

/// Operator pipeline latencies (cycles), for pipeline depth.
mod latency {
    pub const FADD: u32 = 3;
    pub const FMUL: u32 = 3;
    pub const FDIV: u32 = 14;
    pub const TRIG: u32 = 24;
    pub const SQRT: u32 = 8;
    pub const EXP: u32 = 16;
    pub const MEM: u32 = 2;
    pub const INT: u32 = 1;
}

/// Result of pre-compiling one kernel (one offloaded loop).
#[derive(Debug, Clone)]
pub struct HlsReport {
    /// The loop the kernel was generated from.
    pub loop_id: crate::cparse::ast::LoopId,
    /// unroll factor the datapath was built for (b parallel iteration
    /// bodies -> b iterations retired per II cycles)
    pub unroll: usize,
    /// kernel resources excluding the BSP static region
    pub resources: Resources,
    /// device utilization including BSP (0..1+, >1 = does not fit)
    pub utilization: f64,
    /// pipeline initiation interval of the innermost loop
    pub ii: u32,
    /// pipeline depth (fill/drain cycles per loop entry)
    pub depth: u32,
    /// achievable kernel clock after derating
    pub fmax_hz: f64,
    /// simulated pre-compile time (the "minutes, not hours" path)
    pub precompile_s: f64,
    /// operator counts the estimate was built from
    pub ops: OpCounts,
}

impl HlsReport {
    /// "リソース量は全体リソース量の割合で表示される" — the fraction the
    /// paper's resource-efficiency metric divides by.
    pub fn resource_frac(&self) -> f64 {
        self.utilization
    }
}

/// Pre-compile one offloadable loop at unroll factor `b`.
pub fn precompile(
    program: &Program,
    la: &LoopAnalysis,
    unroll: usize,
    device: &Device,
) -> HlsReport {
    let ops = opcount::count(program, la);
    let b = unroll.max(1) as f64;

    // --- datapath resources (scaled by unroll: b parallel iteration bodies)
    let mut r = Resources::ZERO;
    r = r.add(&cost::FADD.scale(ops.fadd as f64 * b));
    r = r.add(&cost::FMUL.scale(ops.fmul as f64 * b));
    r = r.add(&cost::FDIV.scale(ops.fdiv as f64 * b));
    r = r.add(&cost::TRIG.scale(ops.trig as f64 * b));
    r = r.add(&cost::SQRT.scale(ops.sqrt as f64 * b));
    r = r.add(&cost::EXP.scale(ops.exp as f64 * b));
    r = r.add(&cost::FMISC.scale(ops.fmisc as f64 * b));
    r = r.add(&cost::INT_OP.scale(ops.int_ops as f64 * b));
    r = r.add(&cost::CMP.scale(ops.cmps as f64 * b));
    // LSUs: one per distinct global array (not scaled by unroll — aoc
    // coalesces; wider accesses grow the LSU mildly)
    r = r.add(&cost::LSU.scale(ops.arrays as f64 * (1.0 + 0.25 * (b - 1.0))));
    r = r.add(&cost::SHIFT_REG.scale(ops.plus_reductions as f64));
    r = r.add(&cost::LOOP_CTRL.scale(ops.nest_depth as f64));
    r = r.add(&cost::KERNEL_BASE);

    let utilization = device.utilization(&r);

    // --- schedule
    // II: shift-register idiom gives + -reductions II=1; *-reductions
    // carry the multiplier latency; otherwise fully pipelined.
    let ii = if ops.star_reductions > 0 {
        latency::FMUL + 3
    } else {
        1
    };
    let depth = 5
        + ops.fadd.min(8) * latency::FADD
        + ops.fmul.min(8) * latency::FMUL
        + ops.fdiv * latency::FDIV
        + ops.trig * latency::TRIG
        + ops.sqrt * latency::SQRT
        + ops.exp * latency::EXP
        + 2 * latency::MEM
        + ops.int_ops.min(4) * latency::INT;

    let fmax_hz = device.fmax_hz(utilization);

    // pre-compile (OpenCL -> HDL) time: ~1.5 min base + per-operator cost
    let total_ops = ops.total();
    let precompile_s = 90.0 + 1.5 * total_ops as f64;

    HlsReport {
        loop_id: la.info.id,
        unroll: unroll.max(1),
        resources: r,
        utilization,
        ii,
        depth,
        fmax_hz,
        precompile_s,
        ops,
    }
}

/// Combined utilization of several kernels on one device (pattern fit
/// check: the paper drops combinations that exceed the cap).
pub fn combined_utilization(reports: &[&HlsReport], device: &Device) -> f64 {
    let total = reports
        .iter()
        .fold(Resources::ZERO, |acc, r| acc.add(&r.resources));
    device.utilization(&total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::fpga::device::ARRIA10_GX;
    use crate::ir;

    fn report(src: &str, idx: usize, unroll: usize) -> HlsReport {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        precompile(&p, &loops[idx], unroll, &ARRIA10_GX)
    }

    const MAP: &str = "void f(float a[], float b[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = b[i] * 2.0 + 1.0; } }";

    #[test]
    fn small_kernel_fits_easily() {
        let r = report(MAP, 0, 1);
        assert!(r.utilization < 0.25, "utilization {}", r.utilization);
        assert!(r.utilization > 0.18, "must exceed the BSP floor");
        assert_eq!(r.ii, 1);
    }

    #[test]
    fn unroll_scales_resources() {
        let r1 = report(MAP, 0, 1);
        let r8 = report(MAP, 0, 8);
        assert!(r8.resources.dsps > 4.0 * r1.resources.dsps);
        assert!(r8.utilization > r1.utilization);
    }

    #[test]
    fn trig_kernel_costs_more_than_mul_kernel() {
        let trig = report(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]) + cos(a[i]); } }",
            0,
            1,
        );
        let mul = report(MAP, 0, 1);
        assert!(trig.resources.dsps > mul.resources.dsps);
        assert!(trig.depth > mul.depth);
        assert!(trig.fmax_hz <= mul.fmax_hz);
    }

    #[test]
    fn plus_reduction_keeps_ii_1() {
        let r = report(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i] * a[i]; } }",
            0,
            1,
        );
        assert_eq!(r.ii, 1, "shift-register idiom restores II=1");
        assert_eq!(r.ops.plus_reductions, 1);
    }

    #[test]
    fn precompile_is_minutes_not_hours() {
        let r = report(MAP, 0, 1);
        assert!(r.precompile_s > 30.0);
        assert!(r.precompile_s < 1800.0, "precompile must stay in minutes");
    }

    #[test]
    fn combined_utilization_adds() {
        let r = report(MAP, 0, 1);
        let solo = ARRIA10_GX.utilization(&r.resources);
        let both = combined_utilization(&[&r, &r], &ARRIA10_GX);
        assert!(both > solo);
        assert!(both < 2.0 * solo, "BSP counted once");
    }
}
