//! Static datapath operator counting over a kernel loop body.
//!
//! Types are resolved from the program symbol table; an arithmetic node is
//! a *float* operator if either operand is float-typed.  Counts are per
//! innermost iteration body — they size the datapath, not the trip count
//! (which the dynamic profile provides).

use std::collections::HashMap;

use crate::cparse::ast::*;
use crate::cparse::Program;
use crate::ir::LoopAnalysis;
use crate::opencl::kernel::type_env;
use crate::util::intern::Symbol;

/// Datapath operator counts.
#[derive(Debug, Clone, Default)]
pub struct OpCounts {
    /// Float adds/subtracts.
    pub fadd: u32,
    /// Float multiplies.
    pub fmul: u32,
    /// Float divides (and float modulo).
    pub fdiv: u32,
    /// `sin`/`cos` cores.
    pub trig: u32,
    /// `sqrt` cores.
    pub sqrt: u32,
    /// `exp` cores.
    pub exp: u32,
    /// Cheap float ops (`fabs`, `floor`, `fmin`, `fmax`, negation).
    pub fmisc: u32,
    /// Integer ALU ops (index math, counters).
    pub int_ops: u32,
    /// Comparisons and logical ops.
    pub cmps: u32,
    /// distinct global arrays accessed (→ LSU count)
    pub arrays: u32,
    /// `+`-reductions (→ shift registers)
    pub plus_reductions: u32,
    /// `*`-reductions (carry the multiplier latency).
    pub star_reductions: u32,
    /// loops in the offloaded nest (→ loop-control logic)
    pub nest_depth: u32,
}

impl OpCounts {
    /// Total datapath operators (excludes structural counts).
    pub fn total(&self) -> u32 {
        self.fadd + self.fmul + self.fdiv + self.trig + self.sqrt + self.exp
            + self.fmisc + self.int_ops + self.cmps
    }
}

struct Counter<'e> {
    env: &'e HashMap<Symbol, Type>,
    c: OpCounts,
    locals_float: HashMap<Symbol, bool>,
}

impl<'e> Counter<'e> {
    fn is_float_expr(&self, e: &Expr) -> bool {
        match &e.kind {
            ExprKind::IntLit(_) => false,
            ExprKind::FloatLit(_) => true,
            ExprKind::Var(n) => self
                .locals_float
                .get(n)
                .copied()
                .unwrap_or_else(|| self.env.get(n).map(|t| t.is_float()).unwrap_or(false)),
            ExprKind::Index(n, _) => self
                .env
                .get(n)
                .map(|t| match t {
                    Type::Array(e, _) => e.is_float(),
                    t => t.is_float(),
                })
                .unwrap_or(true),
            ExprKind::Unary(_, a) => self.is_float_expr(a),
            ExprKind::Binary(op, a, b) => {
                if op.is_arith() {
                    self.is_float_expr(a) || self.is_float_expr(b)
                } else {
                    false // comparisons/logicals yield int
                }
            }
            ExprKind::Call(f, _) => is_float_builtin(f.as_str()),
        }
    }

    fn count_expr(&mut self, e: &Expr) {
        match &e.kind {
            ExprKind::IntLit(_) | ExprKind::FloatLit(_) | ExprKind::Var(_) => {}
            ExprKind::Index(_, i) => self.count_expr(i),
            ExprKind::Unary(op, a) => {
                self.count_expr(a);
                match op {
                    UnOp::Neg if self.is_float_expr(a) => self.c.fmisc += 1,
                    _ => self.c.int_ops += 1,
                }
            }
            ExprKind::Binary(op, a, b) => {
                self.count_expr(a);
                self.count_expr(b);
                if op.is_arith() {
                    if self.is_float_expr(a) || self.is_float_expr(b) {
                        match op {
                            BinOp::Add | BinOp::Sub => self.c.fadd += 1,
                            BinOp::Mul => self.c.fmul += 1,
                            BinOp::Div | BinOp::Mod => self.c.fdiv += 1,
                            _ => unreachable!(),
                        }
                    } else {
                        self.c.int_ops += 1;
                    }
                } else {
                    self.c.cmps += 1;
                }
            }
            ExprKind::Call(f, args) => {
                for a in args {
                    self.count_expr(a);
                }
                match f.as_str() {
                    "sin" | "cos" => self.c.trig += 1,
                    "sqrt" => self.c.sqrt += 1,
                    "exp" => self.c.exp += 1,
                    "fabs" | "floor" | "fmin" | "fmax" => self.c.fmisc += 1,
                    _ => {} // non-builtin: rejected upstream by deps
                }
            }
        }
    }

    fn count_stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl(d) => {
                self.locals_float.insert(d.name, d.ty.is_float());
                if let Some(e) = &d.init {
                    self.count_expr(e);
                }
            }
            Stmt::Assign { target, op, value, .. } => {
                self.count_expr(value);
                if let LValue::Index(_, i) = target {
                    self.count_expr(i);
                }
                if *op != AssignOp::Assign {
                    // compound assign adds one more ALU op
                    let lhs_float = match target {
                        LValue::Var(n) => self
                            .locals_float
                            .get(n)
                            .copied()
                            .unwrap_or_else(|| {
                                self.env.get(n).map(|t| t.is_float()).unwrap_or(false)
                            }),
                        LValue::Index(n, _) => self
                            .env
                            .get(n)
                            .map(|t| match t {
                                Type::Array(e, _) => e.is_float(),
                                t => t.is_float(),
                            })
                            .unwrap_or(true),
                    };
                    if lhs_float || self.is_float_expr(value) {
                        match op {
                            AssignOp::MulAssign => self.c.fmul += 1,
                            AssignOp::DivAssign => self.c.fdiv += 1,
                            _ => self.c.fadd += 1,
                        }
                    } else {
                        self.c.int_ops += 1;
                    }
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                self.count_expr(cond);
                for s in then_branch.iter().chain(else_branch) {
                    self.count_stmt(s);
                }
            }
            Stmt::For { header, body, .. } => {
                self.c.nest_depth += 1;
                // loop bookkeeping: one int add + one compare per level
                self.c.int_ops += 1;
                self.c.cmps += 1;
                if let Some(c) = &header.cond {
                    self.count_expr(c);
                }
                for s in body {
                    self.count_stmt(s);
                }
            }
            Stmt::While { cond, body, .. } => {
                self.c.nest_depth += 1;
                self.count_expr(cond);
                for s in body {
                    self.count_stmt(s);
                }
            }
            Stmt::Return(Some(e), _) => self.count_expr(e),
            Stmt::Return(None, _) => {}
            Stmt::Expr(e, _) => self.count_expr(e),
            Stmt::Block(body) => {
                for s in body {
                    self.count_stmt(s);
                }
            }
        }
    }
}

fn is_float_builtin(name: &str) -> bool {
    crate::ir::varref::is_builtin(name)
}

/// Count datapath operators for one offloaded loop.
pub fn count(program: &Program, la: &LoopAnalysis) -> OpCounts {
    let env = type_env(program, la.info.function);
    let mut counter = Counter { env: &env, c: OpCounts::default(), locals_float: HashMap::new() };
    // the offloaded loop itself is one nest level
    counter.c.nest_depth = 1;
    counter.c.int_ops += 1;
    counter.c.cmps += 1;
    for s in &la.info.body {
        counter.count_stmt(s);
    }
    counter.c.arrays = la.refs.arrays().len() as u32;
    for r in &la.deps.reductions {
        if r.op == '+' {
            counter.c.plus_reductions += 1;
        } else {
            counter.c.star_reductions += 1;
        }
    }
    counter.c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir;

    fn ops(src: &str, idx: usize) -> OpCounts {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        count(&p, &loops[idx])
    }

    #[test]
    fn counts_float_ops() {
        let c = ops(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[i] * 2.0 + 1.0 - b[i] / 3.0; } }",
            0,
        );
        assert_eq!(c.fmul, 1);
        assert_eq!(c.fadd, 2); // + and -
        assert_eq!(c.fdiv, 1);
        assert_eq!(c.arrays, 2);
    }

    #[test]
    fn int_index_math_counted_as_int() {
        let c = ops(
            "void f(float c[], int n) { int i; \
             for (i = 0; i < n; i++) { \
               for (int j = 0; j < n; j++) { c[i * n + j] = 1.0; } } }",
            0,
        );
        // i*n and +j are int ops; no float arithmetic at all
        assert!(c.int_ops >= 2);
        assert_eq!(c.fadd + c.fmul, 0);
        assert_eq!(c.nest_depth, 2);
    }

    #[test]
    fn builtins_classified() {
        let c = ops(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]) + sqrt(fabs(a[i])); } }",
            0,
        );
        assert_eq!(c.trig, 1);
        assert_eq!(c.sqrt, 1);
        assert_eq!(c.fmisc, 1);
    }

    #[test]
    fn reductions_detected() {
        let c = ops(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i]; } }",
            0,
        );
        assert_eq!(c.plus_reductions, 1);
        assert_eq!(c.star_reductions, 0);
    }

    #[test]
    fn compound_float_assign_counts_accumulate_op() {
        let c = ops(
            "void f(float a[], float b[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i] * b[i]; } }",
            0,
        );
        // one fmul for a*b, one fadd for +=
        assert_eq!(c.fmul, 1);
        assert_eq!(c.fadd, 1);
    }
}
