//! The steady-state service report: the deterministic summary
//! `flopt serve` prints and the serve tests pin byte-for-byte.

use std::fmt::Write as _;

use crate::cache::CacheStats;

/// Per-tenant admission and latency statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantRow {
    /// Tenant app name.
    pub name: String,
    /// Still active when the run ended?
    pub active: bool,
    /// Final placement label (`board N · <option>`), `cpu` if unplaced.
    pub placement: String,
    /// Requests admitted (passed the quota gate).
    pub admitted: u64,
    /// Requests turned away by the per-epoch admission quota.
    pub rejected_quota: u64,
    /// Requests completed (admitted work always completes).
    pub completed: u64,
    /// Median sojourn latency, seconds.
    pub p50_s: f64,
    /// 99th-percentile sojourn latency, seconds.
    pub p99_s: f64,
    /// Mean sojourn latency, seconds.
    pub mean_s: f64,
}

/// The complete steady-state report (see [`crate::serve::run_serve`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Seed the arrival/churn streams were derived from.
    pub seed: u64,
    /// Arrivals generated (requested load).
    pub requests: usize,
    /// Requests completed.
    pub completed: usize,
    /// Requests rejected by per-tenant admission quotas.
    pub rejected_quota: u64,
    /// Requests addressed to an inactive/unknown tenant (trace-driven).
    pub rejected_inactive: u64,
    /// Simulated span from first arrival to last completion, hours.
    pub duration_h: f64,
    /// Completed requests per simulated hour.
    pub throughput_per_h: f64,
    /// Global sojourn-latency percentiles and moments, seconds.
    pub p50_s: f64,
    /// 99th percentile.
    pub p99_s: f64,
    /// Mean.
    pub mean_s: f64,
    /// Maximum.
    pub max_s: f64,
    /// Epoch boundaries processed.
    pub epochs: u64,
    /// Tenant joins (mid-run, beyond the initial set).
    pub joins: u64,
    /// Tenant departures.
    pub leaves: u64,
    /// Joins provisioned entirely from warm cache artifacts.
    pub warm_joins: u64,
    /// Incremental re-packs run (one per epoch boundary + the initial).
    pub repacks: u64,
    /// Re-packs escalated to a full FFD pack.
    pub full_repacks: u64,
    /// Live migrations (placements moved off a resident bitstream).
    pub migrations: u64,
    /// Simulated hours of bitstream-swap work those migrations cost.
    pub migration_hours: f64,
    /// Total simulated automation hours on the shared clock (searches,
    /// reconfigurations) — the provisioning cost of the whole run.
    pub search_hours: f64,
    /// Compile-lane hours within `search_hours`.
    pub compile_hours: f64,
    /// Artifact-cache counters at the end of the run.
    pub cache: CacheStats,
    /// Per-tenant rows, in tenant-table order.
    pub tenants: Vec<TenantRow>,
}

/// `q`-th percentile of an ascending-sorted slice (nearest-rank on the
/// rounded index — deterministic, no interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl ServeReport {
    /// Render the deterministic report (what `flopt serve` prints).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "=== flopt serve — steady-state report ===");
        let _ = writeln!(
            s,
            "seed {} · {} arrivals over {:.2} sim h · {} epochs",
            self.seed, self.requests, self.duration_h, self.epochs
        );
        let _ = writeln!(s);
        let _ = writeln!(
            s,
            "completed            {:>8}    throughput {:>10.2} req/h",
            self.completed, self.throughput_per_h
        );
        let _ = writeln!(
            s,
            "rejected (quota)     {:>8}    latency p50  {:>8.3} s",
            self.rejected_quota, self.p50_s
        );
        let _ = writeln!(
            s,
            "rejected (inactive)  {:>8}    latency p99  {:>8.3} s",
            self.rejected_inactive, self.p99_s
        );
        let _ = writeln!(
            s,
            "joins {:>3} (warm {:>3})          latency mean {:>8.3} s",
            self.joins, self.warm_joins, self.mean_s
        );
        let _ = writeln!(
            s,
            "leaves {:>2}                      latency max  {:>8.3} s",
            self.leaves, self.max_s
        );
        let _ = writeln!(s);
        let _ = writeln!(
            s,
            "re-packs {} (full {}) · migrations {} costing {:.2} h of swaps",
            self.repacks, self.full_repacks, self.migrations, self.migration_hours
        );
        let _ = writeln!(
            s,
            "automation {:.2} sim h (compile lanes {:.2} h)",
            self.search_hours, self.compile_hours
        );
        let _ = writeln!(
            s,
            "cache: {} mem hits · {} disk hits · {} misses · {} ttl + {} lru evictions · \
             {} disk read errors · {} corrupt recomputes",
            self.cache.mem_hits,
            self.cache.disk_hits,
            self.cache.misses,
            self.cache.ttl_evictions,
            self.cache.lru_evictions,
            self.cache.disk_read_errors,
            self.cache.corrupt_recomputes()
        );
        let _ = writeln!(s);
        let _ = writeln!(
            s,
            "{:<14} {:<5} {:<34} {:>7} {:>7} {:>7} {:>9} {:>9}",
            "tenant", "state", "placement", "adm", "rej", "done", "p50 s", "p99 s"
        );
        let _ = writeln!(s, "{}", "-".repeat(98));
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "{:<14} {:<5} {:<34} {:>7} {:>7} {:>7} {:>9.3} {:>9.3}",
                t.name,
                if t.active { "on" } else { "off" },
                t.placement,
                t.admitted,
                t.rejected_quota,
                t.completed,
                t.p50_s,
                t.p99_s
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        // (99*0.5).round() = 50 → xs[50] = 51 (nearest-rank, not interpolated)
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
    }
}
