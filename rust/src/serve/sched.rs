//! Per-board deficit-round-robin (DRR) request scheduling.
//!
//! A board serializes its admitted requests; without fairness a heavy
//! tenant's backlog would starve every co-resident tenant.  Classic DRR
//! fixes that at O(1) per decision: the scheduler visits hosted tenants
//! in a fixed ring, credits each backlogged tenant one quantum of
//! service seconds per visit, and serves a tenant's head-of-line
//! request only when its accumulated deficit covers the request's cost.
//! An idle tenant's deficit resets — fairness is about the present
//! backlog, not banked history.
//!
//! Everything here is integer/`f64` state machines over `Vec`s in fixed
//! tenant order: no hashing, no wall clock, no randomness — the whole
//! schedule is a pure function of the enqueue sequence, which is what
//! lets `flopt serve` stay byte-identical across worker-pool sizes.

use std::collections::VecDeque;

/// One admitted request waiting for (or bound to) a board.
#[derive(Debug, Clone, Copy)]
pub struct QueuedReq {
    /// Submission index (global, deterministic tie-break and audit id).
    pub id: usize,
    /// Tenant index in the service's tenant table.
    pub tenant: usize,
    /// Arrival time (sojourn latency measures from here).
    pub at_s: f64,
    /// Board-occupancy seconds this request needs.
    pub service_s: f64,
}

/// One finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Submission index.
    pub id: usize,
    /// Tenant index.
    pub tenant: usize,
    /// Arrival time.
    pub at_s: f64,
    /// Completion time (sojourn = `finish_s - at_s`).
    pub finish_s: f64,
}

/// One board's DRR scheduler.
#[derive(Debug)]
pub struct BoardSched {
    /// Hosted tenant indices, ascending — the DRR visit ring.
    tenants: Vec<usize>,
    /// Per-hosted-tenant FIFO backlog (parallel to `tenants`).
    queues: Vec<VecDeque<QueuedReq>>,
    /// Per-hosted-tenant deficit counter, in service seconds.
    deficit: Vec<f64>,
    /// Service seconds credited per ring visit.
    quantum_s: f64,
    /// Ring cursor (next slot to visit).
    cursor: usize,
    /// The board is occupied until this simulated time (carried across
    /// re-packs; reconfiguration downtime pushes it forward).
    pub busy_until_s: f64,
    /// DRR decisions taken (requests dequeued to run) — a pure function
    /// of the enqueue sequence, harvested into the serve-level metrics
    /// before a re-pack discards the scheduler.
    pub decisions: u64,
}

impl BoardSched {
    /// A scheduler for `tenants` (any order; sorted internally) with a
    /// per-visit `quantum_s`, busy until `busy_until_s`.
    pub fn new(mut tenants: Vec<usize>, quantum_s: f64, busy_until_s: f64) -> Self {
        tenants.sort_unstable();
        tenants.dedup();
        let n = tenants.len();
        BoardSched {
            tenants,
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            deficit: vec![0.0; n],
            quantum_s: if quantum_s > 0.0 { quantum_s } else { 1.0 },
            cursor: 0,
            busy_until_s,
            decisions: 0,
        }
    }

    /// Does this board host `tenant`?
    pub fn hosts(&self, tenant: usize) -> bool {
        self.tenants.binary_search(&tenant).is_ok()
    }

    /// Queue a request for one of the hosted tenants.
    ///
    /// # Panics
    /// If the request's tenant is not hosted here (a routing bug).
    pub fn enqueue(&mut self, req: QueuedReq) {
        let slot = self
            .tenants
            .binary_search(&req.tenant)
            .expect("request routed to a board that does not host its tenant");
        self.queues[slot].push_back(req);
    }

    /// Is every backlog empty?
    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Remove and return every queued (not yet started) request, in
    /// submission order — used when an epoch re-pack re-routes work.
    pub fn drain_pending(&mut self) -> Vec<QueuedReq> {
        let mut out: Vec<QueuedReq> = self.queues.iter_mut().flat_map(|q| q.drain(..)).collect();
        out.sort_by_key(|r| r.id);
        for d in &mut self.deficit {
            *d = 0.0;
        }
        out
    }

    /// The DRR decision: which queued request runs next?
    fn pop_next(&mut self) -> Option<QueuedReq> {
        if self.tenants.is_empty() || self.is_empty() {
            return None;
        }
        let n = self.tenants.len();
        // Each backlogged tenant gains one quantum per ring pass, so
        // `ceil(max_cost/quantum) + 1` passes always suffice; the bound
        // below is a defensive backstop against a degenerate quantum.
        let max_visits = n * 64;
        for visit in 0..max_visits {
            let i = self.cursor;
            self.cursor = (self.cursor + 1) % n;
            if self.queues[i].is_empty() {
                self.deficit[i] = 0.0; // idle tenants do not bank credit
                continue;
            }
            self.deficit[i] += self.quantum_s;
            let cost = self.queues[i].front().expect("non-empty").service_s;
            if self.deficit[i] + 1e-9 >= cost {
                self.deficit[i] -= cost;
                let _ = visit;
                self.decisions += 1;
                return Some(self.queues[i].pop_front().expect("non-empty"));
            }
        }
        // Backstop: serve the first backlogged tenant outright rather
        // than spin (can only trigger with a pathological quantum).
        let i = (0..n).find(|&i| !self.queues[i].is_empty())?;
        self.deficit[i] = 0.0;
        self.decisions += 1;
        self.queues[i].pop_front()
    }

    /// Run the board forward: start queued work whenever the board
    /// frees up before `now_s`, appending each started request's
    /// completion to `out`.  Call with `f64::INFINITY` to drain.
    pub fn pump(&mut self, now_s: f64, out: &mut Vec<Completion>) {
        while !self.is_empty() && self.busy_until_s < now_s {
            let Some(req) = self.pop_next() else { return };
            let start = if self.busy_until_s > req.at_s { self.busy_until_s } else { req.at_s };
            let finish = start + req.service_s;
            self.busy_until_s = finish;
            out.push(Completion {
                id: req.id,
                tenant: req.tenant,
                at_s: req.at_s,
                finish_s: finish,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, tenant: usize, at_s: f64, service_s: f64) -> QueuedReq {
        QueuedReq { id, tenant, at_s, service_s }
    }

    #[test]
    fn fifo_for_a_single_tenant() {
        let mut b = BoardSched::new(vec![3], 1.0, 0.0);
        b.enqueue(req(0, 3, 0.0, 2.0));
        b.enqueue(req(1, 3, 0.0, 2.0));
        let mut done = Vec::new();
        b.pump(f64::INFINITY, &mut done);
        assert_eq!(done.len(), 2);
        assert_eq!((done[0].id, done[1].id), (0, 1));
        assert_eq!(done[0].finish_s, 2.0);
        assert_eq!(done[1].finish_s, 4.0);
    }

    #[test]
    fn drr_interleaves_a_heavy_backlog_with_a_light_one() {
        // tenant 0 floods 6 requests; tenant 1 has 2.  Round-robin
        // visits must interleave them instead of draining tenant 0.
        let mut b = BoardSched::new(vec![0, 1], 1.0, 0.0);
        for i in 0..6 {
            b.enqueue(req(i, 0, 0.0, 1.0));
        }
        b.enqueue(req(6, 1, 0.0, 1.0));
        b.enqueue(req(7, 1, 0.0, 1.0));
        let mut done = Vec::new();
        b.pump(f64::INFINITY, &mut done);
        assert_eq!(done.len(), 8);
        // both of tenant 1's requests must finish within the first four
        // services — strict alternation while both are backlogged
        let pos_t1: Vec<usize> = done
            .iter()
            .enumerate()
            .filter(|(_, c)| c.tenant == 1)
            .map(|(i, _)| i)
            .collect();
        assert!(pos_t1[1] <= 3, "light tenant served early: {pos_t1:?}");
    }

    #[test]
    fn deficit_accumulates_for_expensive_requests() {
        // tenant 1's request costs 3 quanta: it must still get served
        // (after banking credit across visits), not starve forever.
        let mut b = BoardSched::new(vec![0, 1], 1.0, 0.0);
        for i in 0..5 {
            b.enqueue(req(i, 0, 0.0, 1.0));
        }
        b.enqueue(req(5, 1, 0.0, 3.0));
        let mut done = Vec::new();
        b.pump(f64::INFINITY, &mut done);
        assert_eq!(done.len(), 6);
        let t1_pos = done.iter().position(|c| c.tenant == 1).unwrap();
        assert!(t1_pos < 5, "expensive request must not run dead last");
    }

    #[test]
    fn pump_respects_arrival_and_busy_times() {
        let mut b = BoardSched::new(vec![0], 1.0, 10.0);
        b.enqueue(req(0, 0, 4.0, 2.0));
        let mut done = Vec::new();
        b.pump(5.0, &mut done);
        assert!(done.is_empty(), "board still busy at t=5");
        b.pump(11.0, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish_s, 12.0, "starts when the board frees");
    }

    #[test]
    fn drain_pending_returns_unstarted_work_in_submission_order() {
        let mut b = BoardSched::new(vec![0, 2], 1.0, 0.0);
        b.enqueue(req(3, 2, 0.0, 1.0));
        b.enqueue(req(1, 0, 0.0, 1.0));
        b.enqueue(req(2, 0, 0.0, 1.0));
        let pending = b.drain_pending();
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }
}
