//! The long-lived offload daemon scenario (`flopt serve`).
//!
//! Every other `flopt` entry point is one-shot: all requests known up
//! front, one pack, done.  A production offload service is a stream.
//! This module composes every layer PRs 3–6 built into a persistent
//! service simulated on the shared [`crate::metrics::SimClock`]:
//!
//! * **Arrivals** ([`arrival`]) — a seeded Poisson process (or a replay
//!   trace) delivers thousands of requests over simulated time; each
//!   request belongs to a tenant picked by seeded weight (tenant 0 is
//!   the configurable heavy hitter).
//! * **Churn** — tenants join and leave at epoch boundaries.  A joiner
//!   is provisioned through the batch service ([`crate::service`]): a
//!   cold join pays the full search makespan before its placement is
//!   ready (requests run on the CPU meanwhile); a warm re-join finds
//!   its artifacts in the cache and is ready instantly.
//! * **Incremental re-pack** ([`crate::fleet::incremental_repack`]) —
//!   at each epoch the packer keeps resident tenants in place, first-
//!   fits joiners into residual capacity, and escalates to a full
//!   re-pack only when that places strictly more tenants; every
//!   placement moved off a resident bitstream is a live migration that
//!   pays the swap cost in board downtime and compile-lane work.
//! * **Fairness** ([`sched`]) — per-board deficit-round-robin keeps the
//!   heavy tenant from starving co-residents, and per-tenant per-epoch
//!   admission quotas (`--quota`) bound what it can admit at all.
//! * **Eviction** — the artifact store runs under an
//!   [`EvictionPolicy`] (`--cache-budget`, `--cache-ttl-hours`); the
//!   service feeds it simulated time at each epoch so TTL expiry is
//!   reproducible.
//!
//! The run is a pure function of [`ServeConfig`]: the [`ServeReport`]
//! is byte-identical across worker-pool sizes (all randomness is drawn
//! at generation time from seeded streams; the schedulers are
//! hash-free state machines) — `rust/tests/serve.rs` pins this.

pub mod arrival;
pub mod report;
pub mod sched;

pub use arrival::{parse_trace, poisson_arrivals, Arrival};
pub use report::{ServeReport, TenantRow};
pub use sched::{BoardSched, Completion, QueuedReq};

use std::sync::Arc;

use crate::apps::{self, gen, App};
use crate::backend::{Target, FPGA};
use crate::cache::{self, CacheStore, EvictionPolicy};
use crate::config::SearchConfig;
use crate::coordinator::pipeline::offload_search;
use crate::coordinator::verify_env::VerifyEnv;
use crate::cpu::XEON_3104;
use crate::fleet::{incremental_repack, tenant_from_trace, Placement, TenantDemand};
use crate::fpga::device::Device;
use crate::service::{BatchRequest, BatchService, CacheDisposition};
use crate::util::rng::Rng;

use report::percentile;

/// Everything that determines a serve run (the report is a pure
/// function of this struct).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Master seed for the arrival and churn streams.
    pub seed: u64,
    /// Poisson arrivals to generate (ignored when `arrivals` is set).
    pub requests: usize,
    /// Mean arrival rate, requests per simulated hour.
    pub rate_per_h: f64,
    /// Initially active tenants (clamped to at least 2).
    pub tenants: usize,
    /// FPGA boards in the fleet.
    pub boards: usize,
    /// Epoch length in simulated seconds (churn + re-pack cadence).
    pub epoch_s: f64,
    /// Tenants join/leave at epoch boundaries?
    pub churn: bool,
    /// Per-tenant admitted requests per epoch; 0 = unlimited.
    pub quota: u64,
    /// DRR quantum as a multiple of the slowest hosted service time.
    pub drr_quantum: f64,
    /// Arrival weight of tenant 0 relative to every other tenant.
    pub heavy_weight: f64,
    /// Batch-service worker pool (must not affect any output byte).
    pub pool: usize,
    /// Simulated compile lanes.
    pub lanes: usize,
    /// Memory-tier cache budget in bytes (`None` = unbounded).
    pub cache_budget_bytes: Option<u64>,
    /// Cache TTL in simulated seconds (`None` = no expiry).
    pub cache_ttl_s: Option<f64>,
    /// Search configuration for tenant provisioning.
    pub cfg: SearchConfig,
    /// Workload scale of the tenant searches.
    pub test_scale: bool,
    /// Trace-driven arrivals (overrides the Poisson stream).
    pub arrivals: Option<Vec<Arrival>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            seed: 42,
            requests: 2000,
            rate_per_h: 50.0,
            tenants: 6,
            boards: 2,
            epoch_s: 4.0 * 3600.0,
            churn: true,
            quota: 0,
            drr_quantum: 1.0,
            heavy_weight: 4.0,
            pool: 4,
            lanes: 4,
            cache_budget_bytes: None,
            cache_ttl_s: None,
            cfg: SearchConfig::default(),
            test_scale: true,
            arrivals: None,
        }
    }
}

/// One tenant's live state.
struct Tenant {
    app: &'static App,
    active: bool,
    /// Placement becomes usable at this simulated time (provisioning
    /// latency of a cold join; 0 for the pre-provisioned initial set).
    ready_at_s: f64,
    demand: Option<TenantDemand>,
    /// Current `(board, option)` placement, `None` = CPU.
    placement: Option<(usize, usize)>,
    /// This tenant's dedicated CPU server frees at this time.
    cpu_busy_until_s: f64,
    admitted_epoch: u64,
    admitted: u64,
    rejected_quota: u64,
}

#[derive(Default)]
struct Counters {
    epochs: u64,
    joins: u64,
    leaves: u64,
    warm_joins: u64,
    repacks: u64,
    full_repacks: u64,
    migrations: u64,
    migration_s: f64,
    rejected_quota: u64,
    rejected_inactive: u64,
    /// DRR dequeue decisions, harvested from each scheduler before a
    /// re-pack (or the final drain) discards it.
    drr_decisions: u64,
}

/// The tenant universe: the registered corpus first, extended with
/// seeded generated apps when more tenants are requested than exist.
fn universe(n: usize, seed: u64) -> Vec<&'static App> {
    let mut u = apps::all();
    let mut i = 0u64;
    while u.len() < n {
        u.push(gen::as_app(seed, i));
        i += 1;
    }
    u.truncate(n);
    u
}

/// Extract a tenant demand from the (now warm) trace of `app`.
fn extract_demand(
    service: &BatchService,
    app: &'static App,
    cfg: &SearchConfig,
    test_scale: bool,
    order: usize,
) -> crate::Result<TenantDemand> {
    let backend = &FPGA;
    let tkey = cache::trace_key(app, test_scale, backend, cfg);
    let t = match service.cache().get_trace(tkey) {
        Some(t) => t,
        None => {
            // destination outcome was warm but its trace is not in this
            // store: run the trace-level search on the shared cache +
            // clock (warm stages make it cheap) — same fallback the
            // fleet layer uses
            let env = VerifyEnv::with_clock(
                backend,
                service.cpu(),
                cfg.clone(),
                Arc::clone(service.clock()),
            )
            .with_cache(Arc::clone(service.cache()));
            offload_search(app, &env, test_scale)?
        }
    };
    Ok(tenant_from_trace(&t, backend.device, order))
}

/// Provision one joining tenant through the batch service: returns its
/// demand, the simulated seconds of provisioning makespan (its
/// readiness latency), and whether the join was served warm.
fn provision(
    service: &BatchService,
    app: &'static App,
    cfg: &SearchConfig,
    test_scale: bool,
    order: usize,
) -> crate::Result<(TenantDemand, f64, bool)> {
    let before_s = service.clock().total_seconds();
    let rep = service.run(&[BatchRequest {
        app,
        target: Target::Fpga,
        cfg: cfg.clone(),
        test_scale,
    }])?;
    let warm = rep.items[0].disposition != CacheDisposition::Cold;
    let demand = extract_demand(service, app, cfg, test_scale, order)?;
    let dt_s = service.clock().total_seconds() - before_s;
    Ok((demand, dt_s, warm))
}

/// Weighted pick over the active tenants: tenant 0 carries
/// `heavy_weight`, everyone else weight 1.
fn weighted_pick(tenants: &[Tenant], pick: f64, heavy_weight: f64) -> Option<usize> {
    let weight = |i: usize| if i == 0 { heavy_weight.max(0.0) } else { 1.0 };
    let total: f64 = tenants
        .iter()
        .enumerate()
        .filter(|(_, t)| t.active)
        .map(|(i, _)| weight(i))
        .sum();
    if total <= 0.0 {
        return None;
    }
    let mut x = pick * total;
    let mut last = None;
    for (i, t) in tenants.iter().enumerate() {
        if !t.active {
            continue;
        }
        last = Some(i);
        if x < weight(i) {
            return Some(i);
        }
        x -= weight(i);
    }
    last // floating-point edge: the draw lands on the final tenant
}

/// Re-pack the ready tenants at `now_s` and rebuild the board
/// schedulers: pumps old boards up to `now_s`, re-routes their pending
/// (unstarted) requests under the new placements, charges every
/// reconfiguration as board downtime plus compile-lane work.
#[allow(clippy::too_many_arguments)]
fn repack_boards(
    now_s: f64,
    tenants: &mut [Tenant],
    boards_busy: &mut [f64],
    scheds: &mut Vec<BoardSched>,
    completions: &mut Vec<Completion>,
    service: &BatchService,
    c: &ServeConfig,
    device: &Device,
    stats: &mut Counters,
) {
    // finish what the old configuration can start before `now_s`, then
    // pull the still-waiting requests for re-routing
    let mut pending: Vec<QueuedReq> = Vec::new();
    for (bi, s) in scheds.iter_mut().enumerate() {
        s.pump(now_s, completions);
        pending.extend(s.drain_pending());
        boards_busy[bi] = s.busy_until_s;
        stats.drr_decisions += s.decisions;
    }
    pending.sort_by_key(|r| r.id);

    // the placeable set: active, provisioned, and ready by now
    let placeable: Vec<usize> = (0..tenants.len())
        .filter(|&i| tenants[i].active && tenants[i].ready_at_s <= now_s && tenants[i].demand.is_some())
        .collect();
    let demands: Vec<TenantDemand> = placeable
        .iter()
        .map(|&i| tenants[i].demand.clone().expect("placeable has demand"))
        .collect();
    let previous: Vec<Option<(usize, usize)>> =
        placeable.iter().map(|&i| tenants[i].placement).collect();

    let rp = incremental_repack(&demands, &previous, boards_busy.len(), c.cfg.resource_cap, device);
    stats.repacks += 1;
    if rp.full {
        stats.full_repacks += 1;
    }
    stats.migrations += rp.migrations as u64;
    stats.migration_s += rp.migration_s;
    {
        // re-pack telemetry, stamped on the arrival timeline (`now_s` is
        // a pure function of the config, never of the worker pool)
        let obs = service.clock().obs();
        obs.mark("serve.repack", "serve", now_s);
        obs.count("serve.repacks", 1);
        if rp.full {
            obs.count("serve.full_repacks", 1);
        }
        obs.count("serve.migrations", rp.migrations as u64);
    }

    for t in tenants.iter_mut() {
        t.placement = None;
    }
    for (k, p) in rp.outcome.placements.iter().enumerate() {
        let ti = placeable[k];
        if let Placement::Placed { board, option, reconfig_s } = p {
            tenants[ti].placement = Some((*board, *option));
            if *reconfig_s > 0.0 {
                // a bitstream swap is real compile-farm work AND board
                // downtime: the board serves nothing while it flashes
                service
                    .clock()
                    .schedule_compile(&format!("reconfig {}", demands[k].app_name), *reconfig_s);
                let base = if boards_busy[*board] > now_s { boards_busy[*board] } else { now_s };
                boards_busy[*board] = base + reconfig_s;
            }
        }
    }

    // rebuild one DRR scheduler per board under the new residency
    scheds.clear();
    for (bi, busy) in boards_busy.iter().enumerate() {
        let hosted: Vec<usize> = (0..tenants.len())
            .filter(|&i| matches!(tenants[i].placement, Some((b, _)) if b == bi))
            .collect();
        let max_service = hosted
            .iter()
            .filter_map(|&i| {
                let (_, o) = tenants[i].placement?;
                Some(tenants[i].demand.as_ref()?.options[o].time_s)
            })
            .fold(0.0_f64, f64::max);
        let quantum = if max_service > 0.0 { c.drr_quantum * max_service } else { 1.0 };
        scheds.push(BoardSched::new(hosted, quantum, *busy));
    }

    // re-route the pending requests under the new placements; a tenant
    // that lost its board (or left) finishes on its CPU server
    for req in pending {
        let ti = req.tenant;
        match tenants[ti].placement {
            Some((b, o)) => {
                let service_s =
                    tenants[ti].demand.as_ref().expect("placed tenant has demand").options[o].time_s;
                scheds[b].enqueue(QueuedReq { service_s, ..req });
            }
            None => {
                let cpu_s = tenants[ti].demand.as_ref().map(|d| d.cpu_time_s).unwrap_or(1.0);
                let base = if tenants[ti].cpu_busy_until_s > now_s {
                    tenants[ti].cpu_busy_until_s
                } else {
                    now_s
                };
                let finish = base + cpu_s;
                tenants[ti].cpu_busy_until_s = finish;
                completions.push(Completion {
                    id: req.id,
                    tenant: ti,
                    at_s: req.at_s,
                    finish_s: finish,
                });
            }
        }
    }
    for s in scheds.iter_mut() {
        s.pump(now_s, completions);
    }
}

/// Run the daemon scenario to completion and summarize it.
///
/// `cache` is the artifact store to serve from (a `--cache-dir` store
/// makes re-joins warm across *processes*; the default fresh store
/// still makes them warm within the run).  The report is a pure
/// function of `c` — byte-identical for any `c.pool`.
pub fn run_serve(c: &ServeConfig, cache: Arc<CacheStore>) -> crate::Result<ServeReport> {
    run_serve_with_clock(c, cache).map(|(r, _)| r)
}

/// [`run_serve`], additionally returning the service's shared
/// [`crate::metrics::SimClock`] so callers can export the accumulated
/// trace spans and metrics (`flopt serve --trace-out/--metrics-out`).
pub fn run_serve_with_clock(
    c: &ServeConfig,
    cache: Arc<CacheStore>,
) -> crate::Result<(ServeReport, Arc<crate::metrics::SimClock>)> {
    let service = BatchService::new(c.pool, c.lanes, &XEON_3104).with_cache(cache);
    let store = Arc::clone(service.cache());
    store.set_policy(EvictionPolicy {
        budget_bytes: c.cache_budget_bytes,
        ttl_s: c.cache_ttl_s,
    });
    let backend = &FPGA;
    let device = backend.device;

    // ---- tenant universe -------------------------------------------
    let initial_n = c.tenants.max(2);
    let universe_n = initial_n + if c.churn { 2 } else { 0 };
    let mut tenants: Vec<Tenant> = universe(universe_n, c.seed)
        .into_iter()
        .map(|app| Tenant {
            app,
            active: false,
            ready_at_s: 0.0,
            demand: None,
            placement: None,
            cpu_busy_until_s: 0.0,
            admitted_epoch: 0,
            admitted: 0,
            rejected_quota: 0,
        })
        .collect();
    let initial_n = initial_n.min(tenants.len());

    // ---- initial provisioning (pre-deployed fleet, ready at t=0) ---
    let reqs: Vec<BatchRequest> = tenants[..initial_n]
        .iter()
        .map(|t| BatchRequest {
            app: t.app,
            target: Target::Fpga,
            cfg: c.cfg.clone(),
            test_scale: c.test_scale,
        })
        .collect();
    service.run(&reqs)?;
    for i in 0..initial_n {
        tenants[i].active = true;
        tenants[i].demand = Some(extract_demand(&service, tenants[i].app, &c.cfg, c.test_scale, i)?);
    }

    let mut stats = Counters::default();
    let mut completions: Vec<Completion> = Vec::new();
    let mut boards_busy = vec![0.0_f64; c.boards.max(1)];
    let mut scheds: Vec<BoardSched> = Vec::new();
    repack_boards(
        0.0,
        &mut tenants,
        &mut boards_busy,
        &mut scheds,
        &mut completions,
        &service,
        c,
        device,
        &mut stats,
    );

    // ---- the arrival loop ------------------------------------------
    let arrivals = match &c.arrivals {
        Some(a) => a.clone(),
        None => poisson_arrivals(c.seed, c.requests, c.rate_per_h),
    };
    let mut churn_rng = Rng::new(c.seed ^ 0x4348_5552_4e21_2121); // "CHURN!!!"
    let mut next_epoch_s = c.epoch_s.max(1.0);
    let mut epoch_index: u64 = 0;

    for (id, a) in arrivals.iter().enumerate() {
        // epoch boundaries strictly before this arrival fire first
        while next_epoch_s <= a.at_s {
            let t = next_epoch_s;
            epoch_index += 1;
            stats.epochs += 1;
            service.clock().obs().mark("serve.epoch", "serve", t);
            service.clock().obs().count("serve.epochs", 1);
            store.set_now_sim_s(t);
            for ten in tenants.iter_mut() {
                ten.admitted_epoch = 0;
            }

            let mut joined: Option<usize> = None;
            if c.churn {
                if epoch_index % 2 == 1 {
                    let candidates: Vec<usize> =
                        (0..tenants.len()).filter(|&i| !tenants[i].active).collect();
                    if !candidates.is_empty() {
                        let pick = candidates[churn_rng.below(candidates.len() as u64) as usize];
                        let (demand, dt_s, warm) =
                            provision(&service, tenants[pick].app, &c.cfg, c.test_scale, pick)?;
                        tenants[pick].active = true;
                        tenants[pick].demand = Some(demand);
                        tenants[pick].ready_at_s = t + dt_s;
                        stats.joins += 1;
                        let obs = service.clock().obs();
                        obs.mark("serve.join", "serve", t);
                        obs.count("serve.joins", 1);
                        if warm {
                            stats.warm_joins += 1;
                            obs.count("serve.warm_joins", 1);
                        }
                        joined = Some(pick);
                    }
                }
                if epoch_index % 3 == 0 {
                    let candidates: Vec<usize> = (1..tenants.len())
                        .filter(|&i| tenants[i].active && joined != Some(i))
                        .collect();
                    let active_count = tenants.iter().filter(|t| t.active).count();
                    if active_count > 2 && !candidates.is_empty() {
                        let pick = candidates[churn_rng.below(candidates.len() as u64) as usize];
                        tenants[pick].active = false;
                        tenants[pick].placement = None;
                        stats.leaves += 1;
                        service.clock().obs().mark("serve.leave", "serve", t);
                        service.clock().obs().count("serve.leaves", 1);
                    }
                }
            }

            repack_boards(
                t,
                &mut tenants,
                &mut boards_busy,
                &mut scheds,
                &mut completions,
                &service,
                c,
                device,
                &mut stats,
            );
            next_epoch_s += c.epoch_s.max(1.0);
        }

        // resolve the request's tenant
        service.clock().obs().count("serve.arrivals", 1);
        let ti = match a.tenant {
            Some(i) if i < tenants.len() && tenants[i].active => i,
            Some(_) => {
                stats.rejected_inactive += 1;
                service.clock().obs().count("serve.rejected_inactive", 1);
                continue;
            }
            None => match weighted_pick(&tenants, a.pick, c.heavy_weight) {
                Some(i) => i,
                None => {
                    stats.rejected_inactive += 1;
                    service.clock().obs().count("serve.rejected_inactive", 1);
                    continue;
                }
            },
        };

        // admission quota
        if c.quota > 0 && tenants[ti].admitted_epoch >= c.quota {
            tenants[ti].rejected_quota += 1;
            stats.rejected_quota += 1;
            service.clock().obs().count("serve.rejected_quota", 1);
            continue;
        }
        tenants[ti].admitted_epoch += 1;
        tenants[ti].admitted += 1;

        // route: the board if placed and ready, the CPU otherwise
        let routed = match tenants[ti].placement {
            Some((b, o)) if tenants[ti].ready_at_s <= a.at_s => Some((b, o)),
            _ => None,
        };
        match routed {
            Some((b, o)) => {
                let service_s =
                    tenants[ti].demand.as_ref().expect("placed tenant has demand").options[o].time_s;
                service.clock().obs().count("serve.routed_fpga", 1);
                scheds[b].enqueue(QueuedReq { id, tenant: ti, at_s: a.at_s, service_s });
                scheds[b].pump(a.at_s, &mut completions);
            }
            None => {
                service.clock().obs().count("serve.routed_cpu", 1);
                let cpu_s = tenants[ti].demand.as_ref().map(|d| d.cpu_time_s).unwrap_or(1.0);
                let start = if tenants[ti].cpu_busy_until_s > a.at_s {
                    tenants[ti].cpu_busy_until_s
                } else {
                    a.at_s
                };
                let finish = start + cpu_s;
                tenants[ti].cpu_busy_until_s = finish;
                completions.push(Completion { id, tenant: ti, at_s: a.at_s, finish_s: finish });
            }
        }
    }

    // drain every board
    for s in scheds.iter_mut() {
        s.pump(f64::INFINITY, &mut completions);
        stats.drr_decisions += s.decisions;
    }
    service.clock().obs().count("serve.drr_decisions", stats.drr_decisions);

    // ---- summarize --------------------------------------------------
    let mut lat: Vec<f64> = completions.iter().map(|cm| cm.finish_s - cm.at_s).collect();
    lat.sort_by(f64::total_cmp);
    let duration_s = completions.iter().fold(0.0_f64, |m, cm| m.max(cm.finish_s));
    let duration_h = duration_s / 3600.0;
    let mean_s = if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 };

    let mut per_lat: Vec<Vec<f64>> = vec![Vec::new(); tenants.len()];
    let mut per_done: Vec<u64> = vec![0; tenants.len()];
    for cm in &completions {
        per_lat[cm.tenant].push(cm.finish_s - cm.at_s);
        per_done[cm.tenant] += 1;
    }
    let rows: Vec<TenantRow> = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let mut l = per_lat[i].clone();
            l.sort_by(f64::total_cmp);
            TenantRow {
                name: t.app.name.to_string(),
                active: t.active,
                placement: match t.placement {
                    Some((b, o)) => {
                        let label = t
                            .demand
                            .as_ref()
                            .map(|d| d.options[o].label.as_str())
                            .unwrap_or("?");
                        format!("board {b} · {label}")
                    }
                    None => "cpu".to_string(),
                },
                admitted: t.admitted,
                rejected_quota: t.rejected_quota,
                completed: per_done[i],
                p50_s: percentile(&l, 0.5),
                p99_s: percentile(&l, 0.99),
                mean_s: if l.is_empty() { 0.0 } else { l.iter().sum::<f64>() / l.len() as f64 },
            }
        })
        .collect();

    let clock = Arc::clone(service.clock());
    let report = ServeReport {
        seed: c.seed,
        requests: arrivals.len(),
        completed: completions.len(),
        rejected_quota: stats.rejected_quota,
        rejected_inactive: stats.rejected_inactive,
        duration_h,
        throughput_per_h: if duration_h > 0.0 { completions.len() as f64 / duration_h } else { 0.0 },
        p50_s: percentile(&lat, 0.5),
        p99_s: percentile(&lat, 0.99),
        mean_s,
        max_s: lat.last().copied().unwrap_or(0.0),
        epochs: stats.epochs,
        joins: stats.joins,
        leaves: stats.leaves,
        warm_joins: stats.warm_joins,
        repacks: stats.repacks,
        full_repacks: stats.full_repacks,
        migrations: stats.migrations,
        migration_hours: stats.migration_s / 3600.0,
        search_hours: service.clock().total_hours(),
        compile_hours: service.clock().compile_lane_seconds() / 3600.0,
        cache: store.stats(),
        tenants: rows,
    };
    Ok((report, clock))
}
