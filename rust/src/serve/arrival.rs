//! Deterministic request-arrival generation.
//!
//! The daemon scenario needs an open-loop arrival process that is a
//! pure function of the seed: a Poisson stream by default (exponential
//! inter-arrival gaps from the repo's own xoshiro [`Rng`]), or a replay
//! of a trace file (`flopt serve --trace <file>`).  Every stochastic
//! decision a request needs later — which tenant it belongs to — is
//! drawn **at generation time** and carried on the [`Arrival`], so the
//! simulation itself consumes no RNG state and stays byte-identical for
//! any worker-pool size.

use crate::util::rng::Rng;

/// One request arrival.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Simulated arrival time, seconds from service start.
    pub at_s: f64,
    /// Tenant selector: `Some(i)` pins tenant index `i` (trace-driven
    /// replay); `None` picks from the currently *active* tenant set
    /// using `pick`.
    pub tenant: Option<usize>,
    /// Uniform draw in `[0,1)` for the weighted tenant pick when
    /// `tenant` is `None`.
    pub pick: f64,
}

/// Generate `n` Poisson arrivals at `rate_per_h` requests per simulated
/// hour from a dedicated seeded stream (the stream is salted so it can
/// never collide with the churn or generator streams sharing a seed).
pub fn poisson_arrivals(seed: u64, n: usize, rate_per_h: f64) -> Vec<Arrival> {
    let mut rng = Rng::new(seed ^ 0x4152_5249_5641_4c53); // "ARRIVALS"
    let rate_per_s = (rate_per_h / 3600.0).max(1e-12);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let u = rng.f64();
        // inverse-CDF exponential gap; (1-u) keeps ln() off exactly 0
        t += -(1.0 - u).ln() / rate_per_s;
        out.push(Arrival { at_s: t, tenant: None, pick: rng.f64() });
    }
    out
}

/// Parse a request trace: one arrival per line as
/// `<seconds> <tenant-index>`, `#` comments and blank lines skipped.
/// Arrival times must be non-decreasing.
pub fn parse_trace(text: &str) -> crate::Result<Vec<Arrival>> {
    let mut out = Vec::new();
    let mut last = 0.0_f64;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(ts), Some(ten)) = (it.next(), it.next()) else {
            anyhow::bail!("trace line {}: expected `<seconds> <tenant>`", ln + 1);
        };
        let at_s: f64 = ts
            .parse()
            .map_err(|e| anyhow::anyhow!("trace line {}: bad time `{ts}`: {e}", ln + 1))?;
        let tenant: usize = ten
            .parse()
            .map_err(|e| anyhow::anyhow!("trace line {}: bad tenant `{ten}`: {e}", ln + 1))?;
        if !at_s.is_finite() || at_s < last {
            anyhow::bail!("trace line {}: arrival times must be non-decreasing", ln + 1);
        }
        last = at_s;
        out.push(Arrival { at_s, tenant: Some(tenant), pick: 0.0 });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seeded_and_sorted() {
        let a = poisson_arrivals(42, 100, 50.0);
        let b = poisson_arrivals(42, 100, 50.0);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s, "same seed, same stream");
            assert_eq!(x.pick, y.pick);
        }
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        let c = poisson_arrivals(43, 100, 50.0);
        assert!(a[0].at_s != c[0].at_s, "different seed, different stream");
        // mean gap ≈ 72 s at 50/h; the 100-sample mean stays in range
        let mean_gap = a.last().unwrap().at_s / 100.0;
        assert!((20.0..300.0).contains(&mean_gap), "mean gap {mean_gap}");
    }

    #[test]
    fn trace_parses_and_validates() {
        let t = parse_trace("# comment\n0.5 0\n\n2 1\n2 0\n").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].tenant, Some(0));
        assert_eq!(t[1].at_s, 2.0);
        assert!(parse_trace("5 0\n1 0\n").is_err(), "time must not go back");
        assert!(parse_trace("nope 0\n").is_err());
        assert!(parse_trace("1\n").is_err());
    }
}
