//! Naive baseline: offload every offloadable loop in one pattern — what a
//! "parallelize everything" compiler flag would do.  Usually loses to the
//! narrowed search: cold loops pay PCIe transfer + kernel-launch overhead
//! for no gain, and the combined design may blow the resource cap.

use crate::coordinator::pipeline::AppAnalysis;
use crate::coordinator::verify_env::VerifyEnv;
use crate::opencl::OffloadPattern;

use super::{candidate_pool, reports_for, BaselineOutcome};

/// Offload every offloadable loop in a single pattern.
pub fn search(analysis: &AppAnalysis, env: &VerifyEnv<'_>) -> BaselineOutcome {
    let pool = candidate_pool(analysis);
    let reports = reports_for(analysis, env, &pool, 1);
    let pat = OffloadPattern::of(pool);
    let best = if pat.loops.is_empty() {
        None
    } else {
        Some(env.measure_pattern(analysis, &reports, &pat))
    };
    BaselineOutcome {
        method: "naive-all",
        best: best.filter(|m| m.compiled),
        evaluations: 1,
        sim_hours: env.clock.total_hours(),
        compile_hours: env.clock.compile_lane_seconds() / 3600.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::{analyze_app, search_with_analysis};
    use crate::cpu::XEON_3104;

    #[test]
    fn naive_all_is_no_better_than_proposed() {
        let analysis = analyze_app(&apps::TDFIR, true).unwrap();
        let naive_env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let naive = search(&analysis, &naive_env);

        let prop_env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let proposed = search_with_analysis(
            &apps::TDFIR,
            &analysis,
            &prop_env,
            &SearchConfig::default(),
        )
        .unwrap();

        assert!(
            proposed.speedup() >= naive.speedup() * 0.99,
            "proposed {:.2} vs naive {:.2}",
            proposed.speedup(),
            naive.speedup()
        );
    }
}
