//! GA baseline: the GPU method of [Yamato 2018] transplanted to FPGA.
//!
//! Chromosome = offload bitmask over the candidate pool; fitness = the
//! measured speedup of the pattern — which, on an FPGA, costs a full
//! ≈3-hour compile **per evaluation**.  A modest GA (population 8,
//! 5 generations) therefore burns days of compile time; the bench
//! regenerates that comparison.

use std::collections::HashMap;

use crate::backend::OffloadBackend;
use crate::coordinator::pipeline::AppAnalysis;
use crate::coordinator::verify_env::{PatternMeasurement, VerifyEnv};
use crate::cparse::ast::LoopId;
use crate::opencl::OffloadPattern;
use crate::util::order;
use crate::util::rng::Rng;

use super::{candidate_pool, reports_for, BaselineOutcome};

/// GA parameters (defaults follow the GPU paper's modest settings).
#[derive(Debug, Clone)]
pub struct GaConfig {
    /// Genomes per generation.
    pub population: usize,
    /// Generations to evolve.
    pub generations: usize,
    /// Single-point crossover probability.
    pub crossover_p: f64,
    /// Per-bit mutation probability.
    pub mutation_p: f64,
    /// PRNG seed (runs are deterministic per seed).
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        Self { population: 8, generations: 5, crossover_p: 0.9, mutation_p: 0.05, seed: 1 }
    }
}

type Genome = Vec<bool>;

fn genome_pattern(genome: &Genome, pool: &[LoopId]) -> OffloadPattern {
    OffloadPattern::of(
        genome
            .iter()
            .zip(pool)
            .filter(|(g, _)| **g)
            .map(|(_, id)| *id)
            .collect(),
    )
}

/// Run the GA search.  Every distinct evaluated pattern costs one
/// simulated full compile (cached across generations, as a real harness
/// would cache bitstreams).
pub fn search(
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &GaConfig,
) -> BaselineOutcome {
    let pool = candidate_pool(analysis);
    let reports = reports_for(analysis, env, &pool, 1);
    let mut rng = Rng::new(cfg.seed);
    let n = pool.len();

    let mut cache: HashMap<OffloadPattern, PatternMeasurement> = HashMap::new();
    let mut evaluations = 0usize;
    let eval = |pat: &OffloadPattern,
                    cache: &mut HashMap<OffloadPattern, PatternMeasurement>,
                    evaluations: &mut usize|
     -> PatternMeasurement {
        if let Some(m) = cache.get(pat) {
            return m.clone();
        }
        let m = if pat.loops.is_empty() {
            // empty genome = all-CPU: free, speedup 1
            PatternMeasurement {
                pattern: pat.clone(),
                utilization: env.backend.combined_utilization(&[]),
                compiled: true,
                compile_sim_s: 0.0,
                time_s: env.cpu_baseline_s(analysis),
                speedup: 1.0,
                kernels: Vec::new(),
            }
        } else {
            *evaluations += 1;
            env.measure_pattern(analysis, &reports, pat)
        };
        cache.insert(pat.clone(), m.clone());
        m
    };

    // init population: random genomes biased sparse (FPGA space is small)
    let mut pop: Vec<Genome> = (0..cfg.population)
        .map(|_| (0..n).map(|_| rng.bool(0.3)).collect())
        .collect();

    let mut best: Option<PatternMeasurement> = None;
    for _gen in 0..cfg.generations {
        // evaluate
        let scored: Vec<(f64, Genome)> = pop
            .iter()
            .map(|g| {
                let m = eval(&genome_pattern(g, &pool), &mut cache, &mut evaluations);
                let fit = if m.compiled { m.speedup } else { 0.0 };
                if best.as_ref().map(|b| m.speedup > b.speedup).unwrap_or(true) && m.compiled {
                    best = Some(m.clone());
                }
                (fit, g.clone())
            })
            .collect();

        // tournament selection + crossover + mutation
        let mut next = Vec::with_capacity(cfg.population);
        // elitism: keep the best genome (NaN fitness never wins; exact
        // ties go to the earlier genome, so evolution is deterministic)
        if let Some((_, g)) = order::select_best(
            scored.iter().enumerate(),
            |(_, (fit, _))| *fit,
            |(i, _)| *i,
        )
        .map(|(_, sg)| sg)
        {
            next.push(g.clone());
        }
        while next.len() < cfg.population {
            let mut pick = || -> usize {
                let a = rng.below(scored.len() as u64) as usize;
                let b = rng.below(scored.len() as u64) as usize;
                if scored[a].0 >= scored[b].0 { a } else { b }
            };
            let pa = scored[pick()].1.clone();
            let pb = scored[pick()].1.clone();
            let mut child = if n > 1 && rng.bool(cfg.crossover_p) {
                let cut = 1 + rng.below((n - 1) as u64) as usize;
                let mut c = pa[..cut].to_vec();
                c.extend_from_slice(&pb[cut..]);
                c
            } else {
                pa
            };
            for bit in child.iter_mut() {
                if rng.bool(cfg.mutation_p) {
                    *bit = !*bit;
                }
            }
            next.push(child);
        }
        pop = next;
    }

    BaselineOutcome {
        method: "ga",
        best,
        evaluations,
        sim_hours: env.clock.total_hours(),
        compile_hours: env.clock.compile_lane_seconds() / 3600.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::analyze_app;
    use crate::cpu::XEON_3104;

    #[test]
    fn ga_finds_an_improving_pattern_but_burns_compile_hours() {
        let analysis = analyze_app(&apps::MRIQ, true).unwrap();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let out = search(&analysis, &env, &GaConfig::default());
        assert!(out.speedup() > 1.0, "GA should find the hot loop eventually");
        // the whole point: GA needs far more compiles than the proposed d=4
        assert!(out.evaluations > 4, "evaluations {}", out.evaluations);
        assert!(out.compile_hours > 12.0, "compile hours {}", out.compile_hours);
    }

    #[test]
    fn ga_is_deterministic_per_seed() {
        let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
        let run = |seed| {
            let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
            let out = search(&analysis, &env, &GaConfig { seed, ..Default::default() });
            (out.evaluations, out.speedup())
        };
        assert_eq!(run(7), run(7));
    }
}
