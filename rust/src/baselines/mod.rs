//! Baseline search strategies the paper argues against (§3.2): the
//! GA-driven measurement loop that works for GPUs ([Yamato 2018]) is
//! infeasible on FPGAs because every fitness evaluation is an hours-long
//! compile.  These baselines make that argument quantitative
//! (`benches/search_methods.rs`).
//!
//! * [`ga`] — genetic algorithm over offload bitmasks, each evaluation a
//!   simulated full compile + measurement;
//! * [`exhaustive`] — every subset of the offloadable candidates;
//! * [`naive`] — offload *all* offloadable loops at once.

pub mod exhaustive;
pub mod ga;
pub mod naive;

use std::collections::HashMap;

use crate::backend::{BackendReport, OffloadBackend};
use crate::coordinator::pipeline::AppAnalysis;
use crate::coordinator::verify_env::{PatternMeasurement, VerifyEnv};
use crate::cparse::ast::LoopId;
use crate::intensity;

/// Outcome of a baseline search.
#[derive(Debug)]
pub struct BaselineOutcome {
    /// Which baseline produced this outcome.
    pub method: &'static str,
    /// Fastest compiled pattern found, if any.
    pub best: Option<PatternMeasurement>,
    /// patterns compiled+measured
    pub evaluations: usize,
    /// simulated wall-clock hours the search took
    pub sim_hours: f64,
    /// Simulated compile-lane hours burned.
    pub compile_hours: f64,
}

impl BaselineOutcome {
    /// Best speedup found (1.0 when nothing improved).
    pub fn speedup(&self) -> f64 {
        self.best.as_ref().map(|b| b.speedup).unwrap_or(1.0)
    }
}

/// The candidate set every baseline draws from: outermost offloadable
/// loops (same pool the proposed method ranks).
pub fn candidate_pool(analysis: &AppAnalysis) -> Vec<LoopId> {
    intensity::top_a(&analysis.intensities, &analysis.loops, usize::MAX)
        .into_iter()
        .map(|l| l.id)
        .collect()
}

/// Pre-compile reports for a set of loops (cached per loop).
pub fn reports_for(
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    ids: &[LoopId],
    unroll: usize,
) -> HashMap<LoopId, BackendReport> {
    ids.iter()
        .map(|id| {
            let la = analysis
                .loops
                .iter()
                .find(|l| l.info.id == *id)
                .expect("known loop");
            (*id, env.backend.precompile(&analysis.program, la, unroll))
        })
        .collect()
}
