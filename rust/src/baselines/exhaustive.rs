//! Exhaustive baseline: compile + measure **every** non-empty subset of
//! the candidate pool.  Optimal by construction, but the compile-hour
//! bill is exponential — the upper bound the paper's narrowing avoids.

use crate::coordinator::pipeline::AppAnalysis;
use crate::coordinator::verify_env::VerifyEnv;
use crate::opencl::OffloadPattern;

use super::{candidate_pool, reports_for, BaselineOutcome};

/// Cap on the pool size (2^n subsets — keep the simulation bounded).
pub const MAX_POOL: usize = 12;

/// Compile + measure every non-empty subset of the candidate pool.
pub fn search(analysis: &AppAnalysis, env: &VerifyEnv<'_>) -> BaselineOutcome {
    let mut pool = candidate_pool(analysis);
    pool.truncate(MAX_POOL);
    let reports = reports_for(analysis, env, &pool, 1);

    let mut best = None;
    let mut evaluations = 0usize;
    for mask in 1u32..(1u32 << pool.len()) {
        let loops: Vec<_> = pool
            .iter()
            .enumerate()
            .filter(|(i, _)| mask & (1 << i) != 0)
            .map(|(_, id)| *id)
            .collect();
        let pat = OffloadPattern::of(loops);
        let m = env.measure_pattern(analysis, &reports, &pat);
        evaluations += 1;
        if m.compiled
            && best
                .as_ref()
                .map(|b: &crate::coordinator::verify_env::PatternMeasurement| {
                    m.speedup > b.speedup
                })
                .unwrap_or(true)
        {
            best = Some(m);
        }
    }

    BaselineOutcome {
        method: "exhaustive",
        best,
        evaluations,
        sim_hours: env.clock.total_hours(),
        compile_hours: env.clock.compile_lane_seconds() / 3600.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::analyze_app;
    use crate::cpu::XEON_3104;

    #[test]
    fn exhaustive_is_optimal_but_expensive() {
        let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        let out = search(&analysis, &env);
        assert!(out.evaluations >= 3);
        // every evaluation is a ~3h compile
        assert!(out.compile_hours > 2.0 * out.evaluations as f64);
    }
}
