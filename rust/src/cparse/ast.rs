//! MiniC abstract syntax tree.
//!
//! Loop statements carry a [`LoopId`] assigned in source order by the
//! parser; every later stage (profiling, intensity ranking, OpenCL
//! generation, pattern search) refers to loops by this id, exactly like
//! the paper's "loop statement number".

use super::error::Pos;
use crate::util::intern::Symbol;

/// Stable, source-ordered identifier of a loop statement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LoopId(pub u32);

impl std::fmt::Display for LoopId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}

/// Scalar and array types of the MiniC subset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Type {
    /// `void` (function returns only).
    Void,
    /// 32-bit integer.
    Int,
    /// Single-precision float.
    Float,
    /// Double-precision float.
    Double,
    /// 1-D array; `None` length for array parameters (`float a[]`).
    Array(Box<Type>, Option<usize>),
}

impl Type {
    /// Size in bytes of one element (arrays: of the element type).
    pub fn elem_bytes(&self) -> u64 {
        match self {
            Type::Void => 0,
            Type::Int => 4,
            Type::Float => 4,
            Type::Double => 8,
            Type::Array(t, _) => t.elem_bytes(),
        }
    }

    /// Is this `float` or `double`?
    pub fn is_float(&self) -> bool {
        matches!(self, Type::Float | Type::Double)
    }

    /// Is this an array type?
    pub fn is_array(&self) -> bool {
        matches!(self, Type::Array(..))
    }
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(missing_docs)] // one-symbol operators; names are the documentation
pub enum BinOp {
    Add, Sub, Mul, Div, Mod,
    Lt, Le, Gt, Ge, Eq, Ne,
    And, Or,
}

impl BinOp {
    /// Is this one of the arithmetic operators (`+ - * / %`)?
    pub fn is_arith(self) -> bool {
        matches!(self, BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Mod)
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    /// Arithmetic negation (`-x`).
    Neg,
    /// Logical not (`!x`).
    Not,
}

/// Compound-assignment operators (plain `=` is `Assign`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignOp {
    /// `=`
    Assign,
    /// `+=`
    AddAssign,
    /// `-=`
    SubAssign,
    /// `*=`
    MulAssign,
    /// `/=`
    DivAssign,
}

/// Expression node: a shape ([`ExprKind`]) plus the source position of
/// its first token, so diagnostics (`flopt explain`) can point at the
/// offending subscript.  Equality ignores the position — two exprs are
/// equal iff their kinds are structurally equal — which keeps the
/// syntactic-equality logic in the dependence analyses and the
/// round-trip tests position-blind.
#[derive(Debug, Clone)]
pub struct Expr {
    /// The expression shape.
    pub kind: ExprKind,
    /// Source position of the expression's first token.
    pub pos: Pos,
}

impl PartialEq for Expr {
    fn eq(&self, other: &Expr) -> bool {
        self.kind == other.kind
    }
}

/// Expression shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum ExprKind {
    /// Integer literal.
    IntLit(i64),
    /// Floating-point literal.
    FloatLit(f64),
    /// Scalar variable reference.
    Var(Symbol),
    /// `name[index]`
    Index(Symbol, Box<Expr>),
    /// Unary operator application.
    Unary(UnOp, Box<Expr>),
    /// Binary operator application.
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Function call (builtin or user-defined).
    Call(Symbol, Vec<Expr>),
}

impl Expr {
    /// Build an expression at a known source position.
    pub fn new(kind: ExprKind, pos: Pos) -> Expr {
        Expr { kind, pos }
    }

    /// Build a synthetic expression (no meaningful source position);
    /// used by tests and generated code.
    pub fn synth(kind: ExprKind) -> Expr {
        Expr { kind, pos: Pos::default() }
    }

    /// Walk the expression tree, calling `f` on every node.
    pub fn walk(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match &self.kind {
            ExprKind::Index(_, e) | ExprKind::Unary(_, e) => e.walk(f),
            ExprKind::Binary(_, a, b) => {
                a.walk(f);
                b.walk(f);
            }
            ExprKind::Call(_, args) => {
                for a in args {
                    a.walk(f);
                }
            }
            _ => {}
        }
    }
}

impl From<ExprKind> for Expr {
    fn from(kind: ExprKind) -> Expr {
        Expr::synth(kind)
    }
}

/// Assignment target: scalar variable or array element.
#[derive(Debug, Clone, PartialEq)]
pub enum LValue {
    /// Scalar variable target.
    Var(Symbol),
    /// Array element target (`name[index]`).
    Index(Symbol, Box<Expr>),
}

impl LValue {
    /// The assigned variable or array name.
    pub fn name(&self) -> Symbol {
        match self {
            LValue::Var(n) | LValue::Index(n, _) => *n,
        }
    }
}

/// A variable declaration (local or global).
#[derive(Debug, Clone, PartialEq)]
pub struct Decl {
    /// Declared type.
    pub ty: Type,
    /// Declared name.
    pub name: Symbol,
    /// Optional initializer expression.
    pub init: Option<Expr>,
    /// Source position of the declaration.
    pub pos: Pos,
}

/// Canonical `for` header: `for (var = init; var < limit; var += step)`.
/// Kept alongside the generic exprs so the analyses can recognize
/// canonical counted loops without re-pattern-matching.
#[derive(Debug, Clone, PartialEq)]
pub struct ForHeader {
    /// Init clause: declaration or simple statement.
    pub init: Option<Box<Stmt>>,
    /// Continuation condition.
    pub cond: Option<Expr>,
    /// Step statement run after each iteration.
    pub step: Option<Box<Stmt>>,
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// Local variable declaration.
    Decl(Decl),
    /// Assignment (plain or compound) to a scalar or array element.
    Assign {
        /// Assignment target.
        target: LValue,
        /// Plain `=` or a compound operator.
        op: AssignOp,
        /// Right-hand side.
        value: Expr,
        /// Source position.
        pos: Pos,
    },
    /// `if`/`else` conditional.
    If {
        /// Branch condition.
        cond: Expr,
        /// Statements of the `if` branch.
        then_branch: Vec<Stmt>,
        /// Statements of the `else` branch (empty when absent).
        else_branch: Vec<Stmt>,
        /// Source position.
        pos: Pos,
    },
    /// `for` loop statement.
    For {
        /// Stable source-ordered loop id.
        id: LoopId,
        /// The three header clauses.
        header: ForHeader,
        /// Loop body.
        body: Vec<Stmt>,
        /// Source position.
        pos: Pos,
    },
    /// `while` loop statement.
    While {
        /// Stable source-ordered loop id.
        id: LoopId,
        /// Continuation condition.
        cond: Expr,
        /// Loop body.
        body: Vec<Stmt>,
        /// Source position.
        pos: Pos,
    },
    /// `return` with optional value.
    Return(Option<Expr>, Pos),
    /// Bare expression statement (usually a call).
    Expr(Expr, Pos),
    /// Braced statement block.
    Block(Vec<Stmt>),
}

impl Stmt {
    /// Walk this statement and all nested statements.
    pub fn walk(&self, f: &mut impl FnMut(&Stmt)) {
        f(self);
        match self {
            Stmt::If { then_branch, else_branch, .. } => {
                for s in then_branch.iter().chain(else_branch) {
                    s.walk(f);
                }
            }
            Stmt::For { header, body, .. } => {
                if let Some(s) = &header.init {
                    s.walk(f);
                }
                if let Some(s) = &header.step {
                    s.walk(f);
                }
                for s in body {
                    s.walk(f);
                }
            }
            Stmt::While { body, .. } => {
                for s in body {
                    s.walk(f);
                }
            }
            Stmt::Block(body) => {
                for s in body {
                    s.walk(f);
                }
            }
            _ => {}
        }
    }
}

/// Function parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    /// Parameter type (arrays pass by reference).
    pub ty: Type,
    /// Parameter name.
    pub name: Symbol,
}

/// Function definition.
#[derive(Debug, Clone, PartialEq)]
pub struct Function {
    /// Return type.
    pub ret: Type,
    /// Function name.
    pub name: Symbol,
    /// Parameter list.
    pub params: Vec<Param>,
    /// Function body statements.
    pub body: Vec<Stmt>,
    /// Source position of the definition.
    pub pos: Pos,
}

/// A parsed translation unit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    /// Global declarations, in source order.
    pub globals: Vec<Decl>,
    /// Function definitions, in source order.
    pub functions: Vec<Function>,
}

/// Deep-copy a program with every source position reset to
/// `Pos::default()`: AST equality modulo layout.  The pretty-print
/// round-trip tests (`rust/tests/roundtrip.rs`) and the generative
/// property suite compare reparsed programs with this — positions
/// necessarily differ after printing, nothing else may.
pub fn strip_positions(p: &Program) -> Program {
    fn expr(e: &Expr) -> Expr {
        let kind = match &e.kind {
            ExprKind::IntLit(v) => ExprKind::IntLit(*v),
            ExprKind::FloatLit(v) => ExprKind::FloatLit(*v),
            ExprKind::Var(n) => ExprKind::Var(*n),
            ExprKind::Index(n, i) => ExprKind::Index(*n, Box::new(expr(i))),
            ExprKind::Unary(op, a) => ExprKind::Unary(*op, Box::new(expr(a))),
            ExprKind::Binary(op, a, b) => {
                ExprKind::Binary(*op, Box::new(expr(a)), Box::new(expr(b)))
            }
            ExprKind::Call(n, args) => {
                ExprKind::Call(*n, args.iter().map(expr).collect())
            }
        };
        Expr::synth(kind)
    }
    fn opt_expr(e: &Option<Expr>) -> Option<Expr> {
        e.as_ref().map(expr)
    }
    fn lvalue(lv: &LValue) -> LValue {
        match lv {
            LValue::Var(n) => LValue::Var(*n),
            LValue::Index(n, i) => LValue::Index(*n, Box::new(expr(i))),
        }
    }
    fn decl(d: &Decl) -> Decl {
        Decl {
            ty: d.ty.clone(),
            name: d.name,
            init: opt_expr(&d.init),
            pos: Pos::default(),
        }
    }
    fn stmts(body: &[Stmt]) -> Vec<Stmt> {
        body.iter().map(stmt).collect()
    }
    fn stmt(s: &Stmt) -> Stmt {
        match s {
            Stmt::Decl(d) => Stmt::Decl(decl(d)),
            Stmt::Assign { target, op, value, .. } => Stmt::Assign {
                target: lvalue(target),
                op: *op,
                value: expr(value),
                pos: Pos::default(),
            },
            Stmt::If { cond, then_branch, else_branch, .. } => Stmt::If {
                cond: expr(cond),
                then_branch: stmts(then_branch),
                else_branch: stmts(else_branch),
                pos: Pos::default(),
            },
            Stmt::For { id, header, body, .. } => Stmt::For {
                id: *id,
                header: ForHeader {
                    init: header.init.as_deref().map(|s| Box::new(stmt(s))),
                    cond: opt_expr(&header.cond),
                    step: header.step.as_deref().map(|s| Box::new(stmt(s))),
                },
                body: stmts(body),
                pos: Pos::default(),
            },
            Stmt::While { id, cond, body, .. } => Stmt::While {
                id: *id,
                cond: expr(cond),
                body: stmts(body),
                pos: Pos::default(),
            },
            Stmt::Return(e, _) => {
                Stmt::Return(e.as_ref().map(expr), Pos::default())
            }
            Stmt::Expr(e, _) => Stmt::Expr(expr(e), Pos::default()),
            Stmt::Block(body) => Stmt::Block(stmts(body)),
        }
    }
    Program {
        globals: p.globals.iter().map(decl).collect(),
        functions: p
            .functions
            .iter()
            .map(|f| Function {
                ret: f.ret.clone(),
                name: f.name,
                params: f.params.clone(),
                body: stmts(&f.body),
                pos: Pos::default(),
            })
            .collect(),
    }
}

impl Program {
    /// Look up a function by name.
    pub fn function(&self, name: &str) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == name)
    }

    /// Total number of loop statements (for/while) in the program —
    /// the paper reports this per application (tdfir: 36, MRI-Q: 16).
    pub fn loop_count(&self) -> usize {
        let mut n = 0;
        for func in &self.functions {
            for s in &func.body {
                s.walk(&mut |s| {
                    if matches!(s, Stmt::For { .. } | Stmt::While { .. }) {
                        n += 1;
                    }
                });
            }
        }
        n
    }
}
