//! Parse / lex error type with source position, mirroring what a Clang
//! diagnostic would carry.

use std::fmt;

/// Line/column position in the source (1-based, like compiler diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Pos {
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
}

impl fmt::Display for Pos {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// Error produced by the lexer or parser.
#[derive(Debug, Clone)]
pub struct ParseError {
    /// Where the error was detected.
    pub pos: Pos,
    /// Human-readable description.
    pub message: String,
}

impl ParseError {
    /// Construct an error at `pos`.
    pub fn new(pos: Pos, message: impl Into<String>) -> Self {
        Self { pos, message: message.into() }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.pos, self.message)
    }
}

impl std::error::Error for ParseError {}
