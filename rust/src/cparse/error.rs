//! Parse / lex error type with source position, mirroring what a Clang
//! diagnostic would carry.

use std::fmt;

/// Line/column position in the source (1-based, like compiler diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Pos {
    pub line: u32,
    pub col: u32,
}

impl fmt::Display for Pos {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// Error produced by the lexer or parser.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: Pos,
    pub message: String,
}

impl ParseError {
    pub fn new(pos: Pos, message: impl Into<String>) -> Self {
        Self { pos, message: message.into() }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.pos, self.message)
    }
}

impl std::error::Error for ParseError {}
