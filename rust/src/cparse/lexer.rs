//! Hand-written MiniC lexer with line/column tracking.
//!
//! Handles `//` and `/* */` comments, integer and floating literals
//! (including exponent forms and the trailing `f` suffix C sources use),
//! all MiniC operators, and keywords.

use super::error::{ParseError, Pos};
use crate::util::intern::Symbol;

/// Token kinds.
#[derive(Debug, Clone, PartialEq)]
#[allow(missing_docs)] // token names mirror their lexemes
pub enum Tok {
    // literals / identifiers
    Int(i64),
    Float(f64),
    Ident(Symbol),
    // keywords
    KwVoid, KwInt, KwFloat, KwDouble, KwIf, KwElse, KwFor, KwWhile,
    KwReturn, KwConst,
    // punctuation
    LParen, RParen, LBrace, RBrace, LBracket, RBracket,
    Comma, Semi,
    // operators
    Plus, Minus, Star, Slash, Percent,
    PlusPlus, MinusMinus,
    Assign, PlusAssign, MinusAssign, StarAssign, SlashAssign,
    Lt, Le, Gt, Ge, EqEq, Ne,
    AndAnd, OrOr, Bang,
    Eof,
}

/// A token with its source position.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// The token kind (and literal payload, if any).
    pub tok: Tok,
    /// Position of the token's first character.
    pub pos: Pos,
}

struct Lexer<'a> {
    src: &'a [u8],
    i: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Self { src: src.as_bytes(), i: 0, line: 1, col: 1 }
    }

    fn pos(&self) -> Pos {
        Pos { line: self.line, col: self.col }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.i).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.i + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn skip_trivia(&mut self) -> Result<(), ParseError> {
        loop {
            match (self.peek(), self.peek2()) {
                (Some(c), _) if c.is_ascii_whitespace() => {
                    self.bump();
                }
                (Some(b'/'), Some(b'/')) => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                (Some(b'/'), Some(b'*')) => {
                    let start = self.pos();
                    self.bump();
                    self.bump();
                    loop {
                        match (self.peek(), self.peek2()) {
                            (Some(b'*'), Some(b'/')) => {
                                self.bump();
                                self.bump();
                                break;
                            }
                            (Some(_), _) => {
                                self.bump();
                            }
                            (None, _) => {
                                return Err(ParseError::new(start, "unterminated block comment"));
                            }
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn lex_number(&mut self) -> Result<Tok, ParseError> {
        let start_pos = self.pos();
        let start = self.i;
        let mut is_float = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                self.bump();
            } else if c == b'.' && !is_float {
                is_float = true;
                self.bump();
            } else if (c == b'e' || c == b'E')
                && self.i > start
                && self
                    .peek2()
                    .map(|n| n.is_ascii_digit() || n == b'+' || n == b'-')
                    .unwrap_or(false)
            {
                is_float = true;
                self.bump(); // e
                self.bump(); // sign or first digit
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.i]).unwrap();
        // C float suffix
        if matches!(self.peek(), Some(b'f') | Some(b'F')) {
            is_float = true;
            self.bump();
        }
        if is_float {
            text.parse::<f64>()
                .map(Tok::Float)
                .map_err(|_| ParseError::new(start_pos, format!("bad float literal `{text}`")))
        } else {
            text.parse::<i64>()
                .map(Tok::Int)
                .map_err(|_| ParseError::new(start_pos, format!("bad int literal `{text}`")))
        }
    }

    fn lex_ident(&mut self) -> Tok {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || c == b'_' {
                self.bump();
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.i]).unwrap();
        match text {
            "void" => Tok::KwVoid,
            "int" => Tok::KwInt,
            "float" => Tok::KwFloat,
            "double" => Tok::KwDouble,
            "if" => Tok::KwIf,
            "else" => Tok::KwElse,
            "for" => Tok::KwFor,
            "while" => Tok::KwWhile,
            "return" => Tok::KwReturn,
            "const" => Tok::KwConst,
            _ => Tok::Ident(Symbol::intern(text)),
        }
    }

    fn next_token(&mut self) -> Result<Token, ParseError> {
        self.skip_trivia()?;
        let pos = self.pos();
        let Some(c) = self.peek() else {
            return Ok(Token { tok: Tok::Eof, pos });
        };
        let tok = match c {
            b'0'..=b'9' => self.lex_number()?,
            b'.' if self.peek2().map(|n| n.is_ascii_digit()).unwrap_or(false) => {
                self.lex_number()?
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => self.lex_ident(),
            _ => {
                self.bump();
                match (c, self.peek()) {
                    (b'(', _) => Tok::LParen,
                    (b')', _) => Tok::RParen,
                    (b'{', _) => Tok::LBrace,
                    (b'}', _) => Tok::RBrace,
                    (b'[', _) => Tok::LBracket,
                    (b']', _) => Tok::RBracket,
                    (b',', _) => Tok::Comma,
                    (b';', _) => Tok::Semi,
                    (b'%', _) => Tok::Percent,
                    (b'+', Some(b'+')) => { self.bump(); Tok::PlusPlus }
                    (b'+', Some(b'=')) => { self.bump(); Tok::PlusAssign }
                    (b'+', _) => Tok::Plus,
                    (b'-', Some(b'-')) => { self.bump(); Tok::MinusMinus }
                    (b'-', Some(b'=')) => { self.bump(); Tok::MinusAssign }
                    (b'-', _) => Tok::Minus,
                    (b'*', Some(b'=')) => { self.bump(); Tok::StarAssign }
                    (b'*', _) => Tok::Star,
                    (b'/', Some(b'=')) => { self.bump(); Tok::SlashAssign }
                    (b'/', _) => Tok::Slash,
                    (b'=', Some(b'=')) => { self.bump(); Tok::EqEq }
                    (b'=', _) => Tok::Assign,
                    (b'<', Some(b'=')) => { self.bump(); Tok::Le }
                    (b'<', _) => Tok::Lt,
                    (b'>', Some(b'=')) => { self.bump(); Tok::Ge }
                    (b'>', _) => Tok::Gt,
                    (b'!', Some(b'=')) => { self.bump(); Tok::Ne }
                    (b'!', _) => Tok::Bang,
                    (b'&', Some(b'&')) => { self.bump(); Tok::AndAnd }
                    (b'|', Some(b'|')) => { self.bump(); Tok::OrOr }
                    _ => {
                        return Err(ParseError::new(
                            pos,
                            format!("unexpected character `{}`", c as char),
                        ))
                    }
                }
            }
        };
        Ok(Token { tok, pos })
    }
}

/// Lex a full source string into tokens (terminated by a single `Eof`).
pub fn lex(source: &str) -> Result<Vec<Token>, ParseError> {
    let mut lx = Lexer::new(source);
    let mut out = Vec::new();
    loop {
        let t = lx.next_token()?;
        let done = t.tok == Tok::Eof;
        out.push(t);
        if done {
            return Ok(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lex_basic_tokens() {
        assert_eq!(
            kinds("for (i = 0; i < n; i++)"),
            vec![
                Tok::KwFor, Tok::LParen, Tok::Ident("i".into()), Tok::Assign,
                Tok::Int(0), Tok::Semi, Tok::Ident("i".into()), Tok::Lt,
                Tok::Ident("n".into()), Tok::Semi, Tok::Ident("i".into()),
                Tok::PlusPlus, Tok::RParen, Tok::Eof
            ]
        );
    }

    #[test]
    fn lex_float_forms() {
        assert_eq!(kinds("1.5 2e3 4.0f .25 7f"),
            vec![Tok::Float(1.5), Tok::Float(2000.0), Tok::Float(4.0),
                 Tok::Float(0.25), Tok::Float(7.0), Tok::Eof]);
    }

    #[test]
    fn lex_comments() {
        assert_eq!(
            kinds("a // line\n /* block\n comment */ b"),
            vec![Tok::Ident("a".into()), Tok::Ident("b".into()), Tok::Eof]
        );
    }

    #[test]
    fn lex_compound_ops() {
        assert_eq!(
            kinds("+= -= *= /= == != <= >= && || ++ --"),
            vec![Tok::PlusAssign, Tok::MinusAssign, Tok::StarAssign,
                 Tok::SlashAssign, Tok::EqEq, Tok::Ne, Tok::Le, Tok::Ge,
                 Tok::AndAnd, Tok::OrOr, Tok::PlusPlus, Tok::MinusMinus,
                 Tok::Eof]
        );
    }

    #[test]
    fn lex_tracks_positions() {
        let toks = lex("a\n  b").unwrap();
        assert_eq!(toks[0].pos.line, 1);
        assert_eq!(toks[1].pos.line, 2);
        assert_eq!(toks[1].pos.col, 3);
    }

    #[test]
    fn lex_unterminated_comment_errors() {
        assert!(lex("/* nope").is_err());
    }

    #[test]
    fn lex_bad_char_errors() {
        assert!(lex("a @ b").is_err());
    }
}
