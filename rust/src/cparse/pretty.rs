//! Pretty-printer: AST → C-syntax text.
//!
//! Used by the OpenCL generator ([`crate::opencl`]) to re-emit loop bodies
//! inside generated kernels, and by diagnostics.  Output re-parses to the
//! same AST (round-trip property-tested in `rust/tests/`).

use super::ast::*;

/// Render a type in declaration position (arrays handled by the caller).
pub fn type_str(ty: &Type) -> String {
    match ty {
        Type::Void => "void".into(),
        Type::Int => "int".into(),
        Type::Float => "float".into(),
        Type::Double => "double".into(),
        Type::Array(t, _) => type_str(t),
    }
}

/// Render an expression.
pub fn expr(e: &Expr) -> String {
    match &e.kind {
        ExprKind::IntLit(n) => n.to_string(),
        ExprKind::FloatLit(v) => {
            // keep floats recognizably floating-point on re-parse
            if v.fract() == 0.0 && v.abs() < 1e15 {
                format!("{v:.1}")
            } else {
                format!("{v}")
            }
        }
        ExprKind::Var(n) => n.to_string(),
        ExprKind::Index(n, i) => format!("{n}[{}]", expr(i)),
        ExprKind::Unary(op, a) => {
            let o = match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
            };
            format!("{o}({})", expr(a))
        }
        ExprKind::Binary(op, a, b) => {
            let o = match op {
                BinOp::Add => "+",
                BinOp::Sub => "-",
                BinOp::Mul => "*",
                BinOp::Div => "/",
                BinOp::Mod => "%",
                BinOp::Lt => "<",
                BinOp::Le => "<=",
                BinOp::Gt => ">",
                BinOp::Ge => ">=",
                BinOp::Eq => "==",
                BinOp::Ne => "!=",
                BinOp::And => "&&",
                BinOp::Or => "||",
            };
            format!("({} {o} {})", expr(a), expr(b))
        }
        ExprKind::Call(f, args) => {
            let a: Vec<_> = args.iter().map(expr).collect();
            format!("{f}({})", a.join(", "))
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("    ");
    }
}

/// Render a statement at the given indent depth.
pub fn stmt(s: &Stmt, depth: usize, out: &mut String) {
    match s {
        Stmt::Decl(d) => {
            indent(out, depth);
            match &d.ty {
                Type::Array(t, len) => {
                    let l = len.map(|n| n.to_string()).unwrap_or_default();
                    out.push_str(&format!("{} {}[{}];\n", type_str(t), d.name, l));
                }
                t => {
                    if let Some(init) = &d.init {
                        out.push_str(&format!("{} {} = {};\n", type_str(t), d.name, expr(init)));
                    } else {
                        out.push_str(&format!("{} {};\n", type_str(t), d.name));
                    }
                }
            }
        }
        Stmt::Assign { target, op, value, .. } => {
            indent(out, depth);
            let t = match target {
                LValue::Var(n) => n.to_string(),
                LValue::Index(n, i) => format!("{n}[{}]", expr(i)),
            };
            let o = match op {
                AssignOp::Assign => "=",
                AssignOp::AddAssign => "+=",
                AssignOp::SubAssign => "-=",
                AssignOp::MulAssign => "*=",
                AssignOp::DivAssign => "/=",
            };
            out.push_str(&format!("{t} {o} {};\n", expr(value)));
        }
        Stmt::If { cond, then_branch, else_branch, .. } => {
            indent(out, depth);
            out.push_str(&format!("if ({}) {{\n", expr(cond)));
            for s in then_branch {
                stmt(s, depth + 1, out);
            }
            indent(out, depth);
            if else_branch.is_empty() {
                out.push_str("}\n");
            } else {
                out.push_str("} else {\n");
                for s in else_branch {
                    stmt(s, depth + 1, out);
                }
                indent(out, depth);
                out.push_str("}\n");
            }
        }
        Stmt::For { header, body, .. } => {
            indent(out, depth);
            let init = header
                .init
                .as_deref()
                .map(|s| stmt_inline(s))
                .unwrap_or_default();
            let cond = header.cond.as_ref().map(expr).unwrap_or_default();
            let step = header
                .step
                .as_deref()
                .map(|s| stmt_inline(s))
                .unwrap_or_default();
            out.push_str(&format!("for ({init}; {cond}; {step}) {{\n"));
            for s in body {
                stmt(s, depth + 1, out);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::While { cond, body, .. } => {
            indent(out, depth);
            out.push_str(&format!("while ({}) {{\n", expr(cond)));
            for s in body {
                stmt(s, depth + 1, out);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::Return(e, _) => {
            indent(out, depth);
            match e {
                Some(e) => out.push_str(&format!("return {};\n", expr(e))),
                None => out.push_str("return;\n"),
            }
        }
        Stmt::Expr(e, _) => {
            indent(out, depth);
            out.push_str(&format!("{};\n", expr(e)));
        }
        Stmt::Block(body) => {
            indent(out, depth);
            out.push_str("{\n");
            for s in body {
                stmt(s, depth + 1, out);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
    }
}

/// Render a statement without trailing `;`/newline (for for-headers).
fn stmt_inline(s: &Stmt) -> String {
    match s {
        Stmt::Decl(d) => {
            let init = d
                .init
                .as_ref()
                .map(|e| format!(" = {}", expr(e)))
                .unwrap_or_default();
            format!("{} {}{init}", type_str(&d.ty), d.name)
        }
        Stmt::Assign { target, op, value, .. } => {
            let t = match target {
                LValue::Var(n) => n.to_string(),
                LValue::Index(n, i) => format!("{n}[{}]", expr(i)),
            };
            let o = match op {
                AssignOp::Assign => "=",
                AssignOp::AddAssign => "+=",
                AssignOp::SubAssign => "-=",
                AssignOp::MulAssign => "*=",
                AssignOp::DivAssign => "/=",
            };
            format!("{t} {o} {}", expr(value))
        }
        other => {
            let mut s = String::new();
            stmt(other, 0, &mut s);
            s.trim_end().trim_end_matches(';').to_string()
        }
    }
}

/// Render a whole function definition.
pub fn function(f: &Function) -> String {
    let params: Vec<_> = f
        .params
        .iter()
        .map(|p| match &p.ty {
            Type::Array(t, len) => {
                let l = len.map(|n| n.to_string()).unwrap_or_default();
                format!("{} {}[{l}]", type_str(t), p.name)
            }
            t => format!("{} {}", type_str(t), p.name),
        })
        .collect();
    let mut out = format!("{} {}({}) {{\n", type_str(&f.ret), f.name, params.join(", "));
    for s in &f.body {
        stmt(s, 1, &mut out);
    }
    out.push_str("}\n");
    out
}

/// Render a whole program.
pub fn program(p: &Program) -> String {
    let mut out = String::new();
    for d in &p.globals {
        stmt(&Stmt::Decl(d.clone()), 0, &mut out);
    }
    if !p.globals.is_empty() {
        out.push('\n');
    }
    for f in &p.functions {
        out.push_str(&function(f));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;

    #[test]
    fn roundtrip_simple_program() {
        let src = r#"
            float buf[64];
            void f(float a[], int n) {
                int i;
                for (i = 0; i < n; i++) {
                    if (a[i] > 0.0) { a[i] = a[i] * 2.0; } else { a[i] = 0.0; }
                }
            }
        "#;
        let p1 = parse(src).unwrap();
        let printed = program(&p1);
        let p2 = parse(&printed).unwrap();
        // loop ids and structure must survive the round trip
        assert_eq!(p1.loop_count(), p2.loop_count());
        assert_eq!(p1.globals.len(), p2.globals.len());
        assert_eq!(program(&p2), printed, "printing must be a fixpoint");
    }

    #[test]
    fn float_literals_stay_float() {
        let p = parse("void f() { float x; x = 2.0; }").unwrap();
        let printed = program(&p);
        assert!(printed.contains("2.0"), "got: {printed}");
    }
}
