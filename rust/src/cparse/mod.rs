//! MiniC front end — the reproduction's stand-in for libClang (paper §4).
//!
//! The paper parses C/C++ with LLVM/Clang's python binding to discover
//! `for` statements and the variables they reference.  We implement the
//! same capability as a self-contained substrate: a hand-written lexer and
//! recursive-descent parser for "MiniC", a C subset rich enough to express
//! the paper's evaluation applications (HPEC tdfir, Parboil MRI-Q) plus the
//! extra sample apps in [`crate::apps`]:
//!
//! * types: `void`, `int`, `float`, `double`, 1-D arrays of those;
//! * declarations with initializers, functions, global constants;
//! * statements: blocks, `if`/`else`, `for`, `while`, assignment
//!   (`=`, `+=`, `-=`, `*=`, `/=`), `return`, expression statements;
//! * expressions: literals, variables, array indexing, calls, the usual
//!   arithmetic / comparison / logical operators, and math builtins
//!   (`sin`, `cos`, `sqrt`, `fabs`, `exp`, `floor`, `fmin`, `fmax`).
//!
//! Every loop statement receives a stable [`ast::LoopId`] in source order —
//! the paper numbers candidate loops the same way ("1番, 3番, 5番…").

pub mod ast;
pub mod error;
pub mod lexer;
pub mod parser;
pub mod pretty;

pub use ast::{Expr, ExprKind, Function, LoopId, Program, Stmt, Type};
pub use error::ParseError;

/// Parse a MiniC translation unit into a [`Program`].
pub fn parse(source: &str) -> Result<Program, ParseError> {
    let tokens = lexer::lex(source)?;
    parser::Parser::new(tokens).parse_program()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_empty_function() {
        let p = parse("void main() { }").unwrap();
        assert_eq!(p.functions.len(), 1);
        assert_eq!(p.functions[0].name, "main");
    }

    #[test]
    fn loop_ids_are_source_ordered() {
        let src = r#"
            void f(float a[], int n) {
                int i;
                for (i = 0; i < n; i++) { a[i] = 0.0; }
                for (i = 0; i < n; i++) { a[i] = a[i] + 1.0; }
            }
            void g(float a[], int n) {
                int j;
                while (j < n) { j = j + 1; }
            }
        "#;
        let p = parse(src).unwrap();
        let loops = crate::ir::loops::extract(&p);
        let ids: Vec<u32> = loops.iter().map(|l| l.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }
}
