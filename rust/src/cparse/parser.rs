//! Recursive-descent parser for MiniC with precedence-climbing expressions.
//!
//! Loop statements are numbered in source order ([`LoopId`]) as they are
//! parsed — the id space later stages (intensity ranking, OpenCL
//! generation, the pattern search) operate in.

use super::ast::*;
use super::error::{ParseError, Pos};
use super::lexer::{Tok, Token};
use crate::util::intern::Symbol;

/// Recursive-descent parser over a lexed token stream.
pub struct Parser {
    toks: Vec<Token>,
    i: usize,
    next_loop: u32,
}

impl Parser {
    /// Build a parser over `toks` (must be terminated by `Tok::Eof`).
    pub fn new(toks: Vec<Token>) -> Self {
        Self { toks, i: 0, next_loop: 0 }
    }

    fn peek(&self) -> &Tok {
        &self.toks[self.i].tok
    }

    fn peek2(&self) -> &Tok {
        &self.toks[(self.i + 1).min(self.toks.len() - 1)].tok
    }

    fn pos(&self) -> Pos {
        self.toks[self.i].pos
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks[self.i].tok.clone();
        if self.i + 1 < self.toks.len() {
            self.i += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<(), ParseError> {
        if self.peek() == want {
            self.bump();
            Ok(())
        } else {
            Err(ParseError::new(
                self.pos(),
                format!("expected {what}, found {:?}", self.peek()),
            ))
        }
    }

    fn expect_ident(&mut self, what: &str) -> Result<Symbol, ParseError> {
        match self.peek().clone() {
            Tok::Ident(n) => {
                self.bump();
                Ok(n)
            }
            other => Err(ParseError::new(
                self.pos(),
                format!("expected {what}, found {other:?}"),
            )),
        }
    }

    fn fresh_loop_id(&mut self) -> LoopId {
        let id = LoopId(self.next_loop);
        self.next_loop += 1;
        id
    }

    // ---- program ---------------------------------------------------------

    /// Parse a whole translation unit.
    pub fn parse_program(mut self) -> Result<Program, ParseError> {
        let mut prog = Program::default();
        while *self.peek() != Tok::Eof {
            // `const` prefix on globals is accepted and ignored (MiniC has
            // no mutation of globals outside main anyway).
            if *self.peek() == Tok::KwConst {
                self.bump();
            }
            let ty = self.parse_type()?;
            let pos = self.pos();
            let name = self.expect_ident("identifier")?;
            if *self.peek() == Tok::LParen {
                prog.functions.push(self.parse_function_rest(ty, name, pos)?);
            } else {
                prog.globals.push(self.parse_decl_rest(ty, name, pos)?);
            }
        }
        Ok(prog)
    }

    fn parse_type(&mut self) -> Result<Type, ParseError> {
        let base = match self.peek() {
            Tok::KwVoid => Type::Void,
            Tok::KwInt => Type::Int,
            Tok::KwFloat => Type::Float,
            Tok::KwDouble => Type::Double,
            other => {
                return Err(ParseError::new(
                    self.pos(),
                    format!("expected type, found {other:?}"),
                ))
            }
        };
        self.bump();
        Ok(base)
    }

    fn parse_function_rest(
        &mut self,
        ret: Type,
        name: Symbol,
        pos: Pos,
    ) -> Result<Function, ParseError> {
        self.expect(&Tok::LParen, "`(`")?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                let ty = self.parse_type()?;
                let pname = self.expect_ident("parameter name")?;
                let ty = if *self.peek() == Tok::LBracket {
                    self.bump();
                    let len = if let Tok::Int(n) = self.peek() {
                        let n = *n as usize;
                        self.bump();
                        Some(n)
                    } else {
                        None
                    };
                    self.expect(&Tok::RBracket, "`]`")?;
                    Type::Array(Box::new(ty), len)
                } else {
                    ty
                };
                params.push(Param { ty, name: pname });
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.expect(&Tok::RParen, "`)`")?;
        let body = self.parse_block()?;
        Ok(Function { ret, name, params, body, pos })
    }

    /// Declaration after `type name` has been consumed.
    fn parse_decl_rest(&mut self, ty: Type, name: Symbol, pos: Pos) -> Result<Decl, ParseError> {
        let ty = if *self.peek() == Tok::LBracket {
            self.bump();
            let len = match self.peek() {
                Tok::Int(n) => {
                    let n = *n as usize;
                    self.bump();
                    Some(n)
                }
                _ => None,
            };
            self.expect(&Tok::RBracket, "array length")?;
            Type::Array(Box::new(ty), len)
        } else {
            ty
        };
        let init = if *self.peek() == Tok::Assign {
            self.bump();
            Some(self.parse_expr()?)
        } else {
            None
        };
        self.expect(&Tok::Semi, "`;`")?;
        Ok(Decl { ty, name, init, pos })
    }

    // ---- statements ------------------------------------------------------

    fn parse_block(&mut self) -> Result<Vec<Stmt>, ParseError> {
        self.expect(&Tok::LBrace, "`{`")?;
        let mut out = Vec::new();
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return Err(ParseError::new(self.pos(), "unexpected EOF in block"));
            }
            out.push(self.parse_stmt()?);
        }
        self.bump(); // }
        Ok(out)
    }

    fn parse_stmt(&mut self) -> Result<Stmt, ParseError> {
        let pos = self.pos();
        match self.peek().clone() {
            Tok::LBrace => Ok(Stmt::Block(self.parse_block()?)),
            Tok::KwConst => {
                self.bump();
                self.parse_stmt()
            }
            Tok::KwInt | Tok::KwFloat | Tok::KwDouble => {
                let ty = self.parse_type()?;
                let name = self.expect_ident("variable name")?;
                Ok(Stmt::Decl(self.parse_decl_rest(ty, name, pos)?))
            }
            Tok::KwIf => self.parse_if(),
            Tok::KwFor => self.parse_for(),
            Tok::KwWhile => self.parse_while(),
            Tok::KwReturn => {
                self.bump();
                let e = if *self.peek() == Tok::Semi {
                    None
                } else {
                    Some(self.parse_expr()?)
                };
                self.expect(&Tok::Semi, "`;`")?;
                Ok(Stmt::Return(e, pos))
            }
            _ => {
                let s = self.parse_simple_stmt()?;
                self.expect(&Tok::Semi, "`;`")?;
                Ok(s)
            }
        }
    }

    /// Assignment / increment / expression statement *without* the
    /// trailing semicolon (shared by statement position and for-headers).
    fn parse_simple_stmt(&mut self) -> Result<Stmt, ParseError> {
        let pos = self.pos();
        if let Tok::Ident(name) = self.peek().clone() {
            // lookahead for assignment forms
            match self.peek2().clone() {
                Tok::Assign | Tok::PlusAssign | Tok::MinusAssign
                | Tok::StarAssign | Tok::SlashAssign => {
                    self.bump();
                    let op = self.assign_op()?;
                    let value = self.parse_expr()?;
                    return Ok(Stmt::Assign {
                        target: LValue::Var(name),
                        op,
                        value,
                        pos,
                    });
                }
                Tok::PlusPlus | Tok::MinusMinus => {
                    self.bump();
                    let op = if self.bump() == Tok::PlusPlus {
                        AssignOp::AddAssign
                    } else {
                        AssignOp::SubAssign
                    };
                    return Ok(Stmt::Assign {
                        target: LValue::Var(name),
                        op,
                        value: Expr::new(ExprKind::IntLit(1), pos),
                        pos,
                    });
                }
                Tok::LBracket => {
                    // could be `a[i] = ...` or an expression; parse the
                    // index then decide.
                    let save = self.i;
                    self.bump(); // ident
                    self.bump(); // [
                    let idx = self.parse_expr()?;
                    self.expect(&Tok::RBracket, "`]`")?;
                    match self.peek() {
                        Tok::Assign | Tok::PlusAssign | Tok::MinusAssign
                        | Tok::StarAssign | Tok::SlashAssign => {
                            let op = self.assign_op()?;
                            let value = self.parse_expr()?;
                            return Ok(Stmt::Assign {
                                target: LValue::Index(name, Box::new(idx)),
                                op,
                                value,
                                pos,
                            });
                        }
                        _ => {
                            self.i = save;
                        }
                    }
                }
                _ => {}
            }
        }
        // `++i` prefix form
        if matches!(self.peek(), Tok::PlusPlus | Tok::MinusMinus) {
            let inc = self.bump() == Tok::PlusPlus;
            let name = self.expect_ident("variable after ++/--")?;
            return Ok(Stmt::Assign {
                target: LValue::Var(name),
                op: if inc { AssignOp::AddAssign } else { AssignOp::SubAssign },
                value: Expr::new(ExprKind::IntLit(1), pos),
                pos,
            });
        }
        let e = self.parse_expr()?;
        Ok(Stmt::Expr(e, pos))
    }

    fn assign_op(&mut self) -> Result<AssignOp, ParseError> {
        let op = match self.peek() {
            Tok::Assign => AssignOp::Assign,
            Tok::PlusAssign => AssignOp::AddAssign,
            Tok::MinusAssign => AssignOp::SubAssign,
            Tok::StarAssign => AssignOp::MulAssign,
            Tok::SlashAssign => AssignOp::DivAssign,
            other => {
                return Err(ParseError::new(
                    self.pos(),
                    format!("expected assignment operator, found {other:?}"),
                ))
            }
        };
        self.bump();
        Ok(op)
    }

    fn parse_if(&mut self) -> Result<Stmt, ParseError> {
        let pos = self.pos();
        self.bump(); // if
        self.expect(&Tok::LParen, "`(`")?;
        let cond = self.parse_expr()?;
        self.expect(&Tok::RParen, "`)`")?;
        let then_branch = self.parse_stmt_or_block()?;
        let else_branch = if *self.peek() == Tok::KwElse {
            self.bump();
            self.parse_stmt_or_block()?
        } else {
            Vec::new()
        };
        Ok(Stmt::If { cond, then_branch, else_branch, pos })
    }

    fn parse_stmt_or_block(&mut self) -> Result<Vec<Stmt>, ParseError> {
        if *self.peek() == Tok::LBrace {
            self.parse_block()
        } else {
            Ok(vec![self.parse_stmt()?])
        }
    }

    fn parse_for(&mut self) -> Result<Stmt, ParseError> {
        let pos = self.pos();
        self.bump(); // for
        let id = self.fresh_loop_id();
        self.expect(&Tok::LParen, "`(`")?;
        // init: declaration, simple statement, or empty
        let init = if *self.peek() == Tok::Semi {
            self.bump();
            None
        } else if matches!(self.peek(), Tok::KwInt | Tok::KwFloat | Tok::KwDouble) {
            let dpos = self.pos();
            let ty = self.parse_type()?;
            let name = self.expect_ident("variable name")?;
            let d = self.parse_decl_rest(ty, name, dpos)?; // consumes `;`
            Some(Box::new(Stmt::Decl(d)))
        } else {
            let s = self.parse_simple_stmt()?;
            self.expect(&Tok::Semi, "`;` in for-header")?;
            Some(Box::new(s))
        };
        let cond = if *self.peek() == Tok::Semi {
            None
        } else {
            Some(self.parse_expr()?)
        };
        self.expect(&Tok::Semi, "`;` in for-header")?;
        let step = if *self.peek() == Tok::RParen {
            None
        } else {
            Some(Box::new(self.parse_simple_stmt()?))
        };
        self.expect(&Tok::RParen, "`)`")?;
        let body = self.parse_stmt_or_block()?;
        Ok(Stmt::For { id, header: ForHeader { init, cond, step }, body, pos })
    }

    fn parse_while(&mut self) -> Result<Stmt, ParseError> {
        let pos = self.pos();
        self.bump(); // while
        let id = self.fresh_loop_id();
        self.expect(&Tok::LParen, "`(`")?;
        let cond = self.parse_expr()?;
        self.expect(&Tok::RParen, "`)`")?;
        let body = self.parse_stmt_or_block()?;
        Ok(Stmt::While { id, cond, body, pos })
    }

    // ---- expressions (precedence climbing) --------------------------------

    /// Parse a single expression (precedence climbing from the bottom).
    pub fn parse_expr(&mut self) -> Result<Expr, ParseError> {
        self.parse_bin(0)
    }

    fn bin_op(tok: &Tok) -> Option<(BinOp, u8)> {
        // (op, binding power); higher binds tighter
        Some(match tok {
            Tok::OrOr => (BinOp::Or, 1),
            Tok::AndAnd => (BinOp::And, 2),
            Tok::EqEq => (BinOp::Eq, 3),
            Tok::Ne => (BinOp::Ne, 3),
            Tok::Lt => (BinOp::Lt, 4),
            Tok::Le => (BinOp::Le, 4),
            Tok::Gt => (BinOp::Gt, 4),
            Tok::Ge => (BinOp::Ge, 4),
            Tok::Plus => (BinOp::Add, 5),
            Tok::Minus => (BinOp::Sub, 5),
            Tok::Star => (BinOp::Mul, 6),
            Tok::Slash => (BinOp::Div, 6),
            Tok::Percent => (BinOp::Mod, 6),
            _ => return None,
        })
    }

    fn parse_bin(&mut self, min_bp: u8) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_unary()?;
        while let Some((op, bp)) = Self::bin_op(self.peek()) {
            if bp < min_bp {
                break;
            }
            self.bump();
            let rhs = self.parse_bin(bp + 1)?;
            let pos = lhs.pos;
            lhs = Expr::new(ExprKind::Binary(op, Box::new(lhs), Box::new(rhs)), pos);
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<Expr, ParseError> {
        let pos = self.pos();
        match self.peek() {
            Tok::Minus => {
                self.bump();
                let inner = self.parse_unary()?;
                Ok(Expr::new(ExprKind::Unary(UnOp::Neg, Box::new(inner)), pos))
            }
            Tok::Bang => {
                self.bump();
                let inner = self.parse_unary()?;
                Ok(Expr::new(ExprKind::Unary(UnOp::Not, Box::new(inner)), pos))
            }
            _ => self.parse_postfix(),
        }
    }

    fn parse_postfix(&mut self) -> Result<Expr, ParseError> {
        let pos = self.pos();
        match self.peek().clone() {
            Tok::Int(n) => {
                self.bump();
                Ok(Expr::new(ExprKind::IntLit(n), pos))
            }
            Tok::Float(v) => {
                self.bump();
                Ok(Expr::new(ExprKind::FloatLit(v), pos))
            }
            Tok::LParen => {
                self.bump();
                let e = self.parse_expr()?;
                self.expect(&Tok::RParen, "`)`")?;
                Ok(e)
            }
            Tok::Ident(name) => {
                self.bump();
                match self.peek() {
                    Tok::LParen => {
                        self.bump();
                        let mut args = Vec::new();
                        if *self.peek() != Tok::RParen {
                            loop {
                                args.push(self.parse_expr()?);
                                if *self.peek() == Tok::Comma {
                                    self.bump();
                                } else {
                                    break;
                                }
                            }
                        }
                        self.expect(&Tok::RParen, "`)`")?;
                        Ok(Expr::new(ExprKind::Call(name, args), pos))
                    }
                    Tok::LBracket => {
                        self.bump();
                        let idx = self.parse_expr()?;
                        self.expect(&Tok::RBracket, "`]`")?;
                        Ok(Expr::new(ExprKind::Index(name, Box::new(idx)), pos))
                    }
                    _ => Ok(Expr::new(ExprKind::Var(name), pos)),
                }
            }
            other => Err(ParseError::new(
                self.pos(),
                format!("expected expression, found {other:?}"),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    fn expr(src: &str) -> Expr {
        let toks = super::super::lexer::lex(src).unwrap();
        Parser::new(toks).parse_expr().unwrap()
    }

    fn var(name: &str) -> Expr {
        Expr::synth(ExprKind::Var(name.into()))
    }

    fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::synth(ExprKind::Binary(op, Box::new(a), Box::new(b)))
    }

    #[test]
    fn precedence_mul_over_add() {
        assert_eq!(
            expr("a + b * c"),
            bin(BinOp::Add, var("a"), bin(BinOp::Mul, var("b"), var("c")))
        );
    }

    #[test]
    fn parens_override_precedence() {
        assert_eq!(
            expr("(a + b) * c"),
            bin(BinOp::Mul, bin(BinOp::Add, var("a"), var("b")), var("c"))
        );
    }

    #[test]
    fn comparison_binds_looser_than_arith() {
        assert_eq!(
            expr("i < n + 1"),
            bin(
                BinOp::Lt,
                var("i"),
                bin(BinOp::Add, var("n"), Expr::synth(ExprKind::IntLit(1))),
            )
        );
    }

    #[test]
    fn exprs_carry_source_positions() {
        let e = expr("a + b * c");
        assert_eq!((e.pos.line, e.pos.col), (1, 1));
        if let ExprKind::Binary(_, lhs, rhs) = &e.kind {
            assert_eq!((lhs.pos.line, lhs.pos.col), (1, 1));
            assert_eq!((rhs.pos.line, rhs.pos.col), (1, 5));
        } else {
            panic!("expected binary expr");
        }
    }

    #[test]
    fn equality_ignores_positions() {
        assert_eq!(expr("x + 1"), expr("  x   + 1"));
    }

    #[test]
    fn parse_full_function_with_nested_loops() {
        let src = r#"
            void matmul(float a[], float b[], float c[], int n) {
                int i;
                int j;
                int k;
                for (i = 0; i < n; i++) {
                    for (j = 0; j < n; j++) {
                        float acc;
                        acc = 0.0;
                        for (k = 0; k < n; k++) {
                            acc += a[i * n + k] * b[k * n + j];
                        }
                        c[i * n + j] = acc;
                    }
                }
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.loop_count(), 3);
    }

    #[test]
    fn parse_if_else_and_while() {
        let src = r#"
            int f(int x) {
                int y;
                y = 0;
                while (x > 0) {
                    if (x % 2 == 0) { y += 1; } else y -= 1;
                    x = x - 1;
                }
                return y;
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.loop_count(), 1);
    }

    #[test]
    fn parse_for_with_decl_init() {
        let p = parse("void f(int n) { for (int i = 0; i < n; ++i) { } }").unwrap();
        assert_eq!(p.loop_count(), 1);
    }

    #[test]
    fn parse_globals() {
        let p = parse("const int N = 64; float buf[128]; void main() { }").unwrap();
        assert_eq!(p.globals.len(), 2);
        assert_eq!(p.globals[0].init, Some(Expr::synth(ExprKind::IntLit(64))));
        assert!(p.globals[1].ty.is_array());
    }

    #[test]
    fn parse_call_statement() {
        let p = parse("void main() { init(1, 2.0); }").unwrap();
        assert!(matches!(
            &p.functions[0].body[0],
            Stmt::Expr(Expr { kind: ExprKind::Call(..), .. }, _)
        ));
    }

    #[test]
    fn error_on_missing_semicolon() {
        assert!(parse("void f() { int x x = 1; }").is_err());
    }

    #[test]
    fn error_has_position() {
        let e = parse("void f() {\n  int x @ 1;\n}").unwrap_err();
        assert_eq!(e.pos.line, 2);
    }
}
