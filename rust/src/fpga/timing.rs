//! Pipelined-execution timing model: how long one offloaded loop runs on
//! the FPGA, including PCIe transfers.
//!
//! Single-work-item model (what our generated OpenCL is): the innermost
//! loop iterations stream through the pipeline at one iteration per II
//! cycles; each entry of the offloaded statement pays the pipeline
//! fill/drain depth.  Transfers follow the generated host program: H2D
//! for every touched array, D2H for written arrays (footprint bytes).

use crate::cparse::ast::LoopId;
use crate::hls::HlsReport;
use crate::interp::{LoopProfile, Profile};
use crate::ir::LoopAnalysis;

use super::device::Device;

/// Timing breakdown for one offloaded loop execution.
#[derive(Debug, Clone)]
pub struct KernelExec {
    /// The offloaded loop statement.
    pub loop_id: LoopId,
    /// pipeline execution seconds
    pub kernel_s: f64,
    /// Host→device DMA seconds.
    pub transfer_in_s: f64,
    /// Device→host DMA seconds.
    pub transfer_out_s: f64,
    /// pipelined (innermost) iterations the model charged
    pub inner_iters: u64,
}

impl KernelExec {
    /// Kernel plus both transfer directions.
    pub fn total_s(&self) -> f64 {
        self.kernel_s + self.transfer_in_s + self.transfer_out_s
    }
}

/// H2D/D2H transfer byte counts of one offloaded statement under the
/// generated host program's footprint rule: everything the statement
/// touched goes to the device, written arrays come back.  Shared by the
/// FPGA timing model, the GPU SIMT model, and the function-block layer
/// ([`crate::funcblock`]) so the rule cannot silently diverge.
pub fn transfer_bytes(la: &LoopAnalysis, lp: &LoopProfile) -> (u64, u64) {
    let mut in_bytes = 0u64;
    let mut out_bytes = 0u64;
    for (arr, fp) in &lp.footprints {
        in_bytes += fp.bytes();
        if la.refs.array_writes.contains_key(arr) {
            out_bytes += fp.bytes();
        }
    }
    (in_bytes, out_bytes)
}

/// Innermost pipelined iteration count of the loop statement `id`:
/// the max total-iteration counter over `id` and its descendants.
pub fn pipelined_iters(loops: &[LoopAnalysis], profile: &Profile, id: LoopId) -> u64 {
    let mut best = profile.loop_profile(id).map(|l| l.iterations).unwrap_or(0);
    for la in loops {
        if is_descendant(loops, id, la.info.id) {
            if let Some(lp) = profile.loop_profile(la.info.id) {
                best = best.max(lp.iterations);
            }
        }
    }
    best
}

fn is_descendant(loops: &[LoopAnalysis], anc: LoopId, mut cur: LoopId) -> bool {
    loop {
        let Some(la) = loops.iter().find(|l| l.info.id == cur) else {
            return false;
        };
        match la.info.parent {
            Some(p) if p == anc => return true,
            Some(p) => cur = p,
            None => return false,
        }
    }
}

/// Model one offloaded loop's FPGA execution.
pub fn kernel_time_s(
    loops: &[LoopAnalysis],
    profile: &Profile,
    report: &HlsReport,
    device: &Device,
) -> KernelExec {
    let id = report.loop_id;
    let la = loops
        .iter()
        .find(|l| l.info.id == id)
        .expect("report refers to a known loop");
    let lp = profile.loop_profile(id).cloned().unwrap_or_default();

    let inner_iters = pipelined_iters(loops, profile, id);
    // an unroll-b datapath retires b iterations per II cycles
    let eff_iters = (inner_iters as f64 / report.unroll.max(1) as f64).ceil();
    let cycles = eff_iters * report.ii as f64 + lp.entries as f64 * report.depth as f64;
    let kernel_s = cycles / report.fmax_hz;

    // transfers: H2D everything touched, D2H what the kernel writes
    let (in_bytes, out_bytes) = transfer_bytes(la, &lp);
    // one DMA per direction per entry batch — the generated host
    // transfers once per offloaded-loop invocation region, not per entry
    let transfer_in_s = if in_bytes > 0 { device.transfer_s(in_bytes) } else { 0.0 };
    let transfer_out_s = if out_bytes > 0 { device.transfer_s(out_bytes) } else { 0.0 };

    KernelExec { loop_id: id, kernel_s, transfer_in_s, transfer_out_s, inner_iters }
}

/// Total FPGA-side time of a pattern (kernels run back to back on the
/// single device; the Acceleration Stack serializes the queue).
pub fn pattern_fpga_time_s(execs: &[KernelExec]) -> f64 {
    execs.iter().map(KernelExec::total_s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::fpga::device::ARRIA10_GX;
    use crate::hls;
    use crate::interp;
    use crate::ir;

    fn setup(src: &str, idx: usize) -> (Vec<LoopAnalysis>, Profile, HlsReport) {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        let prof = interp::profile_program(&p).unwrap();
        let rep = hls::precompile(&p, &loops[idx], 1, &ARRIA10_GX);
        (loops, prof, rep)
    }

    const NEST: &str = "
        float acc_out[64]; float x[64];
        void main() {
            int i;
            for (i = 0; i < 64; i++) { x[i] = i * 0.5; }
            for (i = 0; i < 64; i++) {
                float acc; acc = 0.0;
                for (int k = 0; k < 100; k++) { acc += x[i] * 0.9; }
                acc_out[i] = acc;
            }
        }";

    #[test]
    fn pipelined_iters_uses_innermost() {
        let (loops, prof, _) = setup(NEST, 1);
        // loop id 1 = outer compute loop, id 2 = inner k loop
        let iters = pipelined_iters(&loops, &prof, loops[1].info.id);
        assert_eq!(iters, 64 * 100);
    }

    #[test]
    fn kernel_time_scales_with_iters() {
        let (loops, prof, rep) = setup(NEST, 1);
        let exec = kernel_time_s(&loops, &prof, &rep, &ARRIA10_GX);
        assert_eq!(exec.inner_iters, 6400);
        // II=1 at ~270 MHz: ≈ 6400 cycles ≈ 24 µs plus depth
        assert!(exec.kernel_s > 1e-5 && exec.kernel_s < 1e-3, "{}", exec.kernel_s);
    }

    #[test]
    fn transfers_cover_touched_footprints() {
        let (loops, prof, rep) = setup(NEST, 1);
        let exec = kernel_time_s(&loops, &prof, &rep, &ARRIA10_GX);
        // reads x (256 B) + writes acc_out (256 B)
        assert!(exec.transfer_in_s >= ARRIA10_GX.pcie_latency_s);
        assert!(exec.transfer_out_s >= ARRIA10_GX.pcie_latency_s);
    }

    #[test]
    fn pattern_time_sums_kernels() {
        let (loops, prof, rep) = setup(NEST, 1);
        let e = kernel_time_s(&loops, &prof, &rep, &ARRIA10_GX);
        let total = pattern_fpga_time_s(&[e.clone(), e.clone()]);
        assert!((total - 2.0 * e.total_s()).abs() < 1e-12);
    }
}
