//! FPGA device + board model: Intel PAC with Arria10 GX 1150.
//!
//! Resource totals are the public Arria10 GX 1150 numbers; the BSP
//! (board-support package: PCIe/DDR controllers, the Acceleration Stack's
//! static region) permanently occupies a fixed fraction, as on the real
//! PAC card.  Calibration notes in DESIGN.md §6.

/// Absolute resource counts of one FPGA.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    /// Adaptive logic modules.
    pub alms: f64,
    /// Flip-flops.
    pub ffs: f64,
    /// Look-up tables.
    pub luts: f64,
    /// Hardened DSP blocks.
    pub dsps: f64,
    /// M20K block-RAM instances.
    pub m20ks: f64,
}

impl Resources {
    /// The all-zero resource vector.
    pub const ZERO: Resources = Resources { alms: 0.0, ffs: 0.0, luts: 0.0, dsps: 0.0, m20ks: 0.0 };

    /// Component-wise sum.
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            alms: self.alms + o.alms,
            ffs: self.ffs + o.ffs,
            luts: self.luts + o.luts,
            dsps: self.dsps + o.dsps,
            m20ks: self.m20ks + o.m20ks,
        }
    }

    /// Component-wise scaling by `k`.
    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            alms: self.alms * k,
            ffs: self.ffs * k,
            luts: self.luts * k,
            dsps: self.dsps * k,
            m20ks: self.m20ks * k,
        }
    }
}

/// The FPGA device + board model.
#[derive(Debug, Clone)]
pub struct Device {
    /// Marketing name of the board.
    pub name: &'static str,
    /// Total device resources.
    pub total: Resources,
    /// fraction of every resource type held by the BSP static region
    pub bsp_frac: f64,
    /// OpenCL kernel clock before resource-pressure derating
    pub base_fmax_hz: f64,
    /// fmax derating slope vs. logic utilization (DESIGN.md §6)
    pub fmax_derate: f64,
    /// Floor below which the derated clock never drops.
    pub min_fmax_hz: f64,
    /// PCIe Gen3 x8 effective bandwidth
    pub pcie_bw_bytes_per_s: f64,
    /// per-DMA fixed latency
    pub pcie_latency_s: f64,
}

/// Intel PAC with Intel Arria10 GX 1150 (Acceleration Stack 1.2).
pub const ARRIA10_GX: Device = Device {
    name: "Intel PAC with Intel Arria10 GX FPGA",
    total: Resources {
        alms: 427_200.0,
        ffs: 1_708_800.0,
        luts: 854_400.0,
        dsps: 1_518.0,
        m20ks: 2_713.0,
    },
    bsp_frac: 0.18,
    base_fmax_hz: 280.0e6,
    fmax_derate: 0.25,
    min_fmax_hz: 120.0e6,
    pcie_bw_bytes_per_s: 6.0e9,
    pcie_latency_s: 15.0e-6,
};

impl Device {
    /// Utilization fraction of the *whole device* for a kernel using `r`,
    /// including the BSP static region: the max over resource types.
    pub fn utilization(&self, r: &Resources) -> f64 {
        let f = [
            r.alms / self.total.alms,
            r.ffs / self.total.ffs,
            r.luts / self.total.luts,
            r.dsps / self.total.dsps,
            r.m20ks / self.total.m20ks,
        ]
        .into_iter()
        .fold(0.0, f64::max);
        self.bsp_frac + f
    }

    /// Does the kernel fit at all (hard resource failure if not —
    /// the paper: "リソース量オーバーの際は早めにエラー")?
    pub fn fits(&self, r: &Resources) -> bool {
        self.utilization(r) <= 1.0
    }

    /// Kernel clock after resource-pressure derating.
    pub fn fmax_hz(&self, utilization: f64) -> f64 {
        let f = self.base_fmax_hz * (1.0 - self.fmax_derate * utilization.clamp(0.0, 1.0));
        f.max(self.min_fmax_hz)
    }

    /// PCIe transfer time for `bytes` in one direction.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.pcie_latency_s + bytes as f64 / self.pcie_bw_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_includes_bsp() {
        let d = &ARRIA10_GX;
        assert!((d.utilization(&Resources::ZERO) - 0.18).abs() < 1e-12);
        let half_dsps = Resources { dsps: d.total.dsps / 2.0, ..Resources::ZERO };
        assert!((d.utilization(&half_dsps) - 0.68).abs() < 1e-12);
    }

    #[test]
    fn fits_rejects_oversized() {
        let d = &ARRIA10_GX;
        let too_big = Resources { alms: d.total.alms, ..Resources::ZERO };
        assert!(!d.fits(&too_big));
        let ok = Resources { alms: d.total.alms * 0.5, ..Resources::ZERO };
        assert!(d.fits(&ok));
    }

    #[test]
    fn fmax_derates_with_pressure() {
        let d = &ARRIA10_GX;
        assert!(d.fmax_hz(0.2) > d.fmax_hz(0.8));
        assert!(d.fmax_hz(1.0) >= d.min_fmax_hz);
        assert!(d.fmax_hz(0.0) <= d.base_fmax_hz);
    }

    #[test]
    fn transfer_has_latency_floor() {
        let d = &ARRIA10_GX;
        assert!(d.transfer_s(0) >= d.pcie_latency_s);
        // 6 GB at 6 GB/s ≈ 1 s
        assert!((d.transfer_s(6_000_000_000) - 1.0).abs() < 0.01);
    }

    #[test]
    fn resource_arithmetic() {
        let a = Resources { alms: 1.0, ffs: 2.0, luts: 3.0, dsps: 4.0, m20ks: 5.0 };
        let b = a.scale(2.0);
        assert_eq!(b.dsps, 8.0);
        assert_eq!(a.add(&b).alms, 3.0);
    }
}
