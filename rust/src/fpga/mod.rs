//! FPGA board substrate: device model, place-and-route time model, and
//! the pipelined-execution timing simulator.
//!
//! Replaces the paper's Intel PAC (Arria10 GX) + Quartus 17.1 testbed.
//! DESIGN.md §2 documents why each substitution preserves the behaviour
//! the search depends on (ranking + speedup shape, not absolute TFLOPs).

pub mod device;
pub mod pnr;
pub mod timing;

pub use device::{Device, Resources, ARRIA10_GX};
pub use pnr::{full_compile, CompileOutcome};
pub use timing::{kernel_time_s, pattern_fpga_time_s, KernelExec};
