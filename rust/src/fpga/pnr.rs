//! Place-and-route (full compile) **time model** — the reason the paper's
//! whole method exists: a full `aoc` + Quartus compile of even a ~100-line
//! kernel takes ≈3 hours on the authors' machine, so only a handful of
//! patterns can ever be measured.
//!
//! The model: a base fitter time plus a resource-pressure term, with a
//! small deterministic seed jitter (compiles of different kernels do not
//! take identical time).  Resource-overflow kernels fail *early* —
//! "リソース量オーバーの際は早めにエラー" — after only the analysis
//! front-end; semantically un-mappable kernels fail *late* ("数時間後に
//! エラー"), which the coordinator must treat as wasted compile hours.

use crate::fpga::device::Device;
use crate::hls::HlsReport;

/// Result of a simulated full FPGA compile.
#[derive(Debug, Clone)]
pub enum CompileOutcome {
    /// Bitstream produced after `sim_s` seconds of simulated compile time.
    Ok { sim_s: f64 },
    /// Resource overflow — detected early (paper: "早めにエラー").
    ResourceOverflow { sim_s: f64, utilization: f64 },
}

impl CompileOutcome {
    /// Simulated seconds the compile occupied the farm, success or not.
    pub fn sim_seconds(&self) -> f64 {
        match self {
            CompileOutcome::Ok { sim_s } => *sim_s,
            CompileOutcome::ResourceOverflow { sim_s, .. } => *sim_s,
        }
    }

    /// Did the compile produce a bitstream?
    pub fn is_ok(&self) -> bool {
        matches!(self, CompileOutcome::Ok { .. })
    }
}

/// Deterministic per-kernel jitter in `[-1, 1]` from a label hash.
fn jitter(label: &str) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // map to [-1, 1]
    (h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

/// Base fitter time: ~2.4 h; resource term: up to +2.5 h near full;
/// jitter: ±20 min.  Typical small kernel ≈ 2.8–3.2 h — the paper's "3 h".
pub const BASE_COMPILE_S: f64 = 2.4 * 3600.0;
/// Extra compile time added as utilization approaches the device cap.
pub const PRESSURE_COMPILE_S: f64 = 2.5 * 3600.0;
/// Amplitude of the deterministic per-kernel compile-time jitter.
pub const JITTER_S: f64 = 20.0 * 60.0;

/// Simulate the full compile of a pattern's combined kernels.
///
/// `reports` are the pattern's per-kernel pre-compile reports; `label`
/// seeds the jitter (use the pattern label).
pub fn full_compile(reports: &[&HlsReport], device: &Device, label: &str) -> CompileOutcome {
    let total = reports
        .iter()
        .fold(crate::fpga::device::Resources::ZERO, |acc, r| acc.add(&r.resources));
    let utilization = device.utilization(&total);

    if utilization > 1.0 {
        // early resource error: front-end analysis only (~25 min)
        return CompileOutcome::ResourceOverflow { sim_s: 25.0 * 60.0, utilization };
    }

    let sim_s = BASE_COMPILE_S
        + PRESSURE_COMPILE_S * utilization
        + JITTER_S * jitter(label);
    CompileOutcome::Ok { sim_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::fpga::device::ARRIA10_GX;
    use crate::hls;
    use crate::ir;

    fn report(src: &str, unroll: usize) -> hls::HlsReport {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        hls::precompile(&p, &loops[0], unroll, &ARRIA10_GX)
    }

    const MAP: &str = "void f(float a[], float b[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = b[i] * 2.0 + 1.0; } }";

    #[test]
    fn small_kernel_compiles_in_about_three_hours() {
        let r = report(MAP, 1);
        let out = full_compile(&[&r], &ARRIA10_GX, "L0");
        let hours = out.sim_seconds() / 3600.0;
        assert!(out.is_ok());
        assert!((2.5..3.6).contains(&hours), "compile {hours} h");
    }

    #[test]
    fn oversized_kernel_fails_early() {
        // unroll 512 of a trig kernel blows the DSP budget
        let r = report(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]) + cos(a[i]); } }",
            512,
        );
        let out = full_compile(&[&r], &ARRIA10_GX, "L0");
        assert!(!out.is_ok());
        // early error: well under an hour, NOT ~3 h
        assert!(out.sim_seconds() < 3600.0);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        assert_eq!(jitter("L1+L3"), jitter("L1+L3"));
        for label in ["a", "b", "L0", "L1+L3", "xyz"] {
            assert!(jitter(label).abs() <= 1.0);
        }
    }

    #[test]
    fn bigger_patterns_take_longer() {
        let r = report(MAP, 1);
        let one = full_compile(&[&r], &ARRIA10_GX, "same");
        let two = full_compile(&[&r, &r], &ARRIA10_GX, "same");
        assert!(two.sim_seconds() > one.sim_seconds());
    }
}
