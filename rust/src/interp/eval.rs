//! Iterative arena evaluator with profiling hooks.
//!
//! `Interp::new` **lowers** the AST once into a flat arena ([`LProgram`]):
//! every expression and statement becomes a small `Copy` record addressed
//! by a `u32` handle, with child lists packed into shared pools.  Walking
//! the program is then pointer-chasing-free and allocation-free — the hot
//! profiling loop touches a handful of contiguous `Vec`s instead of a
//! `Box`-linked tree.
//!
//! Execution is an **explicit-stack machine** (`ops` continuation stack +
//! `vals` operand stack + `frames` call records), not recursive descent:
//! MiniC recursion depth and statement nesting cost a few machine words
//! each instead of a native stack frame, so deeply nested programs cannot
//! overflow the interpreter's own call stack.  Name resolution compares
//! interned [`Symbol`] ids (`u32` equality) against a spaghetti stack of
//! local bindings — no string hashing or comparison on the hot path.
//!
//! Semantics are unchanged from the original tree-walking evaluator:
//! arrays live in an arena and are passed to functions **by reference**
//! (C array-parameter semantics); scalars are passed by value.  All
//! numeric storage is `i64`/`f64`; `float` arrays round-trip through `f64`
//! without loss for the value ranges MiniC apps use.

use std::collections::HashMap;
use std::marker::PhantomData;

use crate::cparse::ast::*;
use crate::cparse::error::Pos;
use crate::util::intern::Symbol;

use super::oracle::{LoopConflicts, OracleState};
use super::profile::{Footprint, LoopProfile, Profile};

/// Runtime scalar value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    /// Integer value (`int` variables and int literals).
    Int(i64),
    /// Floating value (`float`/`double` variables and float literals).
    Float(f64),
}

impl Value {
    /// Numeric value as `f64` (ints convert exactly).
    pub fn as_f64(self) -> f64 {
        match self {
            Value::Int(n) => n as f64,
            Value::Float(v) => v,
        }
    }

    /// Numeric value truncated to `i64` (C cast semantics).
    pub fn as_i64(self) -> i64 {
        match self {
            Value::Int(n) => n,
            Value::Float(v) => v as i64,
        }
    }

    fn truthy(self) -> bool {
        match self {
            Value::Int(n) => n != 0,
            Value::Float(v) => v != 0.0,
        }
    }
}

/// Interpreter runtime error.
#[derive(Debug, Clone)]
pub struct InterpError {
    /// Human-readable description.
    pub message: String,
    /// Source position, when one is attributable.
    pub pos: Option<Pos>,
}

impl InterpError {
    fn new(message: impl Into<String>) -> Self {
        Self { message: message.into(), pos: None }
    }

    fn at(message: impl Into<String>, pos: Pos) -> Self {
        Self { message: message.into(), pos: Some(pos) }
    }
}

impl std::fmt::Display for InterpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.pos {
            Some(p) => write!(f, "runtime error at {p}: {}", self.message),
            None => write!(f, "runtime error: {}", self.message),
        }
    }
}

impl std::error::Error for InterpError {}

#[derive(Debug, Clone)]
struct ArrayObj {
    is_float: bool,
    data: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
enum Binding {
    Scalar(Value),
    Array(usize),
}

/// Default interpreter step budget — generous for the paper workloads
/// (tdfir full scale ≈ 5M ops) while still catching runaway loops.
pub const DEFAULT_MAX_STEPS: u64 = 2_000_000_000;

// ---- lowered arena IR ------------------------------------------------------

/// Handle into [`LProgram::exprs`].
type EId = u32;
/// Handle into [`LProgram::stmts`].
type SId = u32;

/// A contiguous run inside one of the arena's shared list pools.
#[derive(Clone, Copy)]
struct ListRange {
    start: u32,
    len: u32,
}

/// Lowered expression node (`Copy`, 16 bytes of payload).
#[derive(Clone, Copy)]
enum LExpr {
    Int(i64),
    Float(f64),
    Var(Symbol),
    Index(Symbol, EId),
    Unary(UnOp, EId),
    Binary(BinOp, EId, EId),
    Call(Symbol, ListRange),
}

/// Lowered assignment target.
#[derive(Clone, Copy)]
enum LTarget {
    Var(Symbol),
    Index(Symbol, EId),
}

/// Lowered statement node.  Loop statements keep their own `SId` implicit:
/// the machine re-reads the node each iteration, so the record must carry
/// everything the header needs.
#[derive(Clone, Copy)]
enum LStmt {
    Decl(u32),
    Assign { target: LTarget, op: AssignOp, value: EId, pos: Pos },
    If { cond: EId, then_: ListRange, else_: ListRange, pos: Pos },
    For {
        id: u32,
        init: Option<SId>,
        cond: Option<EId>,
        step: Option<SId>,
        body: ListRange,
        pos: Pos,
    },
    While { id: u32, cond: EId, body: ListRange, pos: Pos },
    Return(Option<EId>, Pos),
    Expr(EId, Pos),
    Block(ListRange),
}

/// Lowered declaration (shared by globals and locals; array initializers
/// are ignored, matching the tree evaluator).
#[derive(Clone, Copy)]
struct LDecl {
    name: Symbol,
    is_array: bool,
    is_float: bool,
    arr_len: Option<usize>,
    init: Option<EId>,
    pos: Pos,
}

/// Lowered function parameter.
#[derive(Clone, Copy)]
struct LParam {
    name: Symbol,
    is_array: bool,
    is_float: bool,
}

/// Lowered function.
struct LFunc {
    name: Symbol,
    params: Vec<LParam>,
    body: ListRange,
}

/// The whole program, flattened: nodes in dense `Vec`s, child lists packed
/// into the `stmt_lists`/`expr_lists` pools as [`ListRange`]s.
#[derive(Default)]
struct LProgram {
    exprs: Vec<LExpr>,
    stmts: Vec<LStmt>,
    stmt_lists: Vec<SId>,
    expr_lists: Vec<EId>,
    decls: Vec<LDecl>,
    funcs: Vec<LFunc>,
    globals: Vec<u32>,
    max_loop: u32,
}

impl LProgram {
    fn lower(program: &Program) -> Self {
        let mut lp = LProgram::default();
        for d in &program.globals {
            let di = lp.lower_decl(d);
            lp.globals.push(di);
        }
        for f in &program.functions {
            let params = f
                .params
                .iter()
                .map(|p| LParam {
                    name: p.name,
                    is_array: p.ty.is_array(),
                    is_float: p.ty.is_float(),
                })
                .collect();
            let body = lp.lower_body(&f.body);
            lp.funcs.push(LFunc { name: f.name, params, body });
        }
        lp
    }

    fn lower_decl(&mut self, d: &Decl) -> u32 {
        let ld = match &d.ty {
            Type::Array(elem, len) => LDecl {
                name: d.name,
                is_array: true,
                is_float: elem.is_float(),
                arr_len: *len,
                init: None,
                pos: d.pos,
            },
            ty => LDecl {
                name: d.name,
                is_array: false,
                is_float: ty.is_float(),
                arr_len: None,
                init: d.init.as_ref().map(|e| self.lower_expr(e)),
                pos: d.pos,
            },
        };
        let di = self.decls.len() as u32;
        self.decls.push(ld);
        di
    }

    fn lower_body(&mut self, body: &[Stmt]) -> ListRange {
        let ids: Vec<SId> = body.iter().map(|s| self.lower_stmt(s)).collect();
        let start = self.stmt_lists.len() as u32;
        self.stmt_lists.extend(ids);
        ListRange { start, len: body.len() as u32 }
    }

    fn lower_stmt(&mut self, s: &Stmt) -> SId {
        let ls = match s {
            Stmt::Decl(d) => LStmt::Decl(self.lower_decl(d)),
            Stmt::Assign { target, op, value, pos } => {
                let target = match target {
                    LValue::Var(n) => LTarget::Var(*n),
                    LValue::Index(n, i) => LTarget::Index(*n, self.lower_expr(i)),
                };
                LStmt::Assign { target, op: *op, value: self.lower_expr(value), pos: *pos }
            }
            Stmt::If { cond, then_branch, else_branch, pos } => {
                let cond = self.lower_expr(cond);
                let then_ = self.lower_body(then_branch);
                let else_ = self.lower_body(else_branch);
                LStmt::If { cond, then_, else_, pos: *pos }
            }
            Stmt::For { id, header, body, pos } => {
                self.max_loop = self.max_loop.max(id.0 + 1);
                let init = header.init.as_deref().map(|s| self.lower_stmt(s));
                let cond = header.cond.as_ref().map(|e| self.lower_expr(e));
                let step = header.step.as_deref().map(|s| self.lower_stmt(s));
                let body = self.lower_body(body);
                LStmt::For { id: id.0, init, cond, step, body, pos: *pos }
            }
            Stmt::While { id, cond, body, pos } => {
                self.max_loop = self.max_loop.max(id.0 + 1);
                let cond = self.lower_expr(cond);
                let body = self.lower_body(body);
                LStmt::While { id: id.0, cond, body, pos: *pos }
            }
            Stmt::Return(e, pos) => {
                LStmt::Return(e.as_ref().map(|e| self.lower_expr(e)), *pos)
            }
            Stmt::Expr(e, pos) => LStmt::Expr(self.lower_expr(e), *pos),
            Stmt::Block(body) => LStmt::Block(self.lower_body(body)),
        };
        let sid = self.stmts.len() as u32;
        self.stmts.push(ls);
        sid
    }

    fn lower_expr(&mut self, e: &Expr) -> EId {
        let le = match &e.kind {
            ExprKind::IntLit(n) => LExpr::Int(*n),
            ExprKind::FloatLit(v) => LExpr::Float(*v),
            ExprKind::Var(n) => LExpr::Var(*n),
            ExprKind::Index(n, i) => LExpr::Index(*n, self.lower_expr(i)),
            ExprKind::Unary(op, a) => LExpr::Unary(*op, self.lower_expr(a)),
            ExprKind::Binary(op, a, b) => {
                let ae = self.lower_expr(a);
                let be = self.lower_expr(b);
                LExpr::Binary(*op, ae, be)
            }
            ExprKind::Call(f, args) => {
                let ids: Vec<EId> = args.iter().map(|a| self.lower_expr(a)).collect();
                let start = self.expr_lists.len() as u32;
                self.expr_lists.extend(ids);
                LExpr::Call(*f, ListRange { start, len: args.len() as u32 })
            }
        };
        let eid = self.exprs.len() as u32;
        self.exprs.push(le);
        eid
    }
}

// ---- the machine -----------------------------------------------------------

/// One continuation on the machine's `ops` stack.  Statements and
/// expressions decompose into these; control flow (loops, calls, scopes)
/// is expressed by pushing the right continuation sequence.
#[derive(Clone, Copy)]
enum Op {
    /// Execute one statement.
    Stmt(SId),
    /// Evaluate one expression, pushing its value onto `vals`.
    Eval(EId),
    /// Truncate `locals` back to a scope mark.
    ScopeEnd(u32),
    /// Pop the innermost loop id off the profiling loop stack.
    PopLoop,
    /// Pop the innermost dynamic-oracle recording frame (only ever
    /// scheduled while the oracle is enabled).
    PopOracleFrame,
    /// Drop the value of an expression statement.
    Discard,
    /// Branch on the just-evaluated `if` condition.
    IfCheck { then_: ListRange, else_: ListRange },
    /// Evaluate the `for` condition (or iterate immediately if absent).
    ForCond(SId),
    /// Branch on the just-evaluated `for` condition.
    ForCheck(SId),
    /// Evaluate the `while` condition.
    WhileCond(SId),
    /// Branch on the just-evaluated `while` condition.
    WhileCheck(SId),
    /// Bind a scalar declaration to its just-evaluated initializer.
    DeclBind(u32),
    /// Finish a scalar assignment with the just-evaluated RHS.
    AssignVar { name: Symbol, op: AssignOp, pos: Pos },
    /// Finish an array-element assignment (pops index, then RHS).
    AssignIndex { name: Symbol, op: AssignOp, pos: Pos },
    /// Apply a unary operator to the top of `vals`.
    Unary(UnOp),
    /// Apply a binary operator to the top two values.
    Binary(BinOp),
    /// `&&`/`||`: inspect LHS, short-circuit or schedule the RHS.
    ShortCircuit { op: BinOp, rhs: EId },
    /// Normalize the RHS of a non-short-circuited `&&`/`||` to 0/1.
    BoolCast,
    /// Read one array element with the just-evaluated index.
    IndexRead(Symbol),
    /// Apply a builtin math function to its evaluated arguments.
    Builtin { name: Symbol, argc: u32 },
    /// Coerce + bind one evaluated scalar argument, then resume binding
    /// the remaining parameters of the call.
    CallBound { func: u32, name: Symbol, param: u32, args: ListRange, bind_base: u32 },
    /// Unwind the current frame with the just-evaluated return value.
    ReturnVal,
    /// Fall off the end of a function body (implicit return).
    CallEnd,
}

/// One call frame: base offsets into the machine stacks, recorded at
/// entry so `return` can unwind everything with four truncates.
struct Frame {
    ops_base: u32,
    vals_base: u32,
    locals_base: u32,
    loop_base: u32,
    oracle_base: u32,
    is_expr: bool,
}

/// The interpreter. One instance per program run.
pub struct Interp<'p> {
    code: LProgram,
    arrays: Vec<ArrayObj>,
    globals: HashMap<Symbol, Binding>,
    /// local bindings as one spaghetti stack: frames/scopes are just
    /// truncation marks, so loop iterations allocate nothing
    locals: Vec<(Symbol, Binding)>,
    frames: Vec<Frame>,
    /// continuation stack (the machine's control state)
    ops: Vec<Op>,
    /// operand stack (evaluated sub-expression values)
    vals: Vec<Value>,
    /// argument bindings being assembled for an in-progress call
    pending: Vec<(Symbol, Binding)>,
    overrides: HashMap<Symbol, Value>,
    // dynamic dependence oracle (None unless enabled for this run)
    oracle: Option<OracleState>,
    // profiling
    loop_counters: Vec<LoopProfile>,
    loop_stack: Vec<u32>,
    totals: Profile,
    steps: u64,
    max_steps: u64,
    globals_ready: bool,
    result: Option<Value>,
    _ast: PhantomData<&'p Program>,
}

impl<'p> Interp<'p> {
    /// Build an interpreter for one run of `program` (lowers the AST into
    /// the flat execution arena once, up front).
    pub fn new(program: &'p Program) -> Self {
        let code = LProgram::lower(program);
        let max_loop = code.max_loop;
        Self {
            code,
            arrays: Vec::new(),
            globals: HashMap::new(),
            locals: Vec::new(),
            frames: Vec::new(),
            ops: Vec::new(),
            vals: Vec::new(),
            pending: Vec::new(),
            overrides: HashMap::new(),
            oracle: None,
            loop_counters: vec![LoopProfile::default(); max_loop as usize],
            loop_stack: Vec::new(),
            totals: Profile::default(),
            steps: 0,
            max_steps: DEFAULT_MAX_STEPS,
            globals_ready: false,
            result: None,
            _ast: PhantomData,
        }
    }

    /// Override a global scalar before the run (e.g. shrink a problem-size
    /// constant for tests: `set_global("N", Value::Int(64))`).
    pub fn set_global(&mut self, name: &str, value: Value) {
        self.overrides.insert(Symbol::intern(name), value);
    }

    /// Override the runaway-loop step budget.
    pub fn set_max_steps(&mut self, max: u64) {
        self.max_steps = max;
    }

    /// Enable the dynamic dependence oracle for this run: every loop
    /// records per-iteration read/write sets and flags loop-carried
    /// conflicts (see [`super::oracle`]).  Call before [`Self::call`].
    pub fn enable_oracle(&mut self, program: &Program) {
        self.oracle = Some(OracleState::new(program, self.code.max_loop));
    }

    /// Conflicts the oracle observed for one loop (`None` when the
    /// oracle was never enabled).
    pub fn oracle_conflicts(&self, id: LoopId) -> Option<&LoopConflicts> {
        self.oracle.as_ref().and_then(|o| o.conflicts_for(id))
    }

    /// Every loop the oracle saw at least one conflict in.
    pub fn oracle_report(&self) -> Vec<(LoopId, LoopConflicts)> {
        self.oracle.as_ref().map(|o| o.all_conflicts()).unwrap_or_default()
    }

    /// Run `main()`.
    pub fn run_main(&mut self) -> Result<Option<Value>, InterpError> {
        self.call("main", &[])
    }

    /// Call a function by name with scalar arguments.
    pub fn call(&mut self, name: &str, args: &[Value]) -> Result<Option<Value>, InterpError> {
        self.init_globals()?;
        let fi = self
            .code
            .funcs
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| InterpError::new(format!("no function `{name}`")))?;
        let nparams = self.code.funcs[fi].params.len();
        if nparams != args.len() {
            return Err(InterpError::new(format!(
                "`{name}` expects {} args, got {}",
                nparams,
                args.len()
            )));
        }
        self.ops.clear();
        self.vals.clear();
        self.frames.clear();
        self.pending.clear();
        self.locals.clear();
        self.result = None;
        for (i, v) in args.iter().enumerate() {
            let pname = self.code.funcs[fi].params[i].name;
            self.pending.push((pname, Binding::Scalar(*v)));
        }
        self.enter_frame(fi as u32, 0, false)?;
        self.run()
    }

    /// Read a global array's contents (output capture for verification).
    pub fn read_array(&mut self, name: &str) -> Result<Vec<f64>, InterpError> {
        self.init_globals()?;
        match self.globals.get(&Symbol::intern(name)) {
            Some(Binding::Array(h)) => Ok(self.arrays[*h].data.clone()),
            Some(Binding::Scalar(_)) => {
                Err(InterpError::new(format!("`{name}` is a scalar, not an array")))
            }
            None => Err(InterpError::new(format!("no global `{name}`"))),
        }
    }

    /// Read a global scalar.
    pub fn read_scalar(&mut self, name: &str) -> Result<Value, InterpError> {
        self.init_globals()?;
        match self.globals.get(&Symbol::intern(name)) {
            Some(Binding::Scalar(v)) => Ok(*v),
            _ => Err(InterpError::new(format!("no scalar global `{name}`"))),
        }
    }

    /// Finish and extract the dynamic profile.
    pub fn into_profile(mut self) -> Profile {
        for (i, lp) in self.loop_counters.into_iter().enumerate() {
            if lp.entries > 0 {
                self.totals.loops.insert(LoopId(i as u32), lp);
            }
        }
        self.totals.steps = self.steps;
        self.totals
    }

    // ---- globals -----------------------------------------------------------

    fn init_globals(&mut self) -> Result<(), InterpError> {
        if self.globals_ready {
            return Ok(());
        }
        self.globals_ready = true;
        for gi in 0..self.code.globals.len() {
            let di = self.code.globals[gi];
            let d = self.code.decls[di as usize];
            let b = if d.is_array {
                let n = match d.arr_len {
                    Some(n) => n,
                    None => {
                        return Err(InterpError::at(
                            format!("array `{}` needs a length", d.name),
                            d.pos,
                        ))
                    }
                };
                let h = self.arrays.len();
                self.arrays.push(ArrayObj { is_float: d.is_float, data: vec![0.0; n] });
                Binding::Array(h)
            } else {
                let v = match d.init {
                    Some(e) => self.eval_const(e)?,
                    None => Value::Int(0),
                };
                let v = if d.is_float {
                    Value::Float(v.as_f64())
                } else {
                    Value::Int(v.as_i64())
                };
                Binding::Scalar(v)
            };
            // apply override after the declared initializer
            let b = match (self.overrides.get(&d.name), b) {
                (Some(v), Binding::Scalar(_)) => Binding::Scalar(*v),
                _ => b,
            };
            self.globals.insert(d.name, b);
        }
        Ok(())
    }

    /// Evaluate one global-initializer expression with a bounded run of
    /// the machine (globals initialize before any frame exists).
    fn eval_const(&mut self, e: EId) -> Result<Value, InterpError> {
        let ops_base = self.ops.len();
        self.ops.push(Op::Eval(e));
        while self.ops.len() > ops_base {
            let op = self.ops.pop().expect("op stack underflow");
            self.step(op)?;
        }
        Ok(self.vals.pop().expect("global initializer produced no value"))
    }

    // ---- machine core ------------------------------------------------------

    fn run(&mut self) -> Result<Option<Value>, InterpError> {
        while let Some(op) = self.ops.pop() {
            self.step(op)?;
        }
        Ok(self.result.take())
    }

    /// Push a call frame and schedule the function body.  Parameter
    /// bindings for this call sit at `pending[bind_base..]`.
    fn enter_frame(
        &mut self,
        fi: u32,
        bind_base: usize,
        is_expr: bool,
    ) -> Result<(), InterpError> {
        if self.frames.len() > 64 {
            return Err(InterpError::new("call stack overflow (depth > 64)"));
        }
        self.frames.push(Frame {
            ops_base: self.ops.len() as u32,
            vals_base: self.vals.len() as u32,
            locals_base: self.locals.len() as u32,
            loop_base: self.loop_stack.len() as u32,
            oracle_base: self.oracle.as_ref().map_or(0, |o| o.frames_len()) as u32,
            is_expr,
        });
        self.ops.push(Op::CallEnd);
        let body = self.code.funcs[fi as usize].body;
        self.push_body_rev(body);
        let n = self.pending.len();
        self.locals.extend(self.pending.drain(bind_base..n));
        Ok(())
    }

    /// Unwind the current frame on `return`: four truncates restore every
    /// machine stack to its at-entry state, whatever was in flight.
    fn return_unwind(&mut self, v: Option<Value>) {
        let frame = self.frames.pop().expect("return outside a call frame");
        self.ops.truncate(frame.ops_base as usize);
        self.vals.truncate(frame.vals_base as usize);
        self.locals.truncate(frame.locals_base as usize);
        self.loop_stack.truncate(frame.loop_base as usize);
        if let Some(o) = &mut self.oracle {
            // PopOracleFrame continuations vanished with ops.truncate
            o.truncate_frames(frame.oracle_base as usize);
        }
        if frame.is_expr {
            self.vals.push(v.unwrap_or(Value::Int(0)));
        } else {
            self.result = v;
        }
    }

    /// Schedule a statement list for execution (reversed: `ops` is LIFO).
    fn push_body_rev(&mut self, body: ListRange) {
        let start = body.start as usize;
        for i in (start..start + body.len as usize).rev() {
            let sid = self.code.stmt_lists[i];
            self.ops.push(Op::Stmt(sid));
        }
    }

    fn step(&mut self, op: Op) -> Result<(), InterpError> {
        match op {
            Op::Stmt(sid) => return self.step_stmt(sid),
            Op::Eval(eid) => return self.step_eval(eid),
            Op::ScopeEnd(mark) => self.locals.truncate(mark as usize),
            Op::PopLoop => {
                self.loop_stack.pop();
            }
            Op::PopOracleFrame => {
                if let Some(o) = &mut self.oracle {
                    o.pop_frame();
                }
            }
            Op::Discard => {
                self.vals.pop();
            }
            Op::IfCheck { then_, else_ } => {
                let c = self.vals.pop().expect("if condition value");
                self.ops.push(Op::ScopeEnd(self.locals.len() as u32));
                self.push_body_rev(if c.truthy() { then_ } else { else_ });
            }
            Op::ForCond(sid) => {
                let LStmt::For { cond, .. } = self.code.stmts[sid as usize] else {
                    unreachable!("ForCond on non-for statement");
                };
                match cond {
                    Some(c) => {
                        self.ops.push(Op::ForCheck(sid));
                        self.ops.push(Op::Eval(c));
                    }
                    None => self.for_iterate(sid),
                }
            }
            Op::ForCheck(sid) => {
                let v = self.vals.pop().expect("for condition value");
                if v.truthy() {
                    self.for_iterate(sid);
                }
            }
            Op::WhileCond(sid) => {
                let LStmt::While { cond, .. } = self.code.stmts[sid as usize] else {
                    unreachable!("WhileCond on non-while statement");
                };
                self.ops.push(Op::WhileCheck(sid));
                self.ops.push(Op::Eval(cond));
            }
            Op::WhileCheck(sid) => {
                let v = self.vals.pop().expect("while condition value");
                if v.truthy() {
                    let LStmt::While { id, body, .. } = self.code.stmts[sid as usize] else {
                        unreachable!("WhileCheck on non-while statement");
                    };
                    self.loop_counters[id as usize].iterations += 1;
                    self.loop_stack.push(id);
                    if let Some(o) = &mut self.oracle {
                        o.bump_iter(id);
                    }
                    self.ops.push(Op::WhileCond(sid));
                    self.ops.push(Op::PopLoop);
                    self.ops.push(Op::ScopeEnd(self.locals.len() as u32));
                    self.push_body_rev(body);
                }
            }
            Op::DeclBind(di) => {
                let d = self.code.decls[di as usize];
                let v = self.vals.pop().expect("declaration initializer value");
                let v = if d.is_float {
                    Value::Float(v.as_f64())
                } else {
                    Value::Int(v.as_i64())
                };
                self.locals.push((d.name, Binding::Scalar(v)));
            }
            Op::AssignVar { name, op, pos } => {
                let rhs = self.vals.pop().expect("assignment RHS value");
                let new = if op == AssignOp::Assign {
                    rhs
                } else {
                    let old = match self.lookup(name) {
                        Some(Binding::Scalar(v)) => v,
                        _ => return Err(InterpError::at(format!("no scalar `{name}`"), pos)),
                    };
                    if let Some(o) = &mut self.oracle {
                        o.scalar_read(name);
                    }
                    self.apply_compound(old, op, rhs)
                };
                if let Some(o) = &mut self.oracle {
                    o.scalar_write(name);
                }
                self.set_scalar(name, new, pos)?;
            }
            Op::AssignIndex { name, op, pos } => {
                let i = self.vals.pop().expect("assignment index value").as_i64();
                let rhs = self.vals.pop().expect("assignment RHS value");
                let h = match self.lookup(name) {
                    Some(Binding::Array(h)) => h,
                    _ => return Err(InterpError::at(format!("no array `{name}`"), pos)),
                };
                let (len, is_float) = (self.arrays[h].data.len(), self.arrays[h].is_float);
                if i < 0 || i as usize >= len {
                    return Err(InterpError::at(
                        format!("index {i} out of bounds for `{name}[{len}]`"),
                        pos,
                    ));
                }
                let elem_bytes = 4;
                let new = if op == AssignOp::Assign {
                    rhs
                } else {
                    let old = self.arrays[h].data[i as usize];
                    self.count_access(name, i, elem_bytes, false);
                    if let Some(o) = &mut self.oracle {
                        o.array_read(name, h, i);
                    }
                    let old = if is_float { Value::Float(old) } else { Value::Int(old as i64) };
                    self.apply_compound(old, op, rhs)
                };
                self.count_access(name, i, elem_bytes, true);
                if let Some(o) = &mut self.oracle {
                    o.array_write(name, h, i);
                }
                self.arrays[h].data[i as usize] = if is_float {
                    new.as_f64()
                } else {
                    new.as_i64() as f64
                };
            }
            Op::Unary(op) => {
                let v = self.vals.pop().expect("unary operand value");
                let r = match op {
                    UnOp::Neg => match v {
                        Value::Int(n) => {
                            self.count_int_ops(1);
                            Value::Int(-n)
                        }
                        Value::Float(f) => {
                            self.count_flops(1);
                            Value::Float(-f)
                        }
                    },
                    UnOp::Not => {
                        self.count_int_ops(1);
                        Value::Int(!v.truthy() as i64)
                    }
                };
                self.vals.push(r);
            }
            Op::Binary(op) => {
                let vb = self.vals.pop().expect("binary RHS value");
                let va = self.vals.pop().expect("binary LHS value");
                let r = self.apply_bin(op, va, vb);
                self.vals.push(r);
            }
            Op::ShortCircuit { op, rhs } => {
                let va = self.vals.pop().expect("short-circuit LHS value");
                self.count_int_ops(1);
                match (op, va.truthy()) {
                    (BinOp::And, false) => self.vals.push(Value::Int(0)),
                    (BinOp::Or, true) => self.vals.push(Value::Int(1)),
                    _ => {
                        self.ops.push(Op::BoolCast);
                        self.ops.push(Op::Eval(rhs));
                    }
                }
            }
            Op::BoolCast => {
                let v = self.vals.pop().expect("boolean operand value");
                self.vals.push(Value::Int(v.truthy() as i64));
            }
            Op::IndexRead(name) => {
                let i = self.vals.pop().expect("index value").as_i64();
                let h = match self.lookup(name) {
                    Some(Binding::Array(h)) => h,
                    _ => return Err(InterpError::new(format!("no array `{name}`"))),
                };
                let arr = &self.arrays[h];
                let len = arr.data.len();
                if i < 0 || i as usize >= len {
                    return Err(InterpError::new(format!(
                        "index {i} out of bounds for `{name}[{len}]`"
                    )));
                }
                let is_float = arr.is_float;
                let v = arr.data[i as usize];
                self.count_access(name, i, 4, false);
                if let Some(o) = &mut self.oracle {
                    o.array_read(name, h, i);
                }
                self.vals.push(if is_float { Value::Float(v) } else { Value::Int(v as i64) });
            }
            Op::Builtin { name, argc } => {
                let base = self.vals.len() - argc as usize;
                self.count_math();
                let v = {
                    let a = |i: usize| self.vals[base + i].as_f64();
                    match (name.as_str(), argc) {
                        ("sin", 1) => a(0).sin(),
                        ("cos", 1) => a(0).cos(),
                        ("sqrt", 1) => a(0).sqrt(),
                        ("fabs", 1) => a(0).abs(),
                        ("exp", 1) => a(0).exp(),
                        ("floor", 1) => a(0).floor(),
                        ("fmin", 2) => a(0).min(a(1)),
                        ("fmax", 2) => a(0).max(a(1)),
                        _ => {
                            return Err(InterpError::new(format!(
                                "builtin `{name}` called with {argc} args"
                            )))
                        }
                    }
                };
                self.vals.truncate(base);
                self.vals.push(Value::Float(v));
            }
            Op::CallBound { func, name, param, args, bind_base } => {
                let v = self.vals.pop().expect("call argument value");
                let p = self.code.funcs[func as usize].params[param as usize];
                let v = if p.is_float {
                    Value::Float(v.as_f64())
                } else {
                    Value::Int(v.as_i64())
                };
                self.pending.push((p.name, Binding::Scalar(v)));
                self.continue_call(func, name, param + 1, args, bind_base)?;
            }
            Op::ReturnVal => {
                let v = self.vals.pop().expect("return value");
                self.return_unwind(Some(v));
            }
            Op::CallEnd => {
                let frame = self.frames.pop().expect("unbalanced call frame");
                self.locals.truncate(frame.locals_base as usize);
                if frame.is_expr {
                    self.vals.push(Value::Int(0));
                } else {
                    self.result = None;
                }
            }
        }
        Ok(())
    }

    fn step_stmt(&mut self, sid: SId) -> Result<(), InterpError> {
        let s = self.code.stmts[sid as usize];
        match s {
            LStmt::Decl(di) => {
                let d = self.code.decls[di as usize];
                self.tick(d.pos)?;
                if let Some(o) = &mut self.oracle {
                    // declared inside the loop body: private per iteration
                    o.mark_private(d.name);
                }
                if d.is_array {
                    let n = match d.arr_len {
                        Some(n) => n,
                        None => {
                            return Err(InterpError::at(
                                format!("array `{}` needs a length", d.name),
                                d.pos,
                            ))
                        }
                    };
                    // a fresh array object per execution of the declaration
                    let h = self.arrays.len();
                    self.arrays.push(ArrayObj { is_float: d.is_float, data: vec![0.0; n] });
                    self.locals.push((d.name, Binding::Array(h)));
                } else if let Some(init) = d.init {
                    self.ops.push(Op::DeclBind(di));
                    self.ops.push(Op::Eval(init));
                } else {
                    let v = if d.is_float { Value::Float(0.0) } else { Value::Int(0) };
                    self.locals.push((d.name, Binding::Scalar(v)));
                }
            }
            LStmt::Assign { target, op, value, pos } => {
                self.tick(pos)?;
                match target {
                    LTarget::Var(name) => {
                        self.ops.push(Op::AssignVar { name, op, pos });
                        self.ops.push(Op::Eval(value));
                    }
                    LTarget::Index(name, idx) => {
                        // RHS evaluates first, then the index (tree-eval order)
                        self.ops.push(Op::AssignIndex { name, op, pos });
                        self.ops.push(Op::Eval(idx));
                        self.ops.push(Op::Eval(value));
                    }
                }
            }
            LStmt::If { cond, then_, else_, pos } => {
                self.tick(pos)?;
                self.ops.push(Op::IfCheck { then_, else_ });
                self.ops.push(Op::Eval(cond));
            }
            LStmt::For { id, init, pos, .. } => {
                self.tick(pos)?;
                self.loop_counters[id as usize].entries += 1;
                if let Some(o) = &mut self.oracle {
                    o.push_frame(id);
                    // pushed below ScopeEnd so it runs after the loop ends
                    self.ops.push(Op::PopOracleFrame);
                }
                // header scope (for decl-in-init) closes when the loop ends
                self.ops.push(Op::ScopeEnd(self.locals.len() as u32));
                self.ops.push(Op::ForCond(sid));
                if let Some(init) = init {
                    self.ops.push(Op::Stmt(init));
                }
            }
            LStmt::While { id, pos, .. } => {
                self.tick(pos)?;
                self.loop_counters[id as usize].entries += 1;
                if let Some(o) = &mut self.oracle {
                    o.push_frame(id);
                    self.ops.push(Op::PopOracleFrame);
                }
                self.ops.push(Op::WhileCond(sid));
            }
            LStmt::Return(e, pos) => {
                self.tick(pos)?;
                match e {
                    Some(e) => {
                        self.ops.push(Op::ReturnVal);
                        self.ops.push(Op::Eval(e));
                    }
                    None => self.return_unwind(None),
                }
            }
            LStmt::Expr(e, pos) => {
                self.tick(pos)?;
                self.ops.push(Op::Discard);
                self.ops.push(Op::Eval(e));
            }
            LStmt::Block(body) => {
                self.ops.push(Op::ScopeEnd(self.locals.len() as u32));
                self.push_body_rev(body);
            }
        }
        Ok(())
    }

    /// One loop-body iteration: count it, push the loop id for profiling
    /// attribution, and schedule body + step + re-check.
    fn for_iterate(&mut self, sid: SId) {
        let LStmt::For { id, step, body, .. } = self.code.stmts[sid as usize] else {
            unreachable!("for_iterate on non-for statement");
        };
        self.loop_counters[id as usize].iterations += 1;
        self.loop_stack.push(id);
        if let Some(o) = &mut self.oracle {
            o.bump_iter(id);
        }
        self.ops.push(Op::ForCond(sid));
        self.ops.push(Op::PopLoop);
        if let Some(step) = step {
            self.ops.push(Op::Stmt(step));
        }
        self.ops.push(Op::ScopeEnd(self.locals.len() as u32));
        self.push_body_rev(body);
    }

    fn step_eval(&mut self, eid: EId) -> Result<(), InterpError> {
        let e = self.code.exprs[eid as usize];
        match e {
            LExpr::Int(n) => self.vals.push(Value::Int(n)),
            LExpr::Float(v) => self.vals.push(Value::Float(v)),
            LExpr::Var(name) => {
                if let Some(o) = &mut self.oracle {
                    o.scalar_read(name);
                }
                match self.lookup(name) {
                    Some(Binding::Scalar(v)) => self.vals.push(v),
                    Some(Binding::Array(_)) => {
                        return Err(InterpError::new(format!("array `{name}` used as scalar")))
                    }
                    None => {
                        return Err(InterpError::new(format!("undeclared variable `{name}`")))
                    }
                }
            }
            LExpr::Index(name, idx) => {
                self.ops.push(Op::IndexRead(name));
                self.ops.push(Op::Eval(idx));
            }
            LExpr::Unary(op, a) => {
                self.ops.push(Op::Unary(op));
                self.ops.push(Op::Eval(a));
            }
            LExpr::Binary(op, a, b) => {
                if matches!(op, BinOp::And | BinOp::Or) {
                    self.ops.push(Op::ShortCircuit { op, rhs: b });
                    self.ops.push(Op::Eval(a));
                } else {
                    self.ops.push(Op::Binary(op));
                    self.ops.push(Op::Eval(b));
                    self.ops.push(Op::Eval(a));
                }
            }
            LExpr::Call(name, args) => self.begin_call(name, args)?,
        }
        Ok(())
    }

    /// Start a call expression: builtins schedule their arguments and a
    /// fold; user calls bind parameters left-to-right via `continue_call`.
    fn begin_call(&mut self, name: Symbol, args: ListRange) -> Result<(), InterpError> {
        if crate::ir::varref::is_builtin(name.as_str()) {
            self.ops.push(Op::Builtin { name, argc: args.len });
            let start = args.start as usize;
            for i in (start..start + args.len as usize).rev() {
                let eid = self.code.expr_lists[i];
                self.ops.push(Op::Eval(eid));
            }
            return Ok(());
        }
        let fi = self
            .code
            .funcs
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| InterpError::new(format!("no function `{name}`")))?;
        let nparams = self.code.funcs[fi].params.len() as u32;
        if nparams != args.len {
            return Err(InterpError::new(format!(
                "`{name}` expects {nparams} args, got {}",
                args.len
            )));
        }
        let bind_base = self.pending.len() as u32;
        self.continue_call(fi as u32, name, 0, args, bind_base)
    }

    /// Bind parameters starting at `param`; array parameters bind
    /// immediately (by reference), scalar parameters schedule an argument
    /// evaluation and resume via [`Op::CallBound`].
    fn continue_call(
        &mut self,
        fi: u32,
        name: Symbol,
        mut param: u32,
        args: ListRange,
        bind_base: u32,
    ) -> Result<(), InterpError> {
        loop {
            let nparams = self.code.funcs[fi as usize].params.len() as u32;
            if param == nparams {
                return self.enter_frame(fi, bind_base as usize, true);
            }
            let p = self.code.funcs[fi as usize].params[param as usize];
            let arg_eid = self.code.expr_lists[(args.start + param) as usize];
            if p.is_array {
                // arrays pass by reference: argument must be a bare name
                match self.code.exprs[arg_eid as usize] {
                    LExpr::Var(an) => match self.lookup(an) {
                        Some(b @ Binding::Array(_)) => {
                            self.pending.push((p.name, b));
                            param += 1;
                        }
                        _ => {
                            return Err(InterpError::new(format!(
                                "`{an}` is not an array (argument to `{name}`)"
                            )))
                        }
                    },
                    _ => {
                        return Err(InterpError::new(format!(
                            "array argument to `{name}` must be a variable"
                        )))
                    }
                }
            } else {
                self.ops.push(Op::CallBound { func: fi, name, param, args, bind_base });
                self.ops.push(Op::Eval(arg_eid));
                return Ok(());
            }
        }
    }

    // ---- environment -------------------------------------------------------

    fn lookup(&self, name: Symbol) -> Option<Binding> {
        let base = self.frames.last().map(|f| f.locals_base as usize).unwrap_or(0);
        for (n, b) in self.locals[base..].iter().rev() {
            if *n == name {
                return Some(*b);
            }
        }
        self.globals.get(&name).copied()
    }

    fn set_scalar(&mut self, name: Symbol, v: Value, pos: Pos) -> Result<(), InterpError> {
        let base = self.frames.last().map(|f| f.locals_base as usize).unwrap_or(0);
        for (n, b) in self.locals[base..].iter_mut().rev() {
            if *n == name {
                match b {
                    Binding::Scalar(old) => {
                        // preserve declared int-ness
                        *old = match old {
                            Value::Int(_) => Value::Int(v.as_i64()),
                            Value::Float(_) => Value::Float(v.as_f64()),
                        };
                        return Ok(());
                    }
                    Binding::Array(_) => {
                        return Err(InterpError::at(
                            format!("cannot assign to array `{name}`"),
                            pos,
                        ))
                    }
                }
            }
        }
        if let Some(Binding::Scalar(old)) = self.globals.get_mut(&name) {
            *old = match old {
                Value::Int(_) => Value::Int(v.as_i64()),
                Value::Float(_) => Value::Float(v.as_f64()),
            };
            return Ok(());
        }
        Err(InterpError::at(format!("assignment to undeclared `{name}`"), pos))
    }

    fn tick(&mut self, pos: Pos) -> Result<(), InterpError> {
        self.steps += 1;
        if self.steps > self.max_steps {
            return Err(InterpError::at(
                format!("step budget exhausted ({} steps)", self.max_steps),
                pos,
            ));
        }
        Ok(())
    }

    // profiling helpers ------------------------------------------------------

    #[inline]
    fn count_flops(&mut self, n: u64) {
        self.totals.total_flops += n;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].flops += n;
        }
    }

    #[inline]
    fn count_math(&mut self) {
        self.totals.total_math_calls += 1;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].math_calls += 1;
        }
    }

    #[inline]
    fn count_int_ops(&mut self, n: u64) {
        self.totals.total_int_ops += n;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].int_ops += n;
        }
    }

    fn count_access(&mut self, array: Symbol, idx: i64, elem_bytes: u64, write: bool) {
        if write {
            self.totals.total_mem_writes += 1;
        } else {
            self.totals.total_mem_reads += 1;
        }
        for &lid in &self.loop_stack {
            let lp = &mut self.loop_counters[lid as usize];
            if write {
                lp.mem_writes += 1;
            } else {
                lp.mem_reads += 1;
            }
            if let Some(fp) = lp.footprints.get_mut(&array) {
                fp.min_idx = fp.min_idx.min(idx);
                fp.max_idx = fp.max_idx.max(idx);
                fp.accesses += 1;
            } else {
                lp.footprints.insert(
                    array,
                    Footprint { min_idx: idx, max_idx: idx, elem_bytes, accesses: 1 },
                );
            }
        }
    }

    // arithmetic -------------------------------------------------------------

    fn apply_compound(&mut self, old: Value, op: AssignOp, rhs: Value) -> Value {
        let bop = match op {
            AssignOp::AddAssign => BinOp::Add,
            AssignOp::SubAssign => BinOp::Sub,
            AssignOp::MulAssign => BinOp::Mul,
            AssignOp::DivAssign => BinOp::Div,
            AssignOp::Assign => unreachable!(),
        };
        self.apply_bin(bop, old, rhs)
    }

    fn apply_bin(&mut self, op: BinOp, a: Value, b: Value) -> Value {
        use BinOp::*;
        let float = matches!(a, Value::Float(_)) || matches!(b, Value::Float(_));
        if op.is_arith() {
            if float {
                self.count_flops(1);
                let (x, y) = (a.as_f64(), b.as_f64());
                Value::Float(match op {
                    Add => x + y,
                    Sub => x - y,
                    Mul => x * y,
                    Div => x / y,
                    Mod => x % y,
                    _ => unreachable!(),
                })
            } else {
                self.count_int_ops(1);
                let (x, y) = (a.as_i64(), b.as_i64());
                Value::Int(match op {
                    Add => x.wrapping_add(y),
                    Sub => x.wrapping_sub(y),
                    Mul => x.wrapping_mul(y),
                    Div => {
                        if y == 0 { 0 } else { x / y }
                    }
                    Mod => {
                        if y == 0 { 0 } else { x % y }
                    }
                    _ => unreachable!(),
                })
            }
        } else {
            self.count_int_ops(1);
            let t = if float {
                let (x, y) = (a.as_f64(), b.as_f64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    And => a.truthy() && b.truthy(),
                    Or => a.truthy() || b.truthy(),
                    _ => unreachable!(),
                }
            } else {
                let (x, y) = (a.as_i64(), b.as_i64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    And => x != 0 && y != 0,
                    Or => x != 0 || y != 0,
                    _ => unreachable!(),
                }
            };
            Value::Int(t as i64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;

    fn run_owned(src: &str) -> (Profile, Vec<f64>) {
        let p = parse(src).unwrap();
        let mut it = Interp::new(&p);
        it.run_main().unwrap();
        let out = it.read_array("out").unwrap_or_default();
        (it.into_profile(), out)
    }

    #[test]
    fn arithmetic_and_output() {
        let (_, out) = run_owned(
            "float out[4]; void main() { int i; \
             for (i = 0; i < 4; i++) { out[i] = i * 2.0 + 1.0; } }",
        );
        assert_eq!(out, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn trip_counts_recorded() {
        let (prof, _) = run_owned(
            "float out[1]; void main() { int i; int j; \
             for (i = 0; i < 10; i++) { for (j = 0; j < 5; j++) { out[0] += 1.0; } } }",
        );
        let l0 = prof.loop_profile(LoopId(0)).unwrap();
        let l1 = prof.loop_profile(LoopId(1)).unwrap();
        assert_eq!(l0.entries, 1);
        assert_eq!(l0.iterations, 10);
        assert_eq!(l1.entries, 10);
        assert_eq!(l1.iterations, 50);
        // inner flops roll up into the outer loop
        assert_eq!(l1.flops, 50);
        assert_eq!(l0.flops, 50);
    }

    #[test]
    fn footprint_ranges() {
        let (prof, _) = run_owned(
            "float out[100]; void main() { int i; \
             for (i = 10; i < 20; i++) { out[i] = 1.0; } }",
        );
        let l0 = prof.loop_profile(LoopId(0)).unwrap();
        let fp = &l0.footprints[&Symbol::intern("out")];
        assert_eq!((fp.min_idx, fp.max_idx), (10, 19));
        assert_eq!(fp.bytes(), 40);
        assert_eq!(l0.mem_writes, 10);
    }

    #[test]
    fn function_calls_and_returns() {
        let (_, out) = run_owned(
            "float out[1]; \
             float square(float x) { return x * x; } \
             void main() { out[0] = square(3.0) + square(4.0); }",
        );
        assert_eq!(out[0], 25.0);
    }

    #[test]
    fn arrays_pass_by_reference() {
        let (_, out) = run_owned(
            "float out[3]; \
             void fill(float a[], int n, float v) { int i; \
               for (i = 0; i < n; i++) { a[i] = v; } } \
             void main() { fill(out, 3, 7.0); }",
        );
        assert_eq!(out, vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn builtins_work() {
        let (_, out) = run_owned(
            "float out[3]; void main() { \
             out[0] = sqrt(16.0); out[1] = fabs(-2.5); out[2] = fmax(1.0, 2.0); }",
        );
        assert_eq!(out, vec![4.0, 2.5, 2.0]);
    }

    #[test]
    fn int_semantics_truncate() {
        let (_, out) = run_owned(
            "float out[2]; void main() { int a; a = 7 / 2; out[0] = a; out[1] = 7 % 2; }",
        );
        assert_eq!(out, vec![3.0, 1.0]);
    }

    #[test]
    fn while_and_if() {
        let (_, out) = run_owned(
            "float out[1]; void main() { int n; n = 10; \
             while (n > 0) { if (n % 2 == 0) { out[0] += 1.0; } n -= 1; } }",
        );
        assert_eq!(out[0], 5.0);
    }

    #[test]
    fn global_override() {
        let p = parse(
            "int N = 100; float out[100]; void main() { int i; \
             for (i = 0; i < N; i++) { out[i] = 1.0; } }",
        )
        .unwrap();
        let mut it = Interp::new(&p);
        it.set_global("N", Value::Int(5));
        it.run_main().unwrap();
        let out = it.read_array("out").unwrap();
        assert_eq!(out.iter().filter(|v| **v == 1.0).count(), 5);
    }

    #[test]
    fn step_budget_catches_infinite_loop() {
        let p = parse("void main() { int i; i = 0; while (i < 1) { i = 0; } }").unwrap();
        let mut it = Interp::new(&p);
        it.set_max_steps(10_000);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn out_of_bounds_is_error() {
        let p = parse("float out[2]; void main() { out[5] = 1.0; }").unwrap();
        let mut it = Interp::new(&p);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn recursion_depth_limited() {
        let p = parse("int f(int x) { return f(x); } void main() { f(1); }").unwrap();
        let mut it = Interp::new(&p);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn short_circuit_and() {
        // `i < 2 && out[i] ...` must not evaluate out[5] when i >= 2
        let (_, out) = run_owned(
            "float out[2]; void main() { int i; i = 5; \
             if (i < 2 && i / 0 > 0) { out[0] = 1.0; } else { out[1] = 1.0; } }",
        );
        assert_eq!(out[1], 1.0);
    }

    #[test]
    fn scoped_locals_shadow_and_expire() {
        // a block-local redeclaration shadows, then expires at scope end
        let (_, out) = run_owned(
            "float out[2]; void main() { int x; x = 1; \
             { int x; x = 9; out[0] = x; } out[1] = x; }",
        );
        assert_eq!(out, vec![9.0, 1.0]);
    }

    #[test]
    fn return_unwinds_nested_loops() {
        // `return` from inside a double loop must fully unwind the frame's
        // loop/scope state and still let the caller keep profiling cleanly
        let (prof, out) = run_owned(
            "float out[1]; \
             int find(int n) { int i; int j; \
               for (i = 0; i < n; i++) { for (j = 0; j < n; j++) { \
                 if (i * 10 + j == 23) { return i * 100 + j; } } } \
               return 0 - 1; } \
             void main() { int i; \
               for (i = 0; i < 3; i++) { out[0] += find(30); } }",
        );
        assert_eq!(out[0], 3.0 * 203.0);
        // the caller's loop profile is intact (3 iterations)
        let l = prof.loop_profile(LoopId(2)).unwrap();
        assert_eq!(l.iterations, 3);
    }
}
