//! Tree-walking evaluator with profiling hooks.
//!
//! Arrays live in an arena and are passed to functions **by reference**
//! (C array-parameter semantics); scalars are passed by value.  All
//! numeric storage is `i64`/`f64`; `float` arrays round-trip through `f64`
//! without loss for the value ranges MiniC apps use.

use std::collections::HashMap;

use crate::cparse::ast::*;
use crate::cparse::error::Pos;

use super::profile::{Footprint, LoopProfile, Profile};

/// Runtime scalar value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    /// Integer value (`int` variables and int literals).
    Int(i64),
    /// Floating value (`float`/`double` variables and float literals).
    Float(f64),
}

impl Value {
    /// Numeric value as `f64` (ints convert exactly).
    pub fn as_f64(self) -> f64 {
        match self {
            Value::Int(n) => n as f64,
            Value::Float(v) => v,
        }
    }

    /// Numeric value truncated to `i64` (C cast semantics).
    pub fn as_i64(self) -> i64 {
        match self {
            Value::Int(n) => n,
            Value::Float(v) => v as i64,
        }
    }

    fn truthy(self) -> bool {
        match self {
            Value::Int(n) => n != 0,
            Value::Float(v) => v != 0.0,
        }
    }
}

/// Interpreter runtime error.
#[derive(Debug, Clone)]
pub struct InterpError {
    /// Human-readable description.
    pub message: String,
    /// Source position, when one is attributable.
    pub pos: Option<Pos>,
}

impl InterpError {
    fn new(message: impl Into<String>) -> Self {
        Self { message: message.into(), pos: None }
    }

    fn at(message: impl Into<String>, pos: Pos) -> Self {
        Self { message: message.into(), pos: Some(pos) }
    }
}

impl std::fmt::Display for InterpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.pos {
            Some(p) => write!(f, "runtime error at {p}: {}", self.message),
            None => write!(f, "runtime error: {}", self.message),
        }
    }
}

impl std::error::Error for InterpError {}

#[derive(Debug, Clone)]
struct ArrayObj {
    is_float: bool,
    data: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
enum Binding {
    Scalar(Value),
    Array(usize),
}

enum Flow {
    Normal,
    Return(Option<Value>),
}

/// Default interpreter step budget — generous for the paper workloads
/// (tdfir full scale ≈ 5M ops) while still catching runaway loops.
pub const DEFAULT_MAX_STEPS: u64 = 2_000_000_000;

/// The interpreter. One instance per program run.
pub struct Interp<'p> {
    program: &'p Program,
    arrays: Vec<ArrayObj>,
    globals: HashMap<String, Binding>,
    /// local bindings as one spaghetti stack: frames/scopes are just
    /// truncation marks and names borrow from the AST, so loop
    /// iterations allocate nothing
    locals: Vec<(&'p str, Binding)>,
    /// per-call-frame base offsets into `locals` (lookup boundary)
    frame_bases: Vec<usize>,
    overrides: HashMap<String, Value>,
    // profiling
    loop_counters: Vec<LoopProfile>,
    loop_stack: Vec<u32>,
    totals: Profile,
    steps: u64,
    max_steps: u64,
    globals_ready: bool,
}

impl<'p> Interp<'p> {
    /// Build an interpreter for one run of `program`.
    pub fn new(program: &'p Program) -> Self {
        let max_loop = {
            let mut m = 0u32;
            for f in &program.functions {
                for s in &f.body {
                    s.walk(&mut |s| {
                        if let Stmt::For { id, .. } | Stmt::While { id, .. } = s {
                            m = m.max(id.0 + 1);
                        }
                    });
                }
            }
            m
        };
        Self {
            program,
            arrays: Vec::new(),
            globals: HashMap::new(),
            locals: Vec::new(),
            frame_bases: Vec::new(),
            overrides: HashMap::new(),
            loop_counters: vec![LoopProfile::default(); max_loop as usize],
            loop_stack: Vec::new(),
            totals: Profile::default(),
            steps: 0,
            max_steps: DEFAULT_MAX_STEPS,
            globals_ready: false,
        }
    }

    /// Override a global scalar before the run (e.g. shrink a problem-size
    /// constant for tests: `set_global("N", Value::Int(64))`).
    pub fn set_global(&mut self, name: &str, value: Value) {
        self.overrides.insert(name.to_string(), value);
    }

    /// Override the runaway-loop step budget.
    pub fn set_max_steps(&mut self, max: u64) {
        self.max_steps = max;
    }

    /// Run `main()`.
    pub fn run_main(&mut self) -> Result<Option<Value>, InterpError> {
        self.call("main", &[])
    }

    /// Call a function by name with scalar arguments.
    pub fn call(&mut self, name: &str, args: &[Value]) -> Result<Option<Value>, InterpError> {
        self.init_globals()?;
        let program: &'p Program = self.program;
        let func = program
            .function(name)
            .ok_or_else(|| InterpError::new(format!("no function `{name}`")))?;
        if func.params.len() != args.len() {
            return Err(InterpError::new(format!(
                "`{name}` expects {} args, got {}",
                func.params.len(),
                args.len()
            )));
        }
        let bindings: Vec<(&'p str, Binding)> = func
            .params
            .iter()
            .zip(args)
            .map(|(p, v)| (p.name.as_str(), Binding::Scalar(*v)))
            .collect();
        self.call_with_bindings(func, bindings)
    }

    /// Read a global array's contents (output capture for verification).
    pub fn read_array(&mut self, name: &str) -> Result<Vec<f64>, InterpError> {
        self.init_globals()?;
        match self.globals.get(name) {
            Some(Binding::Array(h)) => Ok(self.arrays[*h].data.clone()),
            Some(Binding::Scalar(_)) => {
                Err(InterpError::new(format!("`{name}` is a scalar, not an array")))
            }
            None => Err(InterpError::new(format!("no global `{name}`"))),
        }
    }

    /// Read a global scalar.
    pub fn read_scalar(&mut self, name: &str) -> Result<Value, InterpError> {
        self.init_globals()?;
        match self.globals.get(name) {
            Some(Binding::Scalar(v)) => Ok(*v),
            _ => Err(InterpError::new(format!("no scalar global `{name}`"))),
        }
    }

    /// Finish and extract the dynamic profile.
    pub fn into_profile(mut self) -> Profile {
        for (i, lp) in self.loop_counters.into_iter().enumerate() {
            if lp.entries > 0 {
                self.totals.loops.insert(LoopId(i as u32), lp);
            }
        }
        self.totals.steps = self.steps;
        self.totals
    }

    // ---- internals --------------------------------------------------------

    fn init_globals(&mut self) -> Result<(), InterpError> {
        if self.globals_ready {
            return Ok(());
        }
        self.globals_ready = true;
        let program: &'p Program = self.program;
        for d in &program.globals {
            let b = self.make_binding(d)?;
            // apply override after the declared initializer
            let b = match (self.overrides.get(&d.name), &b) {
                (Some(v), Binding::Scalar(_)) => Binding::Scalar(*v),
                _ => b,
            };
            self.globals.insert(d.name.clone(), b);
        }
        Ok(())
    }

    fn make_binding(&mut self, d: &'p Decl) -> Result<Binding, InterpError> {
        match &d.ty {
            Type::Array(elem, len) => {
                // array lengths may reference already-bound globals
                let n = match len {
                    Some(n) => *n,
                    None => {
                        return Err(InterpError::at(
                            format!("array `{}` needs a length", d.name),
                            d.pos,
                        ))
                    }
                };
                let h = self.arrays.len();
                self.arrays.push(ArrayObj { is_float: elem.is_float(), data: vec![0.0; n] });
                Ok(Binding::Array(h))
            }
            ty => {
                let v = match &d.init {
                    Some(e) => self.eval(e)?,
                    None => Value::Int(0),
                };
                let v = if ty.is_float() {
                    Value::Float(v.as_f64())
                } else {
                    Value::Int(v.as_i64())
                };
                Ok(Binding::Scalar(v))
            }
        }
    }

    fn call_with_bindings(
        &mut self,
        func: &'p Function,
        bindings: Vec<(&'p str, Binding)>,
    ) -> Result<Option<Value>, InterpError> {
        if self.frame_bases.len() > 64 {
            return Err(InterpError::new("call stack overflow (depth > 64)"));
        }
        let base = self.locals.len();
        self.frame_bases.push(base);
        for (n, b) in bindings {
            self.locals.push((n, b));
        }
        let mut ret = None;
        for s in &func.body {
            if let Flow::Return(v) = self.exec(s)? {
                ret = v;
                break;
            }
        }
        self.locals.truncate(base);
        self.frame_bases.pop();
        Ok(ret)
    }

    fn lookup(&self, name: &str) -> Option<Binding> {
        let base = self.frame_bases.last().copied().unwrap_or(0);
        for (n, b) in self.locals[base..].iter().rev() {
            if *n == name {
                return Some(*b);
            }
        }
        self.globals.get(name).copied()
    }

    fn bind_local(&mut self, name: &'p str, b: Binding) {
        self.locals.push((name, b));
    }

    fn set_scalar(&mut self, name: &str, v: Value, pos: Pos) -> Result<(), InterpError> {
        let base = self.frame_bases.last().copied().unwrap_or(0);
        for (n, b) in self.locals[base..].iter_mut().rev() {
            if *n == name {
                match b {
                    Binding::Scalar(old) => {
                        // preserve declared int-ness
                        *old = match old {
                            Value::Int(_) => Value::Int(v.as_i64()),
                            Value::Float(_) => Value::Float(v.as_f64()),
                        };
                        return Ok(());
                    }
                    Binding::Array(_) => {
                        return Err(InterpError::at(
                            format!("cannot assign to array `{name}`"),
                            pos,
                        ))
                    }
                }
            }
        }
        if let Some(Binding::Scalar(old)) = self.globals.get_mut(name) {
            *old = match old {
                Value::Int(_) => Value::Int(v.as_i64()),
                Value::Float(_) => Value::Float(v.as_f64()),
            };
            return Ok(());
        }
        Err(InterpError::at(format!("assignment to undeclared `{name}`"), pos))
    }

    fn tick(&mut self, pos: Pos) -> Result<(), InterpError> {
        self.steps += 1;
        if self.steps > self.max_steps {
            return Err(InterpError::at(
                format!("step budget exhausted ({} steps)", self.max_steps),
                pos,
            ));
        }
        Ok(())
    }

    // profiling helpers ------------------------------------------------------

    #[inline]
    fn count_flops(&mut self, n: u64) {
        self.totals.total_flops += n;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].flops += n;
        }
    }

    #[inline]
    fn count_math(&mut self) {
        self.totals.total_math_calls += 1;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].math_calls += 1;
        }
    }

    #[inline]
    fn count_int_ops(&mut self, n: u64) {
        self.totals.total_int_ops += n;
        for &lid in &self.loop_stack {
            self.loop_counters[lid as usize].int_ops += n;
        }
    }

    fn count_access(&mut self, array: &str, idx: i64, elem_bytes: u64, write: bool) {
        if write {
            self.totals.total_mem_writes += 1;
        } else {
            self.totals.total_mem_reads += 1;
        }
        for &lid in &self.loop_stack {
            let lp = &mut self.loop_counters[lid as usize];
            if write {
                lp.mem_writes += 1;
            } else {
                lp.mem_reads += 1;
            }
            // hot path: avoid allocating the key on every access — only
            // the first touch of an array inside a loop inserts
            if let Some(fp) = lp.footprints.get_mut(array) {
                fp.min_idx = fp.min_idx.min(idx);
                fp.max_idx = fp.max_idx.max(idx);
                fp.accesses += 1;
            } else {
                lp.footprints.insert(
                    array.to_string(),
                    Footprint { min_idx: idx, max_idx: idx, elem_bytes, accesses: 1 },
                );
            }
        }
    }

    // execution --------------------------------------------------------------

    fn exec(&mut self, s: &'p Stmt) -> Result<Flow, InterpError> {
        match s {
            Stmt::Decl(d) => {
                self.tick(d.pos)?;
                let b = self.make_binding(d)?;
                self.bind_local(&d.name, b);
                Ok(Flow::Normal)
            }
            Stmt::Assign { target, op, value, pos } => {
                self.tick(*pos)?;
                self.exec_assign(target, *op, value, *pos)?;
                Ok(Flow::Normal)
            }
            Stmt::If { cond, then_branch, else_branch, pos } => {
                self.tick(*pos)?;
                let c = self.eval(cond)?;
                let branch = if c.truthy() { then_branch } else { else_branch };
                self.exec_scoped(branch)
            }
            Stmt::For { id, header, body, pos } => {
                self.tick(*pos)?;
                self.exec_for(*id, header, body, *pos)
            }
            Stmt::While { id, cond, body, pos } => {
                self.tick(*pos)?;
                self.exec_while(*id, cond, body, *pos)
            }
            Stmt::Return(e, pos) => {
                self.tick(*pos)?;
                let v = match e {
                    Some(e) => Some(self.eval(e)?),
                    None => None,
                };
                Ok(Flow::Return(v))
            }
            Stmt::Expr(e, pos) => {
                self.tick(*pos)?;
                self.eval(e)?;
                Ok(Flow::Normal)
            }
            Stmt::Block(body) => self.exec_scoped(body),
        }
    }

    fn exec_scoped(&mut self, body: &'p [Stmt]) -> Result<Flow, InterpError> {
        let mark = self.locals.len();
        let mut flow = Flow::Normal;
        for s in body {
            match self.exec(s)? {
                Flow::Normal => {}
                r @ Flow::Return(_) => {
                    flow = r;
                    break;
                }
            }
        }
        self.locals.truncate(mark);
        Ok(flow)
    }

    fn exec_for(
        &mut self,
        id: LoopId,
        header: &'p ForHeader,
        body: &'p [Stmt],
        _pos: Pos,
    ) -> Result<Flow, InterpError> {
        self.loop_counters[id.0 as usize].entries += 1;
        // header scope (for decl-in-init)
        let mark = self.locals.len();
        let mut flow = Flow::Normal;
        if let Some(init) = &header.init {
            if let Flow::Return(v) = self.exec(init)? {
                self.locals.truncate(mark);
                return Ok(Flow::Return(v));
            }
        }
        loop {
            if let Some(cond) = &header.cond {
                if !self.eval(cond)?.truthy() {
                    break;
                }
            }
            self.loop_counters[id.0 as usize].iterations += 1;
            self.loop_stack.push(id.0);
            let f = self.exec_scoped(body);
            self.loop_stack.pop();
            match f? {
                Flow::Normal => {}
                r @ Flow::Return(_) => {
                    flow = r;
                    break;
                }
            }
            if let Some(step) = &header.step {
                self.loop_stack.push(id.0);
                let f = self.exec(step);
                self.loop_stack.pop();
                if let Flow::Return(v) = f? {
                    flow = Flow::Return(v);
                    break;
                }
            }
        }
        self.locals.truncate(mark);
        Ok(flow)
    }

    fn exec_while(
        &mut self,
        id: LoopId,
        cond: &'p Expr,
        body: &'p [Stmt],
        _pos: Pos,
    ) -> Result<Flow, InterpError> {
        self.loop_counters[id.0 as usize].entries += 1;
        loop {
            if !self.eval(cond)?.truthy() {
                return Ok(Flow::Normal);
            }
            self.loop_counters[id.0 as usize].iterations += 1;
            self.loop_stack.push(id.0);
            let f = self.exec_scoped(body);
            self.loop_stack.pop();
            if let r @ Flow::Return(_) = f? {
                return Ok(r);
            }
        }
    }

    fn exec_assign(
        &mut self,
        target: &LValue,
        op: AssignOp,
        value: &Expr,
        pos: Pos,
    ) -> Result<(), InterpError> {
        let rhs = self.eval(value)?;
        match target {
            LValue::Var(name) => {
                let new = if op == AssignOp::Assign {
                    rhs
                } else {
                    let old = match self.lookup(name) {
                        Some(Binding::Scalar(v)) => v,
                        _ => return Err(InterpError::at(format!("no scalar `{name}`"), pos)),
                    };
                    self.apply_compound(old, op, rhs)
                };
                self.set_scalar(name, new, pos)
            }
            LValue::Index(name, idx) => {
                let i = self.eval(idx)?.as_i64();
                let h = match self.lookup(name) {
                    Some(Binding::Array(h)) => h,
                    _ => return Err(InterpError::at(format!("no array `{name}`"), pos)),
                };
                let (len, is_float) = (self.arrays[h].data.len(), self.arrays[h].is_float);
                if i < 0 || i as usize >= len {
                    return Err(InterpError::at(
                        format!("index {i} out of bounds for `{name}[{len}]`"),
                        pos,
                    ));
                }
                let elem_bytes = if is_float { 4 } else { 4 };
                let new = if op == AssignOp::Assign {
                    rhs
                } else {
                    let old = self.arrays[h].data[i as usize];
                    self.count_access(name, i, elem_bytes, false);
                    let old = if is_float { Value::Float(old) } else { Value::Int(old as i64) };
                    self.apply_compound(old, op, rhs)
                };
                self.count_access(name, i, elem_bytes, true);
                self.arrays[h].data[i as usize] = if is_float {
                    new.as_f64()
                } else {
                    new.as_i64() as f64
                };
                Ok(())
            }
        }
    }

    fn apply_compound(&mut self, old: Value, op: AssignOp, rhs: Value) -> Value {
        let bop = match op {
            AssignOp::AddAssign => BinOp::Add,
            AssignOp::SubAssign => BinOp::Sub,
            AssignOp::MulAssign => BinOp::Mul,
            AssignOp::DivAssign => BinOp::Div,
            AssignOp::Assign => unreachable!(),
        };
        self.apply_bin(bop, old, rhs)
    }

    fn apply_bin(&mut self, op: BinOp, a: Value, b: Value) -> Value {
        use BinOp::*;
        let float = matches!(a, Value::Float(_)) || matches!(b, Value::Float(_));
        if op.is_arith() {
            if float {
                self.count_flops(1);
                let (x, y) = (a.as_f64(), b.as_f64());
                Value::Float(match op {
                    Add => x + y,
                    Sub => x - y,
                    Mul => x * y,
                    Div => x / y,
                    Mod => x % y,
                    _ => unreachable!(),
                })
            } else {
                self.count_int_ops(1);
                let (x, y) = (a.as_i64(), b.as_i64());
                Value::Int(match op {
                    Add => x.wrapping_add(y),
                    Sub => x.wrapping_sub(y),
                    Mul => x.wrapping_mul(y),
                    Div => {
                        if y == 0 { 0 } else { x / y }
                    }
                    Mod => {
                        if y == 0 { 0 } else { x % y }
                    }
                    _ => unreachable!(),
                })
            }
        } else {
            self.count_int_ops(1);
            let t = if float {
                let (x, y) = (a.as_f64(), b.as_f64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    And => a.truthy() && b.truthy(),
                    Or => a.truthy() || b.truthy(),
                    _ => unreachable!(),
                }
            } else {
                let (x, y) = (a.as_i64(), b.as_i64());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Gt => x > y,
                    Ge => x >= y,
                    Eq => x == y,
                    Ne => x != y,
                    And => x != 0 && y != 0,
                    Or => x != 0 || y != 0,
                    _ => unreachable!(),
                }
            };
            Value::Int(t as i64)
        }
    }

    fn eval(&mut self, e: &Expr) -> Result<Value, InterpError> {
        match e {
            Expr::IntLit(n) => Ok(Value::Int(*n)),
            Expr::FloatLit(v) => Ok(Value::Float(*v)),
            Expr::Var(name) => match self.lookup(name) {
                Some(Binding::Scalar(v)) => Ok(v),
                Some(Binding::Array(_)) => {
                    Err(InterpError::new(format!("array `{name}` used as scalar")))
                }
                None => Err(InterpError::new(format!("undeclared variable `{name}`"))),
            },
            Expr::Index(name, idx) => {
                let i = self.eval(idx)?.as_i64();
                let h = match self.lookup(name) {
                    Some(Binding::Array(h)) => h,
                    _ => return Err(InterpError::new(format!("no array `{name}`"))),
                };
                let arr = &self.arrays[h];
                let len = arr.data.len();
                if i < 0 || i as usize >= len {
                    return Err(InterpError::new(format!(
                        "index {i} out of bounds for `{name}[{len}]`"
                    )));
                }
                let is_float = arr.is_float;
                let v = arr.data[i as usize];
                self.count_access(name, i, 4, false);
                Ok(if is_float { Value::Float(v) } else { Value::Int(v as i64) })
            }
            Expr::Unary(op, a) => {
                let v = self.eval(a)?;
                match op {
                    UnOp::Neg => match v {
                        Value::Int(n) => {
                            self.count_int_ops(1);
                            Ok(Value::Int(-n))
                        }
                        Value::Float(f) => {
                            self.count_flops(1);
                            Ok(Value::Float(-f))
                        }
                    },
                    UnOp::Not => {
                        self.count_int_ops(1);
                        Ok(Value::Int(!v.truthy() as i64))
                    }
                }
            }
            Expr::Binary(op, a, b) => {
                // short-circuit logical ops
                if matches!(op, BinOp::And | BinOp::Or) {
                    let va = self.eval(a)?;
                    self.count_int_ops(1);
                    return Ok(match (op, va.truthy()) {
                        (BinOp::And, false) => Value::Int(0),
                        (BinOp::Or, true) => Value::Int(1),
                        _ => Value::Int(self.eval(b)?.truthy() as i64),
                    });
                }
                let va = self.eval(a)?;
                let vb = self.eval(b)?;
                Ok(self.apply_bin(*op, va, vb))
            }
            Expr::Call(name, args) => self.eval_call(name, args),
        }
    }

    fn eval_call(&mut self, name: &str, args: &[Expr]) -> Result<Value, InterpError> {
        // builtins first
        if crate::ir::varref::is_builtin(name) {
            let mut vals = Vec::with_capacity(args.len());
            for a in args {
                vals.push(self.eval(a)?.as_f64());
            }
            self.count_math();
            let v = match (name, vals.as_slice()) {
                ("sin", [x]) => x.sin(),
                ("cos", [x]) => x.cos(),
                ("sqrt", [x]) => x.sqrt(),
                ("fabs", [x]) => x.abs(),
                ("exp", [x]) => x.exp(),
                ("floor", [x]) => x.floor(),
                ("fmin", [x, y]) => x.min(*y),
                ("fmax", [x, y]) => x.max(*y),
                _ => {
                    return Err(InterpError::new(format!(
                        "builtin `{name}` called with {} args",
                        vals.len()
                    )))
                }
            };
            return Ok(Value::Float(v));
        }
        let program: &'p Program = self.program;
        let func = program
            .function(name)
            .ok_or_else(|| InterpError::new(format!("no function `{name}`")))?;
        if func.params.len() != args.len() {
            return Err(InterpError::new(format!(
                "`{name}` expects {} args, got {}",
                func.params.len(),
                args.len()
            )));
        }
        let mut bindings = Vec::with_capacity(args.len());
        for (p, a) in func.params.iter().zip(args) {
            let b = if p.ty.is_array() {
                // arrays pass by reference: argument must be a bare name
                match a {
                    Expr::Var(an) => match self.lookup(an) {
                        Some(b @ Binding::Array(_)) => b,
                        _ => {
                            return Err(InterpError::new(format!(
                                "`{an}` is not an array (argument to `{name}`)"
                            )))
                        }
                    },
                    _ => {
                        return Err(InterpError::new(format!(
                            "array argument to `{name}` must be a variable"
                        )))
                    }
                }
            } else {
                let v = self.eval(a)?;
                let v = if p.ty.is_float() {
                    Value::Float(v.as_f64())
                } else {
                    Value::Int(v.as_i64())
                };
                Binding::Scalar(v)
            };
            bindings.push((p.name.as_str(), b));
        }
        let ret = self.call_with_bindings(func, bindings)?;
        Ok(ret.unwrap_or(Value::Int(0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;

    fn run_owned(src: &str) -> (Profile, Vec<f64>) {
        let p = parse(src).unwrap();
        let mut it = Interp::new(&p);
        it.run_main().unwrap();
        let out = it.read_array("out").unwrap_or_default();
        (it.into_profile(), out)
    }

    #[test]
    fn arithmetic_and_output() {
        let (_, out) = run_owned(
            "float out[4]; void main() { int i; \
             for (i = 0; i < 4; i++) { out[i] = i * 2.0 + 1.0; } }",
        );
        assert_eq!(out, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn trip_counts_recorded() {
        let (prof, _) = run_owned(
            "float out[1]; void main() { int i; int j; \
             for (i = 0; i < 10; i++) { for (j = 0; j < 5; j++) { out[0] += 1.0; } } }",
        );
        let l0 = prof.loop_profile(LoopId(0)).unwrap();
        let l1 = prof.loop_profile(LoopId(1)).unwrap();
        assert_eq!(l0.entries, 1);
        assert_eq!(l0.iterations, 10);
        assert_eq!(l1.entries, 10);
        assert_eq!(l1.iterations, 50);
        // inner flops roll up into the outer loop
        assert_eq!(l1.flops, 50);
        assert_eq!(l0.flops, 50);
    }

    #[test]
    fn footprint_ranges() {
        let (prof, _) = run_owned(
            "float out[100]; void main() { int i; \
             for (i = 10; i < 20; i++) { out[i] = 1.0; } }",
        );
        let l0 = prof.loop_profile(LoopId(0)).unwrap();
        let fp = &l0.footprints["out"];
        assert_eq!((fp.min_idx, fp.max_idx), (10, 19));
        assert_eq!(fp.bytes(), 40);
        assert_eq!(l0.mem_writes, 10);
    }

    #[test]
    fn function_calls_and_returns() {
        let (_, out) = run_owned(
            "float out[1]; \
             float square(float x) { return x * x; } \
             void main() { out[0] = square(3.0) + square(4.0); }",
        );
        assert_eq!(out[0], 25.0);
    }

    #[test]
    fn arrays_pass_by_reference() {
        let (_, out) = run_owned(
            "float out[3]; \
             void fill(float a[], int n, float v) { int i; \
               for (i = 0; i < n; i++) { a[i] = v; } } \
             void main() { fill(out, 3, 7.0); }",
        );
        assert_eq!(out, vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn builtins_work() {
        let (_, out) = run_owned(
            "float out[3]; void main() { \
             out[0] = sqrt(16.0); out[1] = fabs(-2.5); out[2] = fmax(1.0, 2.0); }",
        );
        assert_eq!(out, vec![4.0, 2.5, 2.0]);
    }

    #[test]
    fn int_semantics_truncate() {
        let (_, out) = run_owned(
            "float out[2]; void main() { int a; a = 7 / 2; out[0] = a; out[1] = 7 % 2; }",
        );
        assert_eq!(out, vec![3.0, 1.0]);
    }

    #[test]
    fn while_and_if() {
        let (_, out) = run_owned(
            "float out[1]; void main() { int n; n = 10; \
             while (n > 0) { if (n % 2 == 0) { out[0] += 1.0; } n -= 1; } }",
        );
        assert_eq!(out[0], 5.0);
    }

    #[test]
    fn global_override() {
        let p = parse(
            "int N = 100; float out[100]; void main() { int i; \
             for (i = 0; i < N; i++) { out[i] = 1.0; } }",
        )
        .unwrap();
        let mut it = Interp::new(&p);
        it.set_global("N", Value::Int(5));
        it.run_main().unwrap();
        let out = it.read_array("out").unwrap();
        assert_eq!(out.iter().filter(|v| **v == 1.0).count(), 5);
    }

    #[test]
    fn step_budget_catches_infinite_loop() {
        let p = parse("void main() { int i; i = 0; while (i < 1) { i = 0; } }").unwrap();
        let mut it = Interp::new(&p);
        it.set_max_steps(10_000);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn out_of_bounds_is_error() {
        let p = parse("float out[2]; void main() { out[5] = 1.0; }").unwrap();
        let mut it = Interp::new(&p);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn recursion_depth_limited() {
        let p = parse("int f(int x) { return f(x); } void main() { f(1); }").unwrap();
        let mut it = Interp::new(&p);
        assert!(it.run_main().is_err());
    }

    #[test]
    fn short_circuit_and() {
        // `i < 2 && out[i] ...` must not evaluate out[5] when i >= 2
        let (_, out) = run_owned(
            "float out[2]; void main() { int i; i = 5; \
             if (i < 2 && i / 0 > 0) { out[0] = 1.0; } else { out[1] = 1.0; } }",
        );
        assert_eq!(out[1], 1.0);
    }
}
