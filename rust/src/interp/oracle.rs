//! Dynamic dependence oracle: per-iteration read/write set recording.
//!
//! When enabled on an [`super::Interp`], every loop entry pushes an
//! oracle frame that maps each touched location — `(array, index)`
//! cells and scalar names — to the iteration that last read/wrote it.
//! A read of a cell written in an *earlier* iteration of the same loop
//! is an observed flow dependence; a write over an earlier read is an
//! anti dependence; a write over an earlier write is an output
//! dependence.  Scalar write/write pairs are deliberately *not*
//! flagged: last-value scalar escape is legal for a parallel counted
//! loop in this model, and the loop counter itself is exempt inside
//! its own frame.
//!
//! The oracle is ground truth for the static engine's soundness: a loop
//! the engine calls `Parallel` must show **no** conflicts in any run,
//! and a `Reduction` loop may conflict only on its reduction scalars.
//! The generative suite enforces exactly that as its seventh invariant.

use std::collections::{BTreeSet, HashMap, HashSet};

use crate::cparse::ast::LoopId;
use crate::cparse::Program;
use crate::ir::loops;
use crate::util::intern::Symbol;

/// Loop-carried conflicts the oracle observed for one loop.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LoopConflicts {
    /// Arrays with an observed cross-iteration flow/anti/output conflict.
    pub arrays: BTreeSet<Symbol>,
    /// Scalars with an observed cross-iteration flow/anti conflict.
    pub scalars: BTreeSet<Symbol>,
}

impl LoopConflicts {
    /// No conflicts at all?
    pub fn is_empty(&self) -> bool {
        self.arrays.is_empty() && self.scalars.is_empty()
    }
}

/// One active loop's recording frame.
struct OFrame {
    lid: u32,
    /// Current iteration (−1 while the header init/first check runs).
    iter: i64,
    /// The loop's own counter, exempt from scalar tracking.
    counter: Option<Symbol>,
    /// `(array handle, index)` → last writing / reading iteration.
    array_writes: HashMap<(usize, i64), i64>,
    array_reads: HashMap<(usize, i64), i64>,
    scalar_writes: HashMap<Symbol, i64>,
    scalar_reads: HashMap<Symbol, i64>,
    /// Names declared inside the loop body: private, never tracked.
    private: HashSet<Symbol>,
}

/// Recording state attached to an interpreter run.
pub(super) struct OracleState {
    frames: Vec<OFrame>,
    /// Per-loop conflict sets, indexed by `LoopId` value.
    conflicts: Vec<LoopConflicts>,
    /// Per-loop canonical counter, indexed by `LoopId` value.
    counters: Vec<Option<Symbol>>,
}

impl OracleState {
    pub(super) fn new(program: &Program, max_loop: u32) -> OracleState {
        let mut counters = vec![None; max_loop as usize];
        for info in loops::extract(program) {
            if let Some(can) = &info.canonical {
                counters[info.id.0 as usize] = Some(can.var);
            }
        }
        OracleState {
            frames: Vec::new(),
            conflicts: vec![LoopConflicts::default(); max_loop as usize],
            counters,
        }
    }

    pub(super) fn frames_len(&self) -> usize {
        self.frames.len()
    }

    pub(super) fn truncate_frames(&mut self, len: usize) {
        self.frames.truncate(len);
    }

    pub(super) fn push_frame(&mut self, lid: u32) {
        self.frames.push(OFrame {
            lid,
            iter: -1,
            counter: self.counters.get(lid as usize).copied().flatten(),
            array_writes: HashMap::new(),
            array_reads: HashMap::new(),
            scalar_writes: HashMap::new(),
            scalar_reads: HashMap::new(),
            private: HashSet::new(),
        });
    }

    pub(super) fn pop_frame(&mut self) {
        self.frames.pop();
    }

    /// Begin the next iteration of the innermost active frame for `lid`.
    pub(super) fn bump_iter(&mut self, lid: u32) {
        if let Some(f) = self.frames.iter_mut().rev().find(|f| f.lid == lid) {
            f.iter += 1;
        }
    }

    /// A declaration executed: the name is private to every active loop.
    pub(super) fn mark_private(&mut self, name: Symbol) {
        for f in &mut self.frames {
            f.private.insert(name);
        }
    }

    pub(super) fn array_read(&mut self, name: Symbol, handle: usize, idx: i64) {
        for fi in 0..self.frames.len() {
            let f = &mut self.frames[fi];
            if f.private.contains(&name) {
                continue;
            }
            let key = (handle, idx);
            let cur = f.iter;
            let (lid, hit) = (f.lid, f.array_writes.get(&key).map_or(false, |w| *w != cur));
            f.array_reads.insert(key, cur);
            if hit {
                self.conflicts[lid as usize].arrays.insert(name); // flow
            }
        }
    }

    pub(super) fn array_write(&mut self, name: Symbol, handle: usize, idx: i64) {
        for fi in 0..self.frames.len() {
            let f = &mut self.frames[fi];
            if f.private.contains(&name) {
                continue;
            }
            let key = (handle, idx);
            let cur = f.iter;
            // anti (earlier read) or output (earlier write)
            let hit = f.array_reads.get(&key).map_or(false, |r| *r != cur)
                || f.array_writes.get(&key).map_or(false, |w| *w != cur);
            let lid = f.lid;
            f.array_writes.insert(key, cur);
            if hit {
                self.conflicts[lid as usize].arrays.insert(name);
            }
        }
    }

    pub(super) fn scalar_read(&mut self, name: Symbol) {
        for fi in 0..self.frames.len() {
            let f = &mut self.frames[fi];
            if f.private.contains(&name) || f.counter == Some(name) {
                continue;
            }
            let cur = f.iter;
            let (lid, hit) = (f.lid, f.scalar_writes.get(&name).map_or(false, |w| *w != cur));
            f.scalar_reads.insert(name, cur);
            if hit {
                self.conflicts[lid as usize].scalars.insert(name); // flow
            }
        }
    }

    pub(super) fn scalar_write(&mut self, name: Symbol) {
        for fi in 0..self.frames.len() {
            let f = &mut self.frames[fi];
            if f.private.contains(&name) || f.counter == Some(name) {
                continue;
            }
            let cur = f.iter;
            let (lid, hit) = (f.lid, f.scalar_reads.get(&name).map_or(false, |r| *r != cur));
            f.scalar_writes.insert(name, cur);
            // scalar write/write is NOT a conflict: last-value escape is
            // legal for a parallel loop in this model
            if hit {
                self.conflicts[lid as usize].scalars.insert(name); // anti
            }
        }
    }

    /// Conflicts observed for one loop (empty set if none).
    pub(super) fn conflicts_for(&self, lid: LoopId) -> Option<&LoopConflicts> {
        self.conflicts.get(lid.0 as usize)
    }

    /// All loops with at least one observed conflict.
    pub(super) fn all_conflicts(&self) -> Vec<(LoopId, LoopConflicts)> {
        self.conflicts
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.is_empty())
            .map(|(i, c)| (LoopId(i as u32), c.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::Interp;
    use super::*;
    use crate::cparse::parse;

    fn conflicts(src: &str) -> Vec<(LoopId, LoopConflicts)> {
        let p = parse(src).unwrap();
        let mut it = Interp::new(&p);
        it.enable_oracle(&p);
        it.run_main().unwrap();
        it.oracle_report()
    }

    #[test]
    fn elementwise_loop_is_clean() {
        let r = conflicts(
            "float out[8]; void main() { int i; \
             for (i = 0; i < 8; i++) { out[i] = i * 2.0; } }",
        );
        assert!(r.is_empty(), "{r:?}");
    }

    #[test]
    fn recurrence_flags_a_flow_conflict() {
        let r = conflicts(
            "float a[8]; void main() { int i; a[0] = 1.0; \
             for (i = 1; i < 8; i++) { a[i] = a[i - 1]; } }",
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, LoopId(0));
        assert!(r[0].1.arrays.contains(&Symbol::intern("a")), "{r:?}");
        assert!(r[0].1.scalars.is_empty());
    }

    #[test]
    fn reduction_conflicts_only_on_the_accumulator() {
        let r = conflicts(
            "float a[8]; float s; void main() { int i; \
             for (i = 0; i < 8; i++) { a[i] = 1.0; } \
             for (i = 0; i < 8; i++) { s += a[i]; } }",
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, LoopId(1));
        assert!(r[0].1.arrays.is_empty(), "{r:?}");
        assert_eq!(
            r[0].1.scalars.iter().copied().collect::<Vec<_>>(),
            vec![Symbol::intern("s")]
        );
    }

    #[test]
    fn decl_in_init_counters_stay_private_to_outer_frames() {
        // matmul-style nest: inner counters declared in the for-init are
        // re-declared every outer iteration, so the outer loop must not
        // see their churn as a carried scalar dependence
        let r = conflicts(
            "float c[16]; float acc; void main() { int i; \
             for (i = 0; i < 4; i++) { \
               for (int j = 0; j < 4; j++) { float t; t = i * 4.0 + j; \
                 c[i * 4 + j] = t; } } }",
        );
        assert!(
            !r.iter().any(|(id, _)| *id == LoopId(0)),
            "outer loop must be clean: {r:?}"
        );
    }

    #[test]
    fn function_top_counter_is_carried_for_the_outer_loop() {
        // same nest, but `j` lives at function scope: every outer
        // iteration rewrites a scalar the previous iteration read
        let r = conflicts(
            "float c[16]; void main() { int i; int j; \
             for (i = 0; i < 4; i++) { \
               for (j = 0; j < 4; j++) { c[i * 4 + j] = 1.0; } } }",
        );
        let outer = r.iter().find(|(id, _)| *id == LoopId(0)).expect("outer conflict");
        assert!(outer.1.scalars.contains(&Symbol::intern("j")), "{r:?}");
    }

    #[test]
    fn while_recurrence_flags_the_scalar() {
        let r = conflicts(
            "float out[1]; void main() { int n; n = 5; \
             while (n > 0) { n -= 1; } out[0] = n; }",
        );
        assert_eq!(r.len(), 1);
        assert!(r[0].1.scalars.contains(&Symbol::intern("n")), "{r:?}");
    }

    #[test]
    fn return_inside_a_loop_unwinds_oracle_frames() {
        // find() returns out of a running loop; the caller's loop then
        // continues — frame bookkeeping must stay balanced and the
        // caller's elementwise writes must stay clean
        let r = conflicts(
            "float out[4]; \
             int find(int n) { int i; \
               for (i = 0; i < n; i++) { if (i == 2) { return i; } } \
               return 0 - 1; } \
             void main() { int i; \
               for (i = 0; i < 4; i++) { out[i] = find(10); } }",
        );
        assert!(
            !r.iter().any(|(id, _)| *id == LoopId(1)),
            "caller loop must be clean: {r:?}"
        );
    }

    #[test]
    fn disabled_oracle_reports_nothing() {
        let p = parse(
            "float a[4]; void main() { int i; \
             for (i = 1; i < 4; i++) { a[i] = a[i - 1]; } }",
        )
        .unwrap();
        let mut it = Interp::new(&p);
        it.run_main().unwrap();
        assert!(it.oracle_report().is_empty());
        assert!(it.oracle_conflicts(LoopId(0)).is_none());
    }
}
