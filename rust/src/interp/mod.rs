//! MiniC interpreter + dynamic profiler.
//!
//! Substrate for two things the paper gets from its toolchain:
//!
//! 1. **Dynamic profiling** (the paper: gcov/gprof trip counts + the PGI
//!    compiler's arithmetic-intensity analysis).  Running the application
//!    on its sample data yields, per loop statement: entries, iterations,
//!    float/int op counts, memory traffic, and the array *footprint*
//!    (min..max index range per array) — everything [`crate::intensity`]
//!    needs.
//! 2. **CPU-side numerics** for the verification environment: the
//!    interpreter's outputs are the all-CPU reference the FPGA-offloaded
//!    (PJRT-executed) variant must match.
//! 3. **Dynamic dependence oracle** ([`oracle`]): opt-in per-iteration
//!    read/write set recording that observes loop-carried conflicts —
//!    the ground truth the generative suite validates the static
//!    dependence engine ([`crate::analyze`]) against.

pub mod eval;
pub mod oracle;
pub mod profile;

pub use eval::{Interp, InterpError, Value};
pub use oracle::LoopConflicts;
pub use profile::{LoopProfile, Profile};

use crate::cparse::Program;

/// Convenience: run `main()` and return the profile.
pub fn profile_program(program: &Program) -> Result<Profile, InterpError> {
    let mut interp = Interp::new(program);
    interp.run_main()?;
    Ok(interp.into_profile())
}
