//! Per-loop dynamic profile counters.
//!
//! Counters are attributed to **every loop on the active loop stack**, so
//! an outer loop's numbers include its inner loops — matching how the
//! paper treats a nested loop statement as one offloadable unit.

use std::collections::BTreeMap;

use crate::cparse::ast::LoopId;
use crate::util::intern::Symbol;

/// Footprint of one array inside one loop: contiguous index range touched.
/// (min..=max is the right approximation for the affine accesses MiniC
/// apps make; the HLS local-memory sizing uses it too.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Footprint {
    /// Smallest index touched.
    pub min_idx: i64,
    /// Largest index touched.
    pub max_idx: i64,
    /// Bytes per element (4 for the MiniC f32 model).
    pub elem_bytes: u64,
    /// raw access count (reads + writes)
    pub accesses: u64,
}

impl Footprint {
    /// Distinct bytes covered by the min..=max index range.
    pub fn bytes(&self) -> u64 {
        if self.max_idx < self.min_idx {
            0
        } else {
            (self.max_idx - self.min_idx + 1) as u64 * self.elem_bytes
        }
    }
}

/// Dynamic counters for one loop statement.
#[derive(Debug, Clone, Default)]
pub struct LoopProfile {
    /// times the loop statement was entered
    pub entries: u64,
    /// total iterations across all entries
    pub iterations: u64,
    /// floating-point arithmetic ops (adds/subs/muls/divs)
    pub flops: u64,
    /// builtin math calls (sin/cos/sqrt/...), counted separately: they
    /// cost tens of CPU cycles but one pipelined FPGA core
    pub math_calls: u64,
    /// integer arithmetic ops
    pub int_ops: u64,
    /// array element reads / writes
    pub mem_reads: u64,
    /// Array element writes.
    pub mem_writes: u64,
    /// per-array footprint (index ranges, keyed by the access-site name)
    pub footprints: BTreeMap<Symbol, Footprint>,
}

impl LoopProfile {
    /// Total bytes moved by array accesses (counting each access).
    pub fn traffic_bytes(&self) -> u64 {
        self.footprints
            .values()
            .map(|f| f.accesses * f.elem_bytes)
            .sum()
    }

    /// Distinct bytes touched — the "data size" term of the paper's
    /// arithmetic intensity (and the H2D/D2H transfer size on offload).
    pub fn footprint_bytes(&self) -> u64 {
        self.footprints.values().map(Footprint::bytes).sum()
    }

    /// All float work including builtin math calls.
    pub fn total_flops(&self) -> u64 {
        self.flops + self.math_calls
    }
}

/// Whole-program dynamic profile.
#[derive(Debug, Clone, Default)]
pub struct Profile {
    /// Per-loop counters for every loop that executed at least once.
    pub loops: BTreeMap<LoopId, LoopProfile>,
    /// program-wide totals (for the all-CPU baseline time)
    pub total_flops: u64,
    /// Program-wide builtin math calls.
    pub total_math_calls: u64,
    /// Program-wide integer ops.
    pub total_int_ops: u64,
    /// Program-wide array element reads.
    pub total_mem_reads: u64,
    /// Program-wide array element writes.
    pub total_mem_writes: u64,
    /// interpreter steps executed (safety-valve metric)
    pub steps: u64,
}

impl Profile {
    /// Counters of one loop (None if it never executed).
    pub fn loop_profile(&self, id: LoopId) -> Option<&LoopProfile> {
        self.loops.get(&id)
    }

    /// Bytes moved program-wide (4 B/element nominal f32 traffic).
    pub fn total_traffic_bytes(&self) -> u64 {
        (self.total_mem_reads + self.total_mem_writes) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_bytes() {
        let f = Footprint { min_idx: 10, max_idx: 19, elem_bytes: 4, accesses: 100 };
        assert_eq!(f.bytes(), 40);
        let empty = Footprint { min_idx: 1, max_idx: 0, elem_bytes: 4, accesses: 0 };
        assert_eq!(empty.bytes(), 0);
    }

    #[test]
    fn traffic_vs_footprint() {
        let mut lp = LoopProfile::default();
        lp.footprints.insert(
            "a".into(),
            Footprint { min_idx: 0, max_idx: 99, elem_bytes: 4, accesses: 1000 },
        );
        assert_eq!(lp.footprint_bytes(), 400);
        assert_eq!(lp.traffic_bytes(), 4000);
    }
}
