//! FPGA kernel generation for one offloaded loop statement.
//!
//! Emits Intel-style **single-work-item** kernels (the Intel FPGA SDK's
//! preferred form: the compiler pipelines the loop nest, rather than
//! NDRange work-items).  Acceleration idioms applied:
//!
//! * `restrict`-qualified `__global` pointers (enables pipelining);
//! * `#pragma unroll b` on the innermost loop when `b > 1`;
//! * recognized `+`-reductions are rewritten through a shift-register
//!   accumulator (`SR_LEN`-deep), the documented aocl idiom that breaks
//!   the accumulation dependency and restores II=1.

use std::collections::HashMap;

use crate::cparse::ast::{LoopId, Type};
use crate::cparse::pretty;
use crate::cparse::Program;
use crate::ir::LoopAnalysis;
use crate::util::intern::Symbol;

/// Shift-register depth used for reduction rewriting (fp32 add latency on
/// Arria10 is ~3-4 cycles; 8 gives headroom, matching Intel's examples).
pub const SR_LEN: usize = 8;

/// One kernel argument.
#[derive(Debug, Clone)]
pub struct KernelArg {
    /// Argument name (the source variable it mirrors).
    pub name: String,
    /// OpenCL type text (e.g. `__global float* restrict` or `const int`).
    pub decl: String,
    /// Is this a `__global` buffer argument?
    pub is_array: bool,
    /// element type for arrays
    pub elem: Type,
}

/// Generated kernel source + metadata the HLS estimator and the host
/// generator need.
#[derive(Debug, Clone)]
pub struct KernelSource {
    /// The offloaded loop statement.
    pub loop_id: LoopId,
    /// Kernel symbol name (`loop_<id>`).
    pub name: String,
    /// The `.cl` source of this kernel.
    pub code: String,
    /// Kernel arguments in declaration order.
    pub args: Vec<KernelArg>,
    /// Unroll factor the kernel was generated for.
    pub unroll: usize,
    /// reductions rewritten through shift registers
    pub shift_register_reductions: Vec<String>,
}

/// Map every name visible in `function` to its type (globals shadowed by
/// params shadowed by locals — good enough for MiniC's flat scoping).
pub fn type_env(program: &Program, function: Symbol) -> HashMap<Symbol, Type> {
    let mut env = HashMap::new();
    for g in &program.globals {
        env.insert(g.name, g.ty.clone());
    }
    if let Some(f) = program.function(function.as_str()) {
        for p in &f.params {
            env.insert(p.name, p.ty.clone());
        }
        for s in &f.body {
            s.walk(&mut |s| {
                if let crate::cparse::Stmt::Decl(d) = s {
                    env.insert(d.name, d.ty.clone());
                }
            });
        }
    }
    env
}

fn ocl_scalar_type(ty: &Type) -> &'static str {
    match ty {
        Type::Int => "int",
        Type::Float => "float",
        Type::Double => "double",
        Type::Void => "void",
        Type::Array(t, _) => ocl_scalar_type(t),
    }
}

/// Generate the kernel for one offloadable loop.
pub fn generate_kernel(
    program: &Program,
    la: &LoopAnalysis,
    unroll: usize,
) -> KernelSource {
    let env = type_env(program, la.info.function);
    let name = format!("loop_{}", la.info.id.0);

    // -- arguments: every touched array, then every free scalar ----------
    let mut args = Vec::new();
    for arr in la.refs.arrays() {
        let elem = env
            .get(&arr)
            .cloned()
            .unwrap_or(Type::Array(Box::new(Type::Float), None));
        let e = match &elem {
            Type::Array(t, _) => (**t).clone(),
            t => t.clone(),
        };
        args.push(KernelArg {
            decl: format!("__global {}* restrict {}", ocl_scalar_type(&e), arr),
            name: arr.to_string(),
            is_array: true,
            elem: e,
        });
    }
    for s in la.refs.free_scalars() {
        let ty = env.get(&s).cloned().unwrap_or(Type::Int);
        args.push(KernelArg {
            decl: format!("const {} {}", ocl_scalar_type(&ty), s),
            name: s.to_string(),
            is_array: false,
            elem: ty,
        });
    }

    // -- body -------------------------------------------------------------
    let mut body = String::new();
    // shift-register reductions (II=1 idiom)
    let sr_reds: Vec<String> = la.deps.reductions.iter()
        .filter(|r| r.op == '+')
        .map(|r| r.var.to_string())
        .collect();
    for var in &sr_reds {
        body.push_str(&format!(
            "    // shift-register accumulator for reduction `{var}` (II=1 idiom)\n"
        ));
        body.push_str(&format!("    float {var}_sr[{SR_LEN}];\n"));
        body.push_str(&format!(
            "    #pragma unroll\n    for (int sr_i = 0; sr_i < {SR_LEN}; sr_i++) {{ {var}_sr[sr_i] = 0.0f; }}\n"
        ));
    }

    // the loop statement itself, re-emitted
    let mut loop_text = String::new();
    let stmt = reconstruct_loop_stmt(la);
    pretty::stmt(&stmt, 1, &mut loop_text);
    if unroll > 1 {
        // Intel HLS: pragma applies to the innermost loop of the nest;
        // emitting it above the statement is how aoc expects it for
        // single-level loops, and the estimator scales the datapath by b.
        body.push_str(&format!("    #pragma unroll {unroll}\n"));
    }
    body.push_str(&loop_text);

    for var in &sr_reds {
        body.push_str(&format!(
            "    // fold the shift register back into `{var}`\n"
        ));
        body.push_str(&format!(
            "    #pragma unroll\n    for (int sr_i = 0; sr_i < {SR_LEN}; sr_i++) {{ {var} += {var}_sr[sr_i]; }}\n"
        ));
    }

    let arg_list = args
        .iter()
        .map(|a| a.decl.clone())
        .collect::<Vec<_>>()
        .join(",\n        ");
    let code = format!(
        "__kernel void {name}(\n        {arg_list})\n{{\n{body}}}\n"
    );

    KernelSource {
        loop_id: la.info.id,
        name,
        code,
        args,
        unroll,
        shift_register_reductions: sr_reds,
    }
}

/// Rebuild the loop as a `Stmt` for printing (LoopInfo stores the pieces).
fn reconstruct_loop_stmt(la: &LoopAnalysis) -> crate::cparse::Stmt {
    use crate::cparse::Stmt;
    match (&la.info.header, &la.info.while_cond) {
        (Some(h), _) => Stmt::For {
            id: la.info.id,
            header: h.clone(),
            body: la.info.body.clone(),
            pos: la.info.pos,
        },
        (None, Some(c)) => Stmt::While {
            id: la.info.id,
            cond: c.clone(),
            body: la.info.body.clone(),
            pos: la.info.pos,
        },
        _ => unreachable!("loop is either for or while"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir;

    fn gen(src: &str, idx: usize, unroll: usize) -> KernelSource {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        generate_kernel(&p, &loops[idx], unroll)
    }

    const MAP_SRC: &str = "void f(float a[], float b[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = b[i] * 2.0; } }";

    #[test]
    fn kernel_has_signature_and_args() {
        let k = gen(MAP_SRC, 0, 1);
        assert!(k.code.starts_with("__kernel void loop_0("), "{}", k.code);
        assert!(k.code.contains("__global float* restrict a"));
        assert!(k.code.contains("__global float* restrict b"));
        assert!(k.code.contains("const int n"));
        assert!(k.code.contains("for ("));
    }

    #[test]
    fn unroll_pragma_emitted_when_b_gt_1() {
        assert!(!gen(MAP_SRC, 0, 1).code.contains("#pragma unroll"));
        assert!(gen(MAP_SRC, 0, 4).code.contains("#pragma unroll 4"));
    }

    #[test]
    fn reduction_gets_shift_register() {
        let k = gen(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i] * a[i]; } }",
            0,
            1,
        );
        assert_eq!(k.shift_register_reductions, vec!["s".to_string()]);
        assert!(k.code.contains("s_sr[8]"), "{}", k.code);
        assert!(k.code.contains("shift-register accumulator"));
    }

    #[test]
    fn free_scalar_types_resolved() {
        let k = gen(
            "void f(float a[], int n, float scale) { int i; \
             for (i = 0; i < n; i++) { a[i] = a[i] * scale; } }",
            0,
            1,
        );
        assert!(k.code.contains("const float scale"));
        assert!(k.code.contains("const int n"));
    }

    #[test]
    fn nested_loop_kernel_reemits_nest() {
        let k = gen(
            "void f(float c[], int n) { int i; \
             for (i = 0; i < n; i++) { \
               for (int j = 0; j < n; j++) { c[i * n + j] = i + j; } } }",
            0,
            1,
        );
        let fors = k.code.matches("for (").count();
        assert_eq!(fors, 2, "{}", k.code);
    }
}
