//! Seeded MiniC loop-nest generator: the workload side of the generative
//! property suite (`rust/tests/generative.rs`) and the `flopt gen`
//! subcommand.
//!
//! Each `(seed, index)` pair deterministically produces one small MiniC
//! program: a handful of global `float` arrays, a `main` made of 2–5
//! loop constructs drawn from nine families (trig fills, affine maps,
//! guarded stencils, reductions, FIR-style windows, histogram scatters,
//! sqrt maps, tiny matmuls, `while` sweeps), and a `stats_out` epilogue
//! so every program has verification outputs.  The families are chosen
//! to exercise both sides of every analysis decision: some constructs
//! are provably offloadable, some carry the exact dependences
//! ([`crate::ir::deps`]) must reject (data-dependent scatters,
//! non-canonical `while` headers), and the guarded/accumulating shapes
//! feed the funcblock detector.
//!
//! Determinism is load-bearing: the generator draws **integers only**
//! from the seeded [`Rng`] and builds decimal literals textually
//! (`0.3`, `1.7`), so the emitted bytes are identical across platforms
//! and the CLI golden (`rust/tests/golden/`) can pin them.  `index`
//! seeds an independent stream per program — generating program 7 never
//! depends on whether programs 0–6 were generated (pool-size
//! independence, pinned by the tests below).

use crate::util::rng::Rng;

use super::App;

/// Golden-ratio mixing constant (same one SplitMix64 increments by).
const MIX: u64 = 0x9E3779B97F4A7C15;

/// Length of every generated data array.
pub const ARRAY_LEN: usize = 96;

/// Per-program RNG seed: one `(seed, index)` pair → one independent
/// stream, so a pool of N programs equals N pools of one.
pub fn program_seed(seed: u64, index: u64) -> u64 {
    seed ^ index.wrapping_mul(MIX)
}

/// Generate the MiniC source of program `index` of stream `seed`.
pub fn gen_source(seed: u64, index: u64) -> String {
    let mut rng = Rng::new(program_seed(seed, index));
    let n_arrays = rng.range_i64(2, 4) as u64;

    let mut out = String::new();
    out.push_str(&format!("// gen seed={seed} index={index}\n"));
    out.push_str("float stats_out[8];\n");
    for a in 0..n_arrays {
        out.push_str(&format!("float arr{a}[{ARRAY_LEN}];\n"));
    }
    out.push_str("\nvoid main() {\n");

    let constructs = rng.range_i64(2, 5);
    for c in 0..constructs {
        // the first construct is always a trig fill so every program
        // has data in at least one array before anything reads it
        let kind = if c == 0 { 0 } else { rng.below(9) };
        emit_construct(&mut out, &mut rng, kind, c, n_arrays);
    }

    // verification epilogue: four sampled array elements (slots 0–3;
    // reduction constructs store into slots 4–7)
    for slot in 0..4 {
        let a = rng.below(n_arrays);
        let idx = rng.range_i64(0, ARRAY_LEN as i64 - 1);
        out.push_str(&format!("    stats_out[{slot}] = arr{a}[{idx}];\n"));
    }
    out.push_str("}\n");
    out
}

/// Emit one loop construct.  `c` uniquifies every local name the
/// construct introduces (`i3`, `s3`, …), so constructs never collide.
fn emit_construct(out: &mut String, rng: &mut Rng, kind: u64, c: i64, n: u64) {
    let mut line = |s: String| {
        out.push_str(&s);
        out.push('\n');
    };
    match kind {
        0 => {
            // trig fill: flat, trivially offloadable, feeds the others
            let a = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let d1 = rng.range_i64(1, 9);
            let d2 = rng.range_i64(1, 9);
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!(
                "        arr{a}[i{c}] = sin(i{c} * 0.0{d1}) + cos(i{c} * 0.0{d2}) * 0.5;"
            ));
            line("    }".into());
        }
        1 => {
            // affine map (source may equal destination: `a[i] = f(a[i])`
            // is the allowed same-index read the dependence test accepts)
            let a = rng.below(n);
            let b = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let d1 = rng.range_i64(1, 9);
            let d2 = rng.range_i64(1, 9);
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!("        arr{a}[i{c}] = arr{b}[i{c}] * 1.{d1} + 0.{d2};"));
            line("    }".into());
        }
        2 => {
            // boundary-guarded offset stencil reading a *different*
            // array — offloadable despite the guard and the `i-1` read
            let a = rng.below(n);
            let b = (a + 1) % n;
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let g = rng.range_i64(1, 4);
            let d = rng.range_i64(1, 9);
            line(format!("    for (int i{c} = 1; i{c} < {hi}; i{c}++) {{"));
            line(format!("        if (i{c} > {g}) {{"));
            line(format!(
                "            arr{a}[i{c}] = arr{b}[i{c} - 1] * 0.{d} + arr{b}[i{c}] * 0.5;"
            ));
            line("        }".into());
            line("    }".into());
        }
        3 => {
            // scalar `+` reduction into a dedicated stats slot
            let a = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let slot = rng.range_i64(4, 7);
            line(format!("    float s{c};"));
            line(format!("    s{c} = 0.0;"));
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!("        s{c} += arr{a}[i{c}] * arr{a}[i{c}];"));
            line("    }".into());
            line(format!("    stats_out[{slot}] = s{c};"));
        }
        4 => {
            // FIR-style guarded window: 2-deep, private accumulator,
            // taps either a constant or a second array (detector food)
            let a = rng.below(n);
            let b = (a + 1) % n;
            let taps = rng.range_i64(4, 12);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let tap = if rng.below(2) == 1 {
                let e = rng.below(n);
                format!("arr{e}[k{c}]")
            } else {
                let d = rng.range_i64(1, 9);
                format!("0.{d}")
            };
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!("        float acc{c};"));
            line(format!("        acc{c} = 0.0;"));
            line(format!("        for (int k{c} = 0; k{c} < {taps}; k{c}++) {{"));
            line(format!("            if (i{c} - k{c} >= 0) {{"));
            line(format!("                acc{c} += arr{a}[i{c} - k{c}] * {tap};"));
            line("            }".into());
            line("        }".into());
            line(format!("        arr{b}[i{c}] = acc{c};"));
            line("    }".into());
        }
        5 => {
            // histogram scatter: the data-dependent write the dependence
            // test must reject and the detector must read as a block
            let src = rng.below(n);
            let h = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!("        int b{c};"));
            line(format!("        b{c} = floor((arr{src}[i{c}] + 4.0) * 2.0);"));
            line(format!("        if (b{c} < 0) {{"));
            line(format!("            b{c} = 0;"));
            line("        }".into());
            line(format!("        if (b{c} > 15) {{"));
            line(format!("            b{c} = 15;"));
            line("        }".into());
            line(format!("        arr{h}[b{c}] += 1.0;"));
            line("    }".into());
        }
        6 => {
            // sqrt/fabs map
            let a = rng.below(n);
            let b = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let d = rng.range_i64(1, 9);
            line(format!("    for (int i{c} = 0; i{c} < {hi}; i{c}++) {{"));
            line(format!("        arr{a}[i{c}] = sqrt(fabs(arr{b}[i{c}])) + 0.{d};"));
            line("    }".into());
        }
        7 => {
            // tiny 8×8 matmul: 3-deep nest, indices stay below 64
            let a = rng.below(n);
            let b = rng.below(n);
            let dst = rng.below(n);
            line(format!("    for (int i{c} = 0; i{c} < 8; i{c}++) {{"));
            line(format!("        for (int j{c} = 0; j{c} < 8; j{c}++) {{"));
            line(format!("            float m{c};"));
            line(format!("            m{c} = 0.0;"));
            line(format!("            for (int k{c} = 0; k{c} < 8; k{c}++) {{"));
            line(format!(
                "                m{c} += arr{a}[i{c} * 8 + k{c}] * arr{b}[k{c} * 8 + j{c}];"
            ));
            line("            }".into());
            line(format!("            arr{dst}[i{c} * 8 + j{c}] = m{c};"));
            line("        }".into());
            line("    }".into());
        }
        _ => {
            // `while` sweep: no canonical header — negative space for
            // the offloadability test, still fully deterministic
            let a = rng.below(n);
            let hi = rng.range_i64(16, ARRAY_LEN as i64);
            let d = rng.range_i64(1, 9);
            line(format!("    int w{c};"));
            line(format!("    w{c} = 0;"));
            line(format!("    while (w{c} < {hi}) {{"));
            line(format!("        arr{a}[w{c}] += 0.{d};"));
            line(format!("        w{c} = w{c} + 1;"));
            line("    }".into());
        }
    }
}

/// Opt-in deep-nesting workload: `depth` nested blocks each bumping a
/// counter, plus a `depth`-deep parenthesized sum.  The stress fixture
/// for the iterative evaluator (`rust/tests/regressions.rs`): execution
/// depth no longer consumes host stack, so the program must run even on
/// a tiny thread stack.  Set `FLOPT_GEN_DEEP` to sweep depths in the
/// generative suite.  Draws nothing from [`Rng`], so the seeded streams
/// above are untouched.  Expected outputs: `out[0] == depth`,
/// `out[1] == depth + 1`.
pub fn deep_source(depth: usize) -> String {
    let mut src = String::from("float out[2];\n\nvoid main() {\n    int x;\n    x = 0;\n");
    for _ in 0..depth {
        src.push_str("    { x = x + 1;\n");
    }
    for _ in 0..depth {
        src.push_str("    }\n");
    }
    let mut expr = String::from("1");
    for _ in 0..depth {
        expr = format!("(1 + {expr})");
    }
    src.push_str("    out[0] = x * 1.0;\n");
    src.push_str(&format!("    out[1] = {expr} * 1.0;\n"));
    src.push_str("}\n");
    src
}

/// Wrap one source as a registered-app lookalike so the generated
/// program can flow through everything that takes an [`App`] (the batch
/// service, the fleet planner, the verification environment).  Leaks:
/// callers are tests and the short-lived CLI, where a handful of
/// `'static` strings for the process lifetime is the cheap way to meet
/// `App`'s embedded-source contract.
pub fn leak_app(name: String, source: String) -> &'static App {
    Box::leak(Box::new(App {
        name: Box::leak(name.into_boxed_str()),
        description: "seeded generative MiniC program",
        source: Box::leak(source.into_boxed_str()),
        paper_loop_count: None,
        binding: None,
        test_scale: &[],
        stats_array: "stats_out",
    }))
}

/// Generate program `index` of stream `seed` as a leaked [`App`].
pub fn as_app(seed: u64, index: u64) -> &'static App {
    leak_app(format!("gen-{seed}-{index}"), gen_source(seed, index))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse;

    #[test]
    fn fixed_seed_is_byte_identical() {
        for idx in 0..8 {
            assert_eq!(gen_source(42, idx), gen_source(42, idx));
        }
    }

    #[test]
    fn pool_size_does_not_change_a_program() {
        // program 5 generated alone equals program 5 from a pool of 10:
        // index seeds an independent stream (order-independent too)
        let alone = gen_source(9, 5);
        let pool: Vec<String> = (0..10).map(|i| gen_source(9, i)).collect();
        assert_eq!(alone, pool[5]);
        let reversed: Vec<String> = (0..10).rev().map(|i| gen_source(9, i)).collect();
        assert_eq!(pool[5], reversed[4]);
    }

    #[test]
    fn seeds_diverge() {
        assert_ne!(gen_source(1, 0), gen_source(2, 0));
        assert_ne!(gen_source(42, 0), gen_source(42, 1));
    }

    #[test]
    fn generated_programs_always_parse() {
        for idx in 0..50 {
            let src = gen_source(1106, idx);
            let p = cparse::parse(&src)
                .unwrap_or_else(|e| panic!("gen(1106, {idx}) must parse: {e}\n{src}"));
            assert!(p.loop_count() >= 1, "gen(1106, {idx}) has no loops");
            assert!(p.function("main").is_some());
        }
    }

    #[test]
    fn deep_source_parses_and_runs_at_modest_depth() {
        let src = deep_source(32);
        let p = cparse::parse(&src).expect("deep_source(32) parses");
        assert_eq!(p.loop_count(), 0);
        let mut it = crate::interp::Interp::new(&p);
        it.run_main().expect("runs");
        assert_eq!(it.read_array("out").unwrap(), vec![32.0, 33.0]);
    }

    #[test]
    fn generated_programs_run_and_fill_stats() {
        for idx in 0..10 {
            let app = as_app(7, idx);
            let p = app.parse();
            let mut it = app.interp(&p, false);
            it.run_main().unwrap_or_else(|e| panic!("{}: {e}", app.name));
            let stats = it.read_array("stats_out").expect("stats_out");
            assert_eq!(stats.len(), 8, "{}", app.name);
            assert!(
                stats.iter().all(|v| v.is_finite()),
                "{}: non-finite stats {stats:?}",
                app.name
            );
        }
    }
}
