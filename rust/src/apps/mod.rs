//! Application corpus: the paper's two evaluation applications (tdfir,
//! MRI-Q) as MiniC sources with the paper's exact loop counts, plus the
//! extra workload families for the examples and the analysis tests —
//! dense matmul, a 2-D stencil, a histogram pipeline, an FFT butterfly
//! sweep, sparse CSR matvec, a 3-D stencil, and an n-body pair
//! interaction.  [`gen`] synthesizes additional random programs from a
//! seed (the generative property suite and `flopt gen`).
//!
//! Each [`App`] may carry an [`ArtifactBinding`]: when the offload search
//! selects the bound hot loop, the verification environment executes the
//! loop's computation through the matching PJRT artifact (the L1 Pallas
//! kernel lowered by `python/compile/aot.py`) and cross-checks numerics
//! against the interpreter — the reproduction's stand-in for "runs on the
//! actual FPGA and produces the same answer".

use crate::cparse::{self, Program};
use crate::interp::{Interp, Value};

pub mod gen;

/// Binding of an app's hot loop to an AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactBinding {
    /// function whose outermost loop is the bound hot loop
    pub function: &'static str,
    /// artifact name in `artifacts/manifest.json` (FPGA variant)
    pub artifact: &'static str,
    /// all-CPU reference artifact (cross-check)
    pub cpu_artifact: &'static str,
    /// global arrays feeding the artifact inputs, with lengths
    pub inputs: &'static [(&'static str, usize)],
    /// global arrays the artifact outputs correspond to
    pub outputs: &'static [(&'static str, usize)],
}

/// One registered application.
#[derive(Debug, Clone)]
pub struct App {
    /// Registry name (the CLI's `<app>` argument).
    pub name: &'static str,
    /// One-line description shown by `flopt apps`.
    pub description: &'static str,
    /// Embedded MiniC source.
    pub source: &'static str,
    /// loop count the paper reports (None for the extra apps)
    pub paper_loop_count: Option<usize>,
    /// PJRT artifact binding for the hot loop, when one exists.
    pub binding: Option<ArtifactBinding>,
    /// global scalar overrides that shrink the problem for fast tests
    pub test_scale: &'static [(&'static str, i64)],
    /// array holding the app's verification outputs
    pub stats_array: &'static str,
}

impl App {
    /// Parse the app's source.
    pub fn parse(&self) -> Program {
        cparse::parse(self.source).unwrap_or_else(|e| {
            panic!("embedded app `{}` must parse: {e}", self.name)
        })
    }

    /// Fresh interpreter, optionally at test scale.
    pub fn interp<'p>(&self, program: &'p Program, test_scale: bool) -> Interp<'p> {
        let mut it = Interp::new(program);
        if test_scale {
            for (name, v) in self.test_scale {
                it.set_global(name, Value::Int(*v));
            }
        }
        it
    }
}

/// tdfir — time-domain FIR filter (HPEC Challenge), paper app #1.
pub const TDFIR: App = App {
    name: "tdfir",
    description: "Time-domain finite impulse response filter (HPEC Challenge)",
    source: include_str!("minic/tdfir.mc"),
    paper_loop_count: Some(36),
    binding: Some(ArtifactBinding {
        function: "fir_filter",
        artifact: "tdfir_fpga",
        cpu_artifact: "tdfir_cpu",
        inputs: &[("xr", 4096), ("xi", 4096), ("hr", 128), ("hi", 128)],
        outputs: &[("yr", 4096), ("yi", 4096)],
    }),
    test_scale: &[("N", 512), ("T", 32), ("NP", 543), ("HALF", 256)],
    stats_array: "stats_out",
};

/// MRI-Q — Parboil MRI reconstruction Q-matrix, paper app #2.
pub const MRIQ: App = App {
    name: "mriq",
    description: "MRI-Q non-Cartesian reconstruction (Parboil)",
    source: include_str!("minic/mriq.mc"),
    paper_loop_count: Some(16),
    binding: Some(ArtifactBinding {
        function: "compute_q",
        artifact: "mriq_fpga",
        cpu_artifact: "mriq_cpu",
        inputs: &[
            ("xx", 2048), ("xy", 2048), ("xz", 2048),
            ("kx", 512), ("ky", 512), ("kz", 512),
            ("phir", 512), ("phii", 512),
        ],
        outputs: &[("qr", 2048), ("qi", 2048)],
    }),
    test_scale: &[("X", 256), ("K", 64)],
    stats_array: "stats_out",
};

/// Extra sample app: dense matmul.
pub const MATMUL: App = App {
    name: "matmul",
    description: "Dense single-precision matrix multiply",
    source: include_str!("minic/matmul.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("N", 32)],
    stats_array: "stats_out",
};

/// Extra sample app: 2-D Laplace stencil.
pub const LAPLACE2D: App = App {
    name: "laplace2d",
    description: "2-D Laplace stencil (Jacobi sweeps)",
    source: include_str!("minic/laplace2d.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("W", 32), ("ITERS", 4)],
    stats_array: "stats_out",
};

/// Extra sample app: histogram pipeline.
pub const HISTOGRAM: App = App {
    name: "histogram",
    description: "Histogram + pointwise transform pipeline",
    source: include_str!("minic/histogram.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("N", 1024)],
    stats_array: "stats_out",
};

/// Extra workload: radix-2 FFT butterfly sweep (strided cross-reads).
pub const FFT: App = App {
    name: "fft",
    description: "Radix-2 FFT butterfly sweep (strided cross-read pairs)",
    source: include_str!("minic/fft.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("N", 256), ("STAGES", 8)],
    stats_array: "stats_out",
};

/// Extra workload: sparse CSR matrix-vector product (indirect gather).
pub const SPMV: App = App {
    name: "spmv",
    description: "Sparse CSR matrix-vector product (indirect gather)",
    source: include_str!("minic/spmv.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("ROWS", 256), ("COLS", 128)],
    stats_array: "stats_out",
};

/// Extra workload: 3-D 7-point heat stencil (detector negative space).
pub const STENCIL3D: App = App {
    name: "stencil3d",
    description: "3-D 7-point heat stencil (Jacobi sweeps)",
    source: include_str!("minic/stencil3d.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("D", 12), ("ITERS", 2)],
    stats_array: "stats_out",
};

/// Extra workload: all-pairs n-body interaction (pair-indexed reads).
pub const NBODY: App = App {
    name: "nbody",
    description: "All-pairs n-body gravitational interaction",
    source: include_str!("minic/nbody.mc"),
    paper_loop_count: None,
    binding: None,
    test_scale: &[("NB", 96), ("STEPS", 2)],
    stats_array: "stats_out",
};

/// All registered apps.
pub fn all() -> Vec<&'static App> {
    vec![
        &TDFIR, &MRIQ, &MATMUL, &LAPLACE2D, &HISTOGRAM, &FFT, &SPMV, &STENCIL3D, &NBODY,
    ]
}

/// Look up an app by name.
pub fn by_name(name: &str) -> Option<&'static App> {
    all().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir;

    #[test]
    fn all_apps_parse() {
        for app in all() {
            let p = app.parse();
            assert!(!p.functions.is_empty(), "{}", app.name);
            assert!(p.function("main").is_some(), "{} needs main()", app.name);
        }
    }

    #[test]
    fn paper_loop_counts_match() {
        // §5.1.2: "ループ文数 (時間領域有限インパルス応答フィルタは 36.
        // MRI-Q は 16.)"
        assert_eq!(TDFIR.parse().loop_count(), 36);
        assert_eq!(MRIQ.parse().loop_count(), 16);
    }

    #[test]
    fn hot_loops_are_offloadable() {
        for app in [&TDFIR, &MRIQ] {
            let p = app.parse();
            let loops = ir::analyze(&p);
            let func = app.binding.as_ref().unwrap().function;
            let hot = loops
                .iter()
                .find(|l| l.info.function == func && l.info.depth == 0)
                .unwrap_or_else(|| panic!("{}: no outer loop in {func}", app.name));
            assert!(
                hot.deps.offloadable,
                "{}: hot loop rejected: {:?}",
                app.name, hot.deps.reject_reason
            );
        }
    }

    #[test]
    fn apps_run_at_test_scale() {
        for app in all() {
            let p = app.parse();
            let mut it = app.interp(&p, true);
            it.run_main()
                .unwrap_or_else(|e| panic!("{} failed: {e}", app.name));
            let stats = it.read_array(app.stats_array).unwrap();
            assert!(
                stats.iter().any(|v| *v != 0.0),
                "{}: stats must be non-trivial",
                app.name
            );
        }
    }

    #[test]
    fn tdfir_hot_loop_ids_documented() {
        let p = TDFIR.parse();
        let loops = ir::analyze(&p);
        let fir_outer = loops
            .iter()
            .find(|l| l.info.function == "fir_filter" && l.info.depth == 0)
            .unwrap();
        assert_eq!(fir_outer.info.id.0, 8, "header comment says L8/L9");
    }

    #[test]
    fn mriq_hot_loop_ids_documented() {
        let p = MRIQ.parse();
        let loops = ir::analyze(&p);
        let q_outer = loops
            .iter()
            .find(|l| l.info.function == "compute_q" && l.info.depth == 0)
            .unwrap();
        assert_eq!(q_outer.info.id.0, 6, "header comment says L6/L7");
        let phimag = loops
            .iter()
            .find(|l| l.info.function == "compute_phimag")
            .unwrap();
        assert_eq!(phimag.info.id.0, 4);
        assert!(phimag.deps.offloadable);
    }

    #[test]
    fn corpus_workload_loop_counts_match_header_comments() {
        assert_eq!(FFT.parse().loop_count(), 8);
        assert_eq!(SPMV.parse().loop_count(), 7);
        assert_eq!(STENCIL3D.parse().loop_count(), 9);
        assert_eq!(NBODY.parse().loop_count(), 6);
    }

    #[test]
    fn corpus_hot_nests_are_offloadable() {
        for (app, func) in [
            (&FFT, "butterfly"),
            (&SPMV, "spmv"),
            (&NBODY, "forces"),
        ] {
            let p = app.parse();
            let loops = ir::analyze(&p);
            let hot = loops
                .iter()
                .find(|l| l.info.function == func && l.info.depth == 0)
                .unwrap_or_else(|| panic!("{}: no outer loop in {func}", app.name));
            assert!(
                hot.deps.offloadable,
                "{}: hot loop rejected: {:?}",
                app.name, hot.deps.reject_reason
            );
        }
    }

    #[test]
    fn spmv_prefix_sum_build_is_not_offloadable() {
        let p = SPMV.parse();
        let loops = ir::analyze(&p);
        let build = loops
            .iter()
            .find(|l| l.info.function == "build_rows")
            .unwrap();
        assert!(
            !build.deps.offloadable,
            "a stored running total is a carried flow dependence"
        );
    }

    #[test]
    fn histogram_fill_not_offloadable() {
        let p = HISTOGRAM.parse();
        let loops = ir::analyze(&p);
        let fill = loops
            .iter()
            .find(|l| l.info.function == "build_hist" && l.info.id.0 == 3)
            .unwrap();
        assert!(!fill.deps.offloadable, "data-dependent writes must reject");
    }

    #[test]
    fn laplace_sweep_not_offloadable_but_grid_is() {
        let p = LAPLACE2D.parse();
        let loops = ir::analyze(&p);
        let sweep = loops
            .iter()
            .find(|l| l.info.function == "jacobi" && l.info.depth == 0)
            .unwrap();
        assert!(!sweep.deps.offloadable, "ping-pong sweep carries deps");
        let grid = loops
            .iter()
            .find(|l| l.info.function == "jacobi" && l.info.depth == 1)
            .unwrap();
        assert!(
            grid.deps.offloadable,
            "grid nest rejected: {:?}",
            grid.deps.reject_reason
        );
    }
}
