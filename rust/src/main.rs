//! flopt CLI — the leader entrypoint.
//!
//! ```text
//! flopt apps                       list registered applications
//! flopt env                        print the Fig-3 testbed table
//! flopt analyze <app>              Steps 1-2: loops, intensity ranking
//! flopt explain <app> [--json]     per-loop dependence verdicts with
//!                                  span-anchored diagnostics (cached)
//! flopt offload <app> [opts]       full offload search (paper Fig 2)
//! flopt batch [<app>] [opts]       batched offload service (N requests,
//!                                  one compile farm, cache + dedupe)
//! flopt fleet [<app>] [opts]       multi-tenant FPGA fleet placement:
//!                                  co-schedule every app's winner onto
//!                                  --boards N shared Arria10 boards
//! flopt opencl <app>               print generated OpenCL for the solution
//! flopt verify <app>               PJRT numerics cross-check of the hot loop
//! flopt compare <app>              proposed vs GA vs exhaustive vs naive
//! flopt gen [--seed S --count N]   print N seeded MiniC programs
//! flopt serve [opts]               long-lived offload daemon: Poisson (or
//!                                  --trace) arrivals, tenant churn,
//!                                  incremental re-pack + live migration,
//!                                  DRR fairness, cache eviction
//! flopt bench-compare --baseline <file> --report <file>
//!                                  gate a bench report against a committed
//!                                  baseline (exit 1 on regression)
//! ```
//!
//! Options for `offload`/`batch`/`compare`: `--target {fpga,gpu,mixed}`
//! and `--blocks {off,on,only}` (function-block co-search against the
//! IP/library registry — `on` co-searches blocks with loop statements,
//! `only` searches blocks alone), plus `--a N --b N --c N --d N
//! --lanes N --full-scale` (default runs the paper's a=5, b=1, c=3, d=4
//! against the FPGA at test scale; `--full-scale` uses the paper-sized
//! workloads).  Caching:
//! `--cache-dir <dir>` persists stage artifacts as JSON so repeat
//! searches burn zero additional simulated compile-hours; `--no-cache`
//! disables artifact reuse entirely.  `--pool N` sets the batch
//! service's worker count (output is identical for any pool size).
//! Observability: `--trace-out <file>` writes the deterministic span
//! log (Chrome `trace_event` JSON when the path ends in `.json`, JSON
//! lines otherwise); `--metrics-out <file>` on `batch`/`serve` writes a
//! Prometheus-style metrics snapshot (see DESIGN.md §3i).
//!
//! `flopt --target mixed` (no app) runs **all** registered apps through
//! both backends on one shared simulated clock and reports the winning
//! destination per app.  `flopt batch --target mixed` submits every
//! registered app × {fpga, gpu} to the batch service.

use flopt::apps;
use flopt::backend::{self, OffloadBackend, Target};
use flopt::baselines;
use flopt::cache::{self, CacheStore};
use flopt::config::{fig3_table, SearchConfig};
use flopt::coordinator::mixed::{destination_search, mixed_search_on};
use flopt::coordinator::pipeline::{
    analyze_app, charge_analysis, offload_search, search_with_analysis,
};
use flopt::coordinator::verify_env::VerifyEnv;
use flopt::cpu::XEON_3104;
use flopt::fleet;
use flopt::funcblock::BlockMode;
use flopt::intensity;
use flopt::util::json;
use flopt::util::order;
use flopt::runtime::{default_artifact_dir, Runtime};
use flopt::service::{BatchRequest, BatchService};

use std::sync::Arc;

fn usage() -> ! {
    eprintln!(
        "usage: flopt <command> [args]\n\
         commands:\n\
         \x20 apps                      list applications\n\
         \x20 env                       print the Fig-3 testbed table\n\
         \x20 analyze <app>             loop + intensity analysis\n\
         \x20 explain <app> [--json]    per-loop dependence diagnostics\n\
         \x20 offload [<app>] [opts]    full offload search\n\
         \x20 batch [<app>] [opts]      batched offload service (cache + dedupe)\n\
         \x20 fleet [<app>] [opts]      multi-tenant FPGA fleet placement\n\
         \x20 opencl <app> [opts]       print the solution's OpenCL\n\
         \x20 verify <app>              PJRT numerics cross-check\n\
         \x20 compare <app> [opts]      proposed vs baselines\n\
         \x20 blocks <app>              function-block detection + IP offers\n\
         \x20 adapt <app> [opts]        Steps 4-6: size, place, verify operation\n\
         \x20 gen [--seed S --count N]  print N seeded MiniC programs (fuzz corpus)\n\
         \x20 serve [opts]              long-lived offload daemon (churn + re-pack)\n\
         \x20 bench-compare --baseline <file> --report <file> [--diff <file>]\n\
         \x20     [--bless <file>]      bench regression gate (exit 1 on regression)\n\
         opts: --target {{fpga,gpu,mixed}} --blocks {{off,on,only}}\n\
         \x20     --a N --b N --c N --d N --lanes N --boards N\n\
         \x20     --ga-pop N --ga-gen N --full-scale\n\
         \x20     --cache-dir <dir> --no-cache --pool N\n\
         \x20     --seed S --count N (gen only)\n\
         \x20     --requests N --rate R --tenants N --epoch-hours H --no-churn\n\
         \x20     --quota N --drr-quantum Q --cache-budget BYTES\n\
         \x20     --cache-ttl-hours H --trace <file> (serve only)\n\
         \x20     --trace-out <file> (span log: .json = Chrome trace_event,\n\
         \x20     \x20 else JSON lines) --metrics-out <file> (batch/serve:\n\
         \x20     \x20 Prometheus-style metrics snapshot)\n\
         (`flopt --target mixed` with no app searches all registered apps\n\
         \x20on one shared clock and reports the winning destination per app;\n\
         \x20`flopt batch --target mixed` submits every app x {{fpga,gpu}})"
    );
    std::process::exit(2);
}

struct Opts {
    app: Option<String>,
    cfg: SearchConfig,
    full_scale: bool,
    target: Target,
    cache_dir: Option<String>,
    no_cache: bool,
    /// `explain --json`: print the JSON document instead of the text.
    json: bool,
    pool: usize,
    boards: usize,
    seed: u64,
    count: usize,
    // serve-only knobs
    requests: usize,
    rate_per_h: f64,
    tenants: usize,
    epoch_hours: f64,
    no_churn: bool,
    quota: u64,
    drr_quantum: f64,
    cache_budget: Option<u64>,
    cache_ttl_hours: Option<f64>,
    trace: Option<String>,
    // observability sinks (DESIGN.md §3i)
    trace_out: Option<String>,
    metrics_out: Option<String>,
}

/// A flag was given without its required value: name the flag and exit 2
/// (the same contract as the unknown-value paths pinned by
/// `rust/tests/destinations.rs`).
fn missing_value(flag: &str) -> ! {
    eprintln!("missing value for {flag} (run `flopt` with no arguments for usage)");
    std::process::exit(2);
}

/// A numeric flag was given a non-numeric value: name both and exit 2.
fn invalid_value(flag: &str, got: &str) -> ! {
    eprintln!("invalid value for {flag}: `{got}` (expected a non-negative integer)");
    std::process::exit(2);
}

fn parse_opts(args: &[String]) -> Opts {
    let mut cfg = SearchConfig::default();
    let mut app = None;
    let mut full_scale = false;
    let mut target = Target::Fpga;
    let mut cache_dir = None;
    let mut no_cache = false;
    let mut json_out = false;
    let mut pool = 4;
    let mut boards = 2;
    let mut seed: u64 = 42;
    let mut count = 5;
    let mut requests = 2000;
    let mut rate_per_h = 50.0;
    let mut tenants = 6;
    let mut epoch_hours = 4.0;
    let mut no_churn = false;
    let mut quota: u64 = 0;
    let mut drr_quantum = 1.0;
    let mut cache_budget: Option<u64> = None;
    let mut cache_ttl_hours: Option<f64> = None;
    let mut trace: Option<String> = None;
    let mut trace_out: Option<String> = None;
    let mut metrics_out: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize, flag: &str| -> usize {
            *i += 1;
            match args.get(*i) {
                None => missing_value(flag),
                Some(v) => v.parse().unwrap_or_else(|_| invalid_value(flag, v)),
            }
        };
        let take_f64 = |i: &mut usize, flag: &str| -> f64 {
            *i += 1;
            match args.get(*i) {
                None => missing_value(flag),
                Some(v) => match v.parse::<f64>() {
                    Ok(x) if x.is_finite() && x >= 0.0 => x,
                    _ => invalid_value(flag, v),
                },
            }
        };
        let take_u64 = |i: &mut usize, flag: &str| -> u64 {
            *i += 1;
            match args.get(*i) {
                None => missing_value(flag),
                Some(v) => v.parse().unwrap_or_else(|_| invalid_value(flag, v)),
            }
        };
        match args[i].as_str() {
            "--a" => cfg.a_intensity = take(&mut i, "--a"),
            "--b" => cfg.b_unroll = take(&mut i, "--b"),
            "--c" => cfg.c_efficiency = take(&mut i, "--c"),
            "--d" => cfg.d_patterns = take(&mut i, "--d"),
            "--lanes" => cfg.compile_parallelism = take(&mut i, "--lanes"),
            "--ga-pop" => cfg.ga_population = take(&mut i, "--ga-pop"),
            "--ga-gen" => cfg.ga_generations = take(&mut i, "--ga-gen"),
            "--pool" => pool = take(&mut i, "--pool").max(1),
            "--boards" => boards = take(&mut i, "--boards").max(1),
            "--count" => count = take(&mut i, "--count").max(1),
            "--seed" => {
                // seeds span the full u64 range; `take` parses usize
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--seed") };
                seed = v.parse().unwrap_or_else(|_| invalid_value("--seed", v));
            }
            "--target" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--target") };
                target = Target::parse(v).unwrap_or_else(|| {
                    eprintln!(
                        "unknown --target `{v}`: expected one of fpga, gpu, mixed \
                         (cpu is the baseline, not a search target)"
                    );
                    std::process::exit(2);
                });
            }
            "--blocks" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--blocks") };
                cfg.block_mode = BlockMode::parse(v).unwrap_or_else(|| {
                    eprintln!("unknown --blocks `{v}`: expected one of off, on, only");
                    std::process::exit(2);
                });
            }
            "--cache-dir" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--cache-dir") };
                cache_dir = Some(v.clone());
            }
            "--no-cache" => no_cache = true,
            "--json" => json_out = true,
            "--full-scale" => full_scale = true,
            "--requests" => requests = take(&mut i, "--requests").max(1),
            "--rate" => rate_per_h = take_f64(&mut i, "--rate"),
            "--tenants" => tenants = take(&mut i, "--tenants").max(2),
            "--epoch-hours" => epoch_hours = take_f64(&mut i, "--epoch-hours"),
            "--no-churn" => no_churn = true,
            "--quota" => quota = take_u64(&mut i, "--quota"),
            "--drr-quantum" => drr_quantum = take_f64(&mut i, "--drr-quantum"),
            "--cache-budget" => cache_budget = Some(take_u64(&mut i, "--cache-budget")),
            "--cache-ttl-hours" => cache_ttl_hours = Some(take_f64(&mut i, "--cache-ttl-hours")),
            "--trace" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--trace") };
                trace = Some(v.clone());
            }
            "--trace-out" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--trace-out") };
                trace_out = Some(v.clone());
            }
            "--metrics-out" => {
                i += 1;
                let Some(v) = args.get(i) else { missing_value("--metrics-out") };
                metrics_out = Some(v.clone());
            }
            s if !s.starts_with('-') && app.is_none() => app = Some(s.to_string()),
            _ => usage(),
        }
        i += 1;
    }
    Opts {
        app,
        cfg,
        full_scale,
        target,
        cache_dir,
        no_cache,
        json: json_out,
        pool,
        boards,
        seed,
        count,
        requests,
        rate_per_h,
        tenants,
        epoch_hours,
        no_churn,
        quota,
        drr_quantum,
        cache_budget,
        cache_ttl_hours,
        trace,
        trace_out,
        metrics_out,
    }
}

/// Honor `--trace-out`: write the span log accumulated on `rec`
/// (`.json` selects Chrome `trace_event` format, anything else the
/// JSON-lines log).  A command that never advances a clock writes an
/// empty-but-valid log.
fn export_trace(opts: &Opts, rec: &flopt::obs::Recorder) -> flopt::Result<()> {
    if let Some(path) = &opts.trace_out {
        flopt::obs::export::write_trace(path, rec)
            .map_err(|e| anyhow::anyhow!("cannot write --trace-out {path}: {e}"))?;
    }
    Ok(())
}

/// Honor `--metrics-out` (batch/serve): write the Prometheus-style
/// snapshot, folding the store's [`flopt::cache::CacheStats`] into the
/// counter section.
fn export_metrics(
    opts: &Opts,
    rec: &flopt::obs::Recorder,
    cache: Option<&flopt::cache::CacheStats>,
) -> flopt::Result<()> {
    if let Some(path) = &opts.metrics_out {
        flopt::obs::export::write_metrics(path, rec, cache)
            .map_err(|e| anyhow::anyhow!("cannot write --metrics-out {path}: {e}"))?;
    }
    Ok(())
}

/// The artifact cache this invocation routes searches through.
fn build_cache(opts: &Opts) -> Arc<CacheStore> {
    if opts.no_cache {
        CacheStore::disabled()
    } else if let Some(dir) = &opts.cache_dir {
        CacheStore::with_dir(dir)
    } else {
        CacheStore::fresh()
    }
}

fn get_app(opts: &Opts) -> &'static apps::App {
    let name = opts.app.as_deref().unwrap_or_else(|| usage());
    apps::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown app `{name}`; try `flopt apps`");
        std::process::exit(2);
    })
}

/// The single backend a non-mixed command runs against.
fn single_backend(opts: &Opts, cmd: &str) -> &'static dyn OffloadBackend {
    match opts.target {
        Target::Fpga => &backend::FPGA,
        Target::Gpu => &backend::GPU,
        Target::Mixed => {
            eprintln!("`{cmd}` does not support --target mixed (only `offload` does)");
            std::process::exit(2);
        }
    }
}

/// Reject `--target` on commands whose flow is FPGA-specific.
fn require_fpga_target(opts: &Opts, cmd: &str) {
    if opts.target != Target::Fpga {
        eprintln!("`{cmd}` is FPGA-specific and supports only --target fpga");
        std::process::exit(2);
    }
}

/// `flopt bench-compare`: gate a bench report against a committed
/// baseline.  Exit 0 when every pinned metric is within tolerance,
/// 1 on a regression or a pinned-but-missing metric, 2 on usage/IO
/// errors.  Parses its own flags (they share nothing with `parse_opts`).
fn run_bench_compare(args: &[String]) -> ! {
    let mut baseline: Option<String> = None;
    let mut report: Option<String> = None;
    let mut diff: Option<String> = None;
    let mut bless: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let slot = match args[i].as_str() {
            "--baseline" => &mut baseline,
            "--report" => &mut report,
            "--diff" => &mut diff,
            "--bless" => &mut bless,
            other => {
                eprintln!("bench-compare: unknown argument `{other}`");
                std::process::exit(2);
            }
        };
        let flag = args[i].clone();
        i += 1;
        let Some(v) = args.get(i) else { missing_value(&flag) };
        *slot = Some(v.clone());
        i += 1;
    }
    let (Some(bp), Some(rp)) = (baseline, report) else {
        eprintln!("bench-compare: --baseline <file> and --report <file> are required");
        std::process::exit(2);
    };
    let read = |p: &str| -> String {
        std::fs::read_to_string(p).unwrap_or_else(|e| {
            eprintln!("bench-compare: cannot read {p}: {e}");
            std::process::exit(2);
        })
    };
    let (cmp, blessed) = match flopt::benchcmp::run(&read(&bp), &read(&rp)) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("bench-compare: {e}");
            std::process::exit(2);
        }
    };
    print!("{}", cmp.render());
    let write = |p: &str, text: String| {
        if let Some(parent) = std::path::Path::new(p).parent() {
            if !parent.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(parent);
            }
        }
        if let Err(e) = std::fs::write(p, text) {
            eprintln!("bench-compare: cannot write {p}: {e}");
            std::process::exit(2);
        }
    };
    if let Some(dp) = diff {
        write(&dp, json::to_string(&cmp.to_json()) + "\n");
    }
    if let Some(bp) = bless {
        write(&bp, json::to_string(&blessed) + "\n");
    }
    std::process::exit(if cmp.failed() { 1 } else { 0 });
}

fn main() -> flopt::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(first) = args.first() else { usage() };
    // `flopt --target mixed` etc.: a leading option implies `offload`
    let (cmd, rest) = if first.starts_with('-') {
        ("offload", &args[..])
    } else {
        (first.as_str(), &args[1..])
    };
    if cmd == "bench-compare" {
        run_bench_compare(rest);
    }
    let opts = parse_opts(rest);

    match cmd {
        "apps" => {
            for a in apps::all() {
                let loops = a.parse().loop_count();
                println!(
                    "{:<12} {:>3} loops  {}{}",
                    a.name,
                    loops,
                    a.description,
                    a.paper_loop_count
                        .map(|n| format!("  [paper: {n}]"))
                        .unwrap_or_default()
                );
            }
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "env" => {
            println!("{}", fig3_table());
            for b in Target::Mixed.backends() {
                println!("{:<5} model: {}", b.name(), b.description());
            }
            println!("CPU   model: {}", XEON_3104.name);
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "analyze" => {
            let app = get_app(&opts);
            let analysis = analyze_app(app, !opts.full_scale)?;
            println!(
                "{}: {} loop statements",
                app.name,
                analysis.program.loop_count()
            );
            let mut ints = analysis.intensities.clone();
            ints.sort_by(|a, b| {
                order::desc_nan_last(a.intensity, b.intensity).then_with(|| a.id.cmp(&b.id))
            });
            println!(
                "{:<6} {:<14} {:>10} {:>12} {:>12} {:>10}  {}",
                "loop", "function", "trips", "flops", "footprintB", "intensity", "offloadable"
            );
            for l in &ints {
                println!(
                    "{:<6} {:<14} {:>10} {:>12} {:>12} {:>10.2}  {}",
                    l.id.to_string(),
                    l.function,
                    l.trips,
                    l.flops,
                    l.footprint_bytes,
                    l.intensity,
                    l.offloadable
                );
            }
            let top = intensity::top_a(&analysis.intensities, &analysis.loops, opts.cfg.a_intensity);
            println!(
                "top-{}: {:?}",
                opts.cfg.a_intensity,
                top.iter().map(|l| l.id.to_string()).collect::<Vec<_>>()
            );
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "explain" => {
            let app = get_app(&opts);
            let store = build_cache(&opts);
            let key = cache::explain_key(app);
            let artifact = match store.get_explain(key) {
                Some(a) => a,
                None => {
                    let a = flopt::analyze::explain_program(app.name, &app.parse()).artifact();
                    store.put_explain(key, &a);
                    a
                }
            };
            if opts.json {
                println!("{}", artifact.json);
            } else {
                print!("{}", artifact.text);
            }
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "offload" => match opts.target {
            Target::Fpga => {
                let app = get_app(&opts);
                let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, opts.cfg.clone())
                    .with_cache(build_cache(&opts));
                let trace = offload_search(app, &env, !opts.full_scale)?;
                println!("{}", trace.render());
                export_trace(&opts, env.clock.obs())?;
            }
            Target::Gpu => {
                let app = get_app(&opts);
                let store = build_cache(&opts);
                let clock =
                    Arc::new(flopt::metrics::SimClock::new(opts.cfg.compile_parallelism.max(1)));
                let key =
                    cache::destination_key(app, !opts.full_scale, &backend::GPU, &opts.cfg);
                if let Some(ds) = store.get_destination(key) {
                    clock.mark("cache.hit.destination", "cache");
                    clock.obs().count("cache.hit.destination", 1);
                    println!("{}", ds.render());
                    println!("automation time: 0.0 h simulated (served from cache)");
                } else {
                    clock.obs().count("cache.miss.destination", 1);
                    let env = VerifyEnv::with_clock(
                        &backend::GPU,
                        &XEON_3104,
                        opts.cfg.clone(),
                        Arc::clone(&clock),
                    )
                    .with_cache(Arc::clone(&store));
                    let analysis = analyze_app(app, !opts.full_scale)?;
                    charge_analysis(&env.clock, env.cpu, &analysis);
                    let ds = destination_search(app, &analysis, &env, &opts.cfg)?;
                    store.put_destination(key, &ds);
                    println!("{}", ds.render());
                    println!(
                        "automation time: {:.1} h simulated",
                        env.clock.total_hours()
                    );
                }
                export_trace(&opts, clock.obs())?;
            }
            Target::Mixed => {
                // one app when named, the whole registry otherwise —
                // always on one shared simulated clock (via the batch
                // service: analyze once per app, dedupe through the cache)
                let apps_list: Vec<&'static apps::App> = match opts.app.as_deref() {
                    Some(_) => vec![get_app(&opts)],
                    None => apps::all(),
                };
                let service =
                    BatchService::new(opts.pool, opts.cfg.compile_parallelism, &XEON_3104)
                        .with_cache(build_cache(&opts));
                let traces = mixed_search_on(
                    &service,
                    &apps_list,
                    &Target::Mixed.backends(),
                    &opts.cfg,
                    !opts.full_scale,
                )?;
                for t in &traces {
                    println!("{}", t.render());
                }
                println!(
                    "total automation time (shared clock): {:.1} h simulated",
                    traces.last().map(|t| t.sim_hours).unwrap_or(0.0)
                );
                export_trace(&opts, service.clock().obs())?;
            }
        },
        "batch" => {
            // one app when named, the whole registry otherwise; `mixed`
            // fans each app out to both concrete destinations
            let apps_list: Vec<&'static apps::App> = match opts.app.as_deref() {
                Some(_) => vec![get_app(&opts)],
                None => apps::all(),
            };
            let targets: Vec<Target> = match opts.target {
                Target::Mixed => vec![Target::Fpga, Target::Gpu],
                t => vec![t],
            };
            let mut requests = Vec::new();
            for app in &apps_list {
                for t in &targets {
                    requests.push(BatchRequest {
                        app: *app,
                        target: *t,
                        cfg: opts.cfg.clone(),
                        test_scale: !opts.full_scale,
                    });
                }
            }
            let service =
                BatchService::new(opts.pool, opts.cfg.compile_parallelism, &XEON_3104)
                    .with_cache(build_cache(&opts));
            let report = service.run(&requests)?;
            print!("{}", report.render());
            export_trace(&opts, service.clock().obs())?;
            export_metrics(&opts, service.clock().obs(), Some(&report.cache))?;
        }
        "fleet" => {
            // multi-tenant placement: every app's winner onto a bounded
            // pool of Arria10 boards, on one shared simulated clock
            require_fpga_target(&opts, "fleet");
            let apps_list: Vec<&'static apps::App> = match opts.app.as_deref() {
                Some(_) => vec![get_app(&opts)],
                None => apps::all(),
            };
            let service =
                BatchService::new(opts.pool, opts.cfg.compile_parallelism, &XEON_3104)
                    .with_cache(build_cache(&opts));
            let report = fleet::fleet_search(
                &service,
                &apps_list,
                opts.boards,
                &opts.cfg,
                !opts.full_scale,
            )?;
            print!("{}", report.render());
            export_trace(&opts, service.clock().obs())?;
        }
        "opencl" => {
            let app = get_app(&opts);
            require_fpga_target(&opts, "opencl");
            let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, opts.cfg.clone())
                .with_cache(build_cache(&opts));
            let trace = offload_search(app, &env, !opts.full_scale)?;
            match trace.best {
                Some(best) => {
                    let code = trace
                        .opencl
                        .iter()
                        .find(|c| c.pattern == best.pattern)
                        .expect("solution has generated OpenCL");
                    println!("// ===== {}.cl =====", best.pattern.label());
                    println!("{}", code.cl_source());
                    println!("// ===== host.c =====");
                    println!("{}", code.host);
                }
                None => println!("no improving pattern found"),
            }
            export_trace(&opts, env.clock.obs())?;
        }
        "verify" => {
            let app = get_app(&opts);
            require_fpga_target(&opts, "verify");
            let rt = Runtime::load(default_artifact_dir())?;
            let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, opts.cfg.clone());
            let check = env.check_numerics(app, &rt)?;
            println!(
                "artifact {}: {} elements, max|fpga-cpu| = {:.3e}, max|pallas-jnp| = {:.3e} -> {}",
                check.artifact,
                check.elements,
                check.max_abs_err,
                check.max_abs_err_vs_cpu_artifact,
                if check.passed { "PASS" } else { "FAIL" }
            );
            export_trace(&opts, env.clock.obs())?;
            if !check.passed {
                std::process::exit(1);
            }
        }
        "blocks" => {
            let app = get_app(&opts);
            let program = app.parse();
            let loops = flopt::ir::analyze(&program);
            println!("-- Deckard-style similarity matches (threshold 0.90) --");
            let matches = flopt::ir::funcblock::detect(&loops, 0.90);
            if matches.is_empty() {
                println!("no functional blocks recognized");
            }
            for m in matches {
                println!(
                    "{}: {} (similarity {:.3}){}",
                    m.loop_id,
                    m.block,
                    m.similarity,
                    m.artifact
                        .map(|a| format!("  [pre-optimized artifact: {a}]"))
                        .unwrap_or_default()
                );
            }
            println!("-- structural detector + IP registry offers --");
            let analysis = analyze_app(app, !opts.full_scale)?;
            let detected = flopt::funcblock::detect(&analysis.loops);
            if detected.is_empty() {
                println!("no registry blocks detected");
            }
            for b in &detected {
                println!(
                    "block {} rooted at {} (subsumes {})",
                    b.name,
                    b.root,
                    b.loops
                        .iter()
                        .map(|l| l.to_string())
                        .collect::<Vec<_>>()
                        .join("+")
                );
                for be in Target::Mixed.backends() {
                    match be.block_offer(&analysis.loops, &analysis.profile, &XEON_3104, b) {
                        Some(o) => println!(
                            "  {:<5} offer: {} — util {:.2}, link {:.0} s, exec {:.3} ms \
                             (replaces {:.3} ms CPU)",
                            be.name(),
                            o.description,
                            o.utilization,
                            o.compile_sim_s,
                            o.exec_s * 1e3,
                            o.cpu_time_s * 1e3
                        ),
                        None => println!("  {:<5} no registry implementation", be.name()),
                    }
                }
            }
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "adapt" => {
            let app = get_app(&opts);
            require_fpga_target(&opts, "adapt");
            let env = VerifyEnv::new(&backend::FPGA, &XEON_3104, opts.cfg.clone())
                .with_cache(build_cache(&opts));
            let trace = offload_search(app, &env, !opts.full_scale)?;
            let Some(best) = &trace.best else {
                println!("no improving pattern — nothing to deploy");
                export_trace(&opts, env.clock.obs())?;
                return Ok(());
            };
            println!("solution pattern: {} ({:.2}x)", best.pattern, best.speedup);
            let plan = flopt::coordinator::adapt::adapt(
                app,
                best,
                &flopt::fpga::ARRIA10_GX,
                &flopt::coordinator::adapt::demo_sites(),
                /*target_rps=*/ 200.0,
                /*max_latency_ms=*/ 100.0,
                &env.clock,
            )?;
            println!(
                "step 4 — resources: {} instance(s)/board, {} board(s), {:.0} runs/s provisioned",
                plan.resources.instances_per_board,
                plan.resources.boards,
                plan.resources.provisioned_rps
            );
            match &plan.placement {
                Some(p) => println!(
                    "step 5 — placement: {} ({} boards, est latency {:.1} ms)",
                    p.site, p.boards, p.est_latency_ms
                ),
                None => println!("step 5 — placement: NO feasible site"),
            }
            println!("step 6 — operation verification:");
            for c in &plan.verification {
                println!(
                    "  {:<24} ref={:.6e} got={:.6e} {}",
                    c.case,
                    c.reference,
                    c.observed,
                    if c.passed { "PASS" } else { "FAIL" }
                );
            }
            export_trace(&opts, env.clock.obs())?;
        }
        "serve" => {
            // persistent offload daemon on simulated time: arrivals,
            // churn, incremental re-pack, DRR fairness, cache eviction
            require_fpga_target(&opts, "serve");
            let arrivals = match &opts.trace {
                Some(path) => {
                    let text = std::fs::read_to_string(path).map_err(|e| {
                        anyhow::anyhow!("cannot read --trace {path}: {e}")
                    })?;
                    Some(flopt::serve::parse_trace(&text)?)
                }
                None => None,
            };
            let sc = flopt::serve::ServeConfig {
                seed: opts.seed,
                requests: opts.requests,
                rate_per_h: opts.rate_per_h,
                tenants: opts.tenants,
                boards: opts.boards,
                epoch_s: opts.epoch_hours * 3600.0,
                churn: !opts.no_churn,
                quota: opts.quota,
                drr_quantum: opts.drr_quantum,
                pool: opts.pool,
                lanes: opts.cfg.compile_parallelism,
                cache_budget_bytes: opts.cache_budget,
                cache_ttl_s: opts.cache_ttl_hours.map(|h| h * 3600.0),
                cfg: opts.cfg.clone(),
                test_scale: !opts.full_scale,
                arrivals,
                ..flopt::serve::ServeConfig::default()
            };
            let (report, clock) = flopt::serve::run_serve_with_clock(&sc, build_cache(&opts))?;
            print!("{}", report.render());
            export_trace(&opts, clock.obs())?;
            export_metrics(&opts, clock.obs(), Some(&report.cache))?;
        }
        "gen" => {
            // seeded MiniC corpus on stdout: program `i` depends only on
            // (--seed, i), so any slice of the pool is reproducible
            for idx in 0..opts.count {
                if idx > 0 {
                    println!();
                }
                print!("{}", apps::gen::gen_source(opts.seed, idx as u64));
            }
            export_trace(&opts, &flopt::obs::Recorder::new(true))?;
        }
        "compare" => {
            let app = get_app(&opts);
            let be = single_backend(&opts, "compare");
            let analysis = analyze_app(app, !opts.full_scale)?;
            println!("search methods on the {} backend:", be.name());
            println!(
                "{:<12} {:>9} {:>8} {:>14}",
                "method", "speedup", "evals", "compile-hours"
            );
            let proposed_env = VerifyEnv::new(be, &XEON_3104, opts.cfg.clone())
                .with_cache(build_cache(&opts));
            {
                let t = search_with_analysis(app, &analysis, &proposed_env, &opts.cfg)?;
                println!(
                    "{:<12} {:>9.2} {:>8} {:>14.1}",
                    "proposed",
                    t.speedup(),
                    t.patterns_measured(),
                    t.compile_hours
                );
            }
            let ga_cfg = baselines::ga::GaConfig {
                population: opts.cfg.ga_population,
                generations: opts.cfg.ga_generations,
                ..baselines::ga::GaConfig::default()
            };
            for (name, out) in [
                ("ga", {
                    let env = VerifyEnv::new(be, &XEON_3104, opts.cfg.clone());
                    baselines::ga::search(&analysis, &env, &ga_cfg)
                }),
                ("exhaustive", {
                    let env = VerifyEnv::new(be, &XEON_3104, opts.cfg.clone());
                    baselines::exhaustive::search(&analysis, &env)
                }),
                ("naive-all", {
                    let env = VerifyEnv::new(be, &XEON_3104, opts.cfg.clone());
                    baselines::naive::search(&analysis, &env)
                }),
            ] {
                println!(
                    "{:<12} {:>9.2} {:>8} {:>14.1}",
                    name,
                    out.speedup(),
                    out.evaluations,
                    out.compile_hours
                );
            }
            export_trace(&opts, proposed_env.clock.obs())?;
        }
        _ => usage(),
    }
    Ok(())
}
