//! CPU time model — the all-CPU baseline of the paper's speedup ratios.
//!
//! The paper's baseline is the unmodified sequential C application on a
//! Xeon Bronze 3104 (6C/1.7 GHz, no turbo; the app uses one core).  We
//! model execution time from the dynamic profile's op counters with
//! per-op cycle costs calibrated to scalar (non-vectorized, `-O2`-like)
//! x86 (DESIGN.md §6):
//!
//! * float add/sub/mul: dependency-chained FP latency dominates in the
//!   paper's loop bodies (accumulators) — ~2.5 cycles effective;
//! * libm calls (`sinf`/`cosf`/`sqrtf`): ~8 cycles amortized (glibc
//!   polynomial kernels, partially pipelined);
//! * array access: ~1 cycle (L1-resident working sets at these sizes);
//! * int/branch ops: ~0.5 cycles (superscalar pairing).

use crate::interp::{LoopProfile, Profile};

/// Per-op cycle costs + clock of one CPU.
#[derive(Debug, Clone)]
pub struct CpuModel {
    /// CPU part name and clock.
    pub name: &'static str,
    /// Core clock in Hz.
    pub freq_hz: f64,
    /// Effective cycles per float arithmetic op.
    pub cycles_per_flop: f64,
    /// Effective cycles per libm call.
    pub cycles_per_math_call: f64,
    /// Effective cycles per array element access.
    pub cycles_per_mem_access: f64,
    /// Effective cycles per integer/branch op.
    pub cycles_per_int_op: f64,
    /// loop/call bookkeeping overhead per loop entry
    pub cycles_per_loop_entry: f64,
}

/// Xeon Bronze 3104 — the paper's verification/running machine CPU.
pub const XEON_3104: CpuModel = CpuModel {
    name: "Intel Xeon Bronze 3104 @ 1.70GHz",
    freq_hz: 1.7e9,
    cycles_per_flop: 2.5,
    cycles_per_math_call: 8.0,
    cycles_per_mem_access: 1.0,
    cycles_per_int_op: 0.5,
    cycles_per_loop_entry: 4.0,
};

impl CpuModel {
    fn time_from_counters(
        &self,
        flops: u64,
        math: u64,
        mem: u64,
        int_ops: u64,
        entries: u64,
    ) -> f64 {
        let cycles = flops as f64 * self.cycles_per_flop
            + math as f64 * self.cycles_per_math_call
            + mem as f64 * self.cycles_per_mem_access
            + int_ops as f64 * self.cycles_per_int_op
            + entries as f64 * self.cycles_per_loop_entry;
        cycles / self.freq_hz
    }

    /// Modeled time for one loop statement (its whole subtree).
    pub fn loop_time_s(&self, lp: &LoopProfile) -> f64 {
        self.time_from_counters(
            lp.flops,
            lp.math_calls,
            lp.mem_reads + lp.mem_writes,
            lp.int_ops,
            lp.entries,
        )
    }

    /// Modeled time for the whole program run.
    pub fn program_time_s(&self, p: &Profile) -> f64 {
        self.time_from_counters(
            p.total_flops,
            p.total_math_calls,
            p.total_mem_reads + p.total_mem_writes,
            p.total_int_ops,
            p.loops.values().map(|l| l.entries).sum(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::interp;

    #[test]
    fn flop_heavy_loop_time_scales_with_trips() {
        let src_small = "float a[100]; void main() { int i; \
            for (i = 0; i < 100; i++) { a[i] = a[i] * 2.0 + 1.0; } }";
        let src_big = "float a[100]; void main() { int i; int r; \
            for (r = 0; r < 10; r++) { \
              for (i = 0; i < 100; i++) { a[i] = a[i] * 2.0 + 1.0; } } }";
        let t_small = {
            let p = parse(src_small).unwrap();
            XEON_3104.program_time_s(&interp::profile_program(&p).unwrap())
        };
        let t_big = {
            let p = parse(src_big).unwrap();
            XEON_3104.program_time_s(&interp::profile_program(&p).unwrap())
        };
        let ratio = t_big / t_small;
        assert!((8.0..12.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn math_calls_cost_more_than_flops() {
        let flop_src = "float a[1000]; void main() { int i; \
            for (i = 0; i < 1000; i++) { a[i] = a[i] * 1.5; } }";
        let math_src = "float a[1000]; void main() { int i; \
            for (i = 0; i < 1000; i++) { a[i] = sin(a[i]); } }";
        let t = |s: &str| {
            let p = parse(s).unwrap();
            XEON_3104.program_time_s(&interp::profile_program(&p).unwrap())
        };
        assert!(t(math_src) > 1.5 * t(flop_src));
    }

    #[test]
    fn loop_time_below_program_time() {
        let src = "float a[500]; void main() { int i; \
            for (i = 0; i < 500; i++) { a[i] = 1.0; } \
            for (i = 0; i < 500; i++) { a[i] = a[i] + 1.0; } }";
        let p = parse(src).unwrap();
        let prof = interp::profile_program(&p).unwrap();
        let total = XEON_3104.program_time_s(&prof);
        for lp in prof.loops.values() {
            assert!(XEON_3104.loop_time_s(lp) < total);
        }
    }
}
