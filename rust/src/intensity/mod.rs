//! Arithmetic-intensity analysis — the paper's Step-2 narrowing signal.
//!
//! The paper (§3.3): *"算術強度は、ループ回数やデータ量が多いと増加し、
//! アクセス数が多いと減少する指標"* — intensity **rises** with trip count
//! and data volume and **falls** with access count.  We realize that as
//!
//! ```text
//! intensity(loop) = total float work (flops + math calls)
//!                   ───────────────────────────────────────
//!                        distinct bytes touched (footprint)
//! ```
//!
//! computed from the dynamic profile ([`crate::interp`]), which plays the
//! role of PGI 19.4's intensity analysis + gcov trip counts.  A loop that
//! streams a large array once with heavy math per element scores high; a
//! memory-shuffling loop scores low.  Ties (and the ranking the paper's
//! top-`a` cut needs) are broken by absolute float work so that a
//! 3-iteration loop never outranks the real hot loop.

use crate::cparse::ast::LoopId;
use crate::interp::Profile;
use crate::ir::LoopAnalysis;

/// Intensity metrics of one candidate loop.
#[derive(Debug, Clone)]
pub struct LoopIntensity {
    /// The loop statement this row describes.
    pub id: LoopId,
    /// enclosing function (diagnostics)
    pub function: String,
    /// total iterations observed on the sample workload
    pub trips: u64,
    /// total float work (arith flops + math-builtin calls)
    pub flops: u64,
    /// distinct bytes touched (min..max index ranges)
    pub footprint_bytes: u64,
    /// raw access traffic in bytes
    pub traffic_bytes: u64,
    /// flops / footprint — the narrowing key
    pub intensity: f64,
    /// whether the dependence tests allow offloading at all
    pub offloadable: bool,
}

/// Compute intensity for every *offloadable* loop that actually ran.
///
/// Non-offloadable loops are included with `offloadable = false` (the
/// report the paper logs shows them), but [`top_a`] skips them.
pub fn analyze(loops: &[LoopAnalysis], profile: &Profile) -> Vec<LoopIntensity> {
    let mut out = Vec::new();
    for la in loops {
        let Some(lp) = profile.loop_profile(la.info.id) else {
            continue; // never executed on the sample workload
        };
        let flops = lp.total_flops();
        let footprint = lp.footprint_bytes();
        let intensity = if footprint == 0 {
            0.0
        } else {
            flops as f64 / footprint as f64
        };
        out.push(LoopIntensity {
            id: la.info.id,
            function: la.info.function.to_string(),
            trips: lp.iterations,
            flops,
            footprint_bytes: footprint,
            traffic_bytes: lp.traffic_bytes(),
            intensity,
            offloadable: la.deps.offloadable,
        });
    }
    out
}

/// The paper's first narrowing: keep the top-`a` offloadable loops by
/// intensity.  Nested loops: when an ancestor and its descendant both
/// qualify, only the **outermost** offloadable loop stays a candidate —
/// the paper offloads a loop *statement*, which subsumes everything
/// nested inside it (and offloading the outer statement avoids paying
/// pipeline fill + transfer once per outer iteration).
pub fn top_a(
    all: &[LoopIntensity],
    loops: &[LoopAnalysis],
    a: usize,
) -> Vec<LoopIntensity> {
    let offloadable: Vec<&LoopIntensity> = all.iter().filter(|l| l.offloadable).collect();
    // keep only candidates with no offloadable ancestor candidate
    let mut cands: Vec<&LoopIntensity> = offloadable
        .iter()
        .filter(|c| {
            !offloadable
                .iter()
                .any(|anc| anc.id != c.id && is_ancestor(loops, anc.id, c.id))
        })
        .copied()
        .collect();
    // rank: intensity first (total order, NaN last), absolute float work
    // as tiebreak, loop id as the final deterministic tiebreak
    cands.sort_by(|x, y| {
        crate::util::order::desc_nan_last(x.intensity, y.intensity)
            .then_with(|| y.flops.cmp(&x.flops))
            .then_with(|| x.id.cmp(&y.id))
    });
    cands.into_iter().take(a).cloned().collect()
}

/// Is `anc` an ancestor loop of `desc`?
fn is_ancestor(loops: &[LoopAnalysis], anc: LoopId, desc: LoopId) -> bool {
    let mut cur = desc;
    loop {
        let Some(la) = loops.iter().find(|l| l.info.id == cur) else {
            return false;
        };
        match la.info.parent {
            Some(p) if p == anc => return true,
            Some(p) => cur = p,
            None => return false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::interp;
    use crate::ir;

    fn pipeline(src: &str) -> (Vec<ir::LoopAnalysis>, Vec<LoopIntensity>) {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        let prof = interp::profile_program(&p).unwrap();
        let ints = analyze(&loops, &prof);
        (loops, ints)
    }

    const TWO_LOOPS: &str = "
        float a[1000]; float b[1000];
        void main() {
            int i; int r;
            // hot: 40 math-heavy passes over a (outer loop is sequential —
            // pass r+1 reads pass r's values — but the inner loop offloads)
            for (r = 0; r < 40; r++) {
                for (i = 0; i < 1000; i++) { a[i] = a[i] * 1.5 + 0.5; }
            }
            // cold: one cheap pass over b
            for (i = 0; i < 1000; i++) { b[i] = b[i] + 1.0; }
        }";

    #[test]
    fn hot_loop_has_higher_intensity() {
        let (_, ints) = pipeline(TWO_LOOPS);
        // inner hot loop is id 1 (outer id 0 is not offloadable)
        let hot = ints.iter().find(|l| l.id.0 == 1).unwrap();
        let cold = ints.iter().find(|l| l.id.0 == 2).unwrap();
        assert!(hot.offloadable && cold.offloadable);
        assert!(!ints.iter().find(|l| l.id.0 == 0).unwrap().offloadable);
        assert!(hot.intensity > cold.intensity,
            "hot {} vs cold {}", hot.intensity, cold.intensity);
        // 40 entries * 1000 iters * 2 flops / 4000 B footprint = 20 fl/B
        assert!((hot.intensity - 20.0).abs() < 0.5, "{}", hot.intensity);
    }

    #[test]
    fn top_a_skips_non_offloadable_outer() {
        let (loops, ints) = pipeline(TWO_LOOPS);
        let top = top_a(&ints, &loops, 5);
        let ids: Vec<u32> = top.iter().map(|l| l.id.0).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn top_a_truncates() {
        let (loops, ints) = pipeline(TWO_LOOPS);
        assert_eq!(top_a(&ints, &loops, 1).len(), 1);
        assert_eq!(top_a(&ints, &loops, 1)[0].id.0, 1);
    }

    // NOTE: the inner counter is declared in its own header — were it a
    // function-scope `int j;`, the conservative scalar-dependence test
    // would (correctly, conservatively) reject the outer loop.
    const PARALLEL_NEST: &str = "
        float c[900];
        void main() {
            int i;
            for (i = 0; i < 30; i++) {
                for (int j = 0; j < 30; j++) { c[i * 30 + j] = i * 1.0 + j * 2.0; }
            }
        }";

    #[test]
    fn top_a_prefers_outermost_of_parallel_nest() {
        let (loops, ints) = pipeline(PARALLEL_NEST);
        let outer = ints.iter().find(|l| l.id.0 == 0).unwrap();
        assert!(outer.offloadable, "outer parallel loop must qualify");
        let top = top_a(&ints, &loops, 5);
        let ids: Vec<u32> = top.iter().map(|l| l.id.0).collect();
        // outer subsumes inner: only the outermost survives
        assert_eq!(ids, vec![0]);
    }

    #[test]
    fn non_offloadable_excluded_from_top_a() {
        let (loops, ints) = pipeline(
            "float a[100];
             void main() {
                 int i;
                 for (i = 1; i < 100; i++) { a[i] = a[i - 1] * 2.0; }
             }",
        );
        assert!(!ints[0].offloadable);
        assert!(top_a(&ints, &loops, 5).is_empty());
    }

    #[test]
    fn unexecuted_loops_skipped() {
        let (_, ints) = pipeline(
            "float a[10];
             void unused(int n) { int i; for (i = 0; i < n; i++) { a[i] = 0.0; } }
             void main() { a[0] = 1.0; }",
        );
        assert!(ints.is_empty());
    }
}
