//! Verification environment (検証環境): compiles offload patterns on the
//! simulated compile farm, measures the sample application under each
//! pattern, and cross-checks offloaded numerics through the PJRT
//! artifacts.
//!
//! Performance model of one measurement (the paper runs the app's
//! built-in sample benchmark):
//!
//! ```text
//! t(pattern) = t_cpu(all) − Σ_{L∈pattern} t_cpu(L) + Σ_{L∈pattern} t_dev(L)
//! ```
//!
//! with `t_dev` from the backend's offloaded-timing model (FPGA: the
//! pipelined single-work-item model; GPU: the calibrated SIMT model —
//! both include host↔device transfers).  The compile farm schedules the
//! backend's simulated compiles (FPGA: hours; GPU: minutes) over
//! `compile_parallelism` lanes (paper: 1).

use std::collections::HashMap;
use std::sync::Arc;

use crate::apps::App;
use crate::backend::{BackendReport, OffloadBackend};
use crate::cache::CacheStore;
use crate::config::SearchConfig;
use crate::cparse::ast::LoopId;
use crate::cpu::CpuModel;
use crate::fpga::timing;
use crate::metrics::SimClock;
use crate::opencl::OffloadPattern;
use crate::runtime::Runtime;

use super::pipeline::AppAnalysis;

/// Result of compiling + measuring one offload pattern.
#[derive(Debug, Clone)]
pub struct PatternMeasurement {
    /// The measured offload pattern.
    pub pattern: OffloadPattern,
    /// combined device utilization (incl. BSP)
    pub utilization: f64,
    /// did the simulated full compile produce a bitstream?
    pub compiled: bool,
    /// simulated compile seconds charged to the farm
    pub compile_sim_s: f64,
    /// measured wall-clock of the sample app under this pattern (model)
    pub time_s: f64,
    /// speedup vs. the all-CPU run (the paper's Fig-4 metric)
    pub speedup: f64,
    /// per-kernel device-side breakdown
    pub kernels: Vec<timing::KernelExec>,
}

/// Outcome of the PJRT numerics cross-check for a bound hot loop.
#[derive(Debug, Clone)]
pub struct NumericsCheck {
    /// Name of the checked FPGA-variant artifact.
    pub artifact: String,
    /// max |fpga − cpu-interpreter| over all output elements
    pub max_abs_err: f64,
    /// max |fpga − cpu-artifact| (pallas vs pure-jnp via PJRT)
    pub max_abs_err_vs_cpu_artifact: f64,
    /// Total output elements compared.
    pub elements: usize,
    /// Did both comparisons stay within tolerance?
    pub passed: bool,
}

/// The verification environment.
pub struct VerifyEnv<'a> {
    /// The offload backend patterns compile against.
    pub backend: &'a dyn OffloadBackend,
    /// The CPU model providing the all-CPU baseline.
    pub cpu: &'a CpuModel,
    /// Simulated clock tracking automation time.  `Arc` so a
    /// mixed-destination search can share one clock across backends.
    pub clock: Arc<SimClock>,
    /// Content-addressed artifact cache the staged pipeline routes
    /// through.  Defaults to a private in-memory store (inert for a
    /// one-shot search); hand in a shared / on-disk store via
    /// [`VerifyEnv::with_cache`] to reuse artifacts across searches.
    pub cache: Arc<CacheStore>,
    cfg: SearchConfig,
}

impl<'a> VerifyEnv<'a> {
    /// Build an environment with `cfg.compile_parallelism` compile lanes.
    pub fn new(backend: &'a dyn OffloadBackend, cpu: &'a CpuModel, cfg: SearchConfig) -> Self {
        let clock = Arc::new(SimClock::new(cfg.compile_parallelism.max(1)));
        Self::with_clock(backend, cpu, cfg, clock)
    }

    /// Build an environment on an existing (shared) simulated clock —
    /// the mixed-destination search accounts every backend on one clock.
    pub fn with_clock(
        backend: &'a dyn OffloadBackend,
        cpu: &'a CpuModel,
        cfg: SearchConfig,
        clock: Arc<SimClock>,
    ) -> Self {
        Self { backend, cpu, clock, cache: CacheStore::fresh(), cfg }
    }

    /// Route this environment's searches through a shared artifact cache
    /// (the CLI's `--cache-dir` store, or the batch service's store).
    pub fn with_cache(mut self, cache: Arc<CacheStore>) -> Self {
        self.cache = cache;
        self
    }

    /// The search configuration this environment was built with.
    pub fn config(&self) -> &SearchConfig {
        &self.cfg
    }

    /// All-CPU baseline time of the sample app (model).
    pub fn cpu_baseline_s(&self, analysis: &AppAnalysis) -> f64 {
        self.cpu.program_time_s(&analysis.profile)
    }

    /// Compile + measure one pattern.  `reports` must contain a
    /// [`BackendReport`] for every loop in the pattern.
    pub fn measure_pattern(
        &self,
        analysis: &AppAnalysis,
        reports: &HashMap<LoopId, BackendReport>,
        pattern: &OffloadPattern,
    ) -> PatternMeasurement {
        let refs: Vec<&BackendReport> = pattern
            .loops
            .iter()
            .map(|l| reports.get(l).expect("pattern loop has a pre-compile report"))
            .collect();
        let utilization = self.backend.combined_utilization(&refs);

        // full compile on the farm (FPGA: hours-scale; GPU: minutes)
        let outcome = self.backend.full_compile(&refs, &pattern.label());
        let compile_sim_s = outcome.sim_s;
        self.clock
            .schedule_compile(&format!("compile {}", pattern.label()), compile_sim_s);

        let cpu_total = self.cpu_baseline_s(analysis);
        if !outcome.ok {
            // no bitstream: the pattern cannot be measured
            return PatternMeasurement {
                pattern: pattern.clone(),
                utilization,
                compiled: false,
                compile_sim_s,
                time_s: f64::INFINITY,
                speedup: 0.0,
                kernels: Vec::new(),
            };
        }

        // measurement: run the sample benchmark once on the verification
        // machine (simulated time = the modeled app run)
        let mut kernels = Vec::new();
        let mut offloaded_cpu = 0.0;
        for l in &pattern.loops {
            let rep = reports.get(l).unwrap();
            kernels.push(self.backend.kernel_exec(
                &analysis.loops,
                &analysis.profile,
                self.cpu,
                rep,
            ));
            if let Some(lp) = analysis.profile.loop_profile(*l) {
                offloaded_cpu += self.cpu.loop_time_s(lp);
            }
        }
        let device_s = timing::pattern_fpga_time_s(&kernels);
        let time_s = (cpu_total - offloaded_cpu).max(0.0) + device_s;
        self.clock
            .advance_serial(&format!("measure {}", pattern.label()), time_s);

        PatternMeasurement {
            pattern: pattern.clone(),
            utilization,
            compiled: true,
            compile_sim_s,
            time_s,
            speedup: cpu_total / time_s,
            kernels,
        }
    }

    /// Cross-check the app's bound hot loop through the PJRT artifacts.
    ///
    /// Runs the app at **full scale** in the interpreter (the all-CPU
    /// reference), feeds the recorded inputs to both the FPGA (pallas)
    /// and CPU (pure-jnp) artifacts, and compares outputs.
    pub fn check_numerics(&self, app: &App, runtime: &Runtime) -> crate::Result<NumericsCheck> {
        let binding = app
            .binding
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("app `{}` has no artifact binding", app.name))?;

        let program = app.parse();
        let mut interp = app.interp(&program, false);
        interp
            .run_main()
            .map_err(|e| anyhow::anyhow!("interpreter: {e}"))?;

        let mut inputs = Vec::new();
        for (arr, len) in binding.inputs {
            let data = interp
                .read_array(arr)
                .map_err(|e| anyhow::anyhow!("input `{arr}`: {e}"))?;
            anyhow::ensure!(data.len() >= *len, "input `{arr}` too short");
            inputs.push(data[..*len].iter().map(|v| *v as f32).collect::<Vec<f32>>());
        }

        let fpga_out = runtime.execute_f32(binding.artifact, &inputs)?;
        let cpu_out = runtime.execute_f32(binding.cpu_artifact, &inputs)?;

        let mut max_err = 0.0f64;
        let mut max_err_vs_cpu = 0.0f64;
        let mut elements = 0usize;
        for (i, (arr, len)) in binding.outputs.iter().enumerate() {
            let reference = interp
                .read_array(arr)
                .map_err(|e| anyhow::anyhow!("output `{arr}`: {e}"))?;
            let got = &fpga_out[i];
            let cpu_got = &cpu_out[i];
            anyhow::ensure!(got.len() == *len, "output `{arr}` length mismatch");
            for k in 0..*len {
                let err = (got[k] as f64 - reference[k]).abs();
                max_err = max_err.max(err);
                let errc = (got[k] as f64 - cpu_got[k] as f64).abs();
                max_err_vs_cpu = max_err_vs_cpu.max(errc);
            }
            elements += len;
        }
        // tolerance: f32 accumulation over ≤512-term reductions
        let tol = 5e-2;
        Ok(NumericsCheck {
            artifact: binding.artifact.to_string(),
            max_abs_err: max_err,
            max_abs_err_vs_cpu_artifact: max_err_vs_cpu,
            elements,
            passed: max_err < tol && max_err_vs_cpu < tol,
        })
    }
}
