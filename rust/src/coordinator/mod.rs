//! L3 coordinator — the paper's contribution (環境適応処理 Steps 1–3 for
//! FPGA): narrow the loop candidates with arithmetic intensity and
//! resource efficiency, generate OpenCL offload patterns, compile and
//! measure only a handful on the verification environment, and pick the
//! fastest.
//!
//! * [`pipeline`] — the cache-aware search drivers
//!   ([`pipeline::offload_search`]);
//! * [`stages`] — the search body as six explicit, individually callable
//!   stages with typed artifacts (what the cache stores);
//! * [`verify_env`] — the verification environment: simulated compile
//!   farm + performance measurement + PJRT numerics cross-check;
//! * [`patterns`] — round-1/round-2 offload-pattern construction;
//! * [`mixed`] — the mixed-destination search (arXiv:2011.12431): every
//!   backend's own flow on one shared clock, winner per app (routed
//!   through the batch service, [`crate::service`]).

pub mod adapt;
pub mod mixed;
pub mod patterns;
pub mod pipeline;
pub mod stages;
pub mod verify_env;

pub use mixed::{mixed_search, mixed_search_all, DestinationSearch, MixedTrace};
pub use pipeline::{analyze_app, offload_search, AppAnalysis, CandidateReport, SearchTrace};
pub use stages::{EfficiencyCut, IntensityCut, MeasureArtifact, PrecompileArtifact};
pub use verify_env::{NumericsCheck, PatternMeasurement, VerifyEnv};
