//! The end-to-end offload search (paper Fig 2): code analysis → intensity
//! narrowing → OpenCL generation + pre-compile → resource-efficiency
//! narrowing → two measured rounds on the verification environment →
//! solution selection.
//!
//! The search body lives in [`super::stages`] as six explicit,
//! individually callable stages; the drivers here
//! ([`offload_search`], [`search_with_analysis`]) wire those stages
//! through the content-addressed artifact cache ([`crate::cache`]): a
//! stage whose artifact is already cached is skipped entirely — its
//! simulated time is *not* re-charged — and a fully warm search returns
//! the stored [`SearchTrace`] bit-identically while burning zero
//! additional simulated compile-lane hours.

use crate::apps::App;
use crate::backend::Destination;
use crate::cache;
use crate::config::SearchConfig;
use crate::cparse::ast::LoopId;
use crate::cparse::Program;
use crate::funcblock::{BlockMeasurement, BlockMode};
use crate::intensity::{self, LoopIntensity};
use crate::interp::Profile;
use crate::ir::{self, LoopAnalysis};
use crate::opencl::{self, OpenClCode};

use super::stages::{
    charge_precompile, stage_analyze, stage_block_narrow, stage_efficiency_narrow,
    stage_intensity_narrow, stage_measure_blocks, stage_measure_rounds, stage_precompile,
    stage_select, BlockMeasureArtifact, IntensityCut,
};
use super::verify_env::{PatternMeasurement, VerifyEnv};

/// Step-1/2 analysis products, reusable across searches.
pub struct AppAnalysis {
    /// Registry name of the analyzed app.
    pub app_name: String,
    /// Parsed program.
    pub program: Program,
    /// Per-loop structural + dependence analysis.
    pub loops: Vec<LoopAnalysis>,
    /// Dynamic profile of the sample run.
    pub profile: Profile,
    /// Intensity metrics of every executed loop.
    pub intensities: Vec<LoopIntensity>,
}

/// Analyze an app: parse, extract loops, profile on the sample workload,
/// compute intensities (paper Steps 1–2).
pub fn analyze_app(app: &App, test_scale: bool) -> crate::Result<AppAnalysis> {
    let program = app.parse();
    let loops = ir::analyze(&program);
    let mut it = app.interp(&program, test_scale);
    it.run_main().map_err(|e| anyhow::anyhow!("profiling `{}`: {e}", app.name))?;
    let profile = it.into_profile();
    let intensities = intensity::analyze(&loops, &profile);
    Ok(AppAnalysis {
        app_name: app.name.to_string(),
        program,
        loops,
        profile,
        intensities,
    })
}

/// A loop that survived the intensity cut, with its pre-compile report
/// and resource efficiency (the paper's 算術強度/リソース量).
#[derive(Debug, Clone)]
pub struct CandidateReport {
    /// The candidate loop.
    pub id: LoopId,
    /// Arithmetic intensity from the profile.
    pub intensity: f64,
    /// Device utilization of the pre-compiled kernel.
    pub utilization: f64,
    /// Resource efficiency: intensity / utilization.
    pub efficiency: f64,
    /// The full backend pre-compile report.
    pub report: crate::backend::BackendReport,
}

/// Everything the search recorded — the paper logs exactly this trace
/// ("算術強度、リソース効率、…途中情報と共に、…性能測定結果を記録").
#[derive(Debug, Clone)]
pub struct SearchTrace {
    /// Registry name of the searched app.
    pub app_name: String,
    /// Destination the search targeted.
    pub destination: Destination,
    /// total loop statements discovered (paper: tdfir 36, MRI-Q 16)
    pub loop_count: usize,
    /// all executed loops with intensity info
    pub intensities: Vec<LoopIntensity>,
    /// the top-a cut
    pub top_a: Vec<LoopId>,
    /// pre-compiled candidates with resource efficiency
    pub candidates: Vec<CandidateReport>,
    /// the top-c cut
    pub top_c: Vec<LoopId>,
    /// generated OpenCL for each measured pattern
    pub opencl: Vec<OpenClCode>,
    /// measured rounds (round 1 = singles, round 2 = combinations)
    pub rounds: Vec<Vec<PatternMeasurement>>,
    /// all-CPU baseline (model)
    pub cpu_time_s: f64,
    /// the solution among loop-statement patterns: fastest measured
    pub best: Option<PatternMeasurement>,
    /// function-block co-search mode this trace ran under
    pub block_mode: BlockMode,
    /// measured function-block placements (empty under `--blocks off`)
    pub blocks: Vec<BlockMeasurement>,
    /// fastest compiled block placement, if any was measured
    pub best_block: Option<BlockMeasurement>,
    /// **Canonical** simulated automation hours of this search: what a
    /// fully cold run charges (paper: ≈ half a day), derived purely from
    /// the stage artifacts — so the cached trace is byte-identical no
    /// matter which stages happened to be warm when it was built.  The
    /// hours actually *burned* by a given run live on its clock/meters.
    pub sim_hours: f64,
    /// Canonical simulated compile-lane hours of this search (same
    /// artifact-derived contract as `sim_hours`).
    pub compile_hours: f64,
}

impl SearchTrace {
    /// The paper's Fig-4 number for this app: the speedup of the overall
    /// solution — the better of the loop-statement and block-placement
    /// sides (so combined `--blocks on` search never reports worse than
    /// loop-only), exactly as [`SearchTrace::render`] prints it.  1.0
    /// when nothing was measured at all (the app stays on the CPU); a
    /// measured solution slower than the CPU reports its real sub-1.0
    /// number, as the loop-only flow always did.
    pub fn speedup(&self) -> f64 {
        self.solution_measurement()
            .map(|m| m.speedup)
            .unwrap_or(1.0)
    }

    /// Total placements measured: loop patterns (≤ d) plus block
    /// placements.
    pub fn patterns_measured(&self) -> usize {
        self.rounds.iter().map(|r| r.len()).sum::<usize>() + self.blocks.len()
    }

    /// Did a block placement strictly beat every loop pattern?  Ties go
    /// to the loop solution so `--blocks on` output degenerates to the
    /// loop-only output when blocks add nothing.
    pub fn solution_is_block(&self) -> bool {
        match (&self.best_block, &self.best) {
            (Some(b), Some(p)) => b.speedup > p.speedup,
            (Some(_), None) => true,
            _ => false,
        }
    }

    /// The overall solution as a pattern measurement: the winning loop
    /// pattern, or the winning block placement viewed as a pattern over
    /// its member + riding loops (what request-level reports carry).
    pub fn solution_measurement(&self) -> Option<PatternMeasurement> {
        if self.solution_is_block() {
            self.best_block.as_ref().map(block_pattern_measurement)
        } else {
            self.best.clone()
        }
    }

    /// Render the trace as the table the paper's evaluation logs.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== offload search: {} → {} ===\nloop statements found: {}\n",
            self.app_name, self.destination, self.loop_count
        ));
        out.push_str(&format!(
            "top-{} by arithmetic intensity: {:?}\n",
            self.top_a.len(),
            self.top_a.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        ));
        out.push_str("candidates (intensity / resource / efficiency):\n");
        for c in &self.candidates {
            out.push_str(&format!(
                "  {}: intensity={:.2}  util={:.3}  efficiency={:.2}\n",
                c.id, c.intensity, c.utilization, c.efficiency
            ));
        }
        out.push_str(&format!(
            "top-{} by resource efficiency: {:?}\n",
            self.top_c.len(),
            self.top_c.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        ));
        out.push_str(&format!("all-CPU baseline: {:.4} s (model)\n", self.cpu_time_s));
        for (i, round) in self.rounds.iter().enumerate() {
            out.push_str(&format!("round {}:\n", i + 1));
            for m in round {
                out.push_str(&format!(
                    "  pattern {:<10} util={:.3} compile={:.1}h {} time={:.5}s speedup={:.2}\n",
                    m.pattern.label(),
                    m.utilization,
                    m.compile_sim_s / 3600.0,
                    if m.compiled { "ok " } else { "FAIL" },
                    m.time_s,
                    m.speedup
                ));
            }
        }
        if !self.blocks.is_empty() {
            out.push_str(&format!(
                "block placements (IP registry, --blocks {}):\n",
                self.block_mode
            ));
            for m in &self.blocks {
                out.push_str(&format!(
                    "  {:<28} util={:.3} compile={:.1}h {} time={:.5}s speedup={:.2}\n",
                    m.label(),
                    m.utilization,
                    m.compile_sim_s / 3600.0,
                    if m.compiled { "ok " } else { "FAIL" },
                    m.time_s,
                    m.speedup
                ));
            }
        }
        if self.solution_is_block() {
            let b = self.best_block.as_ref().expect("block solution exists");
            out.push_str(&format!(
                "solution: block {} on {} — speedup {:.2}x vs all-CPU\n",
                b.label(),
                self.destination,
                b.speedup
            ));
        } else {
            match &self.best {
                Some(b) => out.push_str(&format!(
                    "solution: pattern {} on {} — speedup {:.2}x vs all-CPU\n",
                    b.pattern.label(),
                    self.destination,
                    b.speedup
                )),
                None => out.push_str(&format!(
                    "solution: none (no {} pattern beat the CPU)\n",
                    self.destination
                )),
            }
        }
        out.push_str(&format!(
            "automation time: {:.1} h simulated ({:.1} compile-lane hours)\n",
            self.sim_hours, self.compile_hours
        ));
        out
    }
}

/// View a function-block placement as a pattern measurement over its
/// member + riding loops (no per-kernel breakdown — the IP core is one
/// opaque implementation).  Request-level reports and the GA co-search
/// use this to carry a winning block in the `best` slot.
pub fn block_pattern_measurement(b: &BlockMeasurement) -> PatternMeasurement {
    let mut loops = b.block_loops.clone();
    loops.extend(b.extra_loops.iter().cloned());
    PatternMeasurement {
        pattern: crate::opencl::OffloadPattern::of(loops),
        utilization: b.utilization,
        compiled: b.compiled,
        compile_sim_s: b.compile_sim_s,
        time_s: b.time_s,
        speedup: b.speedup,
        kernels: Vec::new(),
    }
}

/// Charge the Steps 1–2 simulated time (code analysis + one profiled
/// run + intensity pass) for an analyzed app.  Shared by the
/// single-backend flow and the mixed-destination search so their clock
/// semantics cannot diverge.
pub fn charge_analysis(
    clock: &crate::metrics::SimClock,
    cpu: &crate::cpu::CpuModel,
    analysis: &AppAnalysis,
) {
    let sp = clock.span("stage.analyze", "pipeline");
    // Step 1: code analysis (sim: parse + libClang-equivalent walk)
    clock.advance_serial("code analysis", 30.0);
    // Step 2: profiling + intensity analysis (sim: one instrumented run
    // + PGI-style intensity pass)
    clock.advance_serial(
        "intensity analysis",
        120.0 + cpu.program_time_s(&analysis.profile),
    );
    clock.span_end(sp);
}

/// Record a cache hit on the clock's recorder: an instant marker span
/// plus a counter under the same dotted name (`cache.hit.<artifact>`).
pub(crate) fn cache_hit(clock: &crate::metrics::SimClock, name: &str) {
    clock.mark(name, "cache");
    clock.obs().count(name, 1);
}

/// Run the paper's full offload search for one app.
///
/// This is the canonical cached entry point: a warm trace-cache hit
/// returns the stored [`SearchTrace`] bit-identically without touching
/// the clock at all; otherwise the six stages run, each individually
/// skippable when its artifact is already cached.
pub fn offload_search(
    app: &App,
    env: &VerifyEnv<'_>,
    test_scale: bool,
) -> crate::Result<SearchTrace> {
    let trace_key = cache::trace_key(app, test_scale, env.backend, env.config());
    if let Some(t) = env.cache.get_trace(trace_key) {
        cache_hit(&env.clock, "cache.hit.trace");
        return Ok(t);
    }
    env.clock.obs().count("cache.miss.trace", 1);
    let cfg: SearchConfig = env.config().clone();
    let analysis = stage_analyze(app, test_scale, &env.cache, env.cpu, Some(&env.clock))?;
    let mut t = search_with_analysis(app, &analysis, env, &cfg)?;
    // the trace's canonical times cover the whole search *including*
    // Steps 1-2 when entered here (search_with_analysis stamped only its
    // own stages — its callers charge the analysis themselves)
    stamp_canonical_times(
        &mut t,
        Some((env.cpu, &analysis)),
        cfg.compile_parallelism,
    );
    env.cache.put_trace(trace_key, &t);
    Ok(t)
}

/// Stamp `sim_hours`/`compile_hours` with the trace's **canonical**
/// cost: replay the artifact-recorded work (optionally Steps 1–2, then
/// every pre-compile, then each pattern's compile + measurement, in
/// measurement order) onto a virtual fresh clock with the search's lane
/// count.  For a fully cold run this reproduces the live clock's charges
/// event-for-event; for a partially warm run it reports what the search
/// *costs*, independent of what this run happened to reuse — so a trace
/// stored under a cache key is a pure function of that key's inputs.
fn stamp_canonical_times(
    t: &mut SearchTrace,
    analysis_cost: Option<(&crate::cpu::CpuModel, &AppAnalysis)>,
    lanes: usize,
) {
    // untraced: this clock exists only to total the canonical charges —
    // the spans for the work live on the recorder of the clock that
    // actually performed it
    let clock = crate::metrics::SimClock::new_untraced(lanes.max(1));
    if let Some((cpu, analysis)) = analysis_cost {
        charge_analysis(&clock, cpu, analysis);
    }
    for c in &t.candidates {
        clock.advance_serial(&format!("precompile {}", c.id), c.report.precompile_s);
    }
    for round in &t.rounds {
        for m in round {
            clock.schedule_compile(&format!("compile {}", m.pattern.label()), m.compile_sim_s);
            if m.compiled {
                clock.advance_serial(&format!("measure {}", m.pattern.label()), m.time_s);
            }
        }
    }
    for m in &t.blocks {
        clock.schedule_compile(&format!("compile {}", m.label()), m.compile_sim_s);
        if m.compiled {
            clock.advance_serial(&format!("measure {}", m.label()), m.time_s);
        }
    }
    t.sim_hours = clock.total_hours();
    t.compile_hours = clock.compile_lane_seconds() / 3600.0;
}

/// The search after Steps 1–2 (reused by baselines and the ablations so
/// analysis cost is not re-paid per configuration).
///
/// Drives the staged pipeline ([`super::stages`]) through the artifact
/// cache on `env`: IntensityNarrow → Precompile → EfficiencyNarrow →
/// MeasureRounds → Select.  Cached stages are skipped and charge no
/// simulated time.
pub fn search_with_analysis(
    app: &App,
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> crate::Result<SearchTrace> {
    // `--blocks only` skips the loop-statement candidates entirely: no
    // pre-compiles, no measured rounds — the IP registry is the search.
    let loops_enabled = cfg.block_mode != BlockMode::Only;

    // ---- intensity cut (top a): pure, always recomputed ----------------
    let cut = if loops_enabled {
        let sp = env.clock.span("stage.intensity_narrow", "pipeline");
        let cut = stage_intensity_narrow(analysis, env.backend, cfg.a_intensity);
        env.clock.span_end(sp);
        cut
    } else {
        IntensityCut { top_a: Vec::new() }
    };

    // ---- kernel generation + backend pre-compile (minutes each) --------
    let pre_key = cache::precompile_key(app, analysis, env.backend, cfg);
    let pre = match env.cache.get_precompile(pre_key) {
        Some(p) => {
            cache_hit(&env.clock, "cache.hit.precompile");
            p
        }
        None => {
            env.clock.obs().count("cache.miss.precompile", 1);
            let sp = env.clock.span("stage.precompile", "pipeline");
            let p = stage_precompile(analysis, &cut, env.backend, cfg.b_unroll);
            charge_precompile(&env.clock, &p);
            env.clock.span_end(sp);
            env.cache.put_precompile(pre_key, &p);
            p
        }
    };

    // ---- resource-efficiency cut (top c): pure --------------------------
    let sp = env.clock.span("stage.efficiency_narrow", "pipeline");
    let eff = stage_efficiency_narrow(&pre, cfg.c_efficiency);
    env.clock.span_end(sp);

    // ---- two measured rounds on the verification environment ------------
    let meas_key = cache::measure_key(app, analysis, env.backend, cfg);
    let meas = match env.cache.get_measure(meas_key) {
        Some(m) => {
            cache_hit(&env.clock, "cache.hit.measure");
            m
        }
        None => {
            env.clock.obs().count("cache.miss.measure", 1);
            let sp = env.clock.span("stage.measure_rounds", "pipeline");
            let m = stage_measure_rounds(analysis, &pre, &eff, env, cfg);
            env.clock.span_end(sp);
            env.cache.put_measure(meas_key, &m);
            m
        }
    };

    // ---- function-block co-search (BlockNarrow + MeasureBlocks) ---------
    let blocks = if cfg.block_mode == BlockMode::Off {
        BlockMeasureArtifact::empty()
    } else {
        let blocks_key = cache::blocks_key(app, analysis, env.backend, cfg);
        match env.cache.get_blocks(blocks_key) {
            Some(b) => {
                cache_hit(&env.clock, "cache.hit.blocks");
                b
            }
            None => {
                env.clock.obs().count("cache.miss.blocks", 1);
                let sp = env.clock.span("stage.block_narrow", "pipeline");
                let offers = stage_block_narrow(analysis, env.backend, env.cpu, cfg.block_mode);
                env.clock.span_end(sp);
                let sp = env.clock.span("stage.measure_blocks", "pipeline");
                let b = stage_measure_blocks(analysis, &pre, &meas, &offers, env, cfg);
                env.clock.span_end(sp);
                env.cache.put_blocks(blocks_key, &b);
                b
            }
        }
    };

    // ---- solution --------------------------------------------------------
    let sp = env.clock.span("stage.select", "pipeline");
    let mut t = stage_select(
        analysis,
        env.backend.destination(),
        &cut,
        &pre,
        &eff,
        &meas,
        &blocks,
    );
    t.block_mode = cfg.block_mode;
    stamp_canonical_times(&mut t, None, cfg.compile_parallelism);
    env.clock.span_end(sp);
    Ok(t)
}

/// Generate the OpenCL for a pattern (kernels + ten-step host program).
pub fn generate_opencl(
    analysis: &AppAnalysis,
    pattern: &crate::opencl::OffloadPattern,
    cfg: &SearchConfig,
) -> OpenClCode {
    let kernels = pattern
        .loops
        .iter()
        .map(|l| {
            let la = analysis
                .loops
                .iter()
                .find(|x| x.info.id == *l)
                .expect("pattern loop exists");
            opencl::generate_kernel(&analysis.program, la, cfg.b_unroll)
        })
        .collect::<Vec<_>>();
    let host = opencl::generate_host(&analysis.app_name, pattern, &kernels);
    OpenClCode { pattern: pattern.clone(), kernels, host }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::cpu::XEON_3104;

    fn run_search(app: &crate::apps::App, test_scale: bool) -> SearchTrace {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(app, &env, test_scale).unwrap()
    }

    #[test]
    fn tdfir_search_selects_the_fir_nest() {
        let t = run_search(&apps::TDFIR, true);
        assert_eq!(t.loop_count, 36);
        assert!(t.top_a.contains(&LoopId(8)), "top-a {:?}", t.top_a);
        assert!(t.top_c.contains(&LoopId(8)), "top-c {:?}", t.top_c);
        let best = t.best.as_ref().expect("a pattern must win");
        assert!(
            best.pattern.loops.contains(&LoopId(8)),
            "solution {:?}",
            best.pattern
        );
        assert!(best.speedup > 1.0);
        assert!(t.patterns_measured() <= 4, "paper budget d=4");
    }

    #[test]
    fn mriq_search_selects_compute_q() {
        let t = run_search(&apps::MRIQ, true);
        assert_eq!(t.loop_count, 16);
        let best = t.best.as_ref().expect("a pattern must win");
        assert!(
            best.pattern.loops.contains(&LoopId(6)),
            "solution {:?}",
            best.pattern
        );
        assert!(best.speedup > 1.0);
    }

    #[test]
    fn narrowing_respects_a_and_c() {
        let t = run_search(&apps::TDFIR, true);
        assert!(t.top_a.len() <= 5);
        assert!(t.top_c.len() <= 3);
        assert!(t.top_c.iter().all(|c| t.top_a.contains(c)));
    }

    #[test]
    fn automation_time_is_hours_scale() {
        let t = run_search(&apps::TDFIR, true);
        // 3-4 patterns at ~3h each, sequential: ≥ 8h, ≤ 16h ("half a day")
        assert!(t.sim_hours > 6.0, "sim {} h", t.sim_hours);
        assert!(t.sim_hours < 20.0, "sim {} h", t.sim_hours);
    }

    #[test]
    fn opencl_generated_for_every_measured_pattern() {
        let t = run_search(&apps::TDFIR, true);
        assert_eq!(t.opencl.len(), t.patterns_measured());
        for code in &t.opencl {
            assert!(code.cl_source().contains("__kernel"));
            assert!(code.host.contains("[6/10] kernel execution"));
        }
    }

    #[test]
    fn trace_renders() {
        let t = run_search(&apps::MRIQ, true);
        assert_eq!(t.destination, Destination::Fpga);
        let s = t.render();
        assert!(s.contains("offload search: mriq → FPGA"));
        assert!(s.contains("solution:"));
        assert!(s.contains("on FPGA"));
        assert!(s.contains("automation time"));
    }
}
