//! The end-to-end offload search (paper Fig 2): code analysis → intensity
//! narrowing → OpenCL generation + pre-compile → resource-efficiency
//! narrowing → two measured rounds on the verification environment →
//! solution selection.

use std::collections::HashMap;

use crate::apps::App;
use crate::backend::{BackendReport, OffloadBackend};
use crate::config::SearchConfig;
use crate::cparse::ast::LoopId;
use crate::cparse::Program;
use crate::intensity::{self, LoopIntensity};
use crate::interp::Profile;
use crate::ir::{self, LoopAnalysis};
use crate::opencl::{self, OpenClCode};

use super::patterns;
use super::verify_env::{PatternMeasurement, VerifyEnv};

/// Step-1/2 analysis products, reusable across searches.
pub struct AppAnalysis {
    /// Registry name of the analyzed app.
    pub app_name: String,
    /// Parsed program.
    pub program: Program,
    /// Per-loop structural + dependence analysis.
    pub loops: Vec<LoopAnalysis>,
    /// Dynamic profile of the sample run.
    pub profile: Profile,
    /// Intensity metrics of every executed loop.
    pub intensities: Vec<LoopIntensity>,
}

/// Analyze an app: parse, extract loops, profile on the sample workload,
/// compute intensities (paper Steps 1–2).
pub fn analyze_app(app: &App, test_scale: bool) -> crate::Result<AppAnalysis> {
    let program = app.parse();
    let loops = ir::analyze(&program);
    let mut it = app.interp(&program, test_scale);
    it.run_main().map_err(|e| anyhow::anyhow!("profiling `{}`: {e}", app.name))?;
    let profile = it.into_profile();
    let intensities = intensity::analyze(&loops, &profile);
    Ok(AppAnalysis {
        app_name: app.name.to_string(),
        program,
        loops,
        profile,
        intensities,
    })
}

/// A loop that survived the intensity cut, with its pre-compile report
/// and resource efficiency (the paper's 算術強度/リソース量).
#[derive(Debug, Clone)]
pub struct CandidateReport {
    /// The candidate loop.
    pub id: LoopId,
    /// Arithmetic intensity from the profile.
    pub intensity: f64,
    /// Device utilization of the pre-compiled kernel.
    pub utilization: f64,
    /// Resource efficiency: intensity / utilization.
    pub efficiency: f64,
    /// The full backend pre-compile report.
    pub report: BackendReport,
}

/// Everything the search recorded — the paper logs exactly this trace
/// ("算術強度、リソース効率、…途中情報と共に、…性能測定結果を記録").
#[derive(Debug)]
pub struct SearchTrace {
    /// Registry name of the searched app.
    pub app_name: String,
    /// Destination the search targeted ("FPGA", "GPU", ...).
    pub destination: &'static str,
    /// total loop statements discovered (paper: tdfir 36, MRI-Q 16)
    pub loop_count: usize,
    /// all executed loops with intensity info
    pub intensities: Vec<LoopIntensity>,
    /// the top-a cut
    pub top_a: Vec<LoopId>,
    /// pre-compiled candidates with resource efficiency
    pub candidates: Vec<CandidateReport>,
    /// the top-c cut
    pub top_c: Vec<LoopId>,
    /// generated OpenCL for each measured pattern
    pub opencl: Vec<OpenClCode>,
    /// measured rounds (round 1 = singles, round 2 = combinations)
    pub rounds: Vec<Vec<PatternMeasurement>>,
    /// all-CPU baseline (model)
    pub cpu_time_s: f64,
    /// the solution: fastest measured pattern
    pub best: Option<PatternMeasurement>,
    /// total simulated automation time (hours) — paper: ≈ half a day
    pub sim_hours: f64,
    /// simulated compile-lane hours actually burned
    pub compile_hours: f64,
}

impl SearchTrace {
    /// The paper's Fig-4 number for this app.
    pub fn speedup(&self) -> f64 {
        self.best.as_ref().map(|b| b.speedup).unwrap_or(1.0)
    }

    /// Total patterns measured (≤ d).
    pub fn patterns_measured(&self) -> usize {
        self.rounds.iter().map(|r| r.len()).sum()
    }

    /// Render the trace as the table the paper's evaluation logs.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== offload search: {} → {} ===\nloop statements found: {}\n",
            self.app_name, self.destination, self.loop_count
        ));
        out.push_str(&format!(
            "top-{} by arithmetic intensity: {:?}\n",
            self.top_a.len(),
            self.top_a.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        ));
        out.push_str("candidates (intensity / resource / efficiency):\n");
        for c in &self.candidates {
            out.push_str(&format!(
                "  {}: intensity={:.2}  util={:.3}  efficiency={:.2}\n",
                c.id, c.intensity, c.utilization, c.efficiency
            ));
        }
        out.push_str(&format!(
            "top-{} by resource efficiency: {:?}\n",
            self.top_c.len(),
            self.top_c.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        ));
        out.push_str(&format!("all-CPU baseline: {:.4} s (model)\n", self.cpu_time_s));
        for (i, round) in self.rounds.iter().enumerate() {
            out.push_str(&format!("round {}:\n", i + 1));
            for m in round {
                out.push_str(&format!(
                    "  pattern {:<10} util={:.3} compile={:.1}h {} time={:.5}s speedup={:.2}\n",
                    m.pattern.label(),
                    m.utilization,
                    m.compile_sim_s / 3600.0,
                    if m.compiled { "ok " } else { "FAIL" },
                    m.time_s,
                    m.speedup
                ));
            }
        }
        match &self.best {
            Some(b) => out.push_str(&format!(
                "solution: pattern {} on {} — speedup {:.2}x vs all-CPU\n",
                b.pattern.label(),
                self.destination,
                b.speedup
            )),
            None => out.push_str(&format!(
                "solution: none (no {} pattern beat the CPU)\n",
                self.destination
            )),
        }
        out.push_str(&format!(
            "automation time: {:.1} h simulated ({:.1} compile-lane hours)\n",
            self.sim_hours, self.compile_hours
        ));
        out
    }
}

/// Charge the Steps 1–2 simulated time (code analysis + one profiled
/// run + intensity pass) for an analyzed app.  Shared by the
/// single-backend flow and the mixed-destination search so their clock
/// semantics cannot diverge.
pub fn charge_analysis(
    clock: &crate::metrics::SimClock,
    cpu: &crate::cpu::CpuModel,
    analysis: &AppAnalysis,
) {
    // Step 1: code analysis (sim: parse + libClang-equivalent walk)
    clock.advance_serial("code analysis", 30.0);
    // Step 2: profiling + intensity analysis (sim: one instrumented run
    // + PGI-style intensity pass)
    clock.advance_serial(
        "intensity analysis",
        120.0 + cpu.program_time_s(&analysis.profile),
    );
}

/// Run the paper's full offload search for one app.
pub fn offload_search(
    app: &App,
    env: &VerifyEnv<'_>,
    test_scale: bool,
) -> crate::Result<SearchTrace> {
    let cfg: SearchConfig = env.config().clone();
    let analysis = analyze_app(app, test_scale)?;
    charge_analysis(&env.clock, env.cpu, &analysis);
    search_with_analysis(app, &analysis, env, &cfg)
}

/// The search after Steps 1–2 (reused by baselines and the ablations so
/// analysis cost is not re-paid per configuration).
pub fn search_with_analysis(
    _app: &App,
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> crate::Result<SearchTrace> {
    // ---- intensity cut (top a) ----------------------------------------
    // Backend legality applies before the quota so a stricter device
    // backfills with the next-ranked legal loops instead of silently
    // under-filling `a`.  (No-op for the built-in backends today — the
    // dependence tests already decide — but the seam keeps stricter
    // devices possible.)
    let top_a_loops: Vec<LoopIntensity> =
        intensity::top_a(&analysis.intensities, &analysis.loops, usize::MAX)
            .into_iter()
            .filter(|li| {
                analysis
                    .loops
                    .iter()
                    .find(|l| l.info.id == li.id)
                    .map(|la| env.backend.offloadable(la))
                    .unwrap_or(false)
            })
            .take(cfg.a_intensity)
            .collect();
    let top_a: Vec<LoopId> = top_a_loops.iter().map(|l| l.id).collect();

    // ---- kernel generation + backend pre-compile (minutes each) --------
    let mut reports: HashMap<LoopId, BackendReport> = HashMap::new();
    let mut candidates = Vec::new();
    for li in &top_a_loops {
        let la = analysis
            .loops
            .iter()
            .find(|l| l.info.id == li.id)
            .expect("intensity refers to a known loop");
        let rep = env.backend.precompile(&analysis.program, la, cfg.b_unroll);
        env.clock.advance_serial(
            &format!("precompile {}", li.id),
            rep.precompile_s,
        );
        candidates.push(CandidateReport {
            id: li.id,
            intensity: li.intensity,
            utilization: rep.utilization,
            efficiency: li.intensity / rep.utilization,
            report: rep.clone(),
        });
        reports.insert(li.id, rep);
    }

    // ---- resource-efficiency cut (top c) --------------------------------
    let mut by_eff = candidates.clone();
    by_eff.sort_by(|a, b| b.efficiency.partial_cmp(&a.efficiency).unwrap());
    let top_c: Vec<LoopId> = by_eff
        .iter()
        .take(cfg.c_efficiency)
        .map(|c| c.id)
        .collect();

    // ---- round 1: singles ------------------------------------------------
    let d = cfg.d_patterns;
    let round1_pats: Vec<_> = patterns::round1(&top_c).into_iter().take(d).collect();
    let mut opencl_codes = Vec::new();
    let mut round1_meas = Vec::new();
    for pat in &round1_pats {
        opencl_codes.push(generate_opencl(analysis, pat, cfg));
        round1_meas.push(env.measure_pattern(analysis, &reports, pat));
    }

    // ---- round 2: combinations of the improving singles ------------------
    let budget = d.saturating_sub(round1_meas.len());
    let round2_pats =
        patterns::round2(&round1_meas, &reports, env.backend, cfg.resource_cap, budget);
    let mut round2_meas = Vec::new();
    for pat in &round2_pats {
        opencl_codes.push(generate_opencl(analysis, pat, cfg));
        round2_meas.push(env.measure_pattern(analysis, &reports, pat));
    }

    // ---- solution ---------------------------------------------------------
    let cpu_time_s = env.cpu_baseline_s(analysis);
    let best = round1_meas
        .iter()
        .chain(&round2_meas)
        .filter(|m| m.compiled)
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
        .cloned();

    let mut rounds = vec![round1_meas];
    if !round2_meas.is_empty() {
        rounds.push(round2_meas);
    }

    Ok(SearchTrace {
        app_name: analysis.app_name.clone(),
        destination: env.backend.name(),
        loop_count: analysis.program.loop_count(),
        intensities: analysis.intensities.clone(),
        top_a,
        candidates,
        top_c,
        opencl: opencl_codes,
        rounds,
        cpu_time_s,
        best,
        sim_hours: env.clock.total_hours(),
        compile_hours: env.clock.compile_lane_seconds() / 3600.0,
    })
}

/// Generate the OpenCL for a pattern (kernels + ten-step host program).
pub fn generate_opencl(
    analysis: &AppAnalysis,
    pattern: &crate::opencl::OffloadPattern,
    cfg: &SearchConfig,
) -> OpenClCode {
    let kernels = pattern
        .loops
        .iter()
        .map(|l| {
            let la = analysis
                .loops
                .iter()
                .find(|x| x.info.id == *l)
                .expect("pattern loop exists");
            opencl::generate_kernel(&analysis.program, la, cfg.b_unroll)
        })
        .collect::<Vec<_>>();
    let host = opencl::generate_host(&analysis.app_name, pattern, &kernels);
    OpenClCode { pattern: pattern.clone(), kernels, host }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::cpu::XEON_3104;

    fn run_search(app: &crate::apps::App, test_scale: bool) -> SearchTrace {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(app, &env, test_scale).unwrap()
    }

    #[test]
    fn tdfir_search_selects_the_fir_nest() {
        let t = run_search(&apps::TDFIR, true);
        assert_eq!(t.loop_count, 36);
        assert!(t.top_a.contains(&LoopId(8)), "top-a {:?}", t.top_a);
        assert!(t.top_c.contains(&LoopId(8)), "top-c {:?}", t.top_c);
        let best = t.best.as_ref().expect("a pattern must win");
        assert!(
            best.pattern.loops.contains(&LoopId(8)),
            "solution {:?}",
            best.pattern
        );
        assert!(best.speedup > 1.0);
        assert!(t.patterns_measured() <= 4, "paper budget d=4");
    }

    #[test]
    fn mriq_search_selects_compute_q() {
        let t = run_search(&apps::MRIQ, true);
        assert_eq!(t.loop_count, 16);
        let best = t.best.as_ref().expect("a pattern must win");
        assert!(
            best.pattern.loops.contains(&LoopId(6)),
            "solution {:?}",
            best.pattern
        );
        assert!(best.speedup > 1.0);
    }

    #[test]
    fn narrowing_respects_a_and_c() {
        let t = run_search(&apps::TDFIR, true);
        assert!(t.top_a.len() <= 5);
        assert!(t.top_c.len() <= 3);
        assert!(t.top_c.iter().all(|c| t.top_a.contains(c)));
    }

    #[test]
    fn automation_time_is_hours_scale() {
        let t = run_search(&apps::TDFIR, true);
        // 3-4 patterns at ~3h each, sequential: ≥ 8h, ≤ 16h ("half a day")
        assert!(t.sim_hours > 6.0, "sim {} h", t.sim_hours);
        assert!(t.sim_hours < 20.0, "sim {} h", t.sim_hours);
    }

    #[test]
    fn opencl_generated_for_every_measured_pattern() {
        let t = run_search(&apps::TDFIR, true);
        assert_eq!(t.opencl.len(), t.patterns_measured());
        for code in &t.opencl {
            assert!(code.cl_source().contains("__kernel"));
            assert!(code.host.contains("[6/10] kernel execution"));
        }
    }

    #[test]
    fn trace_renders() {
        let t = run_search(&apps::MRIQ, true);
        assert_eq!(t.destination, "FPGA");
        let s = t.render();
        assert!(s.contains("offload search: mriq → FPGA"));
        assert!(s.contains("solution:"));
        assert!(s.contains("on FPGA"));
        assert!(s.contains("automation time"));
    }
}
