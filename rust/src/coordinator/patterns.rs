//! Offload-pattern construction (paper §3.3 / §4).
//!
//! Round 1: one pattern per surviving single loop ("まず、選択された単
//! ループ文に対してパターンを作って…性能測定する").
//!
//! Round 2: combinations of the loops whose single-loop patterns beat the
//! CPU ("高速化できる単ループ文に対してはその組み合わせのパターンも2回目
//! に作り"), skipping combinations whose summed resources blow the cap
//! ("上限値に納まらない場合は、その組合せパターンは作らない"), within the
//! remaining measurement budget `d − |round 1|`.

use std::collections::HashMap;

use crate::backend::{BackendReport, OffloadBackend};
use crate::cparse::ast::LoopId;
use crate::opencl::OffloadPattern;
use crate::util::order;

use super::verify_env::PatternMeasurement;

/// Round-1 patterns: singles, in ranking order.
pub fn round1(top_c: &[LoopId]) -> Vec<OffloadPattern> {
    top_c.iter().map(|l| OffloadPattern::single(*l)).collect()
}

/// Round-2 patterns: combinations of improving loops.
pub fn round2(
    round1_results: &[PatternMeasurement],
    reports: &HashMap<LoopId, BackendReport>,
    backend: &dyn OffloadBackend,
    resource_cap: f64,
    budget: usize,
) -> Vec<OffloadPattern> {
    // loops whose single pattern compiled and beat the CPU, best first
    let mut improving: Vec<(&PatternMeasurement, LoopId)> = round1_results
        .iter()
        .filter(|m| m.compiled && m.speedup > 1.0 && m.pattern.loops.len() == 1)
        .map(|m| (m, m.pattern.loops[0]))
        .collect();
    improving.sort_by(|a, b| {
        order::desc_nan_last(a.0.speedup, b.0.speedup).then_with(|| a.1.cmp(&b.1))
    });
    let ids: Vec<LoopId> = improving.iter().map(|(_, id)| *id).collect();

    // candidate combinations: larger subsets first within each size tier,
    // pairs before triples etc. in greedy best-speedup order
    let mut combos: Vec<(f64, OffloadPattern)> = Vec::new();
    let n = ids.len();
    for size in 2..=n {
        for subset in subsets_of_size(&ids, size) {
            // estimated gain: sum of measured individual gains
            let est: f64 = improving
                .iter()
                .filter(|(_, id)| subset.contains(id))
                .map(|(m, _)| m.speedup - 1.0)
                .sum();
            combos.push((est, OffloadPattern::of(subset)));
        }
    }
    combos.sort_by(|a, b| order::desc_nan_last(a.0, b.0).then_with(|| a.1.cmp(&b.1)));

    let mut out = Vec::new();
    for (_, pat) in combos {
        if out.len() >= budget {
            break;
        }
        let refs: Vec<&BackendReport> = pat
            .loops
            .iter()
            .filter_map(|l| reports.get(l))
            .collect();
        if refs.len() != pat.loops.len() {
            continue;
        }
        if backend.combined_utilization(&refs) > resource_cap {
            continue; // paper: over-cap combinations are never built
        }
        out.push(pat);
    }
    out
}

fn subsets_of_size(ids: &[LoopId], size: usize) -> Vec<Vec<LoopId>> {
    let mut out = Vec::new();
    let n = ids.len();
    if size > n {
        return out;
    }
    // small n (≤ ~8): bitmask enumeration is fine
    for mask in 1u32..(1 << n) {
        if mask.count_ones() as usize == size {
            out.push(
                (0..n)
                    .filter(|i| mask & (1 << i) != 0)
                    .map(|i| ids[i])
                    .collect(),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{FPGA, ReportDetail};
    use crate::opencl::OffloadPattern;

    fn meas(id: u32, speedup: f64, compiled: bool) -> PatternMeasurement {
        PatternMeasurement {
            pattern: OffloadPattern::single(LoopId(id)),
            utilization: 0.3,
            compiled,
            compile_sim_s: 3.0 * 3600.0,
            time_s: 1.0 / speedup.max(1e-9),
            speedup,
            kernels: Vec::new(),
        }
    }

    #[test]
    fn round1_is_one_pattern_per_loop() {
        let pats = round1(&[LoopId(1), LoopId(5)]);
        assert_eq!(pats.len(), 2);
        assert_eq!(pats[0].label(), "L1");
        assert_eq!(pats[1].label(), "L5");
    }

    #[test]
    fn round2_combines_improving_loops() {
        let r1 = vec![meas(1, 3.0, true), meas(3, 1.5, true), meas(5, 0.8, true)];
        let reports = fake_reports(&[1, 3, 5]);
        let pats = round2(&r1, &reports, &FPGA, 0.85, 4);
        // L5 did not improve: only the L1+L3 pair remains
        assert_eq!(pats, vec![OffloadPattern::of(vec![LoopId(1), LoopId(3)])]);
    }

    #[test]
    fn round2_respects_budget() {
        let r1 = vec![meas(1, 3.0, true), meas(3, 2.0, true), meas(5, 1.5, true)];
        let reports = fake_reports(&[1, 3, 5]);
        let pats = round2(&r1, &reports, &FPGA, 0.85, 1);
        assert_eq!(pats.len(), 1);
        // all three improved: their full combination has the largest
        // estimated gain and wins the single remaining slot
        assert_eq!(
            pats[0],
            OffloadPattern::of(vec![LoopId(1), LoopId(3), LoopId(5)])
        );
    }

    #[test]
    fn round2_skips_failed_compiles() {
        let r1 = vec![meas(1, 3.0, false), meas(3, 2.0, true)];
        let reports = fake_reports(&[1, 3]);
        let pats = round2(&r1, &reports, &FPGA, 0.85, 4);
        assert!(pats.is_empty(), "only one improving loop => no combos");
    }

    #[test]
    fn round2_enforces_resource_cap() {
        let r1 = vec![meas(1, 3.0, true), meas(3, 2.0, true)];
        let mut reports = fake_reports(&[1, 3]);
        // inflate L3's resources so the pair blows the cap
        if let Some(r) = reports.get_mut(&LoopId(3)) {
            if let ReportDetail::Fpga(hls) = &mut r.detail {
                hls.resources.alms = crate::fpga::ARRIA10_GX.total.alms * 0.9;
            }
        }
        let pats = round2(&r1, &reports, &FPGA, 0.85, 4);
        assert!(pats.is_empty());
    }

    fn fake_reports(ids: &[u32]) -> HashMap<LoopId, BackendReport> {
        use crate::cparse::parse;
        use crate::ir;
        // a real small kernel report, duplicated under several ids
        let p = parse(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = a[i] * 2.0; } }",
        )
        .unwrap();
        let loops = ir::analyze(&p);
        let base = FPGA.precompile(&p, &loops[0], 1);
        ids.iter()
            .map(|id| {
                let mut r = base.clone();
                r.loop_id = LoopId(*id);
                if let ReportDetail::Fpga(hls) = &mut r.detail {
                    hls.loop_id = LoopId(*id);
                }
                (LoopId(*id), r)
            })
            .collect()
    }
}
