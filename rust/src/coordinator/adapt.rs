//! Environment-adaptation Steps 4–6 (paper §3.1, Fig 1).
//!
//! The paper's flow continues past the code-conversion Steps 1–3 this
//! repo reproduces in full:
//!
//! * **Step 4 — リソース量調整** (resource-amount adjustment): given a
//!   throughput target, size the deployment — how many FPGA instances
//!   (and whether the chosen pattern's utilization allows multiple
//!   kernel instances per device);
//! * **Step 5 — 配置場所調整** (placement): choose the running
//!   environment from the facility-resource DB;
//! * **Step 6 — 実行ファイル配置と動作検証** (deploy + operation
//!   verification): install the solution pattern and run the test-case
//!   DB against it (the paper cites Jenkins; here a self-contained
//!   runner that replays the app's sample checks and compares against
//!   the all-CPU reference).

use crate::apps::App;
use crate::cparse::ast::LoopId;
use crate::fpga::device::Device;
use crate::metrics::SimClock;

use super::verify_env::PatternMeasurement;

/// Step 4 output: a sized deployment plan.
#[derive(Debug, Clone)]
pub struct ResourcePlan {
    /// requests/s one board sustains with the solution pattern
    pub per_board_rps: f64,
    /// kernel instances that fit on one device (resource replication)
    pub instances_per_board: usize,
    /// boards needed for the target
    pub boards: usize,
    /// headroom factor actually provisioned
    pub provisioned_rps: f64,
}

/// Step 4: size the deployment for `target_rps` sample-workload runs/s.
pub fn plan_resources(
    best: &PatternMeasurement,
    device: &Device,
    target_rps: f64,
) -> ResourcePlan {
    // replicate the kernel while the pattern still fits the device
    let kernel_frac = (best.utilization - device.bsp_frac).max(1e-6);
    let spare = (1.0 - device.bsp_frac - kernel_frac).max(0.0);
    let instances_per_board = 1 + (spare / kernel_frac).floor() as usize;
    let per_instance_rps = 1.0 / best.time_s.max(1e-12);
    let per_board_rps = per_instance_rps * instances_per_board as f64;
    let boards = (target_rps / per_board_rps).ceil().max(1.0) as usize;
    ResourcePlan {
        per_board_rps,
        instances_per_board,
        boards,
        provisioned_rps: per_board_rps * boards as f64,
    }
}

/// A facility-resource-DB entry (Step 5 candidates).
#[derive(Debug, Clone)]
pub struct Site {
    /// Site identifier.
    pub name: &'static str,
    /// FPGA boards currently free at the site.
    pub free_fpga_boards: usize,
    /// network RTT from the clients this app serves
    pub client_rtt_ms: f64,
    /// per-board-hour cost (arbitrary units)
    pub cost: f64,
}

/// Step 5 output.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Chosen site.
    pub site: &'static str,
    /// Boards reserved there.
    pub boards: usize,
    /// Estimated client-observed latency (RTT + one app run).
    pub est_latency_ms: f64,
}

/// Step 5: place `plan.boards` on the cheapest site that has capacity
/// and meets the latency bound.
pub fn choose_placement(
    plan: &ResourcePlan,
    sites: &[Site],
    max_latency_ms: f64,
    app_time_s: f64,
) -> Option<Placement> {
    let mut feasible: Vec<&Site> = sites
        .iter()
        .filter(|s| s.free_fpga_boards >= plan.boards)
        .filter(|s| s.client_rtt_ms + app_time_s * 1e3 <= max_latency_ms)
        .collect();
    feasible.sort_by(|a, b| {
        crate::util::order::asc_nan_last(a.cost, b.cost).then_with(|| a.name.cmp(b.name))
    });
    feasible.first().map(|s| Placement {
        site: s.name,
        boards: plan.boards,
        est_latency_ms: s.client_rtt_ms + app_time_s * 1e3,
    })
}

/// One operation-verification test case (the paper's テストケースDB).
#[derive(Debug, Clone)]
pub struct TestCase {
    /// Test-case name (unique within the app's DB).
    pub name: String,
    /// global scalar overrides applied before the run
    pub overrides: Vec<(String, i64)>,
    /// stats slot checked
    pub stat_index: usize,
    /// relative tolerance vs. the all-CPU reference
    pub rtol: f64,
}

/// Default test-case DB for an app: the sample workload at two scales.
pub fn default_cases(app: &App) -> Vec<TestCase> {
    let mut cases = vec![TestCase {
        name: format!("{}-sample-full", app.name),
        overrides: vec![],
        stat_index: 0,
        rtol: 1e-6,
    }];
    if !app.test_scale.is_empty() {
        cases.push(TestCase {
            name: format!("{}-sample-small", app.name),
            overrides: app
                .test_scale
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            stat_index: 0,
            rtol: 1e-6,
        });
    }
    cases
}

/// Step 6 outcome for one case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Name of the executed test case.
    pub case: String,
    /// All-CPU reference value.
    pub reference: f64,
    /// Value observed on the deployed configuration.
    pub observed: f64,
    /// Did the observation match within tolerance?
    pub passed: bool,
}

/// Step 6: run the test-case DB.  The offloaded deployment's numerics
/// are represented by a second interpreter run (the FPGA path is
/// bit-compatible for these kernels — `verify_env::check_numerics`
/// proves the PJRT artifact agrees); what Step 6 adds is the
/// *operational* check: every test case runs end-to-end on the deployed
/// configuration and matches the reference output.
pub fn verify_operation(app: &App, clock: &SimClock) -> crate::Result<Vec<CaseResult>> {
    let program = app.parse();
    let mut out = Vec::new();
    for case in default_cases(app) {
        let run = |with_overrides: bool| -> crate::Result<f64> {
            let mut it = crate::interp::Interp::new(&program);
            if with_overrides {
                for (k, v) in &case.overrides {
                    it.set_global(k, crate::interp::Value::Int(*v));
                }
            }
            it.run_main().map_err(|e| anyhow::anyhow!("{e}"))?;
            let stats = it.read_array(app.stats_array).map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok(stats[case.stat_index])
        };
        // reference and deployed run use the same configuration
        let reference = run(!case.overrides.is_empty())?;
        let observed = run(!case.overrides.is_empty())?;
        let denom = reference.abs().max(1e-12);
        let passed = ((observed - reference) / denom).abs() <= case.rtol;
        clock.advance_serial(&format!("testcase {}", case.name), 30.0);
        out.push(CaseResult { case: case.name, reference, observed, passed });
    }
    Ok(out)
}

/// The full Step 4→6 adaptation record.
#[derive(Debug, Clone)]
pub struct AdaptationPlan {
    /// The deployed offload pattern.
    pub pattern: Vec<LoopId>,
    /// Step-4 sizing decision.
    pub resources: ResourcePlan,
    /// Step-5 placement decision (None when no site fits).
    pub placement: Option<Placement>,
    /// Step-6 operation-verification results.
    pub verification: Vec<CaseResult>,
}

/// Run Steps 4–6 after an offload search.
pub fn adapt(
    app: &App,
    best: &PatternMeasurement,
    device: &Device,
    sites: &[Site],
    target_rps: f64,
    max_latency_ms: f64,
    clock: &SimClock,
) -> crate::Result<AdaptationPlan> {
    let resources = plan_resources(best, device, target_rps);
    let placement = choose_placement(&resources, sites, max_latency_ms, best.time_s);
    let verification = verify_operation(app, clock)?;
    Ok(AdaptationPlan {
        pattern: best.pattern.loops.clone(),
        resources,
        placement,
        verification,
    })
}

/// Demo facility DB (matches the paper's verification/running split).
pub fn demo_sites() -> Vec<Site> {
    vec![
        Site { name: "edge-tokyo", free_fpga_boards: 2, client_rtt_ms: 2.0, cost: 3.0 },
        Site { name: "dc-musashino", free_fpga_boards: 16, client_rtt_ms: 8.0, cost: 1.0 },
        Site { name: "dc-osaka", free_fpga_boards: 8, client_rtt_ms: 15.0, cost: 0.8 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::config::SearchConfig;
    use crate::coordinator::pipeline::offload_search;
    use crate::coordinator::verify_env::VerifyEnv;
    use crate::cpu::XEON_3104;
    use crate::fpga::ARRIA10_GX;

    fn best_of(app: &crate::apps::App) -> PatternMeasurement {
        let env = VerifyEnv::new(&FPGA, &XEON_3104, SearchConfig::default());
        offload_search(app, &env, true).unwrap().best.unwrap()
    }

    #[test]
    fn resource_plan_scales_with_target() {
        let best = best_of(&apps::MRIQ);
        let p1 = plan_resources(&best, &ARRIA10_GX, 100.0);
        let p2 = plan_resources(&best, &ARRIA10_GX, 100_000.0);
        assert!(p2.boards >= p1.boards);
        assert!(p1.instances_per_board >= 1);
        assert!(p1.provisioned_rps >= 100.0);
    }

    #[test]
    fn small_kernels_replicate_on_one_board() {
        let best = best_of(&apps::TDFIR);
        let p = plan_resources(&best, &ARRIA10_GX, 1.0);
        // utilization ~0.2 incl. BSP => several instances fit
        assert!(p.instances_per_board >= 2, "{p:?}");
        assert_eq!(p.boards, 1);
    }

    #[test]
    fn placement_prefers_cheapest_feasible() {
        let best = best_of(&apps::TDFIR);
        let plan = plan_resources(&best, &ARRIA10_GX, 10.0);
        let placement =
            choose_placement(&plan, &demo_sites(), 1000.0, best.time_s).expect("feasible");
        // dc-osaka is cheapest and has capacity at this scale
        assert_eq!(placement.site, "dc-osaka");
    }

    #[test]
    fn placement_respects_latency_bound() {
        let best = best_of(&apps::TDFIR);
        let plan = plan_resources(&best, &ARRIA10_GX, 10.0);
        // tight bound excludes the far DCs
        let placement = choose_placement(&plan, &demo_sites(), 3.0, 0.0005).expect("edge fits");
        assert_eq!(placement.site, "edge-tokyo");
        // impossible bound -> no placement
        assert!(choose_placement(&plan, &demo_sites(), 0.1, best.time_s).is_none());
    }

    #[test]
    fn operation_verification_passes_for_all_apps() {
        let clock = SimClock::new(1);
        for app in [&apps::HISTOGRAM, &apps::MATMUL] {
            let results = verify_operation(app, &clock).unwrap();
            assert!(!results.is_empty());
            for r in &results {
                assert!(r.passed, "{}: {:?}", app.name, r);
            }
        }
        assert!(clock.total_seconds() > 0.0, "verification consumes sim time");
    }

    #[test]
    fn full_adaptation_plan() {
        let best = best_of(&apps::HISTOGRAM);
        let clock = SimClock::new(1);
        let plan = adapt(
            &apps::HISTOGRAM,
            &best,
            &ARRIA10_GX,
            &demo_sites(),
            50.0,
            1000.0,
            &clock,
        )
        .unwrap();
        assert!(plan.placement.is_some());
        assert!(plan.verification.iter().all(|c| c.passed));
        assert_eq!(plan.pattern, best.pattern.loops);
    }
}
