//! Mixed-destination offload (the follow-up proposal, arXiv:2011.12431):
//! run every available backend's *own* search flow for an application on
//! one shared simulated clock, then pick the winning destination.
//!
//! Each backend declares its feasible search method
//! ([`crate::backend::SearchMethod`]): the FPGA runs the paper's
//! narrowed two-round flow (compiles are hours), the GPU runs the
//! measurement-driven GA of [Yamato 2018] (compiles are minutes).  The
//! winner is the destination whose best pattern beats the all-CPU
//! baseline by the most; when nothing improves, the app stays on the
//! CPU — mixed placement never loses to all-CPU.

use std::sync::Arc;

use crate::apps::App;
use crate::backend::{OffloadBackend, SearchMethod};
use crate::baselines::ga::{self, GaConfig};
use crate::config::SearchConfig;
use crate::cpu::CpuModel;
use crate::metrics::SimClock;

use super::pipeline::{analyze_app, charge_analysis, search_with_analysis, AppAnalysis};
use super::verify_env::{PatternMeasurement, VerifyEnv};

/// Outcome of one backend's search for one app.
#[derive(Debug)]
pub struct DestinationSearch {
    /// Registry name of the searched app.
    pub app_name: String,
    /// Destination the search targeted ("FPGA", "GPU").
    pub destination: &'static str,
    /// Search flow that produced the result.
    pub method: &'static str,
    /// Best speedup found vs. all-CPU (may be < 1 when nothing improved).
    pub speedup: f64,
    /// The winning measured pattern, if any compiled.
    pub best: Option<PatternMeasurement>,
    /// Patterns compiled + measured by this search.
    pub patterns_measured: usize,
    /// Compile-lane hours this search burned on the shared clock.
    pub compile_hours: f64,
}

impl DestinationSearch {
    /// One-destination report (the `--target gpu` CLI output).
    pub fn render(&self) -> String {
        let pattern = self
            .best
            .as_ref()
            .map(|b| b.pattern.label())
            .unwrap_or_else(|| "none".to_string());
        format!(
            "=== offload search: {} → {} ({}) ===\n\
             patterns measured: {}\n\
             compile-lane hours: {:.1}\n\
             solution: pattern {} on {} — speedup {:.2}x vs all-CPU\n",
            self.app_name,
            self.destination,
            self.method,
            self.patterns_measured,
            self.compile_hours,
            pattern,
            self.destination,
            self.speedup
        )
    }
}

/// The mixed-destination record for one app.
#[derive(Debug)]
pub struct MixedTrace {
    /// Registry name of the searched app.
    pub app_name: String,
    /// All-CPU baseline time of the sample run (model).
    pub cpu_time_s: f64,
    /// Per-backend search outcomes, in search order.
    pub searches: Vec<DestinationSearch>,
    /// Winning destination ("FPGA", "GPU", or "CPU" when nothing won).
    pub winner: &'static str,
    /// Speedup of the winning placement (1.0 when staying on CPU).
    pub speedup: f64,
    /// Total simulated hours on the shared clock after this app.
    pub sim_hours: f64,
}

impl MixedTrace {
    /// Render the mixed-destination table for this app.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== mixed-destination offload: {} ===\n\
             all-CPU baseline: {:.4} s (model)\n",
            self.app_name, self.cpu_time_s
        ));
        for s in &self.searches {
            out.push_str(&format!(
                "  {:<6} {:<16} speedup {:>6.2}x  patterns {:>3}  compile-lane {:>6.1} h\n",
                s.destination, s.method, s.speedup, s.patterns_measured, s.compile_hours
            ));
        }
        out.push_str(&format!(
            "destination: {} — {:.2}x vs all-CPU\n\
             automation time (shared clock): {:.1} h simulated\n",
            self.winner, self.speedup, self.sim_hours
        ));
        out
    }
}

/// Run one backend's own search flow for an analyzed app.
///
/// Dispatches on [`OffloadBackend::search_method`]: narrowed two-round
/// for hours-scale compiles, measurement-driven GA for minutes-scale.
pub fn destination_search(
    app: &App,
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> crate::Result<DestinationSearch> {
    let meter = env.clock.compile_meter();
    let out = match env.backend.search_method() {
        SearchMethod::NarrowedTwoRound => {
            let t = search_with_analysis(app, analysis, env, cfg)?;
            DestinationSearch {
                app_name: analysis.app_name.clone(),
                destination: env.backend.name(),
                method: "narrowed-2round",
                speedup: t.speedup(),
                best: t.best.clone(),
                patterns_measured: t.patterns_measured(),
                compile_hours: meter.lane_hours(),
            }
        }
        SearchMethod::MeasurementGa => {
            let ga_cfg = GaConfig {
                population: cfg.ga_population,
                generations: cfg.ga_generations,
                ..GaConfig::default()
            };
            let out = ga::search(analysis, env, &ga_cfg);
            DestinationSearch {
                app_name: analysis.app_name.clone(),
                destination: env.backend.name(),
                method: "ga",
                speedup: out.speedup(),
                best: out.best,
                patterns_measured: out.evaluations,
                compile_hours: meter.lane_hours(),
            }
        }
    };
    Ok(out)
}

/// Mixed-destination search for one app on a fresh clock.
pub fn mixed_search(
    app: &App,
    backends: &[&'static dyn OffloadBackend],
    cpu: &CpuModel,
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<MixedTrace> {
    let clock = Arc::new(SimClock::new(cfg.compile_parallelism.max(1)));
    mixed_search_with_clock(app, backends, cpu, cfg, test_scale, clock)
}

/// Mixed-destination search for one app on an existing shared clock
/// (the `flopt --target mixed` run accounts all apps on one clock).
pub fn mixed_search_with_clock(
    app: &App,
    backends: &[&'static dyn OffloadBackend],
    cpu: &CpuModel,
    cfg: &SearchConfig,
    test_scale: bool,
    clock: Arc<SimClock>,
) -> crate::Result<MixedTrace> {
    // Steps 1-2 run once per app and are shared by every backend.
    let analysis = analyze_app(app, test_scale)?;
    charge_analysis(&clock, cpu, &analysis);

    let mut searches = Vec::new();
    for b in backends {
        let env = VerifyEnv::with_clock(*b, cpu, cfg.clone(), clock.clone());
        searches.push(destination_search(app, &analysis, &env, cfg)?);
    }

    let best = searches
        .iter()
        .filter(|s| s.best.is_some() && s.speedup > 1.0)
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap());
    let (winner, speedup) = match best {
        Some(s) => (s.destination, s.speedup),
        None => ("CPU", 1.0),
    };

    Ok(MixedTrace {
        app_name: app.name.to_string(),
        cpu_time_s: cpu.program_time_s(&analysis.profile),
        searches,
        winner,
        speedup,
        sim_hours: clock.total_hours(),
    })
}

/// Mixed-destination search over several apps on **one** shared clock.
pub fn mixed_search_all(
    apps: &[&App],
    backends: &[&'static dyn OffloadBackend],
    cpu: &CpuModel,
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<Vec<MixedTrace>> {
    let clock = Arc::new(SimClock::new(cfg.compile_parallelism.max(1)));
    let mut traces = Vec::new();
    for app in apps {
        traces.push(mixed_search_with_clock(
            app,
            backends,
            cpu,
            cfg,
            test_scale,
            clock.clone(),
        )?);
    }
    Ok(traces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::Target;
    use crate::cpu::XEON_3104;

    #[test]
    fn mixed_runs_both_backends_and_never_loses_to_cpu() {
        let t = mixed_search(
            &apps::MATMUL,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        assert_eq!(t.searches.len(), 2);
        assert_eq!(t.searches[0].destination, "FPGA");
        assert_eq!(t.searches[1].destination, "GPU");
        assert_eq!(t.searches[0].method, "narrowed-2round");
        assert_eq!(t.searches[1].method, "ga");
        assert!(t.speedup >= 1.0, "mixed never loses to CPU: {}", t.speedup);
        assert!(["FPGA", "GPU", "CPU"].contains(&t.winner));
        assert!(t.sim_hours > 0.0);
    }

    #[test]
    fn shared_clock_accumulates_across_apps() {
        let apps_list: Vec<&crate::apps::App> = vec![&apps::HISTOGRAM, &apps::MATMUL];
        let traces = mixed_search_all(
            &apps_list,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        assert_eq!(traces.len(), 2);
        // the second app's snapshot includes the first app's time
        assert!(traces[1].sim_hours > traces[0].sim_hours);
    }

    #[test]
    fn gpu_destination_search_uses_minutes_scale_compiles() {
        let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&crate::backend::GPU, &XEON_3104, cfg.clone());
        let ds = destination_search(&apps::HISTOGRAM, &analysis, &env, &cfg).unwrap();
        assert_eq!(ds.destination, "GPU");
        assert_eq!(ds.method, "ga");
        assert!(ds.patterns_measured > 0);
        // every GPU evaluation is a minutes-long build, not hours
        let per_eval_h = ds.compile_hours / ds.patterns_measured as f64;
        assert!(per_eval_h < 0.5, "per-eval {per_eval_h} h");
        let rendered = ds.render();
        assert!(rendered.contains("→ GPU (ga)"), "{rendered}");
    }

    #[test]
    fn mixed_trace_renders() {
        let t = mixed_search(
            &apps::HISTOGRAM,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        let s = t.render();
        assert!(s.contains("mixed-destination offload: histogram"));
        assert!(s.contains("FPGA"));
        assert!(s.contains("GPU"));
        assert!(s.contains("destination:"));
    }
}
