//! Mixed-destination offload (the follow-up proposal, arXiv:2011.12431):
//! run every available backend's *own* search flow for an application on
//! one shared simulated clock, then pick the winning destination.
//!
//! Each backend declares its feasible search method
//! ([`crate::backend::SearchMethod`]): the FPGA runs the paper's
//! narrowed two-round flow (compiles are hours), the GPU runs the
//! measurement-driven GA of [Yamato 2018] (compiles are minutes).  The
//! winner is the destination whose best pattern beats the all-CPU
//! baseline by the most; when nothing improves, the app stays on the
//! CPU — mixed placement never loses to all-CPU.
//!
//! Since the batch-service refactor this module is a thin veneer over
//! [`crate::service::BatchService`]: `mixed_search_all` submits one
//! request per app × backend, the service analyzes each app once,
//! dedupes identical work through the artifact cache, runs the searches
//! concurrently, and accounts everything on one shared clock in
//! deterministic submission order.

use std::collections::HashMap;

use crate::apps::App;
use crate::backend::{BackendReport, Destination, OffloadBackend, SearchMethod, Target};
use crate::baselines::ga::{self, GaConfig};
use crate::config::SearchConfig;
use crate::cparse::ast::LoopId;
use crate::cpu::CpuModel;
use crate::funcblock::BlockMode;
use crate::service::{BatchRequest, BatchService};
use crate::util::order;

use super::pipeline::{block_pattern_measurement, AppAnalysis};
use super::stages::{measure_block_placement, stage_block_narrow};
use super::verify_env::{PatternMeasurement, VerifyEnv};

/// Outcome of one backend's search for one app.
#[derive(Debug, Clone)]
pub struct DestinationSearch {
    /// Registry name of the searched app.
    pub app_name: String,
    /// Destination the search targeted.
    pub destination: Destination,
    /// Search flow that produced the result.
    pub method: &'static str,
    /// Best speedup found vs. all-CPU (may be < 1 when nothing improved).
    pub speedup: f64,
    /// The winning measured pattern, if any compiled.
    pub best: Option<PatternMeasurement>,
    /// Patterns compiled + measured by this search.
    pub patterns_measured: usize,
    /// Compile-lane hours this search burned (0 when served warm from
    /// the artifact cache).
    pub compile_hours: f64,
    /// All-CPU baseline time the search compared against (model).
    pub cpu_time_s: f64,
}

impl DestinationSearch {
    /// One-destination report (the `--target gpu` CLI output).
    pub fn render(&self) -> String {
        let pattern = self
            .best
            .as_ref()
            .map(|b| b.pattern.label())
            .unwrap_or_else(|| "none".to_string());
        format!(
            "=== offload search: {} → {} ({}) ===\n\
             patterns measured: {}\n\
             compile-lane hours: {:.1}\n\
             solution: pattern {} on {} — speedup {:.2}x vs all-CPU\n",
            self.app_name,
            self.destination,
            self.method,
            self.patterns_measured,
            self.compile_hours,
            pattern,
            self.destination,
            self.speedup
        )
    }
}

/// The mixed-destination record for one app.
#[derive(Debug, Clone)]
pub struct MixedTrace {
    /// Registry name of the searched app.
    pub app_name: String,
    /// All-CPU baseline time of the sample run (model).
    pub cpu_time_s: f64,
    /// Per-backend search outcomes, in search order.
    pub searches: Vec<DestinationSearch>,
    /// Winning destination (CPU when nothing improved).
    pub winner: Destination,
    /// Speedup of the winning placement (1.0 when staying on CPU).
    pub speedup: f64,
    /// Total simulated hours on the shared clock after this app.
    pub sim_hours: f64,
}

impl MixedTrace {
    /// Render the mixed-destination table for this app.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "=== mixed-destination offload: {} ===\n\
             all-CPU baseline: {:.4} s (model)\n",
            self.app_name, self.cpu_time_s
        ));
        for s in &self.searches {
            out.push_str(&format!(
                "  {:<6} {:<16} speedup {:>6.2}x  patterns {:>3}  compile-lane {:>6.1} h\n",
                s.destination, s.method, s.speedup, s.patterns_measured, s.compile_hours
            ));
        }
        out.push_str(&format!(
            "destination: {} — {:.2}x vs all-CPU\n\
             automation time (shared clock): {:.1} h simulated\n",
            self.winner, self.speedup, self.sim_hours
        ));
        out
    }
}

/// Run one backend's own search flow for an analyzed app on the caller's
/// environment (the single-destination `flopt offload --target gpu`
/// path; the batch service drives the same dispatch through
/// [`crate::service::BatchService`]).
///
/// Dispatches on [`OffloadBackend::search_method`]: narrowed two-round
/// for hours-scale compiles, measurement-driven GA for minutes-scale.
pub fn destination_search(
    app: &App,
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> crate::Result<DestinationSearch> {
    let out = match env.backend.search_method() {
        SearchMethod::NarrowedTwoRound => {
            let meter = env.clock.compile_meter();
            let t = super::pipeline::search_with_analysis(app, analysis, env, cfg)?;
            DestinationSearch {
                app_name: analysis.app_name.clone(),
                destination: env.backend.destination(),
                method: "narrowed-2round",
                speedup: t.speedup(),
                best: t.solution_measurement(),
                patterns_measured: t.patterns_measured(),
                compile_hours: meter.lane_hours(),
                cpu_time_s: t.cpu_time_s,
            }
        }
        SearchMethod::MeasurementGa => ga_destination_search(analysis, env, cfg),
    };
    Ok(out)
}

/// The measurement-driven GA flow for one backend, plus the function-
/// block co-search when `--blocks` is on: every registry offer is
/// measured as a standalone placement next to the GA result, and the
/// best wins.  Under `--blocks only` the GA itself is skipped — the IP
/// registry *is* the search.  Shared by [`destination_search`] and the
/// batch service so the two paths cannot diverge.
pub fn ga_destination_search(
    analysis: &AppAnalysis,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> DestinationSearch {
    let meter = env.clock.compile_meter();
    let (mut best, mut measured): (Option<PatternMeasurement>, usize) =
        if cfg.block_mode == BlockMode::Only {
            (None, 0)
        } else {
            let ga_cfg = GaConfig {
                population: cfg.ga_population,
                generations: cfg.ga_generations,
                ..GaConfig::default()
            };
            let out = ga::search(analysis, env, &ga_cfg);
            (out.best, out.evaluations)
        };
    if cfg.block_mode != BlockMode::Off {
        let offers = stage_block_narrow(analysis, env.backend, env.cpu, cfg.block_mode);
        let no_reports: HashMap<LoopId, BackendReport> = HashMap::new();
        for offer in &offers.offers {
            if offer.utilization > cfg.resource_cap {
                continue; // over-cap IP: never built
            }
            let m = measure_block_placement(analysis, &no_reports, offer, &[], env);
            measured += 1;
            let current = best.as_ref().map(|b| b.speedup).unwrap_or(0.0);
            if m.compiled && m.speedup > current {
                best = Some(block_pattern_measurement(&m));
            }
        }
    }
    DestinationSearch {
        app_name: analysis.app_name.clone(),
        destination: env.backend.destination(),
        // under --blocks only the GA never ran: the registry was the search
        method: if cfg.block_mode == BlockMode::Only { "ip-registry" } else { "ga" },
        speedup: best.as_ref().map(|b| b.speedup).unwrap_or(1.0),
        best,
        patterns_measured: measured,
        compile_hours: meter.lane_hours(),
        cpu_time_s: env.cpu_baseline_s(analysis),
    }
}

/// Mixed-destination search for one app on a fresh service.
pub fn mixed_search(
    app: &'static App,
    backends: &[&'static dyn OffloadBackend],
    cpu: &CpuModel,
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<MixedTrace> {
    let traces = mixed_search_all(&[app], backends, cpu, cfg, test_scale)?;
    Ok(traces.into_iter().next().expect("one app in, one trace out"))
}

/// Mixed-destination search over several apps on **one** shared clock:
/// one batch request per app × backend, submitted app-major so the
/// per-app clock snapshots accumulate in app order.
pub fn mixed_search_all(
    apps: &[&'static App],
    backends: &[&'static dyn OffloadBackend],
    cpu: &CpuModel,
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<Vec<MixedTrace>> {
    let service = BatchService::new(backends.len().max(2), cfg.compile_parallelism, cpu);
    mixed_search_on(&service, apps, backends, cfg, test_scale)
}

/// [`mixed_search_all`] on an existing [`BatchService`] (shared clock,
/// cache, and worker pool — e.g. the CLI's `--cache-dir` store).
pub fn mixed_search_on(
    service: &BatchService,
    apps: &[&'static App],
    backends: &[&'static dyn OffloadBackend],
    cfg: &SearchConfig,
    test_scale: bool,
) -> crate::Result<Vec<MixedTrace>> {
    let mut requests = Vec::new();
    for app in apps {
        for b in backends {
            let target = match b.destination() {
                Destination::Fpga => Target::Fpga,
                Destination::Gpu => Target::Gpu,
                Destination::Cpu => {
                    anyhow::bail!("the CPU is the baseline, not a searchable backend")
                }
            };
            requests.push(BatchRequest {
                app: *app,
                target,
                cfg: cfg.clone(),
                test_scale,
            });
        }
    }
    let report = service.run(&requests)?;

    let per_app = backends.len();
    let mut traces = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        let items = &report.items[i * per_app..(i + 1) * per_app];
        let searches: Vec<DestinationSearch> =
            items.iter().map(|it| it.outcome.clone()).collect();
        // NaN speedups are rejected, exact ties go to search order (the
        // FPGA is searched first), so the winner is deterministic.
        let best = order::select_best(
            searches
                .iter()
                .enumerate()
                .filter(|(_, s)| s.best.is_some() && s.speedup > 1.0),
            |(_, s)| s.speedup,
            |(i, _)| *i,
        )
        .map(|(_, s)| s);
        let (winner, speedup) = match best {
            Some(s) => (s.destination, s.speedup),
            None => (Destination::Cpu, 1.0),
        };
        traces.push(MixedTrace {
            app_name: app.name.to_string(),
            cpu_time_s: searches
                .first()
                .map(|s| s.cpu_time_s)
                .unwrap_or_default(),
            searches,
            winner,
            speedup,
            sim_hours: items
                .last()
                .map(|it| it.sim_hours_after)
                .unwrap_or_default(),
        });
    }
    Ok(traces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::Target;
    use crate::coordinator::pipeline::analyze_app;
    use crate::cpu::XEON_3104;

    #[test]
    fn mixed_runs_both_backends_and_never_loses_to_cpu() {
        let t = mixed_search(
            &apps::MATMUL,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        assert_eq!(t.searches.len(), 2);
        assert_eq!(t.searches[0].destination, Destination::Fpga);
        assert_eq!(t.searches[1].destination, Destination::Gpu);
        assert_eq!(t.searches[0].method, "narrowed-2round");
        assert_eq!(t.searches[1].method, "ga");
        assert!(t.speedup >= 1.0, "mixed never loses to CPU: {}", t.speedup);
        assert!(
            [Destination::Fpga, Destination::Gpu, Destination::Cpu].contains(&t.winner)
        );
        assert!(t.sim_hours > 0.0);
    }

    #[test]
    fn shared_clock_accumulates_across_apps() {
        let apps_list: Vec<&'static crate::apps::App> = vec![&apps::HISTOGRAM, &apps::MATMUL];
        let traces = mixed_search_all(
            &apps_list,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        assert_eq!(traces.len(), 2);
        // the second app's snapshot includes the first app's time
        assert!(traces[1].sim_hours > traces[0].sim_hours);
    }

    #[test]
    fn gpu_destination_search_uses_minutes_scale_compiles() {
        let analysis = analyze_app(&apps::HISTOGRAM, true).unwrap();
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&crate::backend::GPU, &XEON_3104, cfg.clone());
        let ds = destination_search(&apps::HISTOGRAM, &analysis, &env, &cfg).unwrap();
        assert_eq!(ds.destination, Destination::Gpu);
        assert_eq!(ds.method, "ga");
        assert!(ds.patterns_measured > 0);
        assert!(ds.cpu_time_s > 0.0);
        // every GPU evaluation is a minutes-long build, not hours
        let per_eval_h = ds.compile_hours / ds.patterns_measured as f64;
        assert!(per_eval_h < 0.5, "per-eval {per_eval_h} h");
        let rendered = ds.render();
        assert!(rendered.contains("→ GPU (ga)"), "{rendered}");
    }

    #[test]
    fn mixed_trace_renders() {
        let t = mixed_search(
            &apps::HISTOGRAM,
            &Target::Mixed.backends(),
            &XEON_3104,
            &SearchConfig::default(),
            true,
        )
        .unwrap();
        let s = t.render();
        assert!(s.contains("mixed-destination offload: histogram"));
        assert!(s.contains("FPGA"));
        assert!(s.contains("GPU"));
        assert!(s.contains("destination:"));
    }
}
