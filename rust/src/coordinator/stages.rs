//! The offload search as explicit, individually callable stages.
//!
//! The paper's Fig-2 flow (Steps 1–3 + verification) decomposes into six
//! stages, each consuming the previous stage's typed artifact:
//!
//! ```text
//! Analyze          -> Arc<AppAnalysis>      (parse, profile, intensity)
//! IntensityNarrow  -> IntensityCut          (top-a by arithmetic intensity)
//! Precompile       -> PrecompileArtifact    (HLS/trial builds, resource efficiency)
//! EfficiencyNarrow -> EfficiencyCut         (top-c by resource efficiency)
//! MeasureRounds    -> MeasureArtifact       (two measured rounds on the farm)
//! BlockNarrow      -> BlockArtifact         (function-block detection + IP offers)
//! MeasureBlocks    -> BlockMeasureArtifact  (block placements on the farm)
//! Select           -> SearchTrace           (the solution + the logged trace)
//! ```
//!
//! The two block stages (`flopt --blocks {on,only}`) co-search
//! function-block replacement ([`crate::funcblock`]) with the loop
//! statement candidates: a block *subsumes* its member loops, so each
//! combined placement strips subsumed loops from the loop-statement
//! pattern it rides with, and the selector takes the best of both kinds
//! — combined search never loses to loop-only search.
//!
//! Stages are *re-entrant*: every function here is a pure function of
//! its inputs (MeasureRounds additionally charges the simulated clock it
//! is handed, exactly as the pre-refactor monolith did), so a driver may
//! run one stage, persist its artifact, and resume later — that is what
//! [`crate::cache`] does, and why a warm re-run burns zero additional
//! simulated compile-lane hours.  The drivers in
//! [`super::pipeline::offload_search`] and
//! [`super::pipeline::search_with_analysis`] wire the stages through the
//! cache; `rust/tests/backends.rs` pins the composed result bit-identical
//! to composing the device models by hand.

use std::collections::HashMap;
use std::sync::Arc;

use crate::apps::App;
use crate::backend::{BackendReport, Destination, OffloadBackend};
use crate::cache::{self, CacheStore};
use crate::config::SearchConfig;
use crate::cparse::ast::LoopId;
use crate::cpu::CpuModel;
use crate::funcblock::{self, BlockMeasurement, BlockMode, BlockOffer};
use crate::intensity::{self, LoopIntensity};
use crate::metrics::SimClock;
use crate::opencl::OpenClCode;
use crate::util::order;

use super::patterns;
use super::pipeline::{
    analyze_app, charge_analysis, generate_opencl, AppAnalysis, CandidateReport, SearchTrace,
};
use super::verify_env::{PatternMeasurement, VerifyEnv};

/// Artifact of the IntensityNarrow stage: the top-`a` offloadable loops
/// by arithmetic intensity, in rank order.
#[derive(Debug, Clone)]
pub struct IntensityCut {
    /// Surviving loops with their intensity rows, best first.
    pub top_a: Vec<LoopIntensity>,
}

impl IntensityCut {
    /// The surviving loop ids, in rank order.
    pub fn ids(&self) -> Vec<LoopId> {
        self.top_a.iter().map(|l| l.id).collect()
    }
}

/// Artifact of the Precompile stage: per-candidate cost/resource reports
/// with the paper's resource-efficiency metric, in intensity-rank order.
#[derive(Debug, Clone)]
pub struct PrecompileArtifact {
    /// One report per surviving candidate.
    pub candidates: Vec<CandidateReport>,
}

impl PrecompileArtifact {
    /// Per-loop backend reports (what pattern measurement consumes).
    pub fn reports(&self) -> HashMap<LoopId, BackendReport> {
        self.candidates
            .iter()
            .map(|c| (c.id, c.report.clone()))
            .collect()
    }
}

/// Artifact of the EfficiencyNarrow stage: the top-`c` candidates by
/// resource efficiency.
#[derive(Debug, Clone)]
pub struct EfficiencyCut {
    /// Surviving loop ids, best efficiency first.
    pub top_c: Vec<LoopId>,
}

/// Artifact of the MeasureRounds stage: everything the verification
/// environment produced — generated OpenCL, both measured rounds, and
/// the all-CPU baseline they were compared against.
#[derive(Debug, Clone)]
pub struct MeasureArtifact {
    /// All-CPU baseline of the sample run (model).
    pub cpu_time_s: f64,
    /// Generated OpenCL for each measured pattern, in measurement order.
    pub opencl: Vec<OpenClCode>,
    /// measured rounds (round 1 = singles, round 2 = combinations)
    pub rounds: Vec<Vec<PatternMeasurement>>,
}

/// Stage 1 — Analyze: parse, profile, compute intensities (paper Steps
/// 1–2), memoized through the cache.  Charges the Steps-1/2 simulated
/// time on `clock` only when the analysis is actually computed — a cache
/// hit reuses the artifact and burns nothing.
pub fn stage_analyze(
    app: &App,
    test_scale: bool,
    cache: &CacheStore,
    cpu: &CpuModel,
    clock: Option<&SimClock>,
) -> crate::Result<Arc<AppAnalysis>> {
    let key = cache::analyze_key(app, test_scale);
    if let Some(a) = cache.get_analysis(key) {
        if let Some(clock) = clock {
            super::pipeline::cache_hit(clock, "cache.hit.analysis");
        }
        return Ok(a);
    }
    let analysis = Arc::new(analyze_app(app, test_scale)?);
    if let Some(clock) = clock {
        clock.obs().count("cache.miss.analysis", 1);
        charge_analysis(clock, cpu, &analysis);
    }
    cache.put_analysis(key, Arc::clone(&analysis));
    Ok(analysis)
}

/// Stage 2 — IntensityNarrow: the top-`a` cut.  Backend legality applies
/// before the quota so a stricter device backfills with the next-ranked
/// legal loops instead of silently under-filling `a`.
pub fn stage_intensity_narrow(
    analysis: &AppAnalysis,
    backend: &dyn OffloadBackend,
    a_intensity: usize,
) -> IntensityCut {
    let top_a = intensity::top_a(&analysis.intensities, &analysis.loops, usize::MAX)
        .into_iter()
        .filter(|li| {
            analysis
                .loops
                .iter()
                .find(|l| l.info.id == li.id)
                .map(|la| backend.offloadable(la))
                .unwrap_or(false)
        })
        .take(a_intensity)
        .collect();
    IntensityCut { top_a }
}

/// Stage 3 — Precompile: kernel generation + backend cost estimation
/// (minutes each) for every surviving candidate.  Pure — the driver
/// charges the simulated pre-compile time when (and only when) this
/// stage actually ran; see [`charge_precompile`].
pub fn stage_precompile(
    analysis: &AppAnalysis,
    cut: &IntensityCut,
    backend: &dyn OffloadBackend,
    b_unroll: usize,
) -> PrecompileArtifact {
    let mut candidates = Vec::new();
    for li in &cut.top_a {
        let la = analysis
            .loops
            .iter()
            .find(|l| l.info.id == li.id)
            .expect("intensity refers to a known loop");
        let rep = backend.precompile(&analysis.program, la, b_unroll);
        candidates.push(CandidateReport {
            id: li.id,
            intensity: li.intensity,
            utilization: rep.utilization,
            efficiency: li.intensity / rep.utilization,
            report: rep,
        });
    }
    PrecompileArtifact { candidates }
}

/// Charge the simulated pre-compile time of a freshly computed
/// [`PrecompileArtifact`] (one serial HLS/trial build per candidate,
/// in candidate order — identical to the pre-stage monolith).
pub fn charge_precompile(clock: &SimClock, pre: &PrecompileArtifact) {
    for c in &pre.candidates {
        clock.advance_serial(&format!("precompile {}", c.id), c.report.precompile_s);
    }
}

/// Stage 4 — EfficiencyNarrow: the top-`c` cut by resource efficiency.
/// NaN efficiencies (a degenerate pre-compile) always sort last and the
/// loop id breaks exact ties, so the cut is a total, deterministic order.
pub fn stage_efficiency_narrow(pre: &PrecompileArtifact, c_efficiency: usize) -> EfficiencyCut {
    let mut by_eff = pre.candidates.clone();
    by_eff.sort_by(|a, b| {
        order::desc_nan_last(a.efficiency, b.efficiency).then_with(|| a.id.cmp(&b.id))
    });
    EfficiencyCut {
        top_c: by_eff.iter().take(c_efficiency).map(|c| c.id).collect(),
    }
}

/// Stage 5 — MeasureRounds: generate OpenCL and compile+measure round-1
/// singles then round-2 combinations on the verification environment.
/// Charges `env.clock` through [`VerifyEnv::measure_pattern`] exactly as
/// the pre-stage monolith did (compile then measurement, per pattern).
pub fn stage_measure_rounds(
    analysis: &AppAnalysis,
    pre: &PrecompileArtifact,
    cut: &EfficiencyCut,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> MeasureArtifact {
    let reports = pre.reports();
    let d = cfg.d_patterns;

    // round 1: singles
    let round1_pats: Vec<_> = patterns::round1(&cut.top_c).into_iter().take(d).collect();
    let mut opencl = Vec::new();
    let mut round1_meas = Vec::new();
    for pat in &round1_pats {
        opencl.push(generate_opencl(analysis, pat, cfg));
        round1_meas.push(env.measure_pattern(analysis, &reports, pat));
    }

    // round 2: combinations of the improving singles
    let budget = d.saturating_sub(round1_meas.len());
    let round2_pats =
        patterns::round2(&round1_meas, &reports, env.backend, cfg.resource_cap, budget);
    let mut round2_meas = Vec::new();
    for pat in &round2_pats {
        opencl.push(generate_opencl(analysis, pat, cfg));
        round2_meas.push(env.measure_pattern(analysis, &reports, pat));
    }

    let mut rounds = vec![round1_meas];
    if !round2_meas.is_empty() {
        rounds.push(round2_meas);
    }

    MeasureArtifact {
        cpu_time_s: env.cpu_baseline_s(analysis),
        opencl,
        rounds,
    }
}

/// Artifact of the BlockNarrow stage: per-detected-block IP offers the
/// backend quoted from the registry.
#[derive(Debug, Clone)]
pub struct BlockArtifact {
    /// One offer per detected block with a registry implementation, in
    /// source (root-loop) order.
    pub offers: Vec<BlockOffer>,
}

/// Artifact of the MeasureBlocks stage: every block placement compiled
/// (IP link + any riding pattern) and measured on the farm.
#[derive(Debug, Clone)]
pub struct BlockMeasureArtifact {
    /// Measured placements, in offer order (block alone, then the
    /// overlap-resolved combination with the best loop pattern).
    pub placements: Vec<BlockMeasurement>,
}

impl BlockMeasureArtifact {
    /// An empty artifact (what `--blocks off` flows through the selector).
    pub fn empty() -> Self {
        BlockMeasureArtifact { placements: Vec::new() }
    }
}

/// Stage B1 — BlockNarrow: detect registry blocks structurally
/// ([`crate::funcblock::detect`]) and ask the backend for an IP offer per
/// block.  Pure; `Off` yields no offers.
pub fn stage_block_narrow(
    analysis: &AppAnalysis,
    backend: &dyn OffloadBackend,
    cpu: &CpuModel,
    mode: BlockMode,
) -> BlockArtifact {
    if mode == BlockMode::Off {
        return BlockArtifact { offers: Vec::new() };
    }
    let detected = funcblock::detect(&analysis.loops);
    let offers = detected
        .iter()
        .filter_map(|b| backend.block_offer(&analysis.loops, &analysis.profile, cpu, b))
        .collect();
    BlockArtifact { offers }
}

/// Compile + measure one block placement (the IP replacement plus any
/// co-offloaded loop statements) on the verification environment.
/// Charges `env.clock` exactly like a pattern: the compile/link on the
/// farm, then one measured sample run.
pub fn measure_block_placement(
    analysis: &AppAnalysis,
    reports: &HashMap<LoopId, BackendReport>,
    offer: &BlockOffer,
    extra: &[LoopId],
    env: &VerifyEnv<'_>,
) -> BlockMeasurement {
    let refs: Vec<&BackendReport> = extra.iter().filter_map(|l| reports.get(l)).collect();
    let mut m = BlockMeasurement {
        block: offer.block.name.to_string(),
        block_loops: offer.block.loops.clone(),
        extra_loops: extra.to_vec(),
        utilization: offer.utilization,
        compiled: true,
        compile_sim_s: offer.compile_sim_s,
        time_s: f64::INFINITY,
        speedup: 0.0,
    };
    let mut ok = true;
    if !refs.is_empty() {
        m.utilization += env.backend.combined_utilization(&refs);
        let outcome = env.backend.full_compile(&refs, &m.label());
        m.compile_sim_s += outcome.sim_s;
        ok = outcome.ok;
    }
    env.clock
        .schedule_compile(&format!("compile {}", m.label()), m.compile_sim_s);
    if !ok {
        m.compiled = false;
        return m;
    }
    let cpu_total = env.cpu_baseline_s(analysis);
    let mut offloaded_cpu = offer.cpu_time_s;
    let mut device_s = offer.exec_s;
    for l in extra {
        let Some(rep) = reports.get(l) else { continue };
        let k = env
            .backend
            .kernel_exec(&analysis.loops, &analysis.profile, env.cpu, rep);
        device_s += k.total_s();
        if let Some(lp) = analysis.profile.loop_profile(*l) {
            offloaded_cpu += env.cpu.loop_time_s(lp);
        }
    }
    m.time_s = (cpu_total - offloaded_cpu).max(0.0) + device_s;
    m.speedup = cpu_total / m.time_s;
    env.clock
        .advance_serial(&format!("measure {}", m.label()), m.time_s);
    m
}

/// Stage B2 — MeasureBlocks: for every offer, measure the block alone
/// and (when a loop pattern improved) the overlap-resolved combination —
/// the block subsumes its member loops, so only the remainder of the
/// best loop pattern rides along, and over-cap combinations are never
/// built (same rule as round 2).
pub fn stage_measure_blocks(
    analysis: &AppAnalysis,
    pre: &PrecompileArtifact,
    meas: &MeasureArtifact,
    blocks: &BlockArtifact,
    env: &VerifyEnv<'_>,
    cfg: &SearchConfig,
) -> BlockMeasureArtifact {
    let reports = pre.reports();
    let base_best = order::select_best(
        meas.rounds
            .iter()
            .flatten()
            .filter(|m| m.compiled && m.speedup > 1.0),
        |m| m.speedup,
        |m| m.pattern.loops.clone(),
    )
    .cloned();

    let mut placements = Vec::new();
    for offer in &blocks.offers {
        if offer.utilization > cfg.resource_cap {
            continue; // over-cap IP: never built (same rule as round 2)
        }
        placements.push(measure_block_placement(analysis, &reports, offer, &[], env));
        if let Some(best) = &base_best {
            let extra: Vec<LoopId> = best
                .pattern
                .loops
                .iter()
                .filter(|l| !offer.block.loops.contains(*l))
                .cloned()
                .collect();
            if extra.is_empty() {
                continue; // the block subsumes the whole pattern
            }
            let refs: Vec<&BackendReport> =
                extra.iter().filter_map(|l| reports.get(l)).collect();
            if refs.len() != extra.len() {
                continue;
            }
            if offer.utilization + env.backend.combined_utilization(&refs) > cfg.resource_cap {
                continue; // over-cap combination: never built
            }
            placements.push(measure_block_placement(analysis, &reports, offer, &extra, env));
        }
    }
    BlockMeasureArtifact { placements }
}

/// Stage 6 — Select: pick the fastest compiled pattern and assemble the
/// full [`SearchTrace`], carrying the measured block placements so the
/// trace's solution is the best of loop patterns *and* blocks.  The
/// caller stamps `sim_hours`/`compile_hours` from its span meter (they
/// are properties of the *run*, not of the stage artifacts).
pub fn stage_select(
    analysis: &AppAnalysis,
    destination: Destination,
    cut: &IntensityCut,
    pre: &PrecompileArtifact,
    eff: &EfficiencyCut,
    meas: &MeasureArtifact,
    blocks: &BlockMeasureArtifact,
) -> SearchTrace {
    // NaN-poisoned measurements are rejected by `select_best` (they can
    // never become the solution, and they can never panic the service);
    // exact speedup ties go to the smaller pattern id so the winner is
    // byte-identical across runs and pool sizes.
    let best = order::select_best(
        meas.rounds.iter().flatten().filter(|m| m.compiled),
        |m| m.speedup,
        |m| m.pattern.loops.clone(),
    )
    .cloned();
    let best_block = order::select_best(
        blocks.placements.iter().filter(|m| m.compiled),
        |m| m.speedup,
        |m| (m.block.clone(), m.block_loops.clone(), m.extra_loops.clone()),
    )
    .cloned();

    SearchTrace {
        app_name: analysis.app_name.clone(),
        destination,
        loop_count: analysis.program.loop_count(),
        intensities: analysis.intensities.clone(),
        top_a: cut.ids(),
        candidates: pre.candidates.clone(),
        top_c: eff.top_c.clone(),
        opencl: meas.opencl.clone(),
        rounds: meas.rounds.clone(),
        cpu_time_s: meas.cpu_time_s,
        best,
        block_mode: BlockMode::Off,
        blocks: blocks.placements.clone(),
        best_block,
        sim_hours: 0.0,
        compile_hours: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::backend::FPGA;
    use crate::cpu::XEON_3104;

    /// Composing the stages by hand must reproduce the driver's trace —
    /// the stages really are the pipeline, not a parallel copy of it.
    #[test]
    fn hand_composed_stages_match_the_driver() {
        let cfg = SearchConfig::default();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let driver = super::super::pipeline::offload_search(&apps::TDFIR, &env, true).unwrap();

        let analysis = Arc::new(analyze_app(&apps::TDFIR, true).unwrap());
        let cut = stage_intensity_narrow(&analysis, &FPGA, cfg.a_intensity);
        let pre = stage_precompile(&analysis, &cut, &FPGA, cfg.b_unroll);
        let eff = stage_efficiency_narrow(&pre, cfg.c_efficiency);
        let env2 = VerifyEnv::new(&FPGA, &XEON_3104, cfg.clone());
        let meas = stage_measure_rounds(&analysis, &pre, &eff, &env2, &cfg);
        let hand = stage_select(
            &analysis,
            Destination::Fpga,
            &cut,
            &pre,
            &eff,
            &meas,
            &BlockMeasureArtifact::empty(),
        );

        assert_eq!(hand.app_name, driver.app_name);
        assert_eq!(hand.destination, driver.destination);
        assert_eq!(hand.top_a, driver.top_a);
        assert_eq!(hand.top_c, driver.top_c);
        assert_eq!(hand.cpu_time_s, driver.cpu_time_s);
        assert_eq!(hand.rounds.len(), driver.rounds.len());
        for (hr, dr) in hand.rounds.iter().zip(&driver.rounds) {
            assert_eq!(hr.len(), dr.len());
            for (hm, dm) in hr.iter().zip(dr) {
                assert_eq!(hm.pattern, dm.pattern);
                assert_eq!(hm.time_s, dm.time_s);
                assert_eq!(hm.speedup, dm.speedup);
                assert_eq!(hm.compile_sim_s, dm.compile_sim_s);
            }
        }
        assert_eq!(
            hand.best.as_ref().map(|b| (b.pattern.clone(), b.speedup)),
            driver.best.as_ref().map(|b| (b.pattern.clone(), b.speedup))
        );
    }

    #[test]
    fn block_narrow_is_mode_gated_and_quotes_offers() {
        let analysis = analyze_app(&apps::TDFIR, true).unwrap();
        let off = stage_block_narrow(&analysis, &FPGA, &XEON_3104, BlockMode::Off);
        assert!(off.offers.is_empty(), "Off must quote nothing");
        let on = stage_block_narrow(&analysis, &FPGA, &XEON_3104, BlockMode::On);
        assert!(!on.offers.is_empty(), "tdfir has registry blocks");
        let fir = on
            .offers
            .iter()
            .find(|o| o.block.root == crate::cparse::ast::LoopId(8))
            .expect("the FIR nest must be offered");
        assert!(fir.exec_s > 0.0 && fir.exec_s < fir.cpu_time_s);
        assert!(fir.compile_sim_s < 3600.0, "prebuilt IP links in minutes");
    }

    #[test]
    fn block_measurement_charges_the_clock() {
        let cfg = SearchConfig::default();
        let analysis = analyze_app(&apps::MATMUL, true).unwrap();
        let env = VerifyEnv::new(&FPGA, &XEON_3104, cfg);
        let offers = stage_block_narrow(&analysis, &FPGA, &XEON_3104, BlockMode::On);
        assert!(!offers.offers.is_empty());
        let reports = HashMap::new();
        let before = env.clock.total_seconds();
        let m = measure_block_placement(&analysis, &reports, &offers.offers[0], &[], &env);
        assert!(m.compiled);
        assert!(m.speedup > 0.0);
        assert!(env.clock.total_seconds() > before, "compile+measure must charge");
        assert_eq!(m.extra_loops, Vec::<LoopId>::new());
    }

    #[test]
    fn analyze_stage_memoizes_and_charges_once() {
        let cache = CacheStore::fresh();
        let clock = SimClock::new(1);
        let a1 = stage_analyze(&apps::MATMUL, true, &cache, &XEON_3104, Some(&clock)).unwrap();
        let charged = clock.total_seconds();
        assert!(charged > 0.0, "cold analyze must charge Steps 1-2 time");
        let a2 = stage_analyze(&apps::MATMUL, true, &cache, &XEON_3104, Some(&clock)).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "second call must be the memoized Arc");
        assert_eq!(clock.total_seconds(), charged, "warm analyze must charge nothing");
    }
}
