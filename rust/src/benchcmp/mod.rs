//! Bench-baseline regression comparison (`flopt bench-compare`).
//!
//! Every bench writes a structured JSON report with a flat `"metrics"`
//! object of deterministic model-derived numbers (speedups, simulated
//! hours, counts).  A committed `BENCH_<name>.json` baseline at the
//! repo root pins those numbers with per-metric relative tolerances and
//! a direction (is bigger better, worse, or must it match exactly?).
//! CI runs each bench, then `flopt bench-compare --baseline … --report
//! …` — a non-zero exit fails the `bench-smoke` job, making model-level
//! performance a gated invariant rather than a graph someone eyeballs.
//!
//! Baselines bootstrap with `"value": null` ("unblessed"): the compare
//! warns but passes, and `--bless <path>` writes a copy of the baseline
//! with every observed value filled in, uploaded as a CI artifact so a
//! maintainer can commit it verbatim.
//!
//! Baseline schema (schema 1):
//!
//! ```json
//! {
//!   "bench": "fig4_speedup",
//!   "schema": 1,
//!   "scale": "test",
//!   "note": "free text",
//!   "metrics": {
//!     "speedup_tdfir": {"value": 4.5, "tol_rel": 0.05,
//!                        "direction": "higher_better"}
//!   }
//! }
//! ```

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

/// Which way a metric is allowed to drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better: only a drop beyond tolerance regresses.
    HigherBetter,
    /// Smaller is better: only a rise beyond tolerance regresses.
    LowerBetter,
    /// Any drift beyond tolerance regresses (counts: tolerance 0).
    Exact,
}

impl Direction {
    /// Parse the schema's `direction` string.
    pub fn parse(s: &str) -> crate::Result<Direction> {
        match s {
            "higher_better" => Ok(Direction::HigherBetter),
            "lower_better" => Ok(Direction::LowerBetter),
            "exact" => Ok(Direction::Exact),
            other => anyhow::bail!(
                "unknown direction `{other}` (want higher_better | lower_better | exact)"
            ),
        }
    }

    fn as_str(&self) -> &'static str {
        match self {
            Direction::HigherBetter => "higher_better",
            Direction::LowerBetter => "lower_better",
            Direction::Exact => "exact",
        }
    }
}

/// One pinned metric in a baseline file.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricSpec {
    /// Pinned value; `None` = unblessed bootstrap (warn, pass).
    pub value: Option<f64>,
    /// Allowed relative drift (`|Δ| / max(|value|, 1e-12)`).
    pub tol_rel: f64,
    /// Drift direction that counts as a regression.
    pub direction: Direction,
}

/// A parsed `BENCH_<name>.json` baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct Baseline {
    /// Bench name the baseline pins (must match the report's).
    pub bench: String,
    /// Metric name → pinned spec.
    pub metrics: BTreeMap<String, MetricSpec>,
}

/// Parse a baseline document.
pub fn parse_baseline(doc: &Json) -> crate::Result<Baseline> {
    let bench = doc
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("baseline: missing string field `bench`"))?
        .to_string();
    let metrics_obj = doc
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("baseline: missing object field `metrics`"))?;
    let mut metrics = BTreeMap::new();
    for (name, spec) in metrics_obj {
        let value = match spec.get("value") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("baseline metric `{name}`: `value` must be a number or null")
            })?),
        };
        let tol_rel = match spec.get("tol_rel") {
            None => 0.0,
            Some(v) => v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("baseline metric `{name}`: `tol_rel` must be a number")
            })?,
        };
        let direction = match spec.get("direction").and_then(Json::as_str) {
            Some(s) => Direction::parse(s)
                .map_err(|e| anyhow::anyhow!("baseline metric `{name}`: {e}"))?,
            None => Direction::Exact,
        };
        metrics.insert(name.clone(), MetricSpec { value, tol_rel, direction });
    }
    Ok(Baseline { bench, metrics })
}

/// Pull the flat `"metrics"` object out of a bench report.
pub fn extract_metrics(report: &Json) -> crate::Result<BTreeMap<String, f64>> {
    let obj = report
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("report: missing object field `metrics`"))?;
    let mut out = BTreeMap::new();
    for (name, v) in obj {
        let n = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("report metric `{name}` is not a number"))?;
        out.insert(name.clone(), n);
    }
    Ok(out)
}

/// Verdict for one metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Within tolerance of the pinned value.
    Pass,
    /// Drifted the bad way beyond tolerance — fails the gate.
    Regressed,
    /// Drifted the *good* way beyond tolerance (informational pass;
    /// worth re-blessing so the gate tracks the improvement).
    Improved,
    /// Pinned in the baseline but absent from the report — fails.
    Missing,
    /// Baseline value is `null` (bootstrap): warn, pass.
    Unblessed,
    /// In the report but not pinned by the baseline (informational).
    New,
}

impl Status {
    fn as_str(&self) -> &'static str {
        match self {
            Status::Pass => "pass",
            Status::Regressed => "REGRESSED",
            Status::Improved => "improved",
            Status::Missing => "MISSING",
            Status::Unblessed => "unblessed",
            Status::New => "new",
        }
    }
}

/// One metric's comparison outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricResult {
    /// Metric name.
    pub name: String,
    /// Verdict.
    pub status: Status,
    /// Pinned value, when the baseline has one.
    pub baseline: Option<f64>,
    /// Observed value, when the report has one.
    pub observed: Option<f64>,
    /// Tolerance the verdict used.
    pub tol_rel: f64,
    /// Relative drift `(observed - baseline) / max(|baseline|, 1e-12)`.
    pub rel_delta: Option<f64>,
}

/// The full comparison: per-metric verdicts in name order.
#[derive(Debug, Clone, PartialEq)]
pub struct CompareReport {
    /// Bench name (from the baseline).
    pub bench: String,
    /// Per-metric outcomes, baseline metrics first, then `New` ones.
    pub results: Vec<MetricResult>,
}

/// Compare observed metrics against a baseline.
pub fn compare(base: &Baseline, observed: &BTreeMap<String, f64>) -> CompareReport {
    let mut results = Vec::with_capacity(base.metrics.len());
    for (name, spec) in &base.metrics {
        let obs = observed.get(name).copied();
        let r = match (spec.value, obs) {
            (_, None) => MetricResult {
                name: name.clone(),
                status: Status::Missing,
                baseline: spec.value,
                observed: None,
                tol_rel: spec.tol_rel,
                rel_delta: None,
            },
            (None, Some(o)) => MetricResult {
                name: name.clone(),
                status: Status::Unblessed,
                baseline: None,
                observed: Some(o),
                tol_rel: spec.tol_rel,
                rel_delta: None,
            },
            (Some(b), Some(o)) => {
                let rel = (o - b) / b.abs().max(1e-12);
                let status = match spec.direction {
                    Direction::HigherBetter if rel < -spec.tol_rel => Status::Regressed,
                    Direction::HigherBetter if rel > spec.tol_rel => Status::Improved,
                    Direction::LowerBetter if rel > spec.tol_rel => Status::Regressed,
                    Direction::LowerBetter if rel < -spec.tol_rel => Status::Improved,
                    Direction::Exact if rel.abs() > spec.tol_rel => Status::Regressed,
                    _ => Status::Pass,
                };
                MetricResult {
                    name: name.clone(),
                    status,
                    baseline: Some(b),
                    observed: Some(o),
                    tol_rel: spec.tol_rel,
                    rel_delta: Some(rel),
                }
            }
        };
        results.push(r);
    }
    for (name, &o) in observed {
        if !base.metrics.contains_key(name) {
            results.push(MetricResult {
                name: name.clone(),
                status: Status::New,
                baseline: None,
                observed: Some(o),
                tol_rel: 0.0,
                rel_delta: None,
            });
        }
    }
    CompareReport { bench: base.bench.clone(), results }
}

impl CompareReport {
    /// Does any metric fail the gate (regressed or missing)?
    pub fn failed(&self) -> bool {
        self.results
            .iter()
            .any(|r| matches!(r.status, Status::Regressed | Status::Missing))
    }

    /// Human-readable comparison table.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "bench-compare: {}", self.bench);
        for r in &self.results {
            let base = r.baseline.map(|v| format!("{v:.6}")).unwrap_or_else(|| "-".into());
            let obs = r.observed.map(|v| format!("{v:.6}")).unwrap_or_else(|| "-".into());
            let drift = r
                .rel_delta
                .map(|d| format!("{:+.2}%", d * 100.0))
                .unwrap_or_else(|| "-".into());
            let _ = writeln!(
                s,
                "  {:<10} {:<34} base {:>14}  got {:>14}  drift {:>8}  tol {:.1}%",
                r.status.as_str(),
                r.name,
                base,
                obs,
                drift,
                r.tol_rel * 100.0
            );
        }
        let _ = writeln!(
            s,
            "  => {}",
            if self.failed() { "FAIL (regression gate)" } else { "ok" }
        );
        s
    }

    /// Machine-readable diff document (the CI artifact).
    pub fn to_json(&self) -> Json {
        let mut results = Vec::with_capacity(self.results.len());
        for r in &self.results {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(r.name.clone()));
            m.insert("status".to_string(), Json::Str(r.status.as_str().to_string()));
            m.insert(
                "baseline".to_string(),
                r.baseline.map(Json::Num).unwrap_or(Json::Null),
            );
            m.insert(
                "observed".to_string(),
                r.observed.map(Json::Num).unwrap_or(Json::Null),
            );
            m.insert("tol_rel".to_string(), Json::Num(r.tol_rel));
            m.insert(
                "rel_delta".to_string(),
                r.rel_delta.map(Json::Num).unwrap_or(Json::Null),
            );
            results.push(Json::Obj(m));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str(self.bench.clone()));
        doc.insert("failed".to_string(), Json::Bool(self.failed()));
        doc.insert("results".to_string(), Json::Arr(results));
        Json::Obj(doc)
    }
}

/// A copy of `baseline_doc` with every pinned metric's `value` replaced
/// by the observed number (metrics absent from the report keep their
/// old value).  This is what `--bless` writes — commit it to adopt the
/// observed numbers as the new baseline.
pub fn bless(baseline_doc: &Json, observed: &BTreeMap<String, f64>) -> Json {
    let mut doc = match baseline_doc {
        Json::Obj(m) => m.clone(),
        _ => BTreeMap::new(),
    };
    if let Some(Json::Obj(metrics)) = doc.get("metrics").cloned().as_ref() {
        let mut blessed = metrics.clone();
        for (name, spec) in metrics {
            if let (Some(&o), Json::Obj(sm)) = (observed.get(name), spec) {
                let mut sm = sm.clone();
                sm.insert("value".to_string(), Json::Num(o));
                blessed.insert(name.clone(), Json::Obj(sm));
            }
        }
        doc.insert("metrics".to_string(), Json::Obj(blessed));
    }
    Json::Obj(doc)
}

/// Convenience for the CLI: parse both documents, compare, and return
/// `(report, blessed baseline)`.
pub fn run(baseline_text: &str, report_text: &str) -> crate::Result<(CompareReport, Json)> {
    let base_doc = json::parse(baseline_text)
        .map_err(|e| anyhow::anyhow!("baseline is not valid JSON: {e}"))?;
    let rep_doc = json::parse(report_text)
        .map_err(|e| anyhow::anyhow!("report is not valid JSON: {e}"))?;
    let base = parse_baseline(&base_doc)?;
    if let Some(rb) = rep_doc.get("bench").and_then(Json::as_str) {
        if rb != base.bench {
            anyhow::bail!(
                "bench mismatch: baseline pins `{}` but the report is `{}`",
                base.bench,
                rb
            );
        }
    }
    let observed = extract_metrics(&rep_doc)?;
    let cmp = compare(&base, &observed);
    let blessed = bless(&base_doc, &observed);
    Ok((cmp, blessed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline(text: &str) -> Baseline {
        parse_baseline(&json::parse(text).unwrap()).unwrap()
    }

    const BASE: &str = r#"{
        "bench": "demo", "schema": 1,
        "metrics": {
            "speedup":  {"value": 4.0,  "tol_rel": 0.05, "direction": "higher_better"},
            "hours":    {"value": 10.0, "tol_rel": 0.05, "direction": "lower_better"},
            "count":    {"value": 7,    "tol_rel": 0,    "direction": "exact"},
            "pending":  {"value": null, "tol_rel": 0.1,  "direction": "higher_better"}
        }
    }"#;

    fn obs(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn within_tolerance_passes() {
        let b = baseline(BASE);
        let cmp = compare(
            &b,
            &obs(&[("speedup", 3.9), ("hours", 10.4), ("count", 7.0), ("pending", 1.0)]),
        );
        assert!(!cmp.failed(), "{}", cmp.render());
        let by_name = |n: &str| cmp.results.iter().find(|r| r.name == n).unwrap().status;
        assert_eq!(by_name("speedup"), Status::Pass);
        assert_eq!(by_name("hours"), Status::Pass);
        assert_eq!(by_name("count"), Status::Pass);
        assert_eq!(by_name("pending"), Status::Unblessed);
    }

    #[test]
    fn bad_direction_drift_regresses_good_direction_improves() {
        let b = baseline(BASE);
        let cmp = compare(
            &b,
            &obs(&[("speedup", 3.0), ("hours", 8.0), ("count", 7.0), ("pending", 1.0)]),
        );
        assert!(cmp.failed());
        let by_name = |n: &str| cmp.results.iter().find(|r| r.name == n).unwrap().status;
        assert_eq!(by_name("speedup"), Status::Regressed, "drop beyond 5%");
        assert_eq!(by_name("hours"), Status::Improved, "20% cheaper is good");
    }

    #[test]
    fn exact_metrics_regress_in_either_direction() {
        let b = baseline(BASE);
        for v in [6.0, 8.0] {
            let cmp = compare(
                &b,
                &obs(&[("speedup", 4.0), ("hours", 10.0), ("count", v), ("pending", 1.0)]),
            );
            assert!(cmp.failed(), "count {v} must fail the exact pin");
        }
    }

    #[test]
    fn missing_metric_fails_new_metric_does_not() {
        let b = baseline(BASE);
        let cmp = compare(&b, &obs(&[("speedup", 4.0), ("hours", 10.0), ("extra", 1.0)]));
        assert!(cmp.failed(), "count+pending are missing from the report");
        let missing: Vec<&str> = cmp
            .results
            .iter()
            .filter(|r| r.status == Status::Missing)
            .map(|r| r.name.as_str())
            .collect();
        assert_eq!(missing, vec!["count", "pending"]);
        let extra = cmp.results.iter().find(|r| r.name == "extra").unwrap();
        assert_eq!(extra.status, Status::New);
        let only_new = compare(&baseline(r#"{"bench":"demo","metrics":{}}"#), &obs(&[("x", 1.0)]));
        assert!(!only_new.failed(), "new metrics alone never fail the gate");
    }

    #[test]
    fn bless_substitutes_observed_values() {
        let doc = json::parse(BASE).unwrap();
        let blessed = bless(&doc, &obs(&[("pending", 2.5), ("speedup", 4.2)]));
        let b = parse_baseline(&blessed).unwrap();
        assert_eq!(b.metrics["pending"].value, Some(2.5));
        assert_eq!(b.metrics["speedup"].value, Some(4.2));
        assert_eq!(b.metrics["hours"].value, Some(10.0), "unobserved keeps its pin");
        assert_eq!(b.metrics["pending"].direction, Direction::HigherBetter);
        assert_eq!(b.metrics["pending"].tol_rel, 0.1);
    }

    #[test]
    fn run_rejects_bench_mismatch_and_bad_json() {
        assert!(run(BASE, r#"{"bench":"other","metrics":{}}"#).is_err());
        assert!(run("not json", "{}").is_err());
        assert!(run(BASE, r#"{"bench":"demo"}"#).is_err(), "report without metrics");
        let (cmp, _) = run(BASE, r#"{"bench":"demo","metrics":{"speedup":4.0,
            "hours":10.0,"count":7,"pending":3.3}}"#)
            .unwrap();
        assert!(!cmp.failed(), "{}", cmp.render());
    }
}
