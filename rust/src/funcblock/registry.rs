//! The accelerator IP / library-kernel registry.
//!
//! Each registry entry pairs one detected block shape with per-backend
//! implementations: an **Arria10 IP core** (prebuilt — the simulated
//! compile is a partial-reconfiguration link of minutes, not the 3-hour
//! place-and-route a generated kernel pays) and a **GPU library kernel**
//! (cuBLAS/cuFFT-class, built in the minutes-scale SIMT regime).  Each
//! implementation carries the cost/resource/transfer model the backend
//! needs to quote a [`BlockOffer`]: a calibrated speedup of the
//! hand-tuned implementation over the single-thread CPU model, a device
//! resource fraction, and the link/build cost.
//!
//! Hand-tuned IP beats auto-generated kernels — that is the whole point
//! of the function-block layer (arXiv:2004.09883): the generated
//! single-work-item OpenCL of the loop path reaches low-single-digit
//! speedups, while a vendor FIR/matmul core streams at full clip.  The
//! speedups below encode that calibration; the combined search still
//! *measures* every placement and keeps whichever side wins.

use crate::backend::Destination;

use super::detect::DetectedBlock;
use super::detect::{
    DENSE_MATMUL, FFT_BUTTERFLY, FIR_FILTER, HISTOGRAM_BIN, NBODY_PAIR, SPMV_CSR,
    TRIG_ACCUMULATION,
};

/// Cost/resource model of one block implementation on one backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IpModel {
    /// Calibrated speedup of the hand-tuned implementation over the
    /// single-thread CPU model of the replaced nest (compute only;
    /// transfers are charged separately from the observed footprints).
    pub speedup_vs_cpu: f64,
    /// Device resource fraction the implementation occupies (FPGA:
    /// utilization incl. BSP share; GPU: occupancy-style pressure).
    pub utilization: f64,
    /// Simulated compile/link seconds: PR-region link for prebuilt FPGA
    /// IP, library build+link for GPU kernels — minutes, never hours.
    pub compile_sim_s: f64,
}

/// One registry entry: a block shape plus its per-backend implementations.
#[derive(Debug, Clone, Copy)]
pub struct BlockIp {
    /// Block-shape name ([`crate::funcblock::detect`] vocabulary).
    pub name: &'static str,
    /// One-line description of the library implementation.
    pub description: &'static str,
    /// Arria10 IP core, when one exists for this shape.
    pub fpga: Option<IpModel>,
    /// GPU library kernel, when one exists for this shape.
    pub gpu: Option<IpModel>,
}

/// The built-in registry.  Deliberately **no** stencil entry: laplace2d's
/// boundary-guarded sweep and stencil3d's 4-deep variant must never be
/// IP-substituted (`rust/tests/funcblock.rs` pins that negative space
/// per backend).
pub const REGISTRY: &[BlockIp] = &[
    BlockIp {
        name: FIR_FILTER,
        description: "systolic complex FIR core / cuFFT-class FIR library kernel",
        fpga: Some(IpModel { speedup_vs_cpu: 16.0, utilization: 0.34, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 6.0, utilization: 0.50, compile_sim_s: 90.0 }),
    },
    BlockIp {
        name: DENSE_MATMUL,
        description: "blocked systolic GEMM core / cuBLAS sgemm",
        fpga: Some(IpModel { speedup_vs_cpu: 12.0, utilization: 0.46, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 8.0, utilization: 0.60, compile_sim_s: 60.0 }),
    },
    BlockIp {
        name: TRIG_ACCUMULATION,
        description: "CORDIC trig-accumulation core / SFU-resident field kernel",
        fpga: Some(IpModel { speedup_vs_cpu: 12.0, utilization: 0.52, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 7.0, utilization: 0.55, compile_sim_s: 90.0 }),
    },
    BlockIp {
        name: HISTOGRAM_BIN,
        description: "banked local-bin histogram core / atomics histogram kernel",
        fpga: Some(IpModel { speedup_vs_cpu: 6.0, utilization: 0.22, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 3.0, utilization: 0.35, compile_sim_s: 60.0 }),
    },
    BlockIp {
        name: FFT_BUTTERFLY,
        description: "streaming radix-2 butterfly core / cuFFT stage kernel",
        fpga: Some(IpModel { speedup_vs_cpu: 14.0, utilization: 0.42, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 9.0, utilization: 0.55, compile_sim_s: 60.0 }),
    },
    BlockIp {
        name: SPMV_CSR,
        description: "banked CSR gather-accumulate core / cuSPARSE csrmv",
        fpga: Some(IpModel { speedup_vs_cpu: 9.0, utilization: 0.30, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 4.0, utilization: 0.45, compile_sim_s: 60.0 }),
    },
    BlockIp {
        // the one shape where the GPU library edges out the FPGA core:
        // the O(n^2) pair sweep is arithmetic-bound SIMT heaven
        name: NBODY_PAIR,
        description: "pipelined pair-interaction core / tiled n-body SIMT kernel",
        fpga: Some(IpModel { speedup_vs_cpu: 10.0, utilization: 0.48, compile_sim_s: 420.0 }),
        gpu: Some(IpModel { speedup_vs_cpu: 11.0, utilization: 0.65, compile_sim_s: 60.0 }),
    },
];

impl BlockIp {
    /// This entry's implementation for a destination (`None` when the
    /// shape has no implementation on that device — the CPU never does).
    pub fn for_destination(&self, dest: Destination) -> Option<&IpModel> {
        match dest {
            Destination::Fpga => self.fpga.as_ref(),
            Destination::Gpu => self.gpu.as_ref(),
            Destination::Cpu => None,
        }
    }
}

/// The registry contents.
pub fn registry() -> &'static [BlockIp] {
    REGISTRY
}

/// Look up a block shape's registry entry by name.
pub fn entry_for(name: &str) -> Option<&'static BlockIp> {
    REGISTRY.iter().find(|b| b.name == name)
}

/// Look up the implementation of a block shape on a destination
/// (`None` when the registry carries no implementation for that pair —
/// the backend then quotes no offer).
pub fn ip_for(name: &str, dest: Destination) -> Option<&'static IpModel> {
    entry_for(name)?.for_destination(dest)
}

/// A backend's quoted offer to replace one detected block with a
/// registry implementation — what the `BlockNarrow` stage collects and
/// the block measurement consumes.
#[derive(Debug, Clone)]
pub struct BlockOffer {
    /// The detected block this offer replaces.
    pub block: DetectedBlock,
    /// Registry description of the implementation.
    pub description: &'static str,
    /// Device resource fraction of the implementation.
    pub utilization: f64,
    /// Simulated compile/link seconds (near-zero for prebuilt IP).
    pub compile_sim_s: f64,
    /// Modeled device-side seconds of the block on the sample workload,
    /// including host↔device transfers.
    pub exec_s: f64,
    /// CPU-model seconds of the replaced nest on the sample workload
    /// (what the replacement removes from the host time).
    pub cpu_time_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_is_minutes_scale_and_sub_cap() {
        for e in registry() {
            for ip in [e.fpga.as_ref(), e.gpu.as_ref()].into_iter().flatten() {
                assert!(ip.speedup_vs_cpu > 1.0, "{}", e.name);
                assert!(ip.utilization > 0.0 && ip.utilization < 0.85, "{}", e.name);
                assert!(
                    ip.compile_sim_s < 1800.0,
                    "{}: IP link must be minutes, not hours",
                    e.name
                );
            }
        }
    }

    #[test]
    fn lookup_by_destination() {
        assert!(ip_for(FIR_FILTER, Destination::Fpga).is_some());
        assert!(ip_for(FIR_FILTER, Destination::Gpu).is_some());
        assert!(ip_for(FIR_FILTER, Destination::Cpu).is_none(), "CPU needs no IP");
        assert!(ip_for("stencil", Destination::Fpga).is_none(), "no stencil entry");
        let f = ip_for(FIR_FILTER, Destination::Fpga).unwrap();
        let g = ip_for(FIR_FILTER, Destination::Gpu).unwrap();
        assert!(f.speedup_vs_cpu > g.speedup_vs_cpu, "deep pipeline beats SIMT on FIR");
    }
}
