//! Structural function-block detection over the loop-nest IR.
//!
//! Every outermost loop statement is normalized into a [`NestSignature`]
//! — nest depth, accumulation pattern, array-access shape, and operator
//! classes — and classified against the registry's block shapes by
//! signature predicates.  Nothing here looks at function or variable
//! *names*: a renamed FIR filter still matches, and a loop that merely
//! lives in a function called `fir` does not.
//!
//! Calibrated against the app corpus:
//!
//! * tdfir's complex FIR nest (2-deep, scalar accumulators, a product of
//!   reads from *different* arrays at cross/offset indices) → `fir_filter`;
//! * matmul's i/j/k nest (3-deep, accumulator, cross-indexed reads, no
//!   guard) → `dense_matmul`;
//! * MRI-Q's per-voxel trig accumulation (2-deep, accumulators, trig
//!   calls in the inner body) → `trig_accumulation`;
//! * the histogram fills (flat loop, array write at a **data-dependent**
//!   index) → `histogram_bin`;
//! * fft's butterfly sweep (2-deep, NO accumulator, strided cross-read
//!   pairs multiplied against a twiddle table, two arrays written) →
//!   `fft_butterfly`;
//! * spmv's CSR gather nest (2-deep accumulation whose inner read index
//!   is loaded from memory — `gather_reads`) → `spmv_csr`;
//! * nbody's force nest (2-deep, guarded self-pair, ≥2 accumulators,
//!   position arrays read at *both* counters — `pair_indexed_arrays`) →
//!   `nbody_pair`;
//! * laplace2d's boundary-guarded Jacobi sweep and stencil3d's 4-deep
//!   variant match **nothing**: neither carries an accumulator
//!   (`dense_matmul` requires one) and both stencils are guarded — the
//!   negative space `rust/tests/funcblock.rs` pins per backend.

use std::collections::BTreeSet;

use crate::cparse::ast::*;
use crate::ir::LoopAnalysis;
use crate::util::intern::Symbol;

/// Registry name of the FIR-convolution block shape.
pub const FIR_FILTER: &str = "fir_filter";
/// Registry name of the dense-matmul block shape.
pub const DENSE_MATMUL: &str = "dense_matmul";
/// Registry name of the trig-accumulation (MRI-Q style) block shape.
pub const TRIG_ACCUMULATION: &str = "trig_accumulation";
/// Registry name of the data-dependent histogram-fill block shape.
pub const HISTOGRAM_BIN: &str = "histogram_bin";
/// Registry name of the strided butterfly (FFT stage) block shape.
pub const FFT_BUTTERFLY: &str = "fft_butterfly";
/// Registry name of the CSR sparse-matvec gather block shape.
pub const SPMV_CSR: &str = "spmv_csr";
/// Registry name of the all-pairs interaction block shape.
pub const NBODY_PAIR: &str = "nbody_pair";

/// Normalized structural signature of one outermost loop nest.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NestSignature {
    /// Nest depth including the root (1 = flat loop).
    pub depth: u32,
    /// Distinct scalar `+=`/`-=`-style accumulators anywhere in the
    /// nest, nest counters excluded (a `k++` step is induction, not
    /// accumulation).
    pub accumulations: u32,
    /// `sin`/`cos` call sites in the nest bodies.
    pub trig_calls: u32,
    /// Does the nest body contain a conditional (boundary guard)?
    pub guarded: bool,
    /// Array reads whose index mixes two or more nest counters
    /// (`a[i*n+k]`, `x[i-k]` — the matmul/convolution shape).
    pub cross_indexed_reads: u32,
    /// Array reads whose index is an additive offset expression
    /// (`x[i-k]`, `e[b*w+j]` — sliding-window/stencil shape).
    pub offset_reads: u32,
    /// Does the nest multiply reads of two *different* arrays (the
    /// signal×taps / A×B product at the heart of FIR and matmul)?
    pub product_of_reads: bool,
    /// Array writes whose index mentions **no** nest counter but does
    /// mention a variable, or whose index contains an array read — a
    /// data-dependent scatter (`h[b] += 1`, `a[idx[i]] = e`).
    pub indirect_writes: u32,
    /// Array reads whose index mentions **no** nest counter but does
    /// mention a variable, or whose index contains an array read — a
    /// data-dependent gather (`x[c]` with `c = colidx[jj]`).
    pub gather_reads: u32,
    /// Arrays read at two or more distinct counter-bearing indices
    /// spanning at least two nest counters (`qx[i]` and `qx[j]` — the
    /// all-pairs interaction shape).
    pub pair_indexed_arrays: u32,
    /// Distinct arrays read in the nest.
    pub arrays_read: u32,
    /// Distinct arrays written in the nest.
    pub arrays_written: u32,
}

/// One recognized block instance: an outermost loop nest whose signature
/// matched a registry block shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DetectedBlock {
    /// Registry name of the matched block shape (e.g. [`FIR_FILTER`]).
    pub name: &'static str,
    /// The outermost loop statement of the nest.
    pub root: LoopId,
    /// Every loop statement the block subsumes (root + descendants,
    /// sorted) — the overlap set the combined selector resolves against.
    pub loops: Vec<LoopId>,
    /// The signature that matched.
    pub signature: NestSignature,
}

fn nest_depth(body: &[Stmt]) -> u32 {
    let mut depth = 0;
    for s in body {
        match s {
            Stmt::For { body: b, .. } | Stmt::While { body: b, .. } => {
                depth = depth.max(1 + nest_depth(b));
            }
            Stmt::If { then_branch, else_branch, .. } => {
                depth = depth.max(nest_depth(then_branch));
                depth = depth.max(nest_depth(else_branch));
            }
            Stmt::Block(b) => depth = depth.max(nest_depth(b)),
            _ => {}
        }
    }
    depth
}

/// Loop-counter names of the nest: the root's induction variable (from
/// its canonical form when recognized, else from the raw `for` header —
/// a decreasing loop still has a counter) plus every nested `for`
/// header's induction variable.  A `while` root contributes none: its
/// counter is indistinguishable from ordinary state.
fn nest_counters(la: &LoopAnalysis) -> BTreeSet<Symbol> {
    let mut counters = BTreeSet::new();
    if let Some(c) = &la.info.canonical {
        counters.insert(c.var);
    }
    if let Some(h) = &la.info.header {
        match h.init.as_deref() {
            Some(Stmt::Decl(d)) => {
                counters.insert(d.name);
            }
            Some(Stmt::Assign { target: LValue::Var(v), .. }) => {
                counters.insert(*v);
            }
            _ => {}
        }
    }
    for s in &la.info.body {
        s.walk(&mut |s| {
            if let Stmt::For { header, .. } = s {
                match header.init.as_deref() {
                    Some(Stmt::Decl(d)) => {
                        counters.insert(d.name);
                    }
                    Some(Stmt::Assign { target: LValue::Var(v), .. }) => {
                        counters.insert(*v);
                    }
                    _ => {}
                }
            }
        });
    }
    counters
}

fn vars_in(e: &Expr) -> BTreeSet<Symbol> {
    let mut out = BTreeSet::new();
    e.walk(&mut |e| {
        if let ExprKind::Var(n) = &e.kind {
            out.insert(*n);
        }
    });
    out
}

fn arrays_read_in(e: &Expr) -> BTreeSet<Symbol> {
    let mut out = BTreeSet::new();
    e.walk(&mut |e| {
        if let ExprKind::Index(n, _) = &e.kind {
            out.insert(*n);
        }
    });
    out
}

/// Top-level expressions of a statement (the detector walks each).
fn stmt_exprs(s: &Stmt) -> Vec<&Expr> {
    match s {
        Stmt::Assign { value, target, .. } => {
            let mut v = vec![value];
            if let LValue::Index(_, i) = target {
                v.push(i);
            }
            v
        }
        Stmt::Decl(d) => d.init.iter().collect(),
        Stmt::If { cond, .. } | Stmt::While { cond, .. } => vec![cond],
        Stmt::Expr(e, _) => vec![e],
        Stmt::Return(Some(e), _) => vec![e],
        _ => Vec::new(),
    }
}

/// Compute the normalized signature of one outermost loop nest.
pub fn signature(la: &LoopAnalysis) -> NestSignature {
    let counters = nest_counters(la);
    let mut sig = NestSignature {
        depth: 1 + nest_depth(&la.info.body),
        arrays_read: la.refs.array_reads.len() as u32,
        arrays_written: la.refs.array_writes.len() as u32,
        ..Default::default()
    };

    // accumulation pattern: distinct scalars updated with += / -=.
    // Nest counters are excluded: `Stmt::walk` visits nested `for`
    // headers, so a `k++` step would otherwise read as an accumulator
    // and no `++`-stepped nest could ever have `accumulations == 0`.
    let mut accumulators = BTreeSet::new();
    for s in &la.info.body {
        s.walk(&mut |s| {
            if let Stmt::Assign {
                target: LValue::Var(v),
                op: AssignOp::AddAssign | AssignOp::SubAssign,
                ..
            } = s
            {
                if !counters.contains(v) {
                    accumulators.insert(*v);
                }
            }
            if matches!(s, Stmt::If { .. }) {
                sig.guarded = true;
            }
        });
    }
    sig.accumulations = accumulators.len() as u32;

    // operator classes + index shapes
    for s in &la.info.body {
        s.walk(&mut |s| {
            for e in stmt_exprs(s) {
                e.walk(&mut |e| match &e.kind {
                    ExprKind::Call(f, _) if f == "sin" || f == "cos" => sig.trig_calls += 1,
                    ExprKind::Index(_, idx) => {
                        let hits = vars_in(idx)
                            .iter()
                            .filter(|v| counters.contains(*v))
                            .count();
                        if hits >= 2 {
                            sig.cross_indexed_reads += 1;
                        }
                        if matches!(idx.kind, ExprKind::Binary(BinOp::Add | BinOp::Sub, ..)) {
                            sig.offset_reads += 1;
                        }
                    }
                    ExprKind::Binary(BinOp::Mul, a, b) => {
                        let ra = arrays_read_in(a);
                        let rb = arrays_read_in(b);
                        if ra.iter().any(|x| rb.iter().any(|y| x != y)) {
                            sig.product_of_reads = true;
                        }
                    }
                    _ => {}
                });
            }
        });
    }

    // data-dependent scatters/gathers: an index with no counter but some
    // var, or an index that itself reads an array (`a[idx[i]]` — the
    // subscript values are data, whatever variables they mention).
    // Only classifiable when the nest has a *known* counter — a `while`
    // nest with no recognizable induction variable must not read every
    // counter-indexed access as data-dependent (false-positive IP bait).
    let data_dependent = |idx: &Expr| {
        let vars = vars_in(idx);
        let mut reads_array = false;
        idx.walk(&mut |e| {
            if matches!(e.kind, ExprKind::Index(..)) {
                reads_array = true;
            }
        });
        (!vars.is_empty() && vars.iter().all(|v| !counters.contains(v))) || reads_array
    };
    if !counters.is_empty() {
        for indices in la.refs.array_writes.values() {
            for idx in indices {
                if data_dependent(idx) {
                    sig.indirect_writes += 1;
                }
            }
        }
        for indices in la.refs.array_reads.values() {
            for idx in indices {
                if data_dependent(idx) {
                    sig.gather_reads += 1;
                }
            }
        }
    }

    // pair-interaction reads: one array read at several distinct
    // counter-bearing indices that together span ≥ 2 nest counters
    for indices in la.refs.array_reads.values() {
        let mut distinct: Vec<&Expr> = Vec::new();
        let mut touched = BTreeSet::new();
        for idx in indices {
            let hits: Vec<Symbol> = vars_in(idx)
                .into_iter()
                .filter(|v| counters.contains(v))
                .collect();
            if hits.is_empty() {
                continue;
            }
            if !distinct.iter().any(|e| *e == idx) {
                distinct.push(idx);
            }
            touched.extend(hits);
        }
        if distinct.len() >= 2 && touched.len() >= 2 {
            sig.pair_indexed_arrays += 1;
        }
    }

    sig
}

/// Classify a signature against the registry block shapes.  Predicates
/// are ordered most-specific first; `None` means no block matches (the
/// laplace2d negative space lands here).
pub fn classify(sig: &NestSignature) -> Option<&'static str> {
    // MRI-Q-style field computation: 2-nest, scalar accumulators, trig
    // in the inner body, and a product of distinct array reads.
    if sig.depth == 2 && sig.accumulations >= 1 && sig.trig_calls >= 2 && sig.product_of_reads {
        return Some(TRIG_ACCUMULATION);
    }
    // FIR convolution: 2-nest, scalar accumulators, sliding-window reads
    // mixing both counters, signal×taps product, no trig in the kernel.
    if sig.depth == 2
        && sig.accumulations >= 1
        && sig.trig_calls == 0
        && sig.cross_indexed_reads >= 1
        && sig.offset_reads >= 1
        && sig.product_of_reads
    {
        return Some(FIR_FILTER);
    }
    // CSR sparse matvec: 2-nest accumulation whose inner read index is
    // itself loaded from memory (the column-index gather), with a
    // values×vector product and no trig.  Disjoint from FIR: a sliding
    // window indexes by its counters, a gather by loaded data.
    if sig.depth == 2
        && sig.accumulations >= 1
        && sig.trig_calls == 0
        && sig.gather_reads >= 1
        && sig.product_of_reads
    {
        return Some(SPMV_CSR);
    }
    // FFT butterfly: 2-nest with NO accumulator, unguarded, strided
    // cross-read pairs (`a[b*span+k]` / `a[b*span+k+half]`) multiplied
    // against a second table, writing ≥ 2 output arrays.
    if sig.depth == 2
        && sig.accumulations == 0
        && sig.trig_calls == 0
        && !sig.guarded
        && sig.cross_indexed_reads >= 2
        && sig.offset_reads >= 1
        && sig.product_of_reads
        && sig.arrays_written >= 2
    {
        return Some(FFT_BUTTERFLY);
    }
    // All-pairs interaction: 2-nest, guarded (self-pair test), several
    // accumulators, and some array read at BOTH counters (`q[i]`/`q[j]`).
    if sig.depth == 2
        && sig.accumulations >= 2
        && sig.guarded
        && sig.trig_calls == 0
        && sig.pair_indexed_arrays >= 1
    {
        return Some(NBODY_PAIR);
    }
    // Dense matmul: 3-nest, inner accumulator, A×B product with both
    // operands cross-indexed, and no boundary guard (a guarded 3-nest is
    // a stencil sweep, not a matmul).
    if sig.depth == 3
        && sig.accumulations >= 1
        && !sig.guarded
        && sig.cross_indexed_reads >= 2
        && sig.product_of_reads
    {
        return Some(DENSE_MATMUL);
    }
    // Histogram fill: flat loop reading an array and scattering writes
    // at a data-dependent bin index.
    if sig.depth == 1 && sig.indirect_writes >= 1 && sig.arrays_read >= 1 {
        return Some(HISTOGRAM_BIN);
    }
    None
}

fn descendants(loops: &[LoopAnalysis], root: LoopId) -> Vec<LoopId> {
    let mut out = vec![root];
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        if let Some(la) = loops.iter().find(|l| l.info.id == id) {
            for c in &la.info.children {
                out.push(*c);
                stack.push(*c);
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Detect every registry block instance in an analyzed program: each
/// outermost loop nest is signatured and classified; matches come back
/// in source (root `LoopId`) order.
pub fn detect(loops: &[LoopAnalysis]) -> Vec<DetectedBlock> {
    let mut out = Vec::new();
    for la in loops {
        if la.info.depth != 0 {
            continue; // blocks are rooted at outermost statements
        }
        let sig = signature(la);
        if let Some(name) = classify(&sig) {
            out.push(DetectedBlock {
                name,
                root: la.info.id,
                loops: descendants(loops, la.info.id),
                signature: sig,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::ir;

    fn blocks_of(app: &apps::App) -> Vec<DetectedBlock> {
        detect(&ir::analyze(&app.parse()))
    }

    #[test]
    fn tdfir_fir_nest_detected_with_members() {
        let bs = blocks_of(&apps::TDFIR);
        let fir = bs
            .iter()
            .find(|b| b.root == LoopId(8))
            .expect("the hot FIR nest must be detected");
        assert_eq!(fir.name, FIR_FILTER);
        assert_eq!(fir.loops, vec![LoopId(8), LoopId(9)], "block subsumes L8+L9");
        assert_eq!(fir.signature.depth, 2);
        assert!(fir.signature.accumulations >= 2, "{:?}", fir.signature);
        assert!(fir.signature.product_of_reads);
    }

    #[test]
    fn tdfir_memset_and_stabilize_do_not_match() {
        let bs = blocks_of(&apps::TDFIR);
        assert!(bs.iter().all(|b| b.root != LoopId(7)), "memset is not a block");
        assert!(bs.iter().all(|b| b.root != LoopId(10)), "stabilize is not a block");
    }

    #[test]
    fn tdfir_histogram_fill_detected() {
        let bs = blocks_of(&apps::TDFIR);
        let h = bs
            .iter()
            .find(|b| b.name == HISTOGRAM_BIN)
            .expect("the envelope histogram fill is a block");
        assert_eq!(h.signature.depth, 1);
        assert!(h.signature.indirect_writes >= 1);
    }

    #[test]
    fn matmul_nest_detected() {
        let bs = blocks_of(&apps::MATMUL);
        let mm = bs
            .iter()
            .find(|b| b.name == DENSE_MATMUL)
            .expect("the i/j/k nest must be detected");
        assert_eq!(mm.root, LoopId(1));
        assert_eq!(mm.loops, vec![LoopId(1), LoopId(2), LoopId(3)]);
        assert_eq!(mm.signature.depth, 3);
        assert!(!mm.signature.guarded);
    }

    #[test]
    fn mriq_trig_accumulation_detected() {
        let bs = blocks_of(&apps::MRIQ);
        let q = bs
            .iter()
            .find(|b| b.root == LoopId(6))
            .expect("compute_q must be detected");
        assert_eq!(q.name, TRIG_ACCUMULATION);
        assert_eq!(q.loops, vec![LoopId(6), LoopId(7)]);
        assert!(q.signature.trig_calls >= 2);
    }

    #[test]
    fn histogram_scatter_detected() {
        let bs = blocks_of(&apps::HISTOGRAM);
        let h = bs
            .iter()
            .find(|b| b.root == LoopId(3))
            .expect("build_hist must be detected");
        assert_eq!(h.name, HISTOGRAM_BIN);
        assert!(h.signature.indirect_writes >= 1);
    }

    #[test]
    fn laplace2d_matches_nothing() {
        // the boundary-guarded Jacobi sweep is the pinned negative space:
        // no false-positive IP substitution on stencils
        assert!(blocks_of(&apps::LAPLACE2D).is_empty());
    }

    #[test]
    fn fft_butterfly_detected() {
        let bs = blocks_of(&apps::FFT);
        let bf = bs
            .iter()
            .find(|b| b.name == FFT_BUTTERFLY)
            .expect("the butterfly nest must be detected");
        assert_eq!(bf.root, LoopId(2));
        assert_eq!(bf.loops, vec![LoopId(2), LoopId(3)]);
        assert_eq!(bf.signature.depth, 2);
        assert_eq!(bf.signature.accumulations, 0, "{:?}", bf.signature);
        assert!(bf.signature.cross_indexed_reads >= 2);
        assert!(bf.signature.arrays_written >= 2);
        // the init/copy/checksum loops must not be claimed
        assert_eq!(bs.iter().filter(|b| b.name == FFT_BUTTERFLY).count(), 1);
    }

    #[test]
    fn spmv_gather_detected() {
        let bs = blocks_of(&apps::SPMV);
        let sp = bs
            .iter()
            .find(|b| b.name == SPMV_CSR)
            .expect("the CSR gather nest must be detected");
        assert_eq!(sp.root, LoopId(4));
        assert_eq!(sp.loops, vec![LoopId(4), LoopId(5)]);
        assert!(sp.signature.gather_reads >= 1, "{:?}", sp.signature);
        assert!(sp.signature.product_of_reads);
        // the CSR build nests (prefix sum, column scatter) match nothing
        assert!(bs.iter().all(|b| b.root != LoopId(0)));
        assert!(bs.iter().all(|b| b.root != LoopId(1)));
    }

    #[test]
    fn nbody_pair_nest_detected() {
        let bs = blocks_of(&apps::NBODY);
        let nb = bs
            .iter()
            .find(|b| b.name == NBODY_PAIR)
            .expect("the force nest must be detected");
        assert_eq!(nb.root, LoopId(1));
        assert_eq!(nb.loops, vec![LoopId(1), LoopId(2)]);
        assert!(nb.signature.pair_indexed_arrays >= 1, "{:?}", nb.signature);
        assert!(nb.signature.guarded);
        assert!(nb.signature.accumulations >= 3);
        // integrate/kinetic/init are not blocks
        assert_eq!(bs.len(), 1, "{bs:?}");
    }

    #[test]
    fn stencil3d_matches_nothing() {
        // the 4-deep guarded stencil is negative space, like laplace2d
        assert!(blocks_of(&apps::STENCIL3D).is_empty());
    }

    #[test]
    fn scatter_through_index_array_is_indirect() {
        // `a[idx[i]]` mentions the counter, but the subscript VALUES are
        // data — the write must still read as a scatter
        let src = "void f(float a[], float idx[], int n) {\
            int i;\
            for (i = 0; i < n; i++) { a[idx[i]] += 1.0; } }";
        let p = crate::cparse::parse(src).unwrap();
        let loops = ir::analyze(&p);
        let sig = signature(&loops[0]);
        assert!(sig.indirect_writes >= 1, "{sig:?}");
        assert_eq!(classify(&sig), Some(HISTOGRAM_BIN));
    }

    #[test]
    fn fir_is_not_misread_as_pair_interaction() {
        // the FIR window reads one array at ONE distinct index expression
        // — pair_indexed_arrays stays 0 and the FIR arm matches first
        let p = apps::TDFIR.parse();
        let loops = ir::analyze(&p);
        let fir = loops
            .iter()
            .find(|l| l.info.id == LoopId(8))
            .unwrap();
        let sig = signature(fir);
        assert_eq!(sig.pair_indexed_arrays, 0, "{sig:?}");
        assert_eq!(classify(&sig), Some(FIR_FILTER));
    }

    #[test]
    fn non_canonical_copy_loops_are_not_scatters() {
        // decreasing `for` and `while` copy loops index by their own
        // counter — neither may be claimed as a histogram block
        let src = "void f(float dst[], float src[], int n) {\
            int i;\
            for (i = n - 1; i >= 0; i -= 1) { dst[i] = src[i]; }\
            i = 0;\
            while (i < n) { dst[i] = src[i]; i = i + 1; } }";
        let p = crate::cparse::parse(src).unwrap();
        let bs = detect(&ir::analyze(&p));
        assert!(bs.is_empty(), "copy loops misread as blocks: {bs:?}");
    }

    #[test]
    fn detection_is_name_blind() {
        // same FIR structure, scrambled identifiers: still matches
        let src = "void zzz(float p[], float q[], float r[], int n, int t) {\
            int a;\
            for (a = 0; a < n; a++) {\
                float z; z = 0.0;\
                for (int b = 0; b < t; b++) {\
                    if (a - b >= 0) { z += p[a - b] * q[b]; }\
                }\
                r[a] = z;\
            } }";
        let p = crate::cparse::parse(src).unwrap();
        let bs = detect(&ir::analyze(&p));
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].name, FIR_FILTER);
    }
}
