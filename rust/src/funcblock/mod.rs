//! Function-block offloading: detect whole algorithmic blocks, match
//! them to an accelerator IP/library registry, and co-search them with
//! loop statements.
//!
//! The source paper offloads individual loop statements; Yamato's
//! follow-ups (arXiv:2004.09883, arXiv:2005.04174) show the bigger wins
//! come from recognizing whole *function blocks* — an FIR filter, a
//! dense matmul, a histogram fill — and swapping them for hand-tuned
//! accelerator IP or library kernels.  This subsystem implements that
//! layer in three parts:
//!
//! * [`detect`] — a **structural** detector over the loop-nest IR: every
//!   outermost loop nest gets a normalized [`NestSignature`] (depth,
//!   accumulation pattern, array-access shape, operator classes) and is
//!   matched against the registry by signature predicates — never by
//!   function or variable names.
//! * [`registry`] — the IP/library registry: per-block, per-backend
//!   implementations with cost/resource/transfer models.  Arria10 IP
//!   cores are **prebuilt** (near-zero recompile cost — linking a
//!   partial-reconfiguration region, not a 3-hour place-and-route);
//!   GPU library kernels ride the existing SIMT cost model.
//! * the combined search — a `BlockNarrow` stage in
//!   [`crate::coordinator::stages`] quotes block offers through the
//!   [`crate::backend::OffloadBackend`] seam and measures block
//!   placements next to the loop-statement patterns; a block *subsumes*
//!   its member loops, and the selector resolves the overlap so the
//!   combined search never loses to loop-only search.
//!
//! Exposed on the CLI as `flopt --blocks {off,on,only}`.

pub mod detect;
pub mod registry;

pub use detect::{detect, DetectedBlock, NestSignature};
pub use registry::{entry_for, ip_for, registry, BlockIp, BlockOffer, IpModel};

use crate::cparse::ast::LoopId;
use crate::interp::Profile;
use crate::ir::LoopAnalysis;

/// How the offload search treats function blocks (`flopt --blocks ...`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlockMode {
    /// Loop-statement search only (the source paper's flow; the default).
    #[default]
    Off,
    /// Co-search function-block replacement with loop-statement offload.
    On,
    /// Function-block replacement only — no loop-statement candidates
    /// are pre-compiled or measured (near-zero compile-lane hours).
    Only,
}

impl BlockMode {
    /// Parse a `--blocks` argument (case-insensitive).
    pub fn parse(s: &str) -> Option<BlockMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(BlockMode::Off),
            "on" => Some(BlockMode::On),
            "only" => Some(BlockMode::Only),
            _ => None,
        }
    }

    /// Canonical label ("off", "on", "only") — also the cache-key and
    /// JSON encoding of the mode.
    pub fn as_str(self) -> &'static str {
        match self {
            BlockMode::Off => "off",
            BlockMode::On => "on",
            BlockMode::Only => "only",
        }
    }
}

impl std::fmt::Display for BlockMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

/// Result of compiling + measuring one function-block placement (the
/// block-replacement analogue of
/// [`crate::coordinator::verify_env::PatternMeasurement`]).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockMeasurement {
    /// Registry name of the placed block (e.g. `fir_filter`).
    pub block: String,
    /// Member loop statements the block replacement subsumes.
    pub block_loops: Vec<LoopId>,
    /// Loop statements co-offloaded alongside the block (the overlap-
    /// resolved remainder of a loop-statement pattern).
    pub extra_loops: Vec<LoopId>,
    /// Combined device resource fraction (IP core + extra kernels).
    pub utilization: f64,
    /// Did the simulated compile/link produce a runnable image?
    pub compiled: bool,
    /// Simulated compile seconds charged to the farm (near-zero for a
    /// prebuilt IP alone; plus the pattern compile when loops ride along).
    pub compile_sim_s: f64,
    /// Measured wall-clock of the sample app under this placement (model).
    pub time_s: f64,
    /// Speedup vs. the all-CPU run.
    pub speedup: f64,
}

impl BlockMeasurement {
    /// Is this placement a prebuilt IP core alone (no co-offloaded loop
    /// kernels)?  Pure-IP placements swap onto a board with a cheap
    /// partial-reconfiguration link instead of a full bitstream build —
    /// the property the fleet scheduler ([`crate::fleet`]) exploits when
    /// boards are contended.
    pub fn is_pure_ip(&self) -> bool {
        self.extra_loops.is_empty()
    }

    /// Human-readable label, e.g. `fir_filter[L8+L9]+L10`.
    pub fn label(&self) -> String {
        let members = self
            .block_loops
            .iter()
            .map(|l| l.to_string())
            .collect::<Vec<_>>()
            .join("+");
        let mut out = format!("{}[{members}]", self.block);
        for l in &self.extra_loops {
            out.push('+');
            out.push_str(&l.to_string());
        }
        out
    }
}

/// H2D/D2H transfer byte counts of a block replacement: the generated
/// host program's footprint rule ([`crate::fpga::timing::transfer_bytes`])
/// applied to the block's root nest — everything the nest touched goes
/// to the device, written arrays come back.
pub fn transfer_bytes(
    loops: &[LoopAnalysis],
    profile: &Profile,
    block: &DetectedBlock,
) -> (u64, u64) {
    let Some(la) = loops.iter().find(|l| l.info.id == block.root) else {
        return (0, 0);
    };
    let Some(lp) = profile.loop_profile(block.root) else {
        return (0, 0);
    };
    crate::fpga::timing::transfer_bytes(la, lp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_and_roundtrips() {
        for m in [BlockMode::Off, BlockMode::On, BlockMode::Only] {
            assert_eq!(BlockMode::parse(m.as_str()), Some(m));
            assert_eq!(format!("{m}"), m.as_str());
        }
        assert_eq!(BlockMode::parse("ON"), Some(BlockMode::On));
        assert_eq!(BlockMode::parse("auto"), None);
        assert_eq!(BlockMode::default(), BlockMode::Off);
    }

    #[test]
    fn measurement_labels() {
        let m = BlockMeasurement {
            block: "fir_filter".to_string(),
            block_loops: vec![LoopId(8), LoopId(9)],
            extra_loops: vec![LoopId(10)],
            utilization: 0.4,
            compiled: true,
            compile_sim_s: 420.0,
            time_s: 0.1,
            speedup: 2.0,
        };
        assert_eq!(m.label(), "fir_filter[L8+L9]+L10");
        let alone = BlockMeasurement { extra_loops: vec![], ..m };
        assert_eq!(alone.label(), "fir_filter[L8+L9]");
    }
}
