//! Per-loop variable/array reference collection (Step 1: "変数参照関係").
//!
//! For a loop body we record which arrays are read/written together with
//! the index expressions used, which scalars are read/written, which
//! scalars are *declared inside* the body (privatizable), and which
//! functions are called.  The OpenCL generator derives kernel arguments
//! from exactly this set; the dependence analysis consumes it too.

use std::collections::{BTreeMap, BTreeSet};

use crate::cparse::ast::*;
use crate::util::intern::Symbol;

use super::loops::LoopInfo;

/// Reference sets of one loop body (including nested loops).
///
/// Keys are interned [`Symbol`]s: membership tests and map lookups are
/// integer comparisons, while `BTreeMap`/`BTreeSet` iteration stays in
/// the lexicographic order the old `String` keys had (`Symbol`'s `Ord`
/// compares resolved spellings).
#[derive(Debug, Clone, Default)]
pub struct LoopRefs {
    /// array name -> index expressions used in reads
    pub array_reads: BTreeMap<Symbol, Vec<Expr>>,
    /// array name -> index expressions used in writes
    pub array_writes: BTreeMap<Symbol, Vec<Expr>>,
    /// Scalars read anywhere in the body.
    pub scalar_reads: BTreeSet<Symbol>,
    /// Scalars written anywhere in the body.
    pub scalar_writes: BTreeSet<Symbol>,
    /// scalars declared inside the loop body (private per iteration)
    pub locals: BTreeSet<Symbol>,
    /// called function names (including math builtins)
    pub calls: BTreeSet<Symbol>,
}

/// Math builtins the interpreter / OpenCL / HLS all understand.
pub const BUILTINS: &[&str] = &[
    "sin", "cos", "sqrt", "fabs", "exp", "floor", "fmin", "fmax",
];

/// Is `name` one of the MiniC math builtins?
pub fn is_builtin(name: &str) -> bool {
    BUILTINS.contains(&name)
}

impl LoopRefs {
    /// All arrays touched (read or written).
    pub fn arrays(&self) -> BTreeSet<Symbol> {
        self.array_reads
            .keys()
            .chain(self.array_writes.keys())
            .copied()
            .collect()
    }

    /// Scalars read before any write and not declared locally —
    /// these must be passed *into* a generated kernel.
    pub fn free_scalars(&self) -> BTreeSet<Symbol> {
        self.scalar_reads
            .union(&self.scalar_writes)
            .filter(|s| !self.locals.contains(*s))
            .copied()
            .collect()
    }

    /// Non-builtin calls — a loop making these cannot be offloaded.
    pub fn non_builtin_calls(&self) -> BTreeSet<Symbol> {
        self.calls
            .iter()
            .filter(|c| !is_builtin(c.as_str()))
            .copied()
            .collect()
    }

    fn read_expr(&mut self, e: &Expr) {
        e.walk(&mut |e| match &e.kind {
            ExprKind::Var(n) => {
                self.scalar_reads.insert(*n);
            }
            ExprKind::Index(n, i) => {
                self.array_reads.entry(*n).or_default().push((**i).clone());
            }
            ExprKind::Call(f, _) => {
                self.calls.insert(*f);
            }
            _ => {}
        });
    }

    fn visit(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl(d) => {
                self.locals.insert(d.name);
                if let Some(init) = &d.init {
                    self.read_expr(init);
                }
            }
            Stmt::Assign { target, op, value, .. } => {
                self.read_expr(value);
                match target {
                    LValue::Var(n) => {
                        self.scalar_writes.insert(*n);
                        // compound assignment also reads the target
                        if *op != AssignOp::Assign {
                            self.scalar_reads.insert(*n);
                        }
                    }
                    LValue::Index(n, i) => {
                        self.read_expr(i);
                        self.array_writes.entry(*n).or_default().push((**i).clone());
                        if *op != AssignOp::Assign {
                            self.array_reads.entry(*n).or_default().push((**i).clone());
                        }
                    }
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                self.read_expr(cond);
                for s in then_branch.iter().chain(else_branch) {
                    self.visit(s);
                }
            }
            Stmt::For { header, body, .. } => {
                if let Some(s) = &header.init {
                    self.visit(s);
                }
                if let Some(c) = &header.cond {
                    self.read_expr(c);
                }
                if let Some(s) = &header.step {
                    self.visit(s);
                }
                for s in body {
                    self.visit(s);
                }
            }
            Stmt::While { cond, body, .. } => {
                self.read_expr(cond);
                for s in body {
                    self.visit(s);
                }
            }
            Stmt::Return(e, _) => {
                if let Some(e) = e {
                    self.read_expr(e);
                }
            }
            Stmt::Expr(e, _) => self.read_expr(e),
            Stmt::Block(body) => {
                for s in body {
                    self.visit(s);
                }
            }
        }
    }
}

/// Collect reference sets for one loop (its whole body subtree).
pub fn collect(info: &LoopInfo) -> LoopRefs {
    let mut refs = LoopRefs::default();
    // the loop's own counter is a local of the loop for kernel purposes
    if let Some(c) = &info.canonical {
        refs.locals.insert(c.var);
        refs.read_expr(&c.lo);
        refs.read_expr(&c.hi);
    }
    for s in &info.body {
        refs.visit(s);
    }
    refs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir::loops;

    fn refs_of(src: &str, idx: usize) -> LoopRefs {
        let p = parse(src).unwrap();
        let l = loops::extract(&p);
        collect(&l[idx])
    }

    fn sym(n: &str) -> Symbol {
        Symbol::intern(n)
    }

    #[test]
    fn collects_array_reads_and_writes() {
        let r = refs_of(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[i] * 2.0; } }",
            0,
        );
        assert!(r.array_writes.contains_key(&sym("a")));
        assert!(r.array_reads.contains_key(&sym("b")));
        assert!(!r.array_reads.contains_key(&sym("a")));
        assert_eq!(r.arrays().len(), 2);
    }

    #[test]
    fn compound_assign_reads_target() {
        let r = refs_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] += 1.0; } }",
            0,
        );
        assert!(r.array_reads.contains_key(&sym("a")));
        assert!(r.array_writes.contains_key(&sym("a")));
    }

    #[test]
    fn locals_are_private() {
        let r = refs_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { float t; t = a[i]; a[i] = t * t; } }",
            0,
        );
        assert!(r.locals.contains(&sym("t")));
        assert!(r.locals.contains(&sym("i")), "loop counter is private");
        assert!(!r.free_scalars().contains(&sym("t")));
        assert!(r.free_scalars().contains(&sym("n")));
    }

    #[test]
    fn builtin_vs_user_calls() {
        let r = refs_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]) + helper(i); } }",
            0,
        );
        assert!(r.calls.contains(&sym("sin")));
        assert_eq!(r.non_builtin_calls().into_iter().collect::<Vec<_>>(), vec!["helper"]);
    }

    #[test]
    fn nested_loop_refs_roll_up() {
        let r = refs_of(
            "void f(float a[], float b[], float c[], int n) { int i; int j; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { c[i * n + j] = a[i] + b[j]; } } }",
            0,
        );
        assert_eq!(r.arrays().len(), 3);
        assert!(r.locals.contains(&sym("i")));
        // j is declared outside both loops, so it is free for the outer loop
        assert!(r.free_scalars().contains(&sym("j")));
    }
}
