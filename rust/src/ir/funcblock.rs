//! Functional-block detection — paper Step 1's "機能ブロック利用の把握".
//!
//! The paper notes that recognizing *what* a piece of code computes
//! (e.g. "this is an FIR filter", "this calls an FFT library") is far
//! harder than structural parsing, and proposes Deckard-style
//! similar-code detection.  This module implements that idea:
//! every known block carries a **normalized structural fingerprint**
//! (a bag of features over the loop nest: depth, reduction shape,
//! operator mix, array-access pattern); candidate loops are scored by
//! cosine similarity against the library, and matches above a threshold
//! are reported as recognized functional blocks.
//!
//! This also powers the paper's stated future work — offloading *whole
//! functional blocks* (FFT 等) by swapping in a pre-optimized kernel
//! (here: a pre-built PJRT artifact) instead of generating OpenCL from
//! the loop body.

use std::collections::BTreeMap;

use crate::cparse::ast::*;
use crate::ir::LoopAnalysis;
use crate::util::intern::Symbol;

/// Feature vector over a loop nest (the Deckard-style characteristic
/// vector, adapted to MiniC).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Fingerprint {
    /// nest depth (capped at 4)
    pub depth: f64,
    /// float mul / add / div / trig / sqrt counts (per innermost body)
    pub fmul: f64,
    /// Float add/sub count (see [`Fingerprint::fmul`]).
    pub fadd: f64,
    /// Float divide count.
    pub fdiv: f64,
    /// `sin`/`cos` call count.
    pub trig: f64,
    /// `sqrt` call count.
    pub sqrt: f64,
    /// number of `+`-reductions carried
    pub reductions: f64,
    /// distinct arrays read / written
    pub arrays_read: f64,
    /// Distinct arrays written.
    pub arrays_written: f64,
    /// array reads whose index mixes BOTH loop counters of a 2-nest
    /// (the matmul/conv signature: a[i*n+k], x[s+t-1-k], ...)
    pub cross_indexed_reads: f64,
    /// reads at shifted index (x[k+l], stencil/conv signature)
    pub shifted_reads: f64,
}

impl Fingerprint {
    fn as_vec(&self) -> [f64; 11] {
        [
            self.depth,
            self.fmul,
            self.fadd,
            self.fdiv,
            self.trig,
            self.sqrt,
            self.reductions,
            self.arrays_read,
            self.arrays_written,
            self.cross_indexed_reads,
            self.shifted_reads,
        ]
    }

    /// Cosine similarity in feature space.
    pub fn similarity(&self, other: &Fingerprint) -> f64 {
        let a = self.as_vec();
        let b = other.as_vec();
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    }
}

/// A known functional block in the library.
#[derive(Debug, Clone)]
pub struct KnownBlock {
    /// Block identifier (e.g. `fir_filter`).
    pub name: &'static str,
    /// One-line description of what the block computes.
    pub description: &'static str,
    /// Reference structural fingerprint.
    pub fingerprint: Fingerprint,
    /// pre-optimized artifact usable instead of generated OpenCL
    pub artifact: Option<&'static str>,
}

/// A match of a loop against the library.
#[derive(Debug, Clone)]
pub struct BlockMatch {
    /// The matched loop statement.
    pub loop_id: LoopId,
    /// Name of the matched library block.
    pub block: &'static str,
    /// Cosine similarity of the fingerprints (0..1).
    pub similarity: f64,
    /// Pre-optimized artifact of the block, when one exists.
    pub artifact: Option<&'static str>,
}

/// Compute the fingerprint of one loop nest.
pub fn fingerprint(la: &LoopAnalysis) -> Fingerprint {
    let mut fp = Fingerprint {
        depth: (1 + count_nested(&la.info.body)).min(4) as f64,
        reductions: count_reductions(la),
        arrays_read: la.refs.array_reads.len() as f64,
        arrays_written: la.refs.array_writes.len() as f64,
        ..Default::default()
    };

    // collect loop counter names in the nest (self + nested headers)
    let mut counters: Vec<Symbol> = Vec::new();
    if let Some(c) = &la.info.canonical {
        counters.push(c.var);
    }
    for s in &la.info.body {
        s.walk(&mut |s| {
            if let Stmt::For { header, .. } = s {
                if let Some(Stmt::Decl(d)) = header.init.as_deref() {
                    counters.push(d.name);
                } else if let Some(Stmt::Assign { target: LValue::Var(v), .. }) =
                    header.init.as_deref()
                {
                    counters.push(*v);
                }
            }
        });
    }

    // operator mix + index-shape features
    for s in &la.info.body {
        s.walk(&mut |s| {
            let exprs: Vec<&Expr> = match s {
                Stmt::Assign { value, target, .. } => {
                    let mut v = vec![value];
                    if let LValue::Index(_, i) = target {
                        v.push(i);
                    }
                    v
                }
                Stmt::Decl(d) => d.init.iter().collect(),
                Stmt::If { cond, .. } | Stmt::While { cond, .. } => vec![cond],
                Stmt::Expr(e, _) => vec![e],
                Stmt::Return(Some(e), _) => vec![e],
                _ => vec![],
            };
            for e in exprs {
                e.walk(&mut |e| match &e.kind {
                    ExprKind::Binary(BinOp::Mul, ..) => fp.fmul += 1.0,
                    ExprKind::Binary(BinOp::Add | BinOp::Sub, ..) => fp.fadd += 1.0,
                    ExprKind::Binary(BinOp::Div, ..) => fp.fdiv += 1.0,
                    ExprKind::Call(f, _) if f == "sin" || f == "cos" => fp.trig += 1.0,
                    ExprKind::Call(f, _) if f == "sqrt" => fp.sqrt += 1.0,
                    ExprKind::Index(_, idx) => {
                        let mut hits = 0usize;
                        for c in &counters {
                            if expr_mentions(idx, *c) {
                                hits += 1;
                            }
                        }
                        if hits >= 2 {
                            fp.cross_indexed_reads += 1.0;
                        }
                        if index_has_offset(idx) {
                            fp.shifted_reads += 1.0;
                        }
                    }
                    _ => {}
                });
            }
        });
    }
    fp
}

fn count_nested(body: &[Stmt]) -> usize {
    let mut depth = 0;
    for s in body {
        if let Stmt::For { body: b, .. } | Stmt::While { body: b, .. } = s {
            depth = depth.max(1 + count_nested(b));
        } else if let Stmt::If { then_branch, else_branch, .. } = s {
            depth = depth.max(count_nested(then_branch));
            depth = depth.max(count_nested(else_branch));
        } else if let Stmt::Block(b) = s {
            depth = depth.max(count_nested(b));
        }
    }
    depth
}

fn count_reductions(la: &LoopAnalysis) -> f64 {
    // reductions carried anywhere in the nest (this loop's analysis
    // rolls nested bodies up)
    let mut n = la.deps.reductions.len();
    if n == 0 {
        // nested reduction accumulators are locals of this loop — detect
        // `x += ...` / `x = x + ...` on local floats
        for s in &la.info.body {
            s.walk(&mut |s| {
                if let Stmt::Assign { target: LValue::Var(_), op, .. } = s {
                    if matches!(op, AssignOp::AddAssign) {
                        n += 1;
                    }
                }
            });
        }
    }
    n.min(4) as f64
}

fn expr_mentions(e: &Expr, var: Symbol) -> bool {
    let mut f = false;
    e.walk(&mut |e| {
        if let ExprKind::Var(n) = &e.kind {
            if *n == var {
                f = true;
            }
        }
    });
    f
}

fn index_has_offset(e: &Expr) -> bool {
    matches!(e.kind, ExprKind::Binary(BinOp::Add | BinOp::Sub, ..))
}

/// The built-in block library (fingerprints derived from the reference
/// implementations in `rust/src/apps/minic/`).
pub fn library() -> Vec<KnownBlock> {
    vec![
        KnownBlock {
            name: "fir_filter",
            description: "time-domain FIR convolution (complex or real)",
            fingerprint: Fingerprint {
                depth: 2.0,
                fmul: 4.0,
                fadd: 4.0,
                reductions: 2.0,
                arrays_read: 4.0,
                arrays_written: 2.0,
                cross_indexed_reads: 2.0,
                shifted_reads: 4.0,
                ..Default::default()
            },
            artifact: Some("tdfir_fpga"),
        },
        KnownBlock {
            name: "mriq_computeq",
            description: "MRI-Q style per-point trig accumulation",
            fingerprint: Fingerprint {
                depth: 2.0,
                fmul: 6.0,
                fadd: 4.0,
                trig: 2.0,
                reductions: 2.0,
                arrays_read: 7.0,
                arrays_written: 2.0,
                ..Default::default()
            },
            artifact: Some("mriq_fpga"),
        },
        KnownBlock {
            name: "matmul",
            description: "dense matrix multiply (3-nest, cross-indexed)",
            fingerprint: Fingerprint {
                depth: 3.0,
                fmul: 3.0,
                fadd: 1.0,
                reductions: 1.0,
                arrays_read: 2.0,
                arrays_written: 1.0,
                cross_indexed_reads: 2.0,
                shifted_reads: 2.0,
                ..Default::default()
            },
            artifact: None,
        },
        KnownBlock {
            name: "stencil",
            description: "neighbor-offset stencil sweep",
            fingerprint: Fingerprint {
                depth: 2.0,
                fmul: 3.0,
                fadd: 4.0,
                arrays_read: 1.0,
                arrays_written: 1.0,
                cross_indexed_reads: 1.0,
                shifted_reads: 4.0,
                ..Default::default()
            },
            artifact: None,
        },
    ]
}

/// Match every analyzed loop against the block library.
pub fn detect(loops: &[LoopAnalysis], threshold: f64) -> Vec<BlockMatch> {
    let lib = library();
    let mut out = Vec::new();
    for la in loops {
        if la.info.depth != 0 {
            continue; // match outermost statements only
        }
        let fp = fingerprint(la);
        let mut best: Option<(&KnownBlock, f64)> = None;
        for k in &lib {
            let s = fp.similarity(&k.fingerprint);
            if best.map(|(_, bs)| s > bs).unwrap_or(true) {
                best = Some((k, s));
            }
        }
        if let Some((k, s)) = best {
            if s >= threshold {
                out.push(BlockMatch {
                    loop_id: la.info.id,
                    block: k.name,
                    similarity: s,
                    artifact: k.artifact,
                });
            }
        }
    }
    out.sort_by(|a, b| {
        crate::util::order::desc_nan_last(a.similarity, b.similarity)
            .then_with(|| a.loop_id.cmp(&b.loop_id))
    });
    out
}

/// Per-loop best matches keyed by loop id (diagnostics table).
pub fn match_table(loops: &[LoopAnalysis]) -> BTreeMap<LoopId, BlockMatch> {
    detect(loops, 0.0)
        .into_iter()
        .map(|m| (m.loop_id, m))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::ir;

    fn matches_for(app: &crate::apps::App) -> Vec<BlockMatch> {
        let p = app.parse();
        let loops = ir::analyze(&p);
        detect(&loops, 0.90)
    }

    #[test]
    fn tdfir_hot_loop_recognized_as_fir() {
        let ms = matches_for(&apps::TDFIR);
        let fir = ms
            .iter()
            .find(|m| m.loop_id == LoopId(8))
            .expect("L8 must match a block");
        assert_eq!(fir.block, "fir_filter", "sim {}", fir.similarity);
        assert!(fir.similarity > 0.90, "{}", fir.similarity);
        assert_eq!(fir.artifact, Some("tdfir_fpga"));
    }

    #[test]
    fn mriq_hot_loop_recognized() {
        let ms = matches_for(&apps::MRIQ);
        let q = ms
            .iter()
            .find(|m| m.loop_id == LoopId(6))
            .expect("L6 must match a block");
        assert_eq!(q.block, "mriq_computeq", "sim {}", q.similarity);
        assert!(q.similarity > 0.92, "{}", q.similarity);
    }

    #[test]
    fn matmul_recognized() {
        let ms = matches_for(&apps::MATMUL);
        let mm = ms.iter().find(|m| m.block == "matmul");
        assert!(mm.is_some(), "matches: {ms:?}");
    }

    #[test]
    fn trivial_loops_do_not_match_strongly() {
        // zero_output (L7) is a plain memset — must not be claimed as
        // FIR/matmul at high similarity
        let p = apps::TDFIR.parse();
        let loops = ir::analyze(&p);
        let table = match_table(&loops);
        if let Some(m) = table.get(&LoopId(7)) {
            assert!(
                m.similarity < 0.90,
                "memset claimed as {} at {}",
                m.block,
                m.similarity
            );
        }
    }

    #[test]
    fn similarity_is_symmetric_and_bounded() {
        let lib = library();
        for a in &lib {
            for b in &lib {
                let s1 = a.fingerprint.similarity(&b.fingerprint);
                let s2 = b.fingerprint.similarity(&a.fingerprint);
                assert!((s1 - s2).abs() < 1e-12);
                assert!((0.0..=1.0 + 1e-12).contains(&s1));
            }
            assert!((a.fingerprint.similarity(&a.fingerprint) - 1.0).abs() < 1e-9);
        }
    }
}
