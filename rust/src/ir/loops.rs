//! Loop-statement extraction: walks the AST and records every `for`/`while`
//! with its nesting context, plus the *canonical* counted form
//! `for (v = lo; v < hi; v += step)` when the header matches it — the form
//! the OpenCL generator and the HLS scheduler reason about.

use crate::cparse::ast::*;
use crate::cparse::error::Pos;
use crate::util::intern::Symbol;

/// Kind of loop statement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopKind {
    /// A `for` statement.
    For,
    /// A `while` statement.
    While,
}

/// Canonical counted loop `for (var = lo; var </<= hi; var += step)`.
#[derive(Debug, Clone, PartialEq)]
pub struct CanonicalLoop {
    /// The loop counter variable.
    pub var: Symbol,
    /// Initial counter value.
    pub lo: Expr,
    /// Loop bound.
    pub hi: Expr,
    /// `true` when the condition is `<=` (trip count = hi - lo + 1).
    pub inclusive: bool,
    /// Positive constant counter increment.
    pub step: i64,
}

/// One loop statement with its nesting context.
#[derive(Debug, Clone)]
pub struct LoopInfo {
    /// Stable source-ordered loop id.
    pub id: LoopId,
    /// `for` or `while`.
    pub kind: LoopKind,
    /// Enclosing function name.
    pub function: Symbol,
    /// Nesting depth inside the function (0 = outermost loop).
    pub depth: u32,
    /// Immediately enclosing loop, if any.
    pub parent: Option<LoopId>,
    /// Loops nested directly inside this one.
    pub children: Vec<LoopId>,
    /// Source position of the loop statement.
    pub pos: Pos,
    /// Canonical counted form, when recognizable.
    pub canonical: Option<CanonicalLoop>,
    /// `while` condition (None for `for`).
    pub while_cond: Option<Expr>,
    /// For-header as parsed (None for `while`).
    pub header: Option<ForHeader>,
    /// Loop body (owned clone — later stages are AST-independent).
    pub body: Vec<Stmt>,
    /// Number of statements in the body subtree (size metric).
    pub body_stmts: usize,
}

impl LoopInfo {
    /// Is this an innermost loop (no nested loops)?
    pub fn is_innermost(&self) -> bool {
        self.children.is_empty()
    }
}

fn canonicalize(header: &ForHeader) -> Option<CanonicalLoop> {
    // init: `v = lo` (assignment or declaration with init)
    let (var, lo) = match header.init.as_deref() {
        Some(Stmt::Assign { target: LValue::Var(v), op: AssignOp::Assign, value, .. }) => {
            (*v, value.clone())
        }
        Some(Stmt::Decl(d)) => (d.name, d.init.clone()?),
        _ => return None,
    };
    // cond: `v < hi` or `v <= hi`
    let is_var = |e: &Expr| e.kind == ExprKind::Var(var);
    let (hi, inclusive) = match header.cond.as_ref().map(|c| &c.kind) {
        Some(ExprKind::Binary(BinOp::Lt, a, b)) if is_var(a) => {
            ((**b).clone(), false)
        }
        Some(ExprKind::Binary(BinOp::Le, a, b)) if is_var(a) => {
            ((**b).clone(), true)
        }
        _ => return None,
    };
    // step: `v += k` / `v = v + k`
    let step = match header.step.as_deref() {
        Some(Stmt::Assign {
            target: LValue::Var(v),
            op: AssignOp::AddAssign,
            value: Expr { kind: ExprKind::IntLit(k), .. },
            ..
        }) if *v == var => *k,
        Some(Stmt::Assign { target: LValue::Var(v), op: AssignOp::Assign, value, .. }) if *v == var => {
            match &value.kind {
                ExprKind::Binary(BinOp::Add, a, b) if is_var(a) => {
                    if let ExprKind::IntLit(k) = b.kind { k } else { return None }
                }
                _ => return None,
            }
        }
        _ => return None,
    };
    if step <= 0 {
        return None;
    }
    Some(CanonicalLoop { var, lo, hi, inclusive, step })
}

fn count_stmts(body: &[Stmt]) -> usize {
    let mut n = 0;
    for s in body {
        s.walk(&mut |_| n += 1);
    }
    n
}

struct Walker {
    out: Vec<LoopInfo>,
    function: Symbol,
    stack: Vec<LoopId>,
}

impl Walker {
    fn visit_all(&mut self, body: &[Stmt]) {
        for s in body {
            self.visit(s);
        }
    }

    fn visit(&mut self, s: &Stmt) {
        match s {
            Stmt::For { id, header, body, pos } => {
                self.push_loop(LoopInfo {
                    id: *id,
                    kind: LoopKind::For,
                    function: self.function,
                    depth: self.stack.len() as u32,
                    parent: self.stack.last().copied(),
                    children: Vec::new(),
                    pos: *pos,
                    canonical: canonicalize(header),
                    while_cond: None,
                    header: Some(header.clone()),
                    body: body.clone(),
                    body_stmts: count_stmts(body),
                });
                self.stack.push(*id);
                self.visit_all(body);
                self.stack.pop();
            }
            Stmt::While { id, cond, body, pos } => {
                self.push_loop(LoopInfo {
                    id: *id,
                    kind: LoopKind::While,
                    function: self.function,
                    depth: self.stack.len() as u32,
                    parent: self.stack.last().copied(),
                    children: Vec::new(),
                    pos: *pos,
                    canonical: None,
                    while_cond: Some(cond.clone()),
                    header: None,
                    body: body.clone(),
                    body_stmts: count_stmts(body),
                });
                self.stack.push(*id);
                self.visit_all(body);
                self.stack.pop();
            }
            Stmt::If { then_branch, else_branch, .. } => {
                self.visit_all(then_branch);
                self.visit_all(else_branch);
            }
            Stmt::Block(body) => self.visit_all(body),
            _ => {}
        }
    }

    fn push_loop(&mut self, info: LoopInfo) {
        if let Some(pid) = info.parent {
            if let Some(p) = self.out.iter_mut().find(|l| l.id == pid) {
                p.children.push(info.id);
            }
        }
        self.out.push(info);
    }
}

/// Extract every loop statement in the program, in source (LoopId) order.
pub fn extract(program: &Program) -> Vec<LoopInfo> {
    let mut w = Walker {
        out: Vec::new(),
        function: Symbol::intern(""),
        stack: Vec::new(),
    };
    for f in &program.functions {
        self_assert_stack_empty(&w);
        w.function = f.name;
        w.visit_all(&f.body);
    }
    w.out.sort_by_key(|l| l.id);
    w.out
}

fn self_assert_stack_empty(w: &Walker) {
    debug_assert!(w.stack.is_empty(), "loop stack must reset between functions");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;

    fn loops(src: &str) -> Vec<LoopInfo> {
        extract(&parse(src).unwrap())
    }

    #[test]
    fn extracts_nesting_structure() {
        let l = loops(
            "void f(int n) { int i; int j; \
             for (i = 0; i < n; i++) { for (j = 0; j < n; j++) { } } \
             for (i = 0; i < n; i++) { } }",
        );
        assert_eq!(l.len(), 3);
        assert_eq!(l[0].depth, 0);
        assert_eq!(l[1].depth, 1);
        assert_eq!(l[1].parent, Some(l[0].id));
        assert_eq!(l[0].children, vec![l[1].id]);
        assert_eq!(l[2].depth, 0);
        assert!(l[1].is_innermost());
        assert!(!l[0].is_innermost());
    }

    #[test]
    fn canonical_for_recognized() {
        let l = loops("void f(int n) { int i; for (i = 0; i < n; i++) { } }");
        let c = l[0].canonical.as_ref().unwrap();
        assert_eq!(c.var, "i");
        assert_eq!(c.step, 1);
        assert!(!c.inclusive);
    }

    #[test]
    fn canonical_variants() {
        let l = loops(
            "void f(int n) { \
             for (int i = 2; i <= n; i += 3) { } \
             for (int j = 0; j < n; j = j + 2) { } }",
        );
        let c0 = l[0].canonical.as_ref().unwrap();
        assert_eq!((c0.step, c0.inclusive), (3, true));
        assert_eq!(c0.lo.kind, crate::cparse::ExprKind::IntLit(2));
        let c1 = l[1].canonical.as_ref().unwrap();
        assert_eq!(c1.step, 2);
    }

    #[test]
    fn non_canonical_forms_rejected() {
        // decreasing loop and while: no canonical form
        let l = loops(
            "void f(int n) { int i; \
             for (i = n; i > 0; i -= 1) { } \
             while (n > 0) { n -= 1; } }",
        );
        assert!(l[0].canonical.is_none());
        assert_eq!(l[1].kind, LoopKind::While);
        assert!(l[1].canonical.is_none());
    }

    #[test]
    fn loops_inside_if_found() {
        let l = loops(
            "void f(int n) { int i; if (n > 0) { for (i = 0; i < n; i++) { } } }",
        );
        assert_eq!(l.len(), 1);
    }
}
