//! Loop-nest IR: what Step 1 of the paper's flow extracts from the AST.
//!
//! * [`loops`] — every loop statement with nesting structure and the
//!   canonical counted-loop header when one exists;
//! * [`varref`] — per-loop variable/array reference sets (the paper:
//!   "for 文内で使われる変数データ等の、プログラム構造を把握する");
//! * [`deps`] — conservative dependence analysis deciding which loops are
//!   parallelizable / FPGA-offloadable, with reduction recognition.

pub mod deps;
pub mod funcblock;
pub mod loops;
pub mod varref;

pub use deps::{DepAnalysis, Reduction};
pub use loops::{CanonicalLoop, LoopInfo, LoopKind};
pub use varref::LoopRefs;

use crate::cparse::Program;

/// Full per-loop analysis bundle used by the rest of the pipeline.
#[derive(Debug, Clone)]
pub struct LoopAnalysis {
    /// Structural facts: nesting, canonical header, body.
    pub info: LoopInfo,
    /// Variable/array reference sets of the body.
    pub refs: LoopRefs,
    /// Dependence verdict and recognized reductions.
    pub deps: DepAnalysis,
}

/// Analyze every loop in the program (Step 1 output).
pub fn analyze(program: &Program) -> Vec<LoopAnalysis> {
    loops::extract(program)
        .into_iter()
        .map(|info| {
            let refs = varref::collect(&info);
            let deps = deps::analyze(&info, &refs);
            LoopAnalysis { info, refs, deps }
        })
        .collect()
}
