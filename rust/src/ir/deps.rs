//! Conservative dependence analysis: which loops may legally run as a
//! parallel/pipelined FPGA kernel, and which scalar reductions they carry.
//!
//! This is the Step-2 "オフロード可能部抽出" check.  The tests are
//! deliberately conservative (a loop is only offloadable when we can
//! *prove* the easy cases), mirroring what automatic parallelizers such as
//! the PGI compiler accept without user directives:
//!
//! 1. the loop has a canonical counted header (`for (v = lo; v < hi; v += k)`);
//! 2. the body makes no non-builtin calls and contains no `return`;
//! 3. every written array is indexed by an expression that *contains the
//!    loop counter* (distinct iterations touch distinct elements) and
//!    contains no array read (`a[idx[i]]` is a data-dependent scatter:
//!    two iterations may collide however the counter appears), and if
//!    the same array is also read, every read index is syntactically equal
//!    to a write index (`a[i] = f(a[i])` allowed, `a[i] = a[i-1]` not);
//! 4. every scalar that is both read and written is either declared inside
//!    the body (private) or forms a recognized reduction
//!    (`s += e` / `s = s + e` / `s *= e` with no other writes to `s`)
//!    whose running value is never consumed elsewhere in the body — a
//!    prefix sum (`t = t + x; out[i] = t;`) updates like a reduction but
//!    each iteration observes the previous one's total.
//!
//! [`analyze`] now delegates to the subscript dependence engine in
//! [`crate::analyze`], which keeps this gate order but *proves* the
//! array cases with ZIV/SIV/MIV tests and adds a write/write overlap
//! check.  The original syntactic rules survive verbatim as
//! [`analyze_legacy`] — the differential baseline the engine was
//! validated against and the denominator of the Analyze-stage overhead
//! benchmark.

use std::collections::BTreeSet;

use crate::analyze::RejectReason;
use crate::cparse::ast::*;
use crate::util::intern::Symbol;

use super::loops::LoopInfo;
use super::varref::LoopRefs;

/// A recognized scalar reduction carried by the loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reduction {
    /// The reduced scalar variable.
    pub var: Symbol,
    /// `+` or `*`.
    pub op: char,
}

/// Outcome of the dependence tests for one loop.
#[derive(Debug, Clone, Default)]
pub struct DepAnalysis {
    /// May the loop run as an FPGA kernel (iterations independent up to
    /// recognized reductions)?
    pub offloadable: bool,
    /// First reason the loop was rejected, for diagnostics.
    pub reject_reason: Option<RejectReason>,
    /// Recognized reductions (empty for fully parallel loops).
    pub reductions: Vec<Reduction>,
}

pub(crate) fn expr_contains_var(e: &Expr, var: Symbol) -> bool {
    let mut found = false;
    e.walk(&mut |e| {
        if let ExprKind::Var(n) = &e.kind {
            if *n == var {
                found = true;
            }
        }
    });
    found
}

pub(crate) fn expr_contains_index(e: &Expr) -> bool {
    let mut found = false;
    e.walk(&mut |e| {
        if matches!(e.kind, ExprKind::Index(..)) {
            found = true;
        }
    });
    found
}

pub(crate) fn body_has_return(body: &[Stmt]) -> bool {
    let mut found = false;
    for s in body {
        s.walk(&mut |s| {
            if matches!(s, Stmt::Return(..)) {
                found = true;
            }
        });
    }
    found
}

/// Collect every `Assign` in the body subtree.
pub(crate) fn assignments(body: &[Stmt]) -> Vec<(LValue, AssignOp, Expr)> {
    let mut out = Vec::new();
    for s in body {
        s.walk(&mut |s| {
            if let Stmt::Assign { target, op, value, .. } = s {
                out.push((target.clone(), *op, value.clone()));
            }
        });
    }
    out
}

/// Try to recognize `var` as a reduction over the body's assignments.
pub(crate) fn recognize_reduction(var: Symbol, assigns: &[(LValue, AssignOp, Expr)]) -> Option<Reduction> {
    let mut op: Option<char> = None;
    for (target, aop, value) in assigns {
        if target.name() != var {
            continue;
        }
        if matches!(target, LValue::Index(..)) {
            return None;
        }
        let this = match aop {
            AssignOp::AddAssign | AssignOp::SubAssign => '+',
            AssignOp::MulAssign => '*',
            AssignOp::Assign => match &value.kind {
                // s = s + e  /  s = e + s
                ExprKind::Binary(BinOp::Add, a, b)
                    if a.kind == ExprKind::Var(var) || b.kind == ExprKind::Var(var) => '+',
                ExprKind::Binary(BinOp::Sub, a, _) if a.kind == ExprKind::Var(var) => '+',
                ExprKind::Binary(BinOp::Mul, a, b)
                    if a.kind == ExprKind::Var(var) || b.kind == ExprKind::Var(var) => '*',
                _ => return None,
            },
            _ => return None,
        };
        // the reduced variable must not appear elsewhere in the RHS
        if *aop == AssignOp::Assign {
            // already structurally checked above
        } else if expr_contains_var(value, var) {
            return None;
        }
        match op {
            None => op = Some(this),
            Some(o) if o == this => {}
            Some(_) => return None, // mixed ops
        }
    }
    op.map(|op| Reduction { var, op })
}

/// Count uses of a recognized reduction variable *outside* its own
/// reduction updates.  A true reduction is write-only until the loop
/// ends; any other read (stored to an array, tested in a guard, fed to
/// another assignment) observes the running value and orders the
/// iterations — the prefix-sum trap the generative suite fuzzes.
pub(crate) fn reduction_extra_uses(var: Symbol, body: &[Stmt]) -> usize {
    fn count_in(e: &Expr, var: Symbol) -> usize {
        let mut n = 0;
        e.walk(&mut |e| {
            if let ExprKind::Var(v) = &e.kind {
                if *v == var {
                    n += 1;
                }
            }
        });
        n
    }
    let mut uses = 0;
    for s in body {
        s.walk(&mut |s| match s {
            Stmt::Assign { target, op, value, .. } => {
                if let LValue::Index(_, idx) = target {
                    uses += count_in(idx, var);
                }
                let mut in_value = count_in(value, var);
                // `s = s + e` carries one structural self-reference the
                // recognizer already accepted; a second (`s = s + s`)
                // still counts
                if matches!(target, LValue::Var(t) if *t == var) && *op == AssignOp::Assign {
                    in_value = in_value.saturating_sub(1);
                }
                uses += in_value;
            }
            Stmt::Decl(d) => {
                if let Some(init) = &d.init {
                    uses += count_in(init, var);
                }
            }
            Stmt::If { cond, .. } | Stmt::While { cond, .. } => uses += count_in(cond, var),
            Stmt::Expr(e, _) | Stmt::Return(Some(e), _) => uses += count_in(e, var),
            // walk covers a nested for's init/step as statements but not
            // its header condition
            Stmt::For { header, .. } => {
                if let Some(c) = &header.cond {
                    uses += count_in(c, var);
                }
            }
            _ => {}
        });
    }
    uses
}

/// Run the dependence tests for one loop.
///
/// Delegates to the subscript dependence engine
/// ([`crate::analyze::analyze_loop`]) and collapses its verdict onto
/// the legacy `offloadable` / `reject_reason` contract.
pub fn analyze(info: &LoopInfo, refs: &LoopRefs) -> DepAnalysis {
    crate::analyze::analyze_loop(info, refs).to_dep_analysis()
}

/// The original syntactic gate sequence, kept as the differential
/// baseline for the engine (see the generative suite) and as the
/// denominator of the Analyze-stage overhead benchmark.
pub fn analyze_legacy(info: &LoopInfo, refs: &LoopRefs) -> DepAnalysis {
    let mut out = DepAnalysis::default();

    let reject = |reason: RejectReason| DepAnalysis {
        offloadable: false,
        reject_reason: Some(reason),
        reductions: Vec::new(),
    };

    // (1) canonical counted loop
    let Some(canon) = &info.canonical else {
        return reject(RejectReason::NoCanonicalHeader);
    };
    // bounds must not depend on anything the body writes (else trip count
    // changes mid-flight)
    for bound in [&canon.lo, &canon.hi] {
        let mut bad = false;
        bound.walk(&mut |e| {
            if let ExprKind::Var(n) = &e.kind {
                if refs.scalar_writes.contains(n) {
                    bad = true;
                }
            }
        });
        if bad {
            return reject(RejectReason::BoundWritten);
        }
    }

    // (2) calls / control flow
    if !refs.non_builtin_calls().is_empty() {
        return reject(RejectReason::NonBuiltinCall);
    }
    if body_has_return(&info.body) {
        return reject(RejectReason::BodyReturn);
    }

    let assigns = assignments(&info.body);

    // (3) array dependence test
    for (arr, writes) in &refs.array_writes {
        for w in writes {
            if !expr_contains_var(w, canon.var) {
                return reject(RejectReason::InvariantWriteIndex);
            }
            // `a[idx[i]]` contains the counter yet the subscript values
            // are data — two iterations may hit the same element
            if expr_contains_index(w) {
                return reject(RejectReason::DataDependentWriteIndex);
            }
        }
        if let Some(reads) = refs.array_reads.get(arr) {
            for r in reads {
                if !writes.iter().any(|w| w == r) {
                    return reject(RejectReason::ReadWriteMismatch);
                }
            }
        }
    }

    // (4) scalar dependence / reduction test
    let carried: BTreeSet<_> = refs
        .scalar_writes
        .intersection(&refs.scalar_reads)
        .filter(|v| !refs.locals.contains(*v) && **v != canon.var)
        .copied()
        .collect();
    for var in carried {
        match recognize_reduction(var, &assigns) {
            Some(r) => {
                if reduction_extra_uses(var, &info.body) > 0 {
                    return reject(RejectReason::ReductionConsumed);
                }
                out.reductions.push(r);
            }
            None => {
                return reject(RejectReason::CarriedScalar);
            }
        }
    }
    // scalars written but never read still escape the loop with the value
    // of the *last* iteration — fine for a counted loop (deterministic).

    out.offloadable = true;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir;

    fn dep(src: &str, idx: usize) -> DepAnalysis {
        let p = parse(src).unwrap();
        ir::analyze(&p)[idx].deps.clone()
    }

    #[test]
    fn elementwise_map_is_offloadable() {
        let d = dep(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[i] * 2.0; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert!(d.reductions.is_empty());
    }

    #[test]
    fn sum_reduction_recognized() {
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i]; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert_eq!(d.reductions, vec![Reduction { var: "s".into(), op: '+' }]);
    }

    #[test]
    fn s_equals_s_plus_form_recognized() {
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s = s + a[i] * a[i]; } }",
            0,
        );
        assert!(d.offloadable);
        assert_eq!(d.reductions.len(), 1);
    }

    #[test]
    fn recurrence_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 1; i < n; i++) { a[i] = a[i - 1] + 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("index mismatch"));
    }

    #[test]
    fn while_loop_rejected() {
        let d = dep("void f(int n) { while (n > 0) { n -= 1; } }", 0);
        assert!(!d.offloadable);
    }

    #[test]
    fn user_call_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = helper(i); } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("non-builtin"));
    }

    #[test]
    fn builtin_call_allowed() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]); } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
    }

    #[test]
    fn scalar_carried_dependence_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; float t; t = 0.0; \
             for (i = 0; i < n; i++) { t = a[i] - t; a[i] = t; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn private_scalar_ok() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { float t; t = a[i] * 2.0; a[i] = t + 1.0; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
    }

    #[test]
    fn loop_invariant_write_index_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[0] = a[0] + 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn bound_written_in_body_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = 0.0; n -= 1; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn outer_loop_of_matmul_offloadable() {
        let d = dep(
            "void mm(float a[], float b[], float c[], int n) { int i; int j; int k; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { \
                 float acc; acc = 0.0; \
                 for (k = 0; k < n; k++) { acc += a[i * n + k] * b[k * n + j]; } \
                 c[i * n + j] = acc; } } }",
            0,
        );
        // `acc` is declared inside loop j's body => private for loop i;
        // j and k counters are also assigned inside, but their headers
        // re-initialize them — they are written AND read...
        // The conservative test sees j,k as loop-carried; however both are
        // fully re-initialized by the inner for-headers, which the
        // reduction recognizer does not model. Accept either outcome but
        // require the *innermost* reduction loop to be classified.
        let _ = d;
    }

    #[test]
    fn scatter_through_index_array_rejected() {
        // `bins[a[i]]` mentions the counter, but the subscript values are
        // data: iterations collide on shared bins
        let d = dep(
            "void f(float bins[], float a[], int n) { int i; \
             for (i = 0; i < n; i++) { bins[a[i]] += 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("data-dependent"));
    }

    #[test]
    fn prefix_sum_store_rejected() {
        // `t` updates like a `+` reduction, but storing the running total
        // makes every iteration observe the previous one
        let d = dep(
            "void f(float a[], float pre[], int n) { int i; float t; t = 0.0; \
             for (i = 0; i < n; i++) { t = t + a[i]; pre[i] = t; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("consumed"));
    }

    #[test]
    fn reduction_var_in_write_index_rejected() {
        // `k -= 1` reduces, but using k to address the store serializes
        // the iterations (and would alias them all onto shifting slots)
        let d = dep(
            "void f(float a[], int n) { int i; int k; k = n; \
             for (i = 0; i < n; i++) { k -= 1; a[i + k] = 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("consumed"));
    }

    #[test]
    fn self_feeding_sum_rejected() {
        // `s = s + s` doubles the carried value — not a reduction over
        // loop-local terms even though it matches the `s = s + e` shape
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 1.0; \
             for (i = 0; i < n; i++) { s = s + s; a[i] = 0.0; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("consumed"));
    }

    #[test]
    fn guard_on_reduction_var_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { if (s < 10.0) { s += a[i]; } } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().to_string().contains("consumed"));
    }

    #[test]
    fn gather_read_still_offloadable() {
        // data-dependent READS are fine — only scattered writes collide
        let d = dep(
            "void f(float a[], float b[], float idx[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[idx[i]] * 2.0; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
    }

    #[test]
    fn innermost_matmul_loop_is_reduction() {
        let d = dep(
            "void mm(float a[], float b[], float c[], int n) { int i; int j; int k; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { \
                 float acc; acc = 0.0; \
                 for (k = 0; k < n; k++) { acc += a[i * n + k] * b[k * n + j]; } \
                 c[i * n + j] = acc; } } }",
            2,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert_eq!(d.reductions[0].var, "acc");
    }
}
