//! Conservative dependence analysis: which loops may legally run as a
//! parallel/pipelined FPGA kernel, and which scalar reductions they carry.
//!
//! This is the Step-2 "オフロード可能部抽出" check.  The tests are
//! deliberately conservative (a loop is only offloadable when we can
//! *prove* the easy cases), mirroring what automatic parallelizers such as
//! the PGI compiler accept without user directives:
//!
//! 1. the loop has a canonical counted header (`for (v = lo; v < hi; v += k)`);
//! 2. the body makes no non-builtin calls and contains no `return`;
//! 3. every written array is indexed by an expression that *contains the
//!    loop counter* (distinct iterations touch distinct elements), and if
//!    the same array is also read, every read index is syntactically equal
//!    to a write index (`a[i] = f(a[i])` allowed, `a[i] = a[i-1]` not);
//! 4. every scalar that is both read and written is either declared inside
//!    the body (private) or forms a recognized reduction
//!    (`s += e` / `s = s + e` / `s *= e` with no other writes to `s`).

use std::collections::BTreeSet;

use crate::cparse::ast::*;

use super::loops::LoopInfo;
use super::varref::LoopRefs;

/// A recognized scalar reduction carried by the loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reduction {
    /// The reduced scalar variable.
    pub var: String,
    /// `+` or `*`.
    pub op: char,
}

/// Outcome of the dependence tests for one loop.
#[derive(Debug, Clone, Default)]
pub struct DepAnalysis {
    /// May the loop run as an FPGA kernel (iterations independent up to
    /// recognized reductions)?
    pub offloadable: bool,
    /// First reason the loop was rejected, for diagnostics.
    pub reject_reason: Option<String>,
    /// Recognized reductions (empty for fully parallel loops).
    pub reductions: Vec<Reduction>,
}

fn expr_contains_var(e: &Expr, var: &str) -> bool {
    let mut found = false;
    e.walk(&mut |e| {
        if let Expr::Var(n) = e {
            if n == var {
                found = true;
            }
        }
    });
    found
}

fn body_has_return(body: &[Stmt]) -> bool {
    let mut found = false;
    for s in body {
        s.walk(&mut |s| {
            if matches!(s, Stmt::Return(..)) {
                found = true;
            }
        });
    }
    found
}

/// Collect every `Assign` in the body subtree.
fn assignments(body: &[Stmt]) -> Vec<(LValue, AssignOp, Expr)> {
    let mut out = Vec::new();
    for s in body {
        s.walk(&mut |s| {
            if let Stmt::Assign { target, op, value, .. } = s {
                out.push((target.clone(), *op, value.clone()));
            }
        });
    }
    out
}

/// Try to recognize `var` as a reduction over the body's assignments.
fn recognize_reduction(var: &str, assigns: &[(LValue, AssignOp, Expr)]) -> Option<Reduction> {
    let mut op: Option<char> = None;
    for (target, aop, value) in assigns {
        if target.name() != var {
            continue;
        }
        if matches!(target, LValue::Index(..)) {
            return None;
        }
        let this = match aop {
            AssignOp::AddAssign | AssignOp::SubAssign => '+',
            AssignOp::MulAssign => '*',
            AssignOp::Assign => match value {
                // s = s + e  /  s = e + s
                Expr::Binary(BinOp::Add, a, b)
                    if **a == Expr::Var(var.into()) || **b == Expr::Var(var.into()) => '+',
                Expr::Binary(BinOp::Sub, a, _) if **a == Expr::Var(var.into()) => '+',
                Expr::Binary(BinOp::Mul, a, b)
                    if **a == Expr::Var(var.into()) || **b == Expr::Var(var.into()) => '*',
                _ => return None,
            },
            _ => return None,
        };
        // the reduced variable must not appear elsewhere in the RHS
        if *aop == AssignOp::Assign {
            // already structurally checked above
        } else if expr_contains_var(value, var) {
            return None;
        }
        match op {
            None => op = Some(this),
            Some(o) if o == this => {}
            Some(_) => return None, // mixed ops
        }
    }
    op.map(|op| Reduction { var: var.into(), op })
}

/// Run the dependence tests for one loop.
pub fn analyze(info: &LoopInfo, refs: &LoopRefs) -> DepAnalysis {
    let mut out = DepAnalysis::default();

    let reject = |reason: &str| DepAnalysis {
        offloadable: false,
        reject_reason: Some(reason.to_string()),
        reductions: Vec::new(),
    };

    // (1) canonical counted loop
    let Some(canon) = &info.canonical else {
        return reject("no canonical counted header");
    };
    // bounds must not depend on anything the body writes (else trip count
    // changes mid-flight)
    for bound in [&canon.lo, &canon.hi] {
        let mut bad = false;
        bound.walk(&mut |e| {
            if let Expr::Var(n) = e {
                if refs.scalar_writes.contains(n) {
                    bad = true;
                }
            }
        });
        if bad {
            return reject("loop bound written inside body");
        }
    }

    // (2) calls / control flow
    if !refs.non_builtin_calls().is_empty() {
        return reject("calls non-builtin function");
    }
    if body_has_return(&info.body) {
        return reject("body contains return");
    }

    let assigns = assignments(&info.body);

    // (3) array dependence test
    for (arr, writes) in &refs.array_writes {
        for w in writes {
            if !expr_contains_var(w, &canon.var) {
                return reject("array written at loop-invariant index");
            }
        }
        if let Some(reads) = refs.array_reads.get(arr) {
            for r in reads {
                if !writes.iter().any(|w| w == r) {
                    return reject("array read/write index mismatch (possible cross-iteration dependence)");
                }
            }
        }
    }

    // (4) scalar dependence / reduction test
    let carried: BTreeSet<_> = refs
        .scalar_writes
        .intersection(&refs.scalar_reads)
        .filter(|v| !refs.locals.contains(*v) && *v != &canon.var)
        .cloned()
        .collect();
    for var in carried {
        match recognize_reduction(&var, &assigns) {
            Some(r) => out.reductions.push(r),
            None => {
                return reject("loop-carried scalar dependence (not a reduction)");
            }
        }
    }
    // scalars written but never read still escape the loop with the value
    // of the *last* iteration — fine for a counted loop (deterministic).

    out.offloadable = true;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir;

    fn dep(src: &str, idx: usize) -> DepAnalysis {
        let p = parse(src).unwrap();
        ir::analyze(&p)[idx].deps.clone()
    }

    #[test]
    fn elementwise_map_is_offloadable() {
        let d = dep(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[i] * 2.0; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert!(d.reductions.is_empty());
    }

    #[test]
    fn sum_reduction_recognized() {
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i]; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert_eq!(d.reductions, vec![Reduction { var: "s".into(), op: '+' }]);
    }

    #[test]
    fn s_equals_s_plus_form_recognized() {
        let d = dep(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s = s + a[i] * a[i]; } }",
            0,
        );
        assert!(d.offloadable);
        assert_eq!(d.reductions.len(), 1);
    }

    #[test]
    fn recurrence_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 1; i < n; i++) { a[i] = a[i - 1] + 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().contains("index mismatch"));
    }

    #[test]
    fn while_loop_rejected() {
        let d = dep("void f(int n) { while (n > 0) { n -= 1; } }", 0);
        assert!(!d.offloadable);
    }

    #[test]
    fn user_call_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = helper(i); } }",
            0,
        );
        assert!(!d.offloadable);
        assert!(d.reject_reason.unwrap().contains("non-builtin"));
    }

    #[test]
    fn builtin_call_allowed() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = sin(a[i]); } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
    }

    #[test]
    fn scalar_carried_dependence_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; float t; t = 0.0; \
             for (i = 0; i < n; i++) { t = a[i] - t; a[i] = t; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn private_scalar_ok() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { float t; t = a[i] * 2.0; a[i] = t + 1.0; } }",
            0,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
    }

    #[test]
    fn loop_invariant_write_index_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[0] = a[0] + 1.0; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn bound_written_in_body_rejected() {
        let d = dep(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = 0.0; n -= 1; } }",
            0,
        );
        assert!(!d.offloadable);
    }

    #[test]
    fn outer_loop_of_matmul_offloadable() {
        let d = dep(
            "void mm(float a[], float b[], float c[], int n) { int i; int j; int k; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { \
                 float acc; acc = 0.0; \
                 for (k = 0; k < n; k++) { acc += a[i * n + k] * b[k * n + j]; } \
                 c[i * n + j] = acc; } } }",
            0,
        );
        // `acc` is declared inside loop j's body => private for loop i;
        // j and k counters are also assigned inside, but their headers
        // re-initialize them — they are written AND read...
        // The conservative test sees j,k as loop-carried; however both are
        // fully re-initialized by the inner for-headers, which the
        // reduction recognizer does not model. Accept either outcome but
        // require the *innermost* reduction loop to be classified.
        let _ = d;
    }

    #[test]
    fn innermost_matmul_loop_is_reduction() {
        let d = dep(
            "void mm(float a[], float b[], float c[], int n) { int i; int j; int k; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { \
                 float acc; acc = 0.0; \
                 for (k = 0; k < n; k++) { acc += a[i * n + k] * b[k * n + j]; } \
                 c[i * n + j] = acc; } } }",
            2,
        );
        assert!(d.offloadable, "{:?}", d.reject_reason);
        assert_eq!(d.reductions[0].var, "acc");
    }
}
