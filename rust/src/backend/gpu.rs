//! GPU backend — a calibrated SIMT offload model.
//!
//! Plays the role of the measurement-driven GPU flow the paper contrasts
//! against (§3.2, citing [Yamato 2018]): automatic OpenACC-style offload
//! where one pattern verification is a *minutes*-long `pgcc`/`nvcc`
//! build, so a GA over offload bitmasks is affordable — unlike the
//! FPGA's ≈3-hour place-and-route.
//!
//! Calibration (DESIGN.md §6b): auto-generated, unoptimized kernels do
//! not approach peak SIMT throughput.  The published automatic-offload
//! results land in the low single digits over one CPU core, so the
//! kernel model is *relative*: offloaded compute runs at a calibrated
//! SIMT speedup over the [`CpuModel`] time of the same loop, floored by
//! device-memory bandwidth, plus per-entry kernel-launch latency and
//! PCIe transfers for the touched footprints.  That keeps the model's
//! *shape* honest — GPUs win modestly on streaming loops, lose on
//! launch/transfer-dominated ones — without chasing absolute TFLOPs.

use crate::cparse::ast::LoopId;
use crate::cparse::Program;
use crate::cpu::CpuModel;
use crate::fpga::timing::{self, KernelExec, pipelined_iters};
use crate::funcblock::{self, BlockOffer, DetectedBlock};
use crate::hls::{opcount, OpCounts};
use crate::interp::Profile;
use crate::ir::LoopAnalysis;

use super::{BackendCompile, BackendReport, OffloadBackend, ReportDetail, SearchMethod};

/// Calibrated parameters of one GPU board.
#[derive(Debug, Clone)]
pub struct GpuDevice {
    /// Marketing name of the board.
    pub name: &'static str,
    /// Streaming multiprocessors (description only).
    pub sms: u32,
    /// Effective device-memory bandwidth (bytes/s).
    pub mem_bw_bytes_per_s: f64,
    /// PCIe effective bandwidth for H2D/D2H (bytes/s).
    pub pcie_bw_bytes_per_s: f64,
    /// Per-DMA fixed latency.
    pub pcie_latency_s: f64,
    /// Per-kernel-launch fixed latency.
    pub launch_latency_s: f64,
    /// Base full-build time (`pgcc -acc` / `nvcc`): minutes, not hours.
    pub compile_base_s: f64,
    /// Extra build seconds per datapath operator in the kernel.
    pub compile_per_op_s: f64,
    /// Calibrated SIMT speedup of an auto-generated kernel over the
    /// single-thread CPU model (memory-bound streaming loop).
    pub base_simt_speedup: f64,
    /// Multiplier for trig/exp/sqrt-heavy bodies (SFU hardware vs libm).
    pub math_simt_bonus: f64,
    /// Multiplier for reduction loops (tree/atomic reduction overhead in
    /// auto-generated code).
    pub reduction_simt_penalty: f64,
    /// Ceiling on the calibrated speedup (unoptimized-kernel regime).
    pub max_simt_speedup: f64,
    /// Ceiling on the occupancy-style pressure estimate.
    pub occupancy_cap: f64,
}

impl GpuDevice {
    /// PCIe transfer seconds for `bytes` in one direction (zero bytes
    /// means no DMA is issued at all).
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        if bytes > 0 {
            self.pcie_latency_s + bytes as f64 / self.pcie_bw_bytes_per_s
        } else {
            0.0
        }
    }
}

/// NVIDIA Tesla P100 (PCIe) — the board class of the GPU-offload papers.
pub const TESLA_P100: GpuDevice = GpuDevice {
    name: "NVIDIA Tesla P100 (PCIe, 16 GB)",
    sms: 56,
    mem_bw_bytes_per_s: 550.0e9,
    pcie_bw_bytes_per_s: 12.0e9,
    pcie_latency_s: 10.0e-6,
    launch_latency_s: 12.0e-6,
    compile_base_s: 150.0,
    compile_per_op_s: 2.0,
    base_simt_speedup: 2.2,
    math_simt_bonus: 1.25,
    reduction_simt_penalty: 0.7,
    max_simt_speedup: 2.9,
    occupancy_cap: 1.0,
};

/// Pre-compile estimate of one loop as an auto-generated GPU kernel.
#[derive(Debug, Clone)]
pub struct GpuKernelReport {
    /// The loop the kernel was generated from.
    pub loop_id: LoopId,
    /// Datapath operator counts (register/ALU pressure input).
    pub ops: OpCounts,
    /// Occupancy-style resource-pressure estimate in (0, 1].
    pub occupancy: f64,
    /// Calibrated kernel-level SIMT speedup over the CPU model.
    pub simt_speedup: f64,
    /// Full-build seconds for this kernel (minutes-scale).
    pub compile_s: f64,
}

/// The GPU offload backend: one device model + the SIMT timing model.
#[derive(Debug, Clone)]
pub struct GpuBackend {
    /// The board the backend compiles against.
    pub device: &'static GpuDevice,
}

/// The default GPU backend.
pub static GPU: GpuBackend = GpuBackend { device: &TESLA_P100 };

impl GpuBackend {
    fn estimate(&self, ops: &OpCounts) -> GpuKernelReport {
        let total = ops.total() as f64;
        // register/ALU pressure grows with datapath size; never zero so
        // the resource-efficiency division stays well-defined
        let occupancy = (0.05 + 0.012 * total).min(self.device.occupancy_cap);
        let mut simt = self.device.base_simt_speedup;
        if ops.trig + ops.exp + ops.sqrt > 0 {
            simt *= self.device.math_simt_bonus;
        }
        if ops.plus_reductions + ops.star_reductions > 0 {
            simt *= self.device.reduction_simt_penalty;
        }
        let simt_speedup = simt.clamp(1.2, self.device.max_simt_speedup);
        GpuKernelReport {
            loop_id: LoopId(0), // caller fills in
            ops: ops.clone(),
            occupancy,
            simt_speedup,
            compile_s: self.device.compile_base_s + self.device.compile_per_op_s * total,
        }
    }
}

impl OffloadBackend for GpuBackend {
    fn destination(&self) -> super::Destination {
        super::Destination::Gpu
    }

    fn description(&self) -> String {
        format!(
            "{} | {} SMs | PCIe {:.1} GB/s | full build ~{:.1} min",
            self.device.name,
            self.device.sms,
            self.device.pcie_bw_bytes_per_s / 1e9,
            self.device.compile_base_s / 60.0
        )
    }

    fn search_method(&self) -> SearchMethod {
        SearchMethod::MeasurementGa
    }

    fn precompile(&self, program: &Program, la: &LoopAnalysis, _unroll: usize) -> BackendReport {
        let ops = opcount::count(program, la);
        let mut rep = self.estimate(&ops);
        rep.loop_id = la.info.id;
        BackendReport {
            loop_id: la.info.id,
            utilization: rep.occupancy,
            // trial OpenACC annotation + fast build: seconds
            precompile_s: 20.0 + 0.5 * ops.total() as f64,
            detail: ReportDetail::Gpu(rep),
        }
    }

    fn combined_utilization(&self, reports: &[&BackendReport]) -> f64 {
        // kernels of one pattern run serialized on the device: pressure
        // is the max single-kernel occupancy, not the sum
        reports
            .iter()
            .map(|r| r.gpu().expect("GPU backend got a non-GPU report").occupancy)
            .fold(0.0, f64::max)
    }

    fn full_compile(&self, reports: &[&BackendReport], _label: &str) -> BackendCompile {
        // one `pgcc -acc` build of the whole pattern: the base build cost
        // once, plus every kernel's per-operator translation cost; GPU
        // builds do not fail on resource overflow the way FPGA fitting does
        let per_op: f64 = reports
            .iter()
            .map(|r| {
                r.gpu().expect("GPU backend got a non-GPU report").compile_s
                    - self.device.compile_base_s
            })
            .sum();
        BackendCompile { ok: true, sim_s: self.device.compile_base_s + per_op }
    }

    fn kernel_exec(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        cpu: &CpuModel,
        report: &BackendReport,
    ) -> KernelExec {
        let id = report.loop_id;
        let rep = report.gpu().expect("GPU backend got a non-GPU report");
        let la = loops
            .iter()
            .find(|l| l.info.id == id)
            .expect("report refers to a known loop");
        let lp = profile.loop_profile(id).cloned().unwrap_or_default();

        let inner_iters = pipelined_iters(loops, profile, id);
        let compute_s = cpu.loop_time_s(&lp) / rep.simt_speedup;
        let mem_s = lp.traffic_bytes() as f64 / self.device.mem_bw_bytes_per_s;
        let kernel_s = compute_s.max(mem_s) + lp.entries as f64 * self.device.launch_latency_s;

        // transfers follow the same footprint rule as the FPGA host
        // program: H2D everything touched, D2H what the kernel writes
        let (in_bytes, out_bytes) = timing::transfer_bytes(la, &lp);

        KernelExec {
            loop_id: id,
            kernel_s,
            transfer_in_s: self.device.transfer_s(in_bytes),
            transfer_out_s: self.device.transfer_s(out_bytes),
            inner_iters,
        }
    }

    fn block_offer(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        cpu: &CpuModel,
        block: &DetectedBlock,
    ) -> Option<BlockOffer> {
        let entry = funcblock::entry_for(block.name)?;
        let ip = entry.for_destination(super::Destination::Gpu)?;
        let lp = profile.loop_profile(block.root)?;
        let cpu_time_s = cpu.loop_time_s(lp);
        let (in_bytes, out_bytes) = funcblock::transfer_bytes(loops, profile, block);
        // library-kernel compute, floored by device-memory bandwidth,
        // plus one launch per block entry and PCIe both ways
        let compute_s = cpu_time_s / ip.speedup_vs_cpu;
        let mem_s = lp.traffic_bytes() as f64 / self.device.mem_bw_bytes_per_s;
        let exec_s = compute_s.max(mem_s)
            + lp.entries as f64 * self.device.launch_latency_s
            + self.device.transfer_s(in_bytes)
            + self.device.transfer_s(out_bytes);
        Some(BlockOffer {
            block: block.clone(),
            description: entry.description,
            utilization: ip.utilization,
            compile_sim_s: ip.compile_sim_s,
            exec_s,
            cpu_time_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::interp;
    use crate::ir;

    const MAP: &str = "void f(float a[], float b[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = b[i] * 2.0 + 1.0; } }";

    const TRIG: &str = "void f(float a[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = sin(a[i]) + cos(a[i]); } }";

    fn report(src: &str) -> BackendReport {
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        GPU.precompile(&p, &loops[0], 1)
    }

    #[test]
    fn gpu_builds_are_minutes_not_hours() {
        let r = report(MAP);
        let c = GPU.full_compile(&[&r], "L0");
        assert!(c.ok);
        assert!(c.sim_s >= 60.0, "build {} s", c.sim_s);
        assert!(c.sim_s < 1800.0, "GPU build must stay in minutes: {} s", c.sim_s);
    }

    #[test]
    fn simt_speedup_is_calibrated_and_bounded() {
        let plain = report(MAP).gpu().unwrap().simt_speedup;
        let trig = report(TRIG).gpu().unwrap().simt_speedup;
        assert!(plain >= 1.2 && plain <= TESLA_P100.max_simt_speedup);
        assert!(trig > plain, "SFU bonus: {trig} vs {plain}");
        assert!(trig <= TESLA_P100.max_simt_speedup);
    }

    #[test]
    fn occupancy_is_positive_and_capped() {
        let r = report(TRIG);
        assert!(r.utilization > 0.0);
        assert!(r.utilization <= TESLA_P100.occupancy_cap);
        // combined pressure of serialized kernels is the max, not sum
        let both = GPU.combined_utilization(&[&r, &r]);
        assert!((both - r.utilization).abs() < 1e-12);
        assert_eq!(GPU.combined_utilization(&[]), 0.0);
    }

    #[test]
    fn kernel_time_beats_cpu_on_a_big_streaming_loop() {
        let src = "float a[32768]; float b[32768];
            void main() { int i;
                for (i = 0; i < 32768; i++) { b[i] = a[i] * 1.5 + 0.5; } }";
        let p = parse(src).unwrap();
        let loops = ir::analyze(&p);
        let prof = interp::profile_program(&p).unwrap();
        let rep = GPU.precompile(&p, &loops[0], 1);
        let exec = GPU.kernel_exec(&loops, &prof, &crate::cpu::XEON_3104, &rep);
        let cpu_s = crate::cpu::XEON_3104.loop_time_s(prof.loop_profile(rep.loop_id).unwrap());
        assert!(exec.kernel_s < cpu_s, "gpu {} vs cpu {}", exec.kernel_s, cpu_s);
        assert!(exec.transfer_in_s > 0.0 && exec.transfer_out_s > 0.0);
        assert_eq!(exec.inner_iters, 32768);
    }
}
