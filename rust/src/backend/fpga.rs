//! FPGA backend — a thin adapter over the pre-seam Arria10 models.
//!
//! Every method delegates to the exact functions the coordinator called
//! before the backend seam existed ([`crate::hls::precompile`],
//! [`crate::fpga::pnr::full_compile`], [`crate::fpga::timing`]), with
//! identical arguments — so FPGA search results are bit-identical to the
//! pre-refactor traces (`rust/tests/backends.rs` asserts this).

use crate::cparse::Program;
use crate::cpu::CpuModel;
use crate::fpga::device::Device;
use crate::fpga::timing::KernelExec;
use crate::fpga::{ARRIA10_GX, pnr};
use crate::funcblock::{self, BlockOffer, DetectedBlock};
use crate::hls::{self, HlsReport};
use crate::interp::Profile;
use crate::ir::LoopAnalysis;

use super::{BackendCompile, BackendReport, OffloadBackend, ReportDetail, SearchMethod};

/// The FPGA offload backend: one board model + the HLS/PnR/timing stack.
#[derive(Debug, Clone)]
pub struct FpgaBackend {
    /// The board the backend compiles against.
    pub device: &'static Device,
}

/// The default FPGA backend — the paper's Intel PAC Arria10 GX testbed.
pub static FPGA: FpgaBackend = FpgaBackend { device: &ARRIA10_GX };

impl FpgaBackend {
    fn hls_refs<'r>(reports: &[&'r BackendReport]) -> Vec<&'r HlsReport> {
        reports
            .iter()
            .map(|r| r.hls().expect("FPGA backend got a non-FPGA report"))
            .collect()
    }
}

impl OffloadBackend for FpgaBackend {
    fn destination(&self) -> super::Destination {
        super::Destination::Fpga
    }

    fn description(&self) -> String {
        format!(
            "{} | base fmax {:.0} MHz | PCIe {:.1} GB/s | full compile ~3 h",
            self.device.name,
            self.device.base_fmax_hz / 1e6,
            self.device.pcie_bw_bytes_per_s / 1e9
        )
    }

    fn search_method(&self) -> SearchMethod {
        SearchMethod::NarrowedTwoRound
    }

    fn precompile(&self, program: &Program, la: &LoopAnalysis, unroll: usize) -> BackendReport {
        let rep = hls::precompile(program, la, unroll, self.device);
        BackendReport {
            loop_id: rep.loop_id,
            utilization: rep.utilization,
            precompile_s: rep.precompile_s,
            detail: ReportDetail::Fpga(rep),
        }
    }

    fn combined_utilization(&self, reports: &[&BackendReport]) -> f64 {
        hls::combined_utilization(&Self::hls_refs(reports), self.device)
    }

    fn full_compile(&self, reports: &[&BackendReport], label: &str) -> BackendCompile {
        let outcome = pnr::full_compile(&Self::hls_refs(reports), self.device, label);
        BackendCompile { ok: outcome.is_ok(), sim_s: outcome.sim_seconds() }
    }

    fn kernel_exec(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        _cpu: &CpuModel,
        report: &BackendReport,
    ) -> KernelExec {
        let rep = report.hls().expect("FPGA backend got a non-FPGA report");
        crate::fpga::timing::kernel_time_s(loops, profile, rep, self.device)
    }

    fn block_offer(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        cpu: &CpuModel,
        block: &DetectedBlock,
    ) -> Option<BlockOffer> {
        let entry = funcblock::entry_for(block.name)?;
        let ip = entry.for_destination(super::Destination::Fpga)?;
        let lp = profile.loop_profile(block.root)?;
        let cpu_time_s = cpu.loop_time_s(lp);
        let (in_bytes, out_bytes) = funcblock::transfer_bytes(loops, profile, block);
        let mut exec_s = cpu_time_s / ip.speedup_vs_cpu;
        if in_bytes > 0 {
            exec_s += self.device.transfer_s(in_bytes);
        }
        if out_bytes > 0 {
            exec_s += self.device.transfer_s(out_bytes);
        }
        Some(BlockOffer {
            block: block.clone(),
            description: entry.description,
            utilization: ip.utilization,
            compile_sim_s: ip.compile_sim_s,
            exec_s,
            cpu_time_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir;

    const MAP: &str = "void f(float a[], float b[], int n) { int i; \
        for (i = 0; i < n; i++) { a[i] = b[i] * 2.0 + 1.0; } }";

    #[test]
    fn precompile_matches_direct_hls_call() {
        let p = parse(MAP).unwrap();
        let loops = ir::analyze(&p);
        let via_trait = FPGA.precompile(&p, &loops[0], 1);
        let direct = hls::precompile(&p, &loops[0], 1, &ARRIA10_GX);
        assert_eq!(via_trait.loop_id, direct.loop_id);
        assert_eq!(via_trait.utilization, direct.utilization);
        assert_eq!(via_trait.precompile_s, direct.precompile_s);
        let hls_rep = via_trait.hls().expect("fpga detail");
        assert_eq!(hls_rep.ii, direct.ii);
        assert_eq!(hls_rep.depth, direct.depth);
        assert_eq!(hls_rep.fmax_hz, direct.fmax_hz);
    }

    #[test]
    fn full_compile_matches_pnr_jitter() {
        let p = parse(MAP).unwrap();
        let loops = ir::analyze(&p);
        let rep = FPGA.precompile(&p, &loops[0], 1);
        let via_trait = FPGA.full_compile(&[&rep], "L0");
        let direct = pnr::full_compile(&[rep.hls().unwrap()], &ARRIA10_GX, "L0");
        assert!(via_trait.ok);
        assert_eq!(via_trait.sim_s, direct.sim_seconds());
    }

    #[test]
    fn empty_pattern_reports_the_bsp_floor() {
        assert!((FPGA.combined_utilization(&[]) - ARRIA10_GX.bsp_frac).abs() < 1e-12);
    }
}
