//! The backend seam: every device-specific assumption of the offload
//! search behind one trait.
//!
//! The paper frames FPGA loop offloading as one step of
//! *environment-adaptive software* that places code on whatever hardware
//! is available; the follow-up (arXiv:2011.12431) makes the mixed
//! CPU/GPU/FPGA destination choice explicit.  This module extracts what
//! the coordinator needs to ask of a device — candidate legality,
//! cost/resource estimation, pattern verification (full-compile) cost,
//! and the offloaded-timing model — so that the search flow in
//! [`crate::coordinator`] is destination-neutral:
//!
//! * [`fpga`] — thin adapter over the existing Arria10 models
//!   ([`crate::hls`], [`crate::fpga::pnr`], [`crate::fpga::timing`]);
//!   results are bit-identical to calling those modules directly.
//! * [`gpu`] — a calibrated SIMT model (minutes-scale compiles, PCIe
//!   transfers, kernel-launch overhead) that makes the paper's §3.2
//!   contrast — measurement-driven GA search is feasible for GPUs,
//!   infeasible for FPGAs — an executable property.

pub mod fpga;
pub mod gpu;

pub use fpga::{FPGA, FpgaBackend};
pub use gpu::{GPU, GpuBackend, GpuDevice, TESLA_P100};

use crate::cparse::ast::LoopId;
use crate::cparse::Program;
use crate::cpu::CpuModel;
use crate::fpga::timing::KernelExec;
use crate::funcblock::{BlockOffer, DetectedBlock};
use crate::hls::HlsReport;
use crate::interp::Profile;
use crate::ir::LoopAnalysis;

/// A concrete offload destination — the typed identity every trace,
/// report, and placement decision carries (previously a bare `&str`,
/// matched stringly in the trace, the mixed search, and the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Destination {
    /// Stay on the host CPU (no pattern beat the all-CPU baseline).
    Cpu,
    /// The Arria10 FPGA backend.
    Fpga,
    /// The SIMT GPU backend.
    Gpu,
}

impl Destination {
    /// Canonical report label ("CPU", "FPGA", "GPU").
    pub fn as_str(self) -> &'static str {
        match self {
            Destination::Cpu => "CPU",
            Destination::Fpga => "FPGA",
            Destination::Gpu => "GPU",
        }
    }

    /// Parse a destination name (case-insensitive).
    pub fn parse(s: &str) -> Option<Destination> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Some(Destination::Cpu),
            "fpga" => Some(Destination::Fpga),
            "gpu" => Some(Destination::Gpu),
            _ => None,
        }
    }

    /// The backend that compiles for this destination (`None` for the
    /// CPU — staying put needs no offload backend).
    pub fn backend(self) -> Option<&'static dyn OffloadBackend> {
        match self {
            Destination::Cpu => None,
            Destination::Fpga => Some(&FPGA as &dyn OffloadBackend),
            Destination::Gpu => Some(&GPU as &dyn OffloadBackend),
        }
    }
}

impl std::fmt::Display for Destination {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

/// Offload destination selected on the CLI (`flopt --target ...`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// FPGA only (the paper's evaluation — the default).
    Fpga,
    /// GPU only (the GA-driven flow of [Yamato 2018]).
    Gpu,
    /// Mixed destination: run every backend, pick the winner per app.
    Mixed,
}

impl Target {
    /// Parse a `--target` argument (case-insensitive): a concrete
    /// [`Destination`] name, or `mixed`.
    pub fn parse(s: &str) -> Option<Target> {
        if s.eq_ignore_ascii_case("mixed") {
            return Some(Target::Mixed);
        }
        match Destination::parse(s)? {
            Destination::Fpga => Some(Target::Fpga),
            Destination::Gpu => Some(Target::Gpu),
            Destination::Cpu => None, // "offload to the CPU" is not a search
        }
    }

    /// The backends this target searches, in search order.
    pub fn backends(self) -> Vec<&'static dyn OffloadBackend> {
        match self {
            Target::Fpga => vec![&FPGA as &dyn OffloadBackend],
            Target::Gpu => vec![&GPU as &dyn OffloadBackend],
            Target::Mixed => vec![&FPGA as &dyn OffloadBackend, &GPU as &dyn OffloadBackend],
        }
    }

    /// The single destination this target compiles for, when it is not
    /// a multi-backend search.
    pub fn destination(self) -> Option<Destination> {
        match self {
            Target::Fpga => Some(Destination::Fpga),
            Target::Gpu => Some(Destination::Gpu),
            Target::Mixed => None,
        }
    }
}

/// Which search flow the coordinator drives for a backend (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMethod {
    /// Analytic narrowing + two measured rounds — the only feasible flow
    /// when one pattern verification is an hours-long compile (FPGA).
    NarrowedTwoRound,
    /// Measurement-driven GA ([Yamato 2018]) — feasible when one pattern
    /// verification is a minutes-long compile (GPU).
    MeasurementGa,
}

/// Backend-specific payload of a pre-compile report.
#[derive(Debug, Clone)]
pub enum ReportDetail {
    /// Arria10 HLS pre-compile report.
    Fpga(HlsReport),
    /// Calibrated GPU kernel estimate.
    Gpu(gpu::GpuKernelReport),
}

/// Device-neutral pre-compile ("cost estimation") report for one loop.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// The loop the estimate describes.
    pub loop_id: LoopId,
    /// Device resource fraction (FPGA: utilization incl. BSP; GPU:
    /// occupancy-style pressure estimate) — the denominator of the
    /// paper's resource-efficiency metric.
    pub utilization: f64,
    /// Simulated estimation time charged to the clock (the FPGA's
    /// "minutes, not hours" HLS path; a trial build on GPU).
    pub precompile_s: f64,
    /// Backend-specific payload.
    pub detail: ReportDetail,
}

impl BackendReport {
    /// The FPGA HLS report, when this estimate came from the FPGA backend.
    pub fn hls(&self) -> Option<&HlsReport> {
        match &self.detail {
            ReportDetail::Fpga(r) => Some(r),
            ReportDetail::Gpu(_) => None,
        }
    }

    /// The GPU kernel estimate, when this came from the GPU backend.
    pub fn gpu(&self) -> Option<&gpu::GpuKernelReport> {
        match &self.detail {
            ReportDetail::Gpu(r) => Some(r),
            ReportDetail::Fpga(_) => None,
        }
    }

    /// The per-type FPGA resource vector of this estimate, when the
    /// report came from the FPGA backend ([`crate::fleet`] sums these to
    /// co-schedule tenants under a board's FF/LUT/DSP/BRAM caps).
    pub fn resources(&self) -> Option<&crate::fpga::device::Resources> {
        self.hls().map(|h| &h.resources)
    }
}

/// Outcome of a full pattern compile on a backend.
#[derive(Debug, Clone)]
pub struct BackendCompile {
    /// Did the compile produce a runnable binary/bitstream?
    pub ok: bool,
    /// Simulated seconds the compile occupied a farm lane, success or not.
    pub sim_s: f64,
}

/// Everything the coordinator asks of an offload destination.
///
/// Implementations must be pure functions of their inputs: the search
/// replays estimates and compiles deterministically, and the FPGA
/// adapter is required to reproduce the pre-seam models bit-identically
/// (`rust/tests/backends.rs` enforces this).
pub trait OffloadBackend: Sync {
    /// The typed destination this backend compiles for.
    fn destination(&self) -> Destination;

    /// Destination name threaded through traces and reports ("FPGA", "GPU").
    fn name(&self) -> &'static str {
        self.destination().as_str()
    }

    /// One-line device description for `flopt env`.
    fn description(&self) -> String;

    /// Which search flow the coordinator should drive (paper §3.2).
    fn search_method(&self) -> SearchMethod;

    /// Candidate legality: can this loop statement run as a kernel on
    /// this device at all?  The default accepts exactly what the
    /// dependence tests allow; backends may restrict further.
    fn offloadable(&self, la: &LoopAnalysis) -> bool {
        la.deps.offloadable
    }

    /// Analytic pre-compile: cost/resource estimation for one loop.
    fn precompile(&self, program: &Program, la: &LoopAnalysis, unroll: usize) -> BackendReport;

    /// Device resource fraction of a multi-kernel pattern (cap checks
    /// and the trace).  An empty pattern reports the static floor.
    fn combined_utilization(&self, reports: &[&BackendReport]) -> f64;

    /// Pattern verification cost: simulate the full compile of a
    /// pattern's kernels.  `label` seeds any deterministic jitter.
    fn full_compile(&self, reports: &[&BackendReport], label: &str) -> BackendCompile;

    /// Offloaded-timing model: one loop's execution on this device,
    /// including host↔device transfers.
    fn kernel_exec(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        cpu: &CpuModel,
        report: &BackendReport,
    ) -> KernelExec;

    /// Quote a function-block replacement offer for a detected block:
    /// look the block shape up in the IP/library registry
    /// ([`crate::funcblock::registry`]) and model its execution
    /// (hand-tuned compute + host↔device transfers for the nest's
    /// observed footprints).  `None` when the registry carries no
    /// implementation for this shape on this device, or the block never
    /// ran on the sample workload.  The default quotes nothing — a
    /// backend without a registry participates in loop-statement search
    /// unchanged.
    fn block_offer(
        &self,
        loops: &[LoopAnalysis],
        profile: &Profile,
        cpu: &CpuModel,
        block: &DetectedBlock,
    ) -> Option<BlockOffer> {
        let _ = (loops, profile, cpu, block);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_parses() {
        assert_eq!(Target::parse("fpga"), Some(Target::Fpga));
        assert_eq!(Target::parse("GPU"), Some(Target::Gpu));
        assert_eq!(Target::parse("Mixed"), Some(Target::Mixed));
        assert_eq!(Target::parse("tpu"), None);
        assert_eq!(Target::parse("cpu"), None, "cpu is a fallback, not a search target");
    }

    #[test]
    fn destination_roundtrips() {
        for d in [Destination::Cpu, Destination::Fpga, Destination::Gpu] {
            assert_eq!(Destination::parse(d.as_str()), Some(d));
            assert_eq!(format!("{d}"), d.as_str());
        }
        assert_eq!(Destination::parse("npu"), None);
        assert_eq!(format!("{:<6}|", Destination::Gpu), "GPU   |", "Display must pad");
    }

    #[test]
    fn backends_declare_their_destination() {
        assert_eq!(FPGA.destination(), Destination::Fpga);
        assert_eq!(GPU.destination(), Destination::Gpu);
        assert_eq!(FPGA.name(), "FPGA");
        assert_eq!(GPU.name(), "GPU");
        assert_eq!(Destination::Fpga.backend().unwrap().name(), "FPGA");
        assert!(Destination::Cpu.backend().is_none());
        assert_eq!(Target::Fpga.destination(), Some(Destination::Fpga));
        assert_eq!(Target::Mixed.destination(), None);
    }

    #[test]
    fn target_backends_cover_the_destination() {
        assert_eq!(Target::Fpga.backends().len(), 1);
        assert_eq!(Target::Gpu.backends().len(), 1);
        let mixed = Target::Mixed.backends();
        assert_eq!(mixed.len(), 2);
        assert_eq!(mixed[0].name(), "FPGA");
        assert_eq!(mixed[1].name(), "GPU");
    }

    #[test]
    fn search_methods_match_the_paper_argument() {
        assert_eq!(FPGA.search_method(), SearchMethod::NarrowedTwoRound);
        assert_eq!(GPU.search_method(), SearchMethod::MeasurementGa);
    }
}
