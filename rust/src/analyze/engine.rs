//! The per-loop dependence engine.
//!
//! [`analyze_loop`] walks the legacy gate sequence — canonical header,
//! stable bounds, builtin-only calls, no `return`, array subscripts,
//! scalar lattice — but proves the array gates with the subscript tests
//! from [`super::pairs`] instead of bare structural equality, records
//! every dependence fact and fired test, and adds a write/write overlap
//! check the legacy gates never had.  Verdicts were differentially
//! validated against [`crate::ir::deps::analyze_legacy`] over the nine
//! embedded apps and the seeded generative corpus: identical
//! offloadable sets, identical first-reject diagnostics.

use std::collections::BTreeSet;

use crate::cparse::ast::ExprKind;
use crate::ir::deps::{
    assignments, body_has_return, expr_contains_index, expr_contains_var, recognize_reduction,
    reduction_extra_uses,
};
use crate::ir::loops::LoopInfo;
use crate::ir::varref::LoopRefs;
use crate::util::intern::Symbol;

use super::linear::{parse_linear, Bounds, LinearForm};
use super::pairs::{classify_pair, DepTest, PairKind};
use super::{DepClass, DepFact, LoopDeps, LoopVerdict, Note, NoteKind, RejectReason};

fn seq(mut res: LoopDeps, r: RejectReason) -> LoopDeps {
    res.verdict = LoopVerdict::Sequential(r);
    res
}

fn unk(mut res: LoopDeps, r: RejectReason) -> LoopDeps {
    res.verdict = LoopVerdict::Unknown(r);
    res
}

fn usable(form: &Option<LinearForm>, varying: &BTreeSet<Symbol>) -> bool {
    match form {
        Some(f) => f.syms().is_disjoint(varying),
        None => false,
    }
}

/// Analyze one loop: verdict, reductions, dependence facts, notes, and
/// per-test fire counts.
pub fn analyze_loop(info: &LoopInfo, refs: &LoopRefs) -> LoopDeps {
    let mut res = LoopDeps::default();

    // (1) canonical counted loop
    let Some(can) = &info.canonical else {
        return unk(res, RejectReason::NoCanonicalHeader);
    };
    // bounds must not depend on anything the body writes (else the trip
    // count changes mid-flight)
    for bound in [&can.lo, &can.hi] {
        let mut bad = false;
        bound.walk(&mut |e| {
            if let ExprKind::Var(n) = &e.kind {
                if refs.scalar_writes.contains(n) {
                    bad = true;
                }
            }
        });
        if bad {
            return seq(res, RejectReason::BoundWritten);
        }
    }
    let counter = can.var;

    // (2) calls / control flow
    if !refs.non_builtin_calls().is_empty() {
        return unk(res, RejectReason::NonBuiltinCall);
    }
    if body_has_return(&info.body) {
        return seq(res, RejectReason::BodyReturn);
    }

    let bnd = Bounds::of(can);
    // symbols that vary within one iteration of this loop: inner
    // counters, body-written scalars, body locals
    let mut varying: BTreeSet<Symbol> = refs.scalar_writes.union(&refs.locals).copied().collect();
    varying.remove(&counter);

    // (3) array dependence tests, arrays in sorted order
    for (name, writes) in &refs.array_writes {
        for idx in writes {
            if !expr_contains_var(idx, counter) {
                return seq(res, RejectReason::InvariantWriteIndex);
            }
            // `a[idx[i]]` mentions the counter yet the subscript values
            // are data — two iterations may hit the same element
            if expr_contains_index(idx) {
                return seq(res, RejectReason::DataDependentWriteIndex);
            }
        }
        let wforms: Vec<Option<LinearForm>> =
            writes.iter().map(|idx| parse_linear(idx, counter)).collect();

        // --- write/read pairs (legacy position: read-match gate)
        for ridx in refs.array_reads.get(name).into_iter().flatten() {
            if writes.iter().any(|w| w == ridx) {
                continue; // structurally identical: same-iteration access
            }
            if expr_contains_index(ridx) {
                // summarized: treat as a whole-array read
                return seq(res, RejectReason::ReadWriteMismatch);
            }
            let rform = parse_linear(ridx, counter);
            if !usable(&rform, &varying) {
                return seq(res, RejectReason::ReadWriteMismatch);
            }
            let rform = rform.expect("usable implies parsed");
            for (widx, wf) in writes.iter().zip(&wforms) {
                if !usable(wf, &varying) {
                    return seq(res, RejectReason::ReadWriteMismatch);
                }
                let wf = wf.as_ref().expect("usable implies parsed");
                let (kind, test) = classify_pair(wf, &rform, &bnd);
                *res.tests.entry(test).or_insert(0) += 1;
                if matches!(kind, PairKind::Carried | PairKind::Unknown) {
                    res.deps.push(DepFact {
                        class: DepClass::FlowAnti,
                        array: *name,
                        source: widx.clone(),
                        sink: ridx.clone(),
                        test,
                    });
                    return seq(res, RejectReason::ReadWriteMismatch);
                }
            }
            res.notes.push(Note {
                kind: NoteKind::ReadProvedIndependent,
                array: *name,
                subscripts: vec![ridx.clone()],
            });
        }

        // --- write/write pairs (dependence class the legacy gates lacked)
        for i in 0..writes.len() {
            for j in i..writes.len() {
                if i == j {
                    match &wforms[i] {
                        Some(fi) if usable(&wforms[i], &varying) => {
                            if fi.a == 0 {
                                // counter cancels: same cell every iteration
                                *res.tests.entry(DepTest::Ziv).or_insert(0) += 1;
                                res.deps.push(DepFact {
                                    class: DepClass::Output,
                                    array: *name,
                                    source: writes[i].clone(),
                                    sink: writes[i].clone(),
                                    test: DepTest::Ziv,
                                });
                                return seq(res, RejectReason::WwOverlap);
                            }
                        }
                        _ => res.notes.push(Note {
                            kind: NoteKind::AssumedInjective,
                            array: *name,
                            subscripts: vec![writes[i].clone()],
                        }),
                    }
                    continue;
                }
                if writes[i] == writes[j] {
                    continue; // identical subscript: same-iteration only
                }
                if usable(&wforms[i], &varying) && usable(&wforms[j], &varying) {
                    let fi = wforms[i].as_ref().expect("usable implies parsed");
                    let fj = wforms[j].as_ref().expect("usable implies parsed");
                    let (kind, test) = classify_pair(fi, fj, &bnd);
                    *res.tests.entry(test).or_insert(0) += 1;
                    if matches!(kind, PairKind::Carried | PairKind::Unknown) {
                        res.deps.push(DepFact {
                            class: DepClass::Output,
                            array: *name,
                            source: writes[i].clone(),
                            sink: writes[j].clone(),
                            test,
                        });
                        return seq(res, RejectReason::WwOverlap);
                    }
                } else {
                    res.notes.push(Note {
                        kind: NoteKind::AssumedDisjoint,
                        array: *name,
                        subscripts: vec![writes[i].clone(), writes[j].clone()],
                    });
                }
            }
        }
    }

    // (4) scalar lattice (identical to the legacy rule)
    let assigns = assignments(&info.body);
    let carried: BTreeSet<Symbol> = refs
        .scalar_writes
        .intersection(&refs.scalar_reads)
        .filter(|v| !refs.locals.contains(*v) && **v != counter)
        .copied()
        .collect();
    for var in carried {
        match recognize_reduction(var, &assigns) {
            Some(r) => {
                if reduction_extra_uses(var, &info.body) > 0 {
                    return seq(res, RejectReason::ReductionConsumed);
                }
                res.reductions.push(r);
            }
            None => return seq(res, RejectReason::CarriedScalar),
        }
    }
    if !res.reductions.is_empty() {
        res.verdict = LoopVerdict::Reduction(res.reductions.iter().map(|r| r.var).collect());
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cparse::parse;
    use crate::ir::{loops, varref};

    fn deps_of(src: &str, idx: usize) -> LoopDeps {
        let p = parse(src).unwrap();
        let infos = loops::extract(&p);
        let info = &infos[idx];
        let refs = varref::collect(info);
        analyze_loop(info, &refs)
    }

    #[test]
    fn elementwise_map_is_parallel() {
        let d = deps_of(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = b[i] * 2.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Parallel);
        assert!(d.deps.is_empty());
    }

    #[test]
    fn in_place_update_proved_by_siv() {
        // a[i] read and written: structurally equal pair is skipped, no
        // test needed, still parallel
        let d = deps_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = a[i] + 1.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Parallel);
    }

    #[test]
    fn recurrence_rejected_with_flow_fact() {
        let d = deps_of(
            "void f(float a[], int n) { int i; \
             for (i = 1; i < n; i++) { a[i] = a[i - 1] + 1.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Sequential(RejectReason::ReadWriteMismatch));
        assert_eq!(d.deps.len(), 1);
        assert_eq!(d.deps[0].class, DepClass::FlowAnti);
        assert_eq!(d.deps[0].test, DepTest::SivStrong);
    }

    #[test]
    fn stride_two_offset_read_proved_independent() {
        // a[2i] written, a[2i+1] read: parity separates them — the
        // legacy structural gate rejected this, the engine proves it
        // independent but the note tier keeps the verdict machinery
        // aligned (read-proved-independent is recorded)
        let d = deps_of(
            "void f(float a[], float b[], int n) { int i; \
             for (i = 0; i < n; i++) { b[i] = a[2 * i + 1]; a[2 * i] = 0.0; } }",
            0,
        );
        // b and a are distinct arrays; the a-pair is the interesting one
        assert_eq!(d.verdict, LoopVerdict::Parallel);
        assert_eq!(d.tests.get(&DepTest::SivStrong), Some(&1));
        assert!(d
            .notes
            .iter()
            .any(|n| n.kind == NoteKind::ReadProvedIndependent));
    }

    #[test]
    fn invariant_write_rejected_before_pair_tests() {
        let d = deps_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[0] = a[0] + 1.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Sequential(RejectReason::InvariantWriteIndex));
    }

    #[test]
    fn ww_overlap_detected() {
        // a[i] and a[i+1] both written: distance-1 output dependence
        let d = deps_of(
            "void f(float a[], int n) { int i; \
             for (i = 0; i < n; i++) { a[i] = 1.0; a[i + 1] = 2.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Sequential(RejectReason::WwOverlap));
        assert_eq!(d.deps[0].class, DepClass::Output);
    }

    #[test]
    fn disjoint_halves_ww_proved_independent() {
        // a[i] and a[i+100] over i in [0,50): distance 100 > width 49
        let d = deps_of(
            "void f(float a[]) { int i; \
             for (i = 0; i < 50; i++) { a[i] = 1.0; a[i + 100] = 2.0; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Parallel);
        assert_eq!(d.tests.get(&DepTest::SivStrong), Some(&1));
    }

    #[test]
    fn reduction_verdict_names_vars() {
        let d = deps_of(
            "void f(float a[], int n) { int i; float s; s = 0.0; \
             for (i = 0; i < n; i++) { s += a[i]; } }",
            0,
        );
        assert_eq!(
            d.verdict,
            LoopVerdict::Reduction(vec![Symbol::intern("s")])
        );
        assert!(d.offloadable());
    }

    #[test]
    fn butterfly_offset_discharged_symbolically() {
        // fft-style: x[base+j] read+written, x[base+j+half] written, with
        // j in [0, half): the write/write pair is exactly span apart
        let d = deps_of(
            "void f(float x[], int base, int half) { int j; \
             for (j = 0; j < half; j++) { \
               float t; t = x[base + j + half]; \
               x[base + j + half] = x[base + j] - t; \
               x[base + j] = x[base + j] + t; } }",
            0,
        );
        assert_eq!(d.verdict, LoopVerdict::Parallel, "{:?}", d);
        assert!(d.tests.contains_key(&DepTest::BanerjeeSymbolic), "{:?}", d.tests);
    }

    #[test]
    fn matches_legacy_on_every_loop_of_a_nest() {
        let src = "void mm(float a[], float b[], float c[], int n) { \
             int i; int j; int k; \
             for (i = 0; i < n; i++) { \
               for (j = 0; j < n; j++) { \
                 float acc; acc = 0.0; \
                 for (k = 0; k < n; k++) { acc += a[i * n + k] * b[k * n + j]; } \
                 c[i * n + j] = acc; } } }";
        let p = parse(src).unwrap();
        for info in &loops::extract(&p) {
            let refs = varref::collect(info);
            let new = analyze_loop(info, &refs);
            let old = crate::ir::deps::analyze_legacy(info, &refs);
            assert_eq!(new.offloadable(), old.offloadable, "loop {}", info.id);
            assert_eq!(
                new.reject_reason().map(|r| r.to_string()),
                old.reject_reason.map(|r| r.to_string()),
                "loop {}",
                info.id
            );
            assert_eq!(new.reductions, old.reductions, "loop {}", info.id);
        }
    }
}
